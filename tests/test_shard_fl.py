"""Mesh-sharded gossip engine: fp32 equivalence with the stacked backend.

Main-process tests cover mesh=1 (the degenerate single-shard mesh on the
default device) plus the UserMesh/FLSharding placement layer; multi-shard
runs (mesh 2 and 8, compression, uneven N_T % shards, the block-local
Pallas mix, cluster-topology halos) execute in ONE subprocess with 8
forced fake host devices — the device count must be set before jax's
first init, so it cannot change inside the main pytest process.
"""

import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.graphs import gossip_task_graph  # noqa: E402
from repro.data.synthetic import ImageDataset  # noqa: E402
from repro.fl.gossip import BACKENDS, GossipConfig, GossipTrainer  # noqa: E402
from repro.launch.sharding import (  # noqa: E402
    FLSharding,
    UserMesh,
    pad_edge_lists,
)

# ---------------------------------------------------------------------------
# Shared tiny workload (subprocess uses the same shapes)
# ---------------------------------------------------------------------------


def _mlp_init(key, d=64, hidden=16, classes=10):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, hidden)) * (2.0 / d) ** 0.5,
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, classes)) * (2.0 / hidden) ** 0.5,
        "b2": jnp.zeros(classes),
    }


def _mlp_loss(params, batch):
    x = batch["x"].reshape(batch["x"].shape[0], -1)
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def _instance(n, seed=0, samples_per_user=48):
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n, degree_low=3, degree_high=4)
    m = n * samples_per_user
    data = ImageDataset(
        x=rng.normal(size=(m, 8, 8, 1)).astype(np.float32),
        y=rng.integers(0, 10, size=m).astype(np.int64),
        num_classes=10,
    )
    return tg, data.split(n, rng)


def _trainer(n, backend, num_shards=None, rounds_cfg=None):
    tg, shards = _instance(n)
    cfg = rounds_cfg or GossipConfig(
        local_steps=2, batch_size=8, num_shards=num_shards
    )
    return GossipTrainer(tg, _mlp_init, _mlp_loss, shards, cfg, seed=0,
                         backend=backend)


def _max_param_diff(a, b, n):
    worst = 0.0
    for i in range(n):
        for x, y in zip(jax.tree.leaves(a.user_params(i)),
                        jax.tree.leaves(b.user_params(i))):
            worst = max(worst, float(jnp.max(jnp.abs(x - y))))
    return worst


# ---------------------------------------------------------------------------
# Mesh = 1 (main process): the degenerate single-shard mesh
# ---------------------------------------------------------------------------


def test_mesh1_sharded_matches_stacked():
    n = 10
    a = _trainer(n, "stacked")
    b = _trainer(n, "sharded", num_shards=1)
    assert b.backend == "sharded"
    for _ in range(3):
        ia, ib = a.step_round(), b.step_round()
        assert abs(ia["mean_loss"] - ib["mean_loss"]) < 1e-5
        assert b.last_round_dispatches == 1
    assert _max_param_diff(a, b, n) < 1e-4
    if hasattr(b._round_jit, "_cache_size"):
        assert b._round_jit._cache_size() == 1
    # single shard, no cross edges: the halo is empty
    assert b.halo_stats["cross_edges"] == 0
    assert b.halo_stats["halo_rows_per_shard"] == 0


def test_sharded_backend_registered():
    assert "sharded" in BACKENDS
    with pytest.raises(ValueError, match="unknown backend"):
        _trainer(4, "meshed")


def test_dropped_samples_in_info():
    """Uneven shards truncate to the common minimum; the count surfaces."""
    rng = np.random.default_rng(0)
    tg = gossip_task_graph(rng, 3, degree_low=1, degree_high=2)

    def shard(m, seed):
        r = np.random.default_rng(seed)
        return ImageDataset(
            x=r.normal(size=(m, 8, 8, 1)).astype(np.float32),
            y=r.integers(0, 10, size=m).astype(np.int64),
            num_classes=10,
        )

    shards = [shard(16, 1), shard(20, 2), shard(19, 3)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # the intentional truncation warning
        tr = GossipTrainer(
            tg, _mlp_init, _mlp_loss, shards,
            GossipConfig(local_steps=1, batch_size=8), seed=0,
            backend="stacked",
        )
    assert tr.dropped_samples == (20 - 16) + (19 - 16)
    info = tr.step_round()
    assert info["dropped_samples"] == 7


# ---------------------------------------------------------------------------
# UserMesh / FLSharding placement layer
# ---------------------------------------------------------------------------


def test_user_mesh_build_and_specs():
    um = UserMesh.build(1)
    assert um.num_shards == 1
    assert um.spec()[0] == "users"
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        UserMesh.build(len(jax.devices()) + 1)
    with pytest.raises(ValueError, match=">= 1 shard"):
        UserMesh.build(0)


def test_fl_sharding_padding():
    fls = FLSharding(user_mesh=UserMesh.build(1), num_users=10)
    assert fls.num_padded == 10 and fls.num_padding == 0
    assert fls.block_size == 10
    assert fls.valid_mask().all()
    np.testing.assert_array_equal(fls.shard_of(), np.zeros(10))
    padded = fls.pad_users(np.arange(10))
    np.testing.assert_array_equal(padded, np.arange(10))
    with pytest.raises(ValueError, match="leading axis"):
        fls.pad_users(np.arange(7))
    with pytest.raises(ValueError, match=">= 1 user"):
        FLSharding(user_mesh=UserMesh.build(1), num_users=0)


def test_pad_edge_lists():
    stacked, lengths = pad_edge_lists(
        [np.array([3, 1]), np.array([7]), np.array([], dtype=np.int64)]
    )
    assert stacked.shape == (3, 2)
    np.testing.assert_array_equal(lengths, [2, 1, 0])
    np.testing.assert_array_equal(stacked[0], [3, 1])
    assert stacked[2, 0] == 0  # fill
    empty, lens = pad_edge_lists([np.array([], dtype=np.int64)] * 2)
    assert empty.shape == (2, 0) and lens.tolist() == [0, 0]


# ---------------------------------------------------------------------------
# Mesh = 2 and 8 (subprocess: forced fake host devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, warnings
import jax, jax.numpy as jnp, numpy as np
from repro.core.graphs import cluster_task_graph, gossip_task_graph
from repro.data.synthetic import ImageDataset
from repro.fl.gossip import GossipConfig, GossipTrainer
from repro.train.compression import Int8, TopK

def mlp_init(key, d=64, hidden=16, classes=10):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (d, hidden)) * (2.0 / d) ** 0.5,
            "b1": jnp.zeros(hidden),
            "w2": jax.random.normal(k2, (hidden, classes)) * (2.0 / hidden) ** 0.5,
            "b2": jnp.zeros(classes)}

def mlp_loss(params, batch):
    x = batch["x"].reshape(batch["x"].shape[0], -1)
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)

def instance(n, topology="gossip", seed=0):
    rng = np.random.default_rng(seed)
    if topology == "cluster":
        tg = cluster_task_graph(rng, n, clusters=3, inner_topology="dense",
                                head_topology="ring")
    else:
        tg = gossip_task_graph(rng, n, degree_low=3, degree_high=4)
    m = n * 48
    data = ImageDataset(x=rng.normal(size=(m, 8, 8, 1)).astype(np.float32),
                        y=rng.integers(0, 10, size=m).astype(np.int64),
                        num_classes=10)
    return tg, data.split(n, rng)

def pair(n, num_shards, compressor=None, mix="auto", topology="gossip",
         rounds=3):
    tg, shards = instance(n, topology)
    cfg = GossipConfig(local_steps=2, batch_size=8, compressor=compressor,
                       mix_backend=mix, num_shards=num_shards)
    mk = lambda be: GossipTrainer(tg, mlp_init, mlp_loss, shards, cfg,
                                  seed=0, backend=be)
    a, b = mk("stacked"), mk("sharded")
    loss_diff, dispatches = 0.0, set()
    for _ in range(rounds):
        ia, ib = a.step_round(), b.step_round()
        loss_diff = max(loss_diff, abs(ia["mean_loss"] - ib["mean_loss"]))
        dispatches.add(b.last_round_dispatches)
    param_diff = 0.0
    for i in range(n):
        for x, y in zip(jax.tree.leaves(a.user_params(i)),
                        jax.tree.leaves(b.user_params(i))):
            param_diff = max(param_diff, float(jnp.max(jnp.abs(x - y))))
    cache = (b._round_jit._cache_size()
             if hasattr(b._round_jit, "_cache_size") else 1)
    return {"loss_diff": loss_diff, "param_diff": param_diff,
            "dispatches": sorted(dispatches), "cache_size": cache,
            "halo": b.halo_stats, "num_padding": b._fls.num_padding}

out = {
    # n = 13: uneven vs 2 (block 7, pad 1) AND vs 8 (block 2, pad 3)
    "mesh2": pair(13, 2),
    "mesh8": pair(13, 8),
    "mesh2_topk": pair(13, 2, compressor=TopK(0.2)),
    "mesh2_int8": pair(13, 2, compressor=Int8()),
    "mesh2_pallas": pair(13, 2, mix="pallas"),
    "cluster_mesh2": pair(24, 2, topology="cluster"),
}
print("RESULT::" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[0][len("RESULT::"):])


@pytest.mark.parametrize("case,loss_tol,param_tol", [
    ("mesh2", 1e-5, 1e-4),
    ("mesh8", 1e-5, 1e-4),
    ("mesh2_topk", 1e-5, 1e-4),
    ("mesh2_int8", 1e-3, 5e-3),   # int8 rounding is threshold-sensitive
    ("mesh2_pallas", 1e-5, 1e-4),
    ("cluster_mesh2", 1e-5, 1e-4),
])
def test_sharded_matches_stacked(sharded_results, case, loss_tol, param_tol):
    r = sharded_results[case]
    assert r["loss_diff"] < loss_tol, r
    assert r["param_diff"] < param_tol, r
    assert r["dispatches"] == [1], r           # one jitted call per round
    assert r["cache_size"] == 1, r             # never retraced


def test_uneven_population_padding(sharded_results):
    # 13 % 2 -> one inert pad user; 13 % 8 -> three
    assert sharded_results["mesh2"]["num_padding"] == 1
    assert sharded_results["mesh8"]["num_padding"] == 3
    h = sharded_results["mesh8"]["halo"]
    assert h["num_shards"] == 8 and h["block_size"] == 2


def test_cluster_halo_sparser_than_dense(sharded_results):
    """On the hierarchical topology only head links cross shards, so the
    halo gathers strictly fewer rows than the dense all-pairs exchange."""
    h = sharded_results["cluster_mesh2"]["halo"]
    assert 0 < h["halo_rows_per_shard"] < h["dense_rows_per_shard"], h
    assert h["cross_edges"] < h["intra_edges"], h
