"""Loop-aware HLO accounting: validate the parser against known workloads."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_stats import analyze_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    text = _compile_text(lambda a, b: a @ b, a, b)
    st = analyze_hlo(text)
    want = 2 * 128 * 256 * 512
    assert abs(st.flops - want) / want < 0.01, (st.flops, want)


def test_scan_multiplies_by_trip_count():
    """A matmul inside a 10-step scan must count 10x."""
    w = jnp.zeros((10, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def fn(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    text = _compile_text(fn, x, w)
    st = analyze_hlo(text)
    want = 10 * 2 * 8 * 64 * 64
    assert abs(st.flops - want) / want < 0.05, (st.flops, want)


def test_unrolled_equals_scanned_flops():
    w = jnp.zeros((6, 32, 32), jnp.float32)
    x = jnp.zeros((4, 32), jnp.float32)

    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0]

    def unrolled(x, w):
        for i in range(6):
            x = x @ w[i]
        return x

    s1 = analyze_hlo(_compile_text(scanned, x, w))
    s2 = analyze_hlo(_compile_text(unrolled, x, w))
    assert abs(s1.flops - s2.flops) / max(s2.flops, 1) < 0.05


def test_cost_analysis_agreement_no_scan():
    """Without loops, our dot counter should be close to XLA's."""
    a = jnp.zeros((64, 128), jnp.float32)
    w1 = jnp.zeros((128, 256), jnp.float32)
    w2 = jnp.zeros((256, 32), jnp.float32)

    def fn(a, w1, w2):
        return jax.nn.relu(a @ w1) @ w2

    compiled = jax.jit(fn).lower(a, w1, w2).compile()
    st = analyze_hlo(compiled.as_text())
    xla = compiled.cost_analysis()
    if isinstance(xla, (list, tuple)):
        xla = xla[0]
    want = float(xla.get("flops", 0.0))
    if want:
        assert abs(st.flops - want) / want < 0.15, (st.flops, want)


def test_bytes_positive_and_collectives_empty_on_single_device():
    a = jnp.zeros((128, 128), jnp.float32)
    st = analyze_hlo(_compile_text(lambda a: a @ a, a))
    assert st.bytes > 128 * 128 * 4
    assert st.link_bytes == 0


def test_tiled_layout_operands_parse():
    """TPU-style tiled layouts nest parens (T(8,128)) inside the out shape
    and operand list; the dot counter must still resolve the lhs shape."""
    text = """HloModule m, is_scheduled=true

ENTRY %main (a: f32[128,256], b: f32[256,64]) -> f32[128,64] {
  %a = f32[128,256]{1,0:T(8,128)} parameter(0)
  %b = f32[256,64]{1,0:T(8,128)} parameter(1)
  ROOT %dot.1 = f32[128,64]{1,0:T(8,128)} dot(f32[128,256]{1,0:T(8,128)} %a, f32[256,64]{1,0:T(8,128)} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    st = analyze_hlo(text)
    assert st.flops == 2 * 128 * 256 * 64
