"""Attention equivalences: dense == chunked == flash-vjp, incl. gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    chunked_attention,
    decode_attention_local,
    decode_attention_seq_sharded,
    dense_attention,
    flash_attention_jnp,
)

rng = np.random.default_rng(0)


def t(shape, dt=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dt)


CASES = [
    (2, 256, 4, 2, 32, True, 0),
    (1, 256, 4, 4, 16, True, 0),
    (2, 256, 4, 1, 32, True, 64),     # MQA + sliding window
    (2, 128, 2, 2, 16, False, 0),     # bidirectional (whisper encoder)
]


@pytest.mark.parametrize("b,s,h,hkv,d,causal,window", CASES)
def test_chunked_matches_dense(b, s, h, hkv, d, causal, window):
    q, k, v = t((b, s, h, d)), t((b, s, hkv, d)), t((b, s, hkv, d))
    o1 = dense_attention(q, k, v, causal=causal, window=window)
    o2 = chunked_attention(
        q, k, v, causal=causal, window=window, q_block=64, kv_chunk=64
    )
    np.testing.assert_allclose(o1, o2, atol=2e-5)


@pytest.mark.parametrize("b,s,h,hkv,d,causal,window", CASES)
def test_flash_vjp_matches_dense_grads(b, s, h, hkv, d, causal, window):
    q, k, v = t((b, s, h, d)), t((b, s, hkv, d)), t((b, s, hkv, d))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(dense_attention(q, k, v, causal=causal, window=window)))

    def loss_fl(q, k, v):
        return jnp.sum(
            jnp.sin(flash_attention_jnp(q, k, v, causal, window, 64, 64, 0))
        )

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gr, gf):
        np.testing.assert_allclose(a, b_, atol=3e-4)


def test_decode_local_matches_dense_row():
    b, s, h, hkv, d = 2, 128, 4, 2, 32
    kc, vc = t((b, s, hkv, d)), t((b, s, hkv, d))
    q = t((b, h, d))
    out = decode_attention_local(q, kc, vc, jnp.full((b,), s))
    ref = dense_attention(q[:, None], kc, vc, causal=False)[:, 0]
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_decode_seq_sharded_matches_local():
    """Distributed flash-softmax == local softmax on a 1-shard mesh, and the
    partial-combine math is validated by manually splitting the cache."""
    b, s, h, hkv, d = 2, 128, 4, 2, 32
    kc, vc = t((b, s, hkv, d)), t((b, s, hkv, d))
    q = t((b, h, d))
    valid = jnp.arange(s)[None, :] < (s - 17)
    want = decode_attention_local(q, kc, vc, jnp.sum(valid, axis=1))

    # emulate the two-shard psum by hand using the same kernel math
    import functools
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("model",))
    fn = functools.partial(decode_attention_seq_sharded, axis_name="model")
    got = shard_map(
        fn, mesh=mesh,
        in_specs=(P(None, None, None), P(None, "model", None, None),
                  P(None, "model", None, None), P(None, "model")),
        out_specs=P(None, None, None),
        check_vma=False,
    )(q, kc, vc, valid)
    np.testing.assert_allclose(got, want, atol=2e-5)
