"""End-to-end system tests: the paper's pipeline from graphs to trained
models, with scheduling, timing, checkpoint/restart."""

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.core import bottleneck_time, compare_methods
from repro.fl.runner import FLExperiment, run_fl
from repro.fl.gossip import GossipConfig


def test_fl_end_to_end_sdp_beats_baselines_and_learns():
    exp = FLExperiment(
        dataset="mnist", num_users=6, num_machines=3, degree_low=2,
        degree_high=3, rounds=4, num_samples=768,
        gossip=GossipConfig(local_steps=2, batch_size=32),
    )
    out = run_fl(exp, methods=("random", "heft", "sdp"))
    # learning: accuracy above chance after a few rounds
    assert out["history"][-1]["accuracy_user0"] > 0.15
    # scheduling: sdp no worse than random on the same instance
    assert (
        out["bottleneck_per_round"]["sdp"]
        <= out["bottleneck_per_round"]["random"] + 1e-9
    )
    # the reported per-round bottleneck matches the exact evaluator
    s = out["schedules"]["sdp"]
    assert np.isclose(
        out["bottleneck_per_round"]["sdp"],
        bottleneck_time(out["task_graph"], out["compute_graph"], s.assignment),
    )


def test_scheduler_comparison_full_stack():
    rng = np.random.default_rng(123)
    from repro.core import random_compute_graph, random_task_graph

    tg = random_task_graph(rng, 9, degree_low=2, degree_high=4)
    cg = random_compute_graph(rng, 4)
    out = compare_methods(
        tg, cg, methods=("heft", "tp_heft", "sdp_naive", "sdp", "sdp_ls"),
        num_samples=1500, rounding_backend="numpy",
    )
    # paper ordering on average: sdp_ls <= sdp; all finite
    assert out["sdp_ls"].bottleneck <= out["sdp"].bottleneck + 1e-9
    for m, s in out.items():
        assert np.isfinite(s.bottleneck), m


def test_checkpoint_restart_mid_training(tmp_path):
    """Kill-and-resume: training continues bit-exact from the checkpoint."""
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.data.synthetic import LMStream
    from repro.models import build_model
    from repro.train.optim import AdamW
    from repro.train.trainer import init_train_state, make_train_step

    cfg = get_smoke_config("granite-3-2b").replace(vocab_size=64)
    api = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(make_train_step(api, opt))
    stream = LMStream(vocab_size=64, seq_len=32, global_batch=4, seed=0)

    def as_jnp(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    # run A: 6 steps straight
    state_a = init_train_state(api, opt, jax.random.PRNGKey(0))
    for i in range(6):
        state_a, _ = step(state_a, as_jnp(stream.batch(i)))

    # run B: 3 steps, checkpoint, "crash", restore, 3 more (data cursor from
    # the manifest step)
    mgr = CheckpointManager(str(tmp_path))
    state_b = init_train_state(api, opt, jax.random.PRNGKey(0))
    for i in range(3):
        state_b, _ = step(state_b, as_jnp(stream.batch(i)))
    mgr.save(3, state_b, metadata={"data_step": 3})
    del state_b
    template = init_train_state(api, opt, jax.random.PRNGKey(42))
    restored, manifest = mgr.load(template)
    for i in range(manifest["data_step"], 6):
        restored, _ = step(restored, as_jnp(stream.batch(i)))

    for a, b in zip(
        jax.tree.leaves(state_a["params"]), jax.tree.leaves(restored["params"])
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
