"""Scenario engine: topology invariants, profiles, presets, sweeps."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core.bqp import bottleneck_time
from repro.core.graphs import (
    ComputeGraph,
    cluster_assignment,
    cluster_shard_permutation,
    cluster_task_graph,
    contiguous_shard_of,
    erdos_renyi_task_graph,
    halo_edge_count,
    layered_dag_task_graph,
    permute_task_graph,
    ring_task_graph,
    scale_free_task_graph,
    small_world_task_graph,
    torus_task_graph,
)
from repro.scenarios import (
    DelayDrift,
    FLWorkload,
    Scenario,
    delay_matrix,
    drifting_delays,
    get_scenario,
    list_scenarios,
    machine_speeds,
    run_scenario,
    run_sweep,
)
from repro.scenarios.engine import build_compute_graph, build_task_graph


def _out_degrees(g):
    deg = np.zeros(g.num_tasks, dtype=int)
    for (i, _) in g.edges:
        deg[i] += 1
    return deg


# ---------------------------------------------------------------------------
# Topology families: TaskGraph invariants
# ---------------------------------------------------------------------------


def test_ring_degrees():
    g = ring_task_graph(8)
    assert np.all(_out_degrees(g) == 2)            # bidirectional
    g1 = ring_task_graph(8, bidirectional=False)
    assert np.all(_out_degrees(g1) == 1)
    assert not g1.validate_is_dag()                 # a ring is a cycle


def test_torus_degrees():
    g = torus_task_graph(4, 4)
    assert g.num_tasks == 16
    assert np.all(_out_degrees(g) == 4)            # 4 lattice neighbors
    # edge set is symmetric (every link has both directions)
    es = set(g.edges)
    assert all((j, i) in es for (i, j) in es)


def test_erdos_renyi_no_self_loops_and_density():
    rng = np.random.default_rng(0)
    g = erdos_renyi_task_graph(rng, 20, edge_prob=0.3)
    assert all(i != j for (i, j) in g.edges)
    n_pairs = 20 * 19
    assert 0.15 * n_pairs < len(g.edges) < 0.45 * n_pairs


def test_scale_free_symmetric_with_hubs():
    rng = np.random.default_rng(1)
    g = scale_free_task_graph(rng, 30, attach=2)
    es = set(g.edges)
    assert all((j, i) in es for (i, j) in es)
    deg = _out_degrees(g)
    assert deg.min() >= 2                          # every vertex attaches >= 2
    assert deg.max() >= 3 * np.median(deg)         # hubs emerge


def test_small_world_symmetric_connected_lattice():
    rng = np.random.default_rng(2)
    g = small_world_task_graph(rng, 16, k=4, rewire_prob=0.2)
    es = set(g.edges)
    assert all((j, i) in es for (i, j) in es)
    assert np.all(_out_degrees(g) >= 1)


def test_layered_dag_is_dag_and_connected():
    rng = np.random.default_rng(3)
    g = layered_dag_task_graph(rng, 4, 4, edge_prob=0.4)
    assert g.num_tasks == 16
    assert g.validate_is_dag()
    has_succ = {i for (i, _) in g.edges}
    has_pred = {j for (_, j) in g.edges}
    assert has_succ >= set(range(12))              # all but the last layer
    assert has_pred >= set(range(4, 16))           # all but the first layer


def test_cluster_topology_symmetric_and_hierarchical():
    rng = np.random.default_rng(4)
    g = cluster_task_graph(rng, 24, clusters=4, inner_topology="dense",
                           head_topology="ring")
    es = set(g.edges)
    assert all(i != j for (i, j) in es)
    assert all((j, i) in es for (i, j) in es)      # both directions emitted
    cl = cluster_assignment(24, 4)
    cross = {(i, j) for (i, j) in es if cl[i] != cl[j]}
    # ring head graph with 1 head/cluster: 4 undirected links = 8 directed
    assert len(cross) == 8
    heads = {int(np.nonzero(cl == c)[0][0]) for c in range(4)}
    assert {i for (i, _) in cross} <= heads        # only heads cross clusters
    # dense inner wiring: 4 * (6*5) directed intra edges
    assert len(es) - len(cross) == 4 * 6 * 5


def test_cluster_topology_inner_families():
    rng = np.random.default_rng(5)
    ring = cluster_task_graph(rng, 24, clusters=4, inner_topology="ring")
    cl = cluster_assignment(24, 4)
    intra = [(i, j) for (i, j) in ring.edges if cl[i] == cl[j]]
    assert len(intra) == 4 * 6 * 2                 # 6-rings, both directions
    gos = cluster_task_graph(rng, 24, clusters=4, inner_topology="gossip",
                             inner_degree=2, head_topology="dense")
    deg = _out_degrees(gos)
    assert deg.min() >= 2                          # >= inner_degree neighbors


def test_cluster_topology_validation():
    rng = np.random.default_rng(6)
    with pytest.raises(ValueError, match="unknown inner topology"):
        cluster_task_graph(rng, 24, inner_topology="torus")
    with pytest.raises(ValueError, match="unknown head topology"):
        cluster_task_graph(rng, 24, head_topology="star")
    with pytest.raises(ValueError, match=">= 2 clusters"):
        cluster_task_graph(rng, 24, clusters=1)
    with pytest.raises(ValueError, match="2 \\* clusters"):
        cluster_task_graph(rng, 6, clusters=4)
    with pytest.raises(ValueError, match="heads_per_cluster"):
        cluster_task_graph(rng, 24, clusters=4, heads_per_cluster=9)
    with pytest.raises(ValueError, match="inner_degree"):
        cluster_task_graph(rng, 24, clusters=4, inner_topology="gossip",
                           inner_degree=0)


def test_cluster_partition_utilities():
    rng = np.random.default_rng(7)
    n, clusters, shards = 64, 8, 4
    g = cluster_task_graph(rng, n, clusters=clusters, inner_topology="dense",
                           head_topology="ring")
    base = halo_edge_count(g, contiguous_shard_of(n, shards))
    # scramble user labels, then re-pack whole clusters onto shard blocks
    scramble = rng.permutation(n)
    scrambled = permute_task_graph(g, scramble)
    cl_scrambled = cluster_assignment(n, clusters)[scramble]
    worse = halo_edge_count(scrambled, contiguous_shard_of(n, shards))
    perm = cluster_shard_permutation(cl_scrambled, shards)
    packed = permute_task_graph(scrambled, perm)
    repacked = halo_edge_count(packed, contiguous_shard_of(n, shards))
    assert repacked == base < worse                # packing recovers optimum
    # permuting preserves the degree multiset (graphs are isomorphic)
    assert sorted(_out_degrees(packed)) == sorted(_out_degrees(g))
    with pytest.raises(ValueError, match="permutation"):
        permute_task_graph(g, np.zeros(n, dtype=np.int64))
    with pytest.raises(ValueError, match="shard_of shape"):
        halo_edge_count(g, np.zeros(n + 1, dtype=np.int64))


def test_cluster_scenario_axis():
    sc = Scenario(
        name="clu", topology="cluster", num_tasks=16, num_machines=2,
        topology_params={"clusters": 4, "inner_topology": "ring"},
        schedulers=("greedy",), rounds=1,
    )
    g = build_task_graph(sc, np.random.default_rng(0))
    assert g.num_tasks == 16
    es = set(g.edges)
    assert all((j, i) in es for (i, j) in es)


# ---------------------------------------------------------------------------
# Machine profiles and delay models
# ---------------------------------------------------------------------------


def test_machine_profiles_positive_speeds():
    rng = np.random.default_rng(4)
    for profile in ("uniform", "bimodal", "lognormal", "paper"):
        e = machine_speeds(profile, rng, 8)
        assert e.shape == (8,) and np.all(e > 0), profile


def test_bimodal_has_two_levels():
    rng = np.random.default_rng(5)
    e = machine_speeds("bimodal", rng, 8, fast=4.0, slow=1.0, fast_fraction=0.25)
    assert set(np.unique(e)) == {1.0, 4.0}
    assert np.sum(e == 4.0) == 2                   # ceil(0.25 * 8)


@pytest.mark.parametrize("model", ["uniform", "distance", "cluster", "paper"])
def test_delay_models_zero_diagonal_nonnegative(model):
    rng = np.random.default_rng(6)
    C = delay_matrix(model, rng, 6)
    assert C.shape == (6, 6)
    assert np.all(np.diag(C) == 0.0)
    assert np.all(C >= 0.0)
    ComputeGraph(e=np.ones(6), C=C)                # passes graph validation


@pytest.mark.parametrize("model", ["distance", "cluster"])
def test_structured_delay_models_symmetric(model):
    rng = np.random.default_rng(7)
    C = delay_matrix(model, rng, 6)
    np.testing.assert_allclose(C, C.T)


def test_profiles_reject_unknown_params():
    """A misspelled parameter must fail loudly, not silently default."""
    rng = np.random.default_rng(9)
    with pytest.raises(ValueError, match="cmax"):
        delay_matrix("uniform", rng, 4, cmax=5.0)          # typo for c_max
    with pytest.raises(ValueError, match="e_sigma"):
        machine_speeds("lognormal", rng, 4, e_sigma=2.0)   # wrong profile's key
    with pytest.raises(ValueError, match="amplituud"):
        drifting_delays(rng, 4, base="distance", amplituud=0.5)


def test_elastic_drift_composes_with_failure():
    """on_delay_update subsets original-label delay matrices after failures."""
    from repro.launch.elastic import ElasticScheduler

    rng = np.random.default_rng(10)
    tg = ring_task_graph(6)
    C = delay_matrix("distance", rng, 4)
    es = ElasticScheduler(tg, ComputeGraph(e=np.ones(4), C=C), method="greedy")
    es.on_failure(1)
    drift = drifting_delays(rng, 4, base="distance")       # original labels
    es.on_delay_update(drift.at(3))
    assert es.compute_graph.num_machines == 3
    expect = drift.at(3)[np.ix_([0, 2, 3], [0, 2, 3])]
    np.testing.assert_allclose(es.compute_graph.C, expect)
    assert np.all(es.current.assignment < 3)


def test_delay_drift_moves_and_stays_valid():
    rng = np.random.default_rng(8)
    drift = drifting_delays(rng, 5, base="distance", amplitude=0.5, period=8.0)
    assert isinstance(drift, DelayDrift)
    C0, C3 = drift.at(0), drift.at(3)
    for C in (C0, C3):
        assert np.all(np.diag(C) == 0.0) and np.all(C >= 0.0)
        np.testing.assert_allclose(C, C.T)         # symmetric base + phase
    assert not np.allclose(C0, C3)                 # delays actually drift
    np.testing.assert_allclose(drift.at(0), drift.at(8))   # periodic


# ---------------------------------------------------------------------------
# Scenario spec + engine
# ---------------------------------------------------------------------------


def test_scenario_validation():
    with pytest.raises(ValueError, match="topology"):
        Scenario(name="x", topology="moebius", num_tasks=8)
    with pytest.raises(ValueError, match="machine profile"):
        Scenario(name="x", topology="ring", num_tasks=8, machine_profile="warp")
    with pytest.raises(ValueError, match="delay model"):
        Scenario(name="x", topology="ring", num_tasks=8, delay_model="psychic")
    with pytest.raises(ValueError, match="scheduler"):
        Scenario(name="x", topology="ring", num_tasks=8, schedulers=("magic",))
    with pytest.raises(ValueError, match="drift"):
        Scenario(name="x", topology="ring", num_tasks=8,
                 delay_model="drift", fl=FLWorkload())


def test_registry_has_presets():
    names = set(list_scenarios())
    assert {"fig6", "fig4_nt10", "fig5_deg2_4", "ring_uniform",
            "torus_cluster", "smallworld_drift"} <= names
    with pytest.raises(KeyError):
        get_scenario("does_not_exist")


@pytest.mark.parametrize("name", [
    "ring_uniform", "torus_cluster", "er_bimodal_distance", "layered_cloud",
])
def test_preset_instances_valid(name):
    """Every preset generates a valid (TaskGraph, ComputeGraph) pair."""
    sc = get_scenario(name)
    rng = np.random.default_rng(sc.seed)
    tg = build_task_graph(sc, rng)
    cg, drift = build_compute_graph(sc, rng)
    assert tg.num_tasks == sc.num_tasks
    assert cg.num_machines == sc.num_machines
    assert np.all(np.diag(cg.C) == 0.0)
    if sc.delay_model != "drift":
        assert drift is None


def test_preset_round_trips_all_four_schedulers():
    """ring_uniform runs schedule() on sdp/heft/tp_heft/random end to end."""
    sc = get_scenario("ring_uniform")
    assert set(sc.schedulers) == {"sdp", "heft", "tp_heft", "random"}
    rec = run_scenario(sc, quick=True)
    rng = np.random.default_rng(sc.seed)
    tg = build_task_graph(sc, rng)
    cg, _ = build_compute_graph(sc, rng)
    for m in sc.schedulers:
        entry = rec["methods"][m]
        a = np.asarray(entry["assignment"])
        assert a.shape == (sc.num_tasks,)
        assert np.all((0 <= a) & (a < sc.num_machines))
        # predicted bottleneck is the exact Eq. 2 value of the assignment
        np.testing.assert_allclose(
            entry["predicted_bottleneck"], bottleneck_time(tg, cg, a)
        )
        # static delays: achieved == predicted every round
        np.testing.assert_allclose(
            entry["mean_round_time"], entry["predicted_bottleneck"]
        )
        assert entry["num_reschedules"] == 0


def test_fig4_preset_matches_paper_instance():
    """fig4_nt10 generation consumes the rng exactly like paper_instance."""
    from benchmarks.common import paper_instance

    sc = get_scenario("fig4_nt10").with_seed(7)
    rng = np.random.default_rng(7)
    tg = build_task_graph(sc, rng)
    cg, _ = build_compute_graph(sc, rng)
    tg2, cg2 = paper_instance(7, 10)
    assert tg.edges == tg2.edges
    np.testing.assert_allclose(tg.p, tg2.p)
    np.testing.assert_allclose(cg.e, cg2.e)
    np.testing.assert_allclose(cg.C, cg2.C)


def test_drift_scenario_reschedules():
    """Drifting delays: achieved diverges from predicted; re-schedules run."""
    sc = Scenario(
        name="mini_drift",
        topology="ring",
        num_tasks=6,
        num_machines=3,
        delay_model="drift",
        delay_params={"base": "distance", "amplitude": 0.8, "period": 4.0},
        schedulers=("greedy",),
        rounds=8,
        reschedule_every=2,
        seed=1,
    )
    rec = run_scenario(sc, quick=True)
    entry = rec["methods"]["greedy"]
    assert entry["num_reschedules"] == 3           # rounds 2, 4, 6
    times = np.asarray(entry["round_times"])
    assert times.shape == (8,)
    assert times.std() > 0                          # delays actually moved
    np.testing.assert_allclose(entry["total_time"], times.sum())


def test_drift_record_reproducible_within_process():
    """The same drift scenario twice in one process yields the same record
    — stale warm-start cache entries must not leak between runs."""
    sc = Scenario(
        name="mini_drift_sdp", topology="ring", num_tasks=6, num_machines=3,
        delay_model="drift", delay_params={"base": "distance"},
        schedulers=("sdp",), rounds=4, reschedule_every=2, seed=2,
    )
    r1 = run_scenario(sc, quick=True)
    r2 = run_scenario(sc, quick=True)
    e1, e2 = r1["methods"]["sdp"], r2["methods"]["sdp"]
    assert e1["assignment"] == e2["assignment"]
    np.testing.assert_allclose(e1["round_times"], e2["round_times"])


def test_paper_setting_budget_independent():
    """paper_setting runs the legacy budgets regardless of quick, so its
    resume key (and record label) ignores the requested budget."""
    from repro.scenarios.engine import budget_quick, scenario_key

    fig6 = get_scenario("fig6")
    assert budget_quick(fig6, True) is False
    assert scenario_key(fig6, True) == scenario_key(fig6, False)
    ring = get_scenario("ring_uniform")
    assert budget_quick(ring, True) is True


def test_fig6_preset_matches_legacy_run_fl():
    """The fig6 preset delegates to the legacy §4.2 path: losses and
    bottlenecks are identical to calling run_fl directly (the pre-engine
    fig6 benchmark), at reduced size for test speed."""
    from repro.fl.gossip import GossipConfig
    from repro.fl.runner import FLExperiment, run_fl

    base = get_scenario("fig6")
    fl = dataclasses.replace(base.fl, rounds=2, num_samples=512)
    sc = dataclasses.replace(base, fl=fl)
    rec = run_scenario(sc, quick=True)

    exp = FLExperiment(
        dataset="mnist", num_users=10, num_machines=4,
        degree_low=6, degree_high=7, rounds=2, num_samples=512,
        backend="stacked", seed=0,
        gossip=GossipConfig(local_steps=2, batch_size=32),
    )
    legacy = run_fl(exp, methods=("heft", "tp_heft", "sdp_naive", "sdp"))

    legacy_losses = [h["mean_loss"] for h in legacy["history"]]
    np.testing.assert_allclose(rec["fl"]["losses"], legacy_losses, rtol=1e-6)
    for m, t in legacy["bottleneck_per_round"].items():
        np.testing.assert_allclose(rec["fl"]["bottleneck_per_round"][m], t)


def test_fl_scenario_on_engine_instance():
    """Non-paper FL: the engine's topology/machines drive the trainer, and
    the methods section and the FL section describe ONE set of schedules."""
    sc = dataclasses.replace(
        get_scenario("smallworld_fl"),
        schedulers=("greedy",),
        fl=FLWorkload(rounds=2, local_steps=1, batch_size=16, num_samples=256),
    )
    rec = run_scenario(sc, quick=True)
    assert rec["fl"]["backend"] == "stacked"
    assert len(rec["fl"]["losses"]) == 2
    assert np.all(np.isfinite(rec["fl"]["losses"]))
    assert set(rec["fl"]["bottleneck_per_round"]) == {"greedy"}
    # one schedule per method, not an engine solve + a run_fl re-solve
    entry = rec["methods"]["greedy"]
    np.testing.assert_allclose(
        entry["predicted_bottleneck"], rec["fl"]["bottleneck_per_round"]["greedy"]
    )
    # simulated totals use the FL round count (rec["rounds"])
    assert rec["rounds"] == 2
    np.testing.assert_allclose(
        entry["total_time"], rec["fl"]["cumulative_time_final"]["greedy"]
    )


def test_run_sweep_resumes(tmp_path):
    out = tmp_path / "sweep.json"
    sc = Scenario(
        name="mini", topology="ring", num_tasks=4, num_machines=2,
        schedulers=("greedy",), rounds=2,
    )
    p1 = run_sweep([sc], out_path=out, quick=True)
    assert len(p1["records"]) == 1
    stamp = out.stat().st_mtime_ns
    data = json.loads(out.read_text())
    assert data["records"][0]["scenario"] == "mini"

    # second entry with a new seed appends; the completed record is skipped
    p2 = run_sweep([sc, sc.with_seed(1)], out_path=out, quick=True)
    assert [(r["scenario"], r["seed"]) for r in p2["records"]] == [
        ("mini", 0), ("mini", 1)
    ]
    assert json.loads(out.read_text())["records"][0] == p1["records"][0]
    assert out.stat().st_mtime_ns != stamp
