"""Churn subsystem: trace generation, engine join/recover/link events,
elastic arrivals + degraded mode, and the churn scenario records."""

import dataclasses

import numpy as np
import pytest

from repro.core.graphs import ComputeGraph, gossip_task_graph, ring_task_graph
from repro.core.scheduler import schedule
from repro.launch.elastic import ElasticScheduler
from repro.scenarios import Scenario, churn_trace, run_scenario
from repro.scenarios.engine import _churn_control_events, _churn_trace_for
from repro.sim import ControlEvent, simulate


def _instance(seed=0, n_tasks=8, n_machines=4):
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n_tasks, degree_low=2, degree_high=3)
    C = rng.uniform(0.1, 1.0, (n_machines, n_machines))
    np.fill_diagonal(C, 0.0)
    cg = ComputeGraph(e=rng.uniform(0.5, 2.0, n_machines), C=C)
    return tg, cg


def _greedy(tg_, cg_, r):
    return schedule(tg_, cg_, "greedy").assignment


# ---------------------------------------------------------------------------
# ControlEvent validation (satellite: no silent speed corruption)
# ---------------------------------------------------------------------------


def test_slowdown_factor_must_be_positive():
    with pytest.raises(ValueError, match="slowdown factor"):
        ControlEvent(round=1, kind="slowdown", machine=0, factor=0.0)
    with pytest.raises(ValueError, match="slowdown factor"):
        ControlEvent(round=1, kind="slowdown", machine=0, factor=-2.0)
    ControlEvent(round=1, kind="slowdown", machine=0, factor=0.5)  # ok


def test_link_event_validation():
    with pytest.raises(ValueError, match="machine and peer"):
        ControlEvent(round=0, kind="link_down", machine=0, factor=2.0)
    with pytest.raises(ValueError, match="distinct"):
        ControlEvent(round=0, kind="link_down", machine=1, peer=1, factor=2.0)
    with pytest.raises(ValueError, match="must be > 1"):
        ControlEvent(round=0, kind="link_down", machine=0, peer=1, factor=1.0)
    ControlEvent(round=0, kind="link_down", machine=0, peer=1, factor=3.0)
    ControlEvent(round=3, kind="link_up", machine=0, peer=1)


def test_join_and_recover_need_machine_label():
    for kind in ("join", "recover"):
        with pytest.raises(ValueError, match="machine label"):
            ControlEvent(round=0, kind=kind)


# ---------------------------------------------------------------------------
# Engine: join / recover / link events at the barrier
# ---------------------------------------------------------------------------


def test_double_fail_raises_in_engine():
    tg, cg = _instance()
    a = schedule(tg, cg, "greedy").assignment
    events = (
        ControlEvent(round=1, kind="fail", machine=2),
        ControlEvent(round=2, kind="fail", machine=2),
    )
    with pytest.raises(ValueError, match="already down"):
        simulate(tg, cg, a, 4, control_events=events, schedule_fn=_greedy)


def test_recover_of_up_machine_raises_in_engine():
    tg, cg = _instance()
    a = schedule(tg, cg, "greedy").assignment
    events = (ControlEvent(round=1, kind="recover", machine=2),)
    with pytest.raises(ValueError, match="already up"):
        simulate(tg, cg, a, 3, control_events=events, schedule_fn=_greedy)


def test_fail_recover_round_trip_restores_round_times_exactly():
    """fail → recover restores the original fleet: with a deterministic
    scheduler the post-recovery rounds time EXACTLY like round 0, absent
    machines report NaN busy, and fleet_size tracks the trace."""
    tg, cg = _instance(seed=3)
    a = schedule(tg, cg, "greedy").assignment
    events = (
        ControlEvent(round=1, kind="fail", machine=1),
        ControlEvent(round=3, kind="recover", machine=1),
    )
    res = simulate(tg, cg, a, 5, control_events=events, schedule_fn=_greedy)
    assert res.round_times[3] == res.round_times[0]
    assert res.round_times[4] == res.round_times[0]
    assert np.isnan(res.busy[1:3, 1]).all()
    assert np.isfinite(res.busy[0, 1]) and np.isfinite(res.busy[3:, 1]).all()
    assert list(res.fleet_size) == [4, 3, 3, 4, 4]
    assert res.machine_ids == [0, 1, 2, 3]
    assert res.reschedule_rounds == [1, 3]


def test_fail_rejoin_fail_of_same_label_composes_in_engine():
    tg, cg = _instance(seed=4)
    a = schedule(tg, cg, "greedy").assignment
    events = (
        ControlEvent(round=1, kind="fail", machine=2),
        ControlEvent(round=2, kind="recover", machine=2),
        ControlEvent(round=3, kind="fail", machine=2),
    )
    res = simulate(tg, cg, a, 5, control_events=events, schedule_fn=_greedy)
    assert res.machine_ids == [0, 1, 3]
    assert list(res.fleet_size) == [4, 3, 4, 3, 3]
    assert np.isnan(res.busy[1, 2]) and np.isfinite(res.busy[2, 2])
    assert np.isnan(res.busy[3:, 2]).all()


def test_link_outage_window_slows_rounds_then_restores_exactly():
    tg, cg = _instance(seed=5)
    a = schedule(tg, cg, "greedy").assignment
    events = (
        ControlEvent(round=1, kind="link_down", machine=0, peer=1, factor=5.0),
        ControlEvent(round=3, kind="link_up", machine=0, peer=1),
    )
    res = simulate(tg, cg, a, 5, control_events=events)
    assert res.round_times[1] == res.round_times[2] >= res.round_times[0]
    assert res.round_times[3] == res.round_times[0]
    # double link_down on an already-down link raises
    bad = (
        ControlEvent(round=1, kind="link_down", machine=0, peer=1, factor=5.0),
        ControlEvent(round=2, kind="link_down", machine=1, peer=0, factor=5.0),
    )
    with pytest.raises(ValueError, match="already in an outage"):
        simulate(tg, cg, a, 4, control_events=bad)
    with pytest.raises(ValueError, match="not in an outage"):
        simulate(
            tg, cg, a, 3,
            control_events=(ControlEvent(round=1, kind="link_up",
                                         machine=0, peer=1),),
        )


def test_join_of_out_of_universe_label_raises():
    tg, cg = _instance()
    a = schedule(tg, cg, "greedy").assignment
    events = (ControlEvent(round=1, kind="join", machine=7),)
    with pytest.raises(ValueError, match="universe"):
        simulate(tg, cg, a, 3, control_events=events, schedule_fn=_greedy)


# ---------------------------------------------------------------------------
# ElasticScheduler: arrivals, recoveries, composition
# ---------------------------------------------------------------------------


def test_elastic_fail_rejoin_restores_fleet_exactly():
    """The acceptance pin: a fail → recover round trip restores speeds,
    delays, and machine labels bit-for-bit."""
    tg, cg = _instance(seed=7)
    es = ElasticScheduler(tg, cg, method="greedy")
    e0, C0 = es.compute_graph.e.copy(), es.compute_graph.C.copy()
    es.on_failure(2, round=1)
    assert es.machine_ids == [0, 1, 3]
    es.on_recovery(2, round=3)
    assert es.machine_ids == [0, 1, 2, 3]
    assert np.array_equal(es.compute_graph.e, e0)
    assert np.array_equal(es.compute_graph.C, C0)


def test_elastic_fail_rejoin_fail_composes():
    tg, cg = _instance(seed=8)
    es = ElasticScheduler(tg, cg, method="greedy")
    for r in range(3):
        es.on_failure(1, round=2 * r)
        assert es.machine_ids == [0, 2, 3]
        es.on_recovery(1, round=2 * r + 1)
        assert es.machine_ids == [0, 1, 2, 3]
    events = [h["event"] for h in es.history]
    assert events == ["init"] + ["fail:1", "recover:1"] * 3


def test_elastic_double_fail_raises():
    tg, cg = _instance()
    es = ElasticScheduler(tg, cg, method="greedy")
    es.on_failure(2)
    with pytest.raises(ValueError, match="not in the live fleet"):
        es.on_failure(2)
    with pytest.raises(ValueError, match="already in the live fleet"):
        es.on_recovery(0)


def test_elastic_recovery_during_delay_drift_uses_current_delays():
    """A machine that fails, sleeps through a delay update, and recovers
    must rejoin under the drifted delays — not the ones of its departure."""
    rng = np.random.default_rng(9)
    tg, cg = _instance(seed=9)
    es = ElasticScheduler(tg, cg, method="greedy", reschedule_threshold=10.0)
    es.on_failure(1, round=1)
    C2 = rng.uniform(2.0, 3.0, (4, 4))
    C2 = 0.5 * (C2 + C2.T)
    np.fill_diagonal(C2, 0.0)
    es.on_delay_update(C2, round=2)          # full-universe update
    es.on_recovery(1, round=3)
    assert np.array_equal(es.compute_graph.C, C2)


def test_elastic_on_arrival_grows_universe():
    tg, cg = _instance(seed=10)
    es = ElasticScheduler(tg, cg, method="greedy")
    es.on_arrival(4, speed=1.5, delays_to=np.full(4, 0.3), round=2)
    assert es.machine_ids == [0, 1, 2, 3, 4]
    assert es.compute_graph.C.shape == (5, 5)
    assert es.compute_graph.e[4] == 1.5
    np.testing.assert_array_equal(es.compute_graph.C[4, :4], np.full(4, 0.3))
    # the new label participates in fail/recover like any original one
    es.on_failure(4, round=3)
    es.on_recovery(4, round=4)
    assert es.machine_ids == [0, 1, 2, 3, 4]


def test_elastic_on_arrival_validation():
    tg, cg = _instance()
    es = ElasticScheduler(tg, cg, method="greedy")
    with pytest.raises(ValueError, match="already in the live fleet"):
        es.on_arrival(0, speed=1.0, delays_to=np.full(3, 0.1))
    with pytest.raises(ValueError, match="no stashed state"):
        es.on_arrival(4)                     # new label needs explicit stats
    with pytest.raises(ValueError, match="speed must be > 0"):
        es.on_arrival(4, speed=0.0, delays_to=np.full(4, 0.1))
    with pytest.raises(ValueError, match="delays_to"):
        es.on_arrival(4, speed=1.0)
    with pytest.raises(ValueError, match="one entry per other"):
        es.on_arrival(4, speed=1.0, delays_to=np.full(2, 0.1))
    with pytest.raises(ValueError, match="dense"):
        es.on_arrival(9, speed=1.0, delays_to=np.full(4, 0.1))
    # arrival without stats delegates to recovery for stashed labels
    es.on_failure(2)
    es.on_arrival(2)
    assert es.machine_ids == [0, 1, 2, 3]


def test_elastic_history_invariants():
    """History rounds are monotone and every entry records a finite
    bottleneck plus the event name."""
    tg, cg = _instance(seed=11)
    es = ElasticScheduler(tg, cg, method="greedy")
    es.on_failure(3, round=1)
    es.on_delay_update(es._C_full * 1.1, round=2)
    es.on_recovery(3, round=4)
    es.observe_round(np.full(4, 0.5), round=5)
    rounds = [h["round"] for h in es.history if h["round"] is not None]
    assert rounds == sorted(rounds)
    for h in es.history:
        assert h["event"]
        assert np.isfinite(h["bottleneck"])


# ---------------------------------------------------------------------------
# Degraded mode: retry-once-then-fallback under solve budgets
# ---------------------------------------------------------------------------


def _sdp_kwargs():
    from repro.core.sdp import SDPOptions

    return {"num_samples": 64, "sdp_options": SDPOptions(max_iters=200)}


def test_injected_timeout_activates_fallback():
    tg, cg = _instance(seed=12, n_tasks=6, n_machines=3)
    es = ElasticScheduler(
        tg, cg, method="sdp", fallback="heft", solve_timeout=0.0,
        schedule_kwargs=_sdp_kwargs(),
    )
    assert es.fallback_count == 1                 # the init solve degraded
    fb = [h for h in es.history if h["event"] == "fallback:heft"]
    assert len(fb) == 1 and fb[0]["reason"].startswith("timeout:")
    heft = schedule(tg, cg, "heft", seed=0)
    assert es.current.bottleneck == heft.bottleneck
    es.on_failure(1, round=2)                     # still degrades, never wedges
    assert es.fallback_count == 2
    assert np.isfinite(es.current.bottleneck)


def test_no_fallback_configured_raises_after_two_attempts():
    tg, cg = _instance(seed=13, n_tasks=6, n_machines=3)
    with pytest.raises(RuntimeError, match="failed twice"):
        ElasticScheduler(
            tg, cg, method="sdp", solve_timeout=0.0,
            schedule_kwargs=_sdp_kwargs(),
        )


def test_fallback_configuration_validation():
    tg, cg = _instance()
    with pytest.raises(ValueError, match="unknown fallback"):
        ElasticScheduler(tg, cg, method="sdp", fallback="nope")
    with pytest.raises(ValueError, match="differ from the primary"):
        ElasticScheduler(tg, cg, method="sdp", fallback="sdp")


def test_solver_max_iters_overrides_schedule_kwargs():
    tg, cg = _instance()
    es = ElasticScheduler(
        tg, cg, method="greedy", solver_max_iters=7,
    )
    # greedy is not an SDP method: the budget must not leak into kwargs
    assert "sdp_options" not in es._schedule_kwargs()
    es2 = ElasticScheduler(
        tg, cg, method="sdp", solver_max_iters=123,
        schedule_kwargs=_sdp_kwargs(),
    )
    assert es2._schedule_kwargs()["sdp_options"].max_iters == 123


# ---------------------------------------------------------------------------
# Composition-keyed warm-start cache: bounded, evicts unreachable fleets
# ---------------------------------------------------------------------------


def test_comp_cache_is_lru_bounded():
    tg, cg = _instance(seed=14, n_tasks=6, n_machines=4)
    es = ElasticScheduler(
        tg, cg, method="sdp", warm_cache_max=2, schedule_kwargs=_sdp_kwargs(),
    )
    for m in (1, 2, 3):                           # 4 distinct compositions
        es.on_failure(m, round=m)
        es.on_recovery(m, round=m)
    assert len(es._comp_states) <= 2


def test_permanent_failure_evicts_unreachable_compositions():
    tg, cg = _instance(seed=15, n_tasks=6, n_machines=4)
    es = ElasticScheduler(
        tg, cg, method="sdp", schedule_kwargs=_sdp_kwargs(),
    )
    es.on_failure(1, round=1)
    es.on_recovery(1, round=2)
    assert any(1 in comp for comp in es._comp_states)
    es.on_failure(1, round=3, permanent=True)
    # every cached composition containing label 1 can no longer recur
    assert all(1 not in comp for comp in es._comp_states)
    with pytest.raises(ValueError, match="no stashed state"):
        es.on_recovery(1)


# ---------------------------------------------------------------------------
# Churn trace generator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["markov", "weibull"])
def test_churn_trace_deterministic_and_consistent(model):
    kw = {"start_down_fraction": 0.2, "link_outages": 2}
    t1 = churn_trace(np.random.default_rng((0, 2)), 6, 30, model=model, **kw)
    t2 = churn_trace(np.random.default_rng((0, 2)), 6, 30, model=model, **kw)
    assert t1.machine_events == t2.machine_events
    assert t1.link_events == t2.link_events
    t3 = churn_trace(np.random.default_rng((1, 2)), 6, 30, model=model, **kw)
    assert (t1.machine_events != t3.machine_events
            or t1.link_events != t3.link_events)
    # replaying the events reproduces the recorded liveness exactly
    up = np.ones(6, dtype=bool)
    by_round: dict = {}
    for (r, kind, m) in t1.machine_events:
        by_round.setdefault(r, []).append((kind, m))
    for r in range(30):
        for kind, m in by_round.get(r, []):
            assert up[m] == (kind == "fail"), (r, kind, m)
            up[m] = kind != "fail"
        assert (up == t1.up_at[r]).all()


def test_churn_trace_min_up_floor():
    for seed in range(5):
        t = churn_trace(
            np.random.default_rng(seed), 5, 40, model="markov",
            p_fail=0.5, p_recover=0.1, min_up=2,
        )
        assert t.up_at.sum(axis=1).min() >= 2


def test_churn_trace_start_down_machines_join():
    t = churn_trace(
        np.random.default_rng(0), 6, 40, model="markov",
        start_down_fraction=0.5, p_recover=0.5, p_fail=0.0,
    )
    assert t.counts["join"] >= 1
    # round-0 fails mark the initial absences
    assert sum(1 for (r, k, _) in t.machine_events
               if r == 0 and k == "fail") == 3


def test_churn_trace_rejects_unknown_params_and_models():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="unknown churn model"):
        churn_trace(rng, 4, 10, model="exponential")
    with pytest.raises(ValueError, match="unknown markov parameter"):
        churn_trace(rng, 4, 10, model="markov", p_fial=0.1)


def test_churn_trace_link_outages_materialize_as_valid_events():
    t = churn_trace(
        np.random.default_rng(3), 6, 30, model="markov",
        link_outages=4, outage_len=4, outage_factor=2.5,
    )
    evs = t.control_events()
    downs = [e for e in evs if e.kind == "link_down"]
    assert len(downs) == 4
    for e in downs:
        assert e.factor == 2.5 and e.machine != e.peer
    # windows never overlap per pair: the engine's double-link_down check
    # must accept every generated trace
    tg, cg = _instance(seed=3, n_tasks=6, n_machines=6)
    a = schedule(tg, cg, "greedy").assignment
    res = simulate(
        tg, cg, a, 30, control_events=_churn_control_events(t),
        schedule_fn=_greedy,
    )
    assert np.isfinite(res.total_time)


# ---------------------------------------------------------------------------
# Scenario axis + end-to-end record
# ---------------------------------------------------------------------------


def _churn_scenario(**over):
    base = dict(
        name="churn_test",
        topology="small_world",
        num_tasks=8,
        num_machines=4,
        schedulers=("sdp",),
        rounds=10,
        topology_params={"k": 4, "rewire_prob": 0.2},
        churn="markov",
        churn_params={
            "p_fail": 0.2, "p_recover": 0.5,
            "start_down_fraction": 0.25, "min_up": 2,
            "link_outages": 1, "outage_len": 3, "outage_factor": 3.0,
        },
    )
    base.update(over)
    return Scenario(**base)


def test_churn_scenario_validation():
    with pytest.raises(ValueError, match="unknown churn model"):
        _churn_scenario(churn="exponential")
    with pytest.raises(ValueError, match="unknown churn policy"):
        _churn_scenario(churn_policies=("sdp_elastic", "nope"))
    with pytest.raises(ValueError, match="requires execution='sync'"):
        _churn_scenario(execution="async")
    with pytest.raises(ValueError, match="separate dynamics axes"):
        _churn_scenario(delay_model="drift")
    with pytest.raises(ValueError, match="unknown markov parameter"):
        _churn_scenario(churn_params={"p_fial": 0.1})
    # policy keys ride in churn_params without reaching the generator
    sc = _churn_scenario(churn_params={"solve_timeout": 0.5})
    assert sc.axes()["churn"] == "markov"
    trace = _churn_trace_for(sc)
    assert trace.num_rounds == 10


def test_churn_scenario_record_end_to_end():
    """One small churn scenario through run_scenario: all three policies
    recorded with finite regret vs the oracle, the injected zero solve
    budget forcing the elastic policy through its fallback."""
    sc = _churn_scenario(
        churn_params={
            "p_fail": 0.2, "p_recover": 0.5,
            "start_down_fraction": 0.25, "min_up": 2,
            "solve_timeout": 0.0,
        },
    )
    rec = run_scenario(sc, quick=True)
    assert rec["axes"]["churn"] == "markov"
    assert set(rec["methods"]) == {"sdp_elastic", "sdp_static", "heft"}
    assert rec["churn"]["oracle_total_time"] > 0
    assert rec["churn"]["counts"]["fail"] >= 2
    assert rec["churn"]["counts"]["join"] + rec["churn"]["counts"]["recover"] >= 1
    for pol, entry in rec["methods"].items():
        assert np.isfinite(entry["regret_vs_oracle"]), pol
        assert np.isfinite(entry["total_time"]), pol
        assert entry["num_consults"] >= 1, pol
    elastic = rec["methods"]["sdp_elastic"]
    assert elastic["fallback_count"] >= 1
    assert elastic["num_elastic_resolves"] >= 1
    # the oracle re-solves cold at every consult: the reactive policies
    # cannot beat it by more than rounding noise
    assert elastic["regret_vs_oracle"] > -0.05
