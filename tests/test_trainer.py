"""Trainer: LM training decreases loss; microbatching ≡ full batch."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.synthetic import LMStream
from repro.models import build_model
from repro.train.optim import AdamW
from repro.train.trainer import init_train_state, make_train_step


def test_lm_training_learns():
    cfg = get_smoke_config("qwen3-8b").replace(vocab_size=64)
    api = build_model(cfg)
    opt = AdamW(learning_rate=3e-3, weight_decay=0.0)
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(api, opt))
    stream = LMStream(vocab_size=64, seq_len=64, global_batch=8, seed=0)
    losses = []
    for i in range(30):
        b = stream.batch(i)
        state, metrics = step(
            state, {k: jnp.asarray(v) for k, v in b.items()}
        )
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]
    assert int(state["opt"].step) == 30


def test_microbatched_step_matches_full():
    # f32 activations so the only difference is reduction order
    cfg = get_smoke_config("granite-3-2b").replace(
        vocab_size=64, dtype=jnp.float32
    )
    api = build_model(cfg)
    opt = AdamW(learning_rate=1e-3, weight_decay=0.0, grad_clip=0.0)
    state0 = init_train_state(api, opt, jax.random.PRNGKey(1))
    stream = LMStream(vocab_size=64, seq_len=32, global_batch=8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}

    s_full, m_full = jax.jit(make_train_step(api, opt, microbatches=1))(
        jax.tree.map(jnp.copy, state0), batch
    )
    s_micro, m_micro = jax.jit(make_train_step(api, opt, microbatches=4))(
        jax.tree.map(jnp.copy, state0), batch
    )
    # CE means differ slightly (per-microbatch token counts equal here), so
    # parameters after one step must match closely
    for a, b in zip(
        jax.tree.leaves(s_full["params"]), jax.tree.leaves(s_micro["params"])
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-4, rtol=5e-3,
        )


def test_vlm_microbatch_split_handles_mrope_positions():
    cfg = get_smoke_config("qwen2-vl-72b").replace(vocab_size=64)
    api = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    state = init_train_state(api, opt, jax.random.PRNGKey(2))
    b, s = 4, 32
    batch = {
        "inputs_embeds": jnp.ones((b, s, cfg.d_model), cfg.dtype),
        "positions": jnp.tile(jnp.arange(s)[None, None], (3, b, 1)),
        "labels": jnp.ones((b, s), jnp.int32),
    }
    step = jax.jit(make_train_step(api, opt, microbatches=2))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
