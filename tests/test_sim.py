"""Discrete-event execution engine: semantics equivalences, staleness,
control-event composition with the elastic scheduling path."""

import dataclasses

import numpy as np
import pytest

from repro.core.graphs import ComputeGraph, gossip_task_graph, ring_task_graph
from repro.core.scheduler import schedule
from repro.fl.simulator import round_time
from repro.launch.elastic import ElasticScheduler
from repro.scenarios import (
    FLWorkload,
    Scenario,
    delay_matrix,
    drifting_delays,
    get_scenario,
    list_scenarios,
    run_scenario,
)
from repro.scenarios.engine import build_compute_graph, build_task_graph
from repro.sim import ControlEvent, ExecutionSpec, simulate


def _instance(seed=0, n_tasks=8, n_machines=3, e=None):
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n_tasks, degree_low=2, degree_high=3)
    C = rng.uniform(0.1, 1.0, (n_machines, n_machines))
    np.fill_diagonal(C, 0.0)
    if e is None:
        e = rng.uniform(0.5, 2.0, n_machines)
    cg = ComputeGraph(e=np.asarray(e, dtype=np.float64), C=C)
    a = rng.integers(0, n_machines, size=n_tasks)
    return tg, cg, a


# ---------------------------------------------------------------------------
# sync semantics: pinned to Eq. 2
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_sync_equals_eq2_on_every_preset(name):
    """The acceptance property: event-engine sync time == Eq. 2
    ``round_time`` to 1e-9 on every registered scenario preset."""
    sc = get_scenario(name)
    rng = np.random.default_rng(sc.seed)
    tg = build_task_graph(sc, rng)
    cg, _ = build_compute_graph(sc, rng)          # drift presets: at(0)
    a = schedule(tg, cg, "heft").assignment
    res = simulate(tg, cg, a, 3)                  # defaults to sync
    expect = round_time(tg, cg, a)
    assert np.all(np.abs(res.round_times - expect) <= 1e-9), name


def test_sync_round_times_exact_for_random_assignments():
    for seed in range(4):
        tg, cg, a = _instance(seed)
        res = simulate(tg, cg, a, 5)
        assert np.all(res.round_times == round_time(tg, cg, a))
        np.testing.assert_allclose(
            res.round_completion, np.cumsum(res.round_times)
        )
        # engine-emitted busy == Eq. 7 machine loads / speeds
        loads = np.zeros(cg.num_machines)
        np.add.at(loads, a, tg.p)
        np.testing.assert_array_equal(res.busy[0], loads / cg.e)


# ---------------------------------------------------------------------------
# overlap semantics
# ---------------------------------------------------------------------------


def test_overlap_never_slower_than_sync():
    for seed in range(4):
        tg, cg, a = _instance(seed)
        sync = simulate(tg, cg, a, 8)
        over = simulate(tg, cg, a, 8, ExecutionSpec(semantics="overlap"))
        assert np.all(
            over.round_completion <= sync.round_completion + 1e-12
        )
        assert over.period <= sync.period + 1e-12
        assert over.staleness_mean == 0.0          # no stale reads


def test_overlap_cycle_throttled_by_cycle_mean():
    """A 2-cycle cannot pipeline past its (comp + delay) cycle mean —
    the crude max(comp, comm) formula under-estimated this."""
    tg = ring_task_graph(2, bidirectional=True)    # 0 <-> 1
    C = np.array([[0.0, 1.0], [1.0, 0.0]])
    cg = ComputeGraph(e=np.ones(2), C=C)
    a = np.array([0, 1])                           # one task per machine
    res = simulate(tg, cg, a, 16, ExecutionSpec(semantics="overlap"))
    # per round each machine needs the other's previous output:
    # period = comp + C = 2; the old overlap flag claimed max(1, 1) = 1
    assert res.period == pytest.approx(2.0, rel=1e-9)
    assert round_time(tg, cg, a, overlap=True) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# async semantics: degeneracy, staleness, throughput
# ---------------------------------------------------------------------------


def test_async_zero_jitter_zero_delay_degenerates_to_sync():
    """With no jitter and no delays the async steady-state period equals
    the synchronous Eq. 2 round time for every scheduler, so the
    schedule ordering is unchanged."""
    rng = np.random.default_rng(3)
    tg = gossip_task_graph(rng, 10, degree_low=2, degree_high=3)
    e = rng.uniform(0.5, 4.0, 4)
    cg = ComputeGraph(e=e, C=np.zeros((4, 4)))
    periods, syncs = {}, {}
    for m in ("heft", "tp_heft", "greedy", "round_robin"):
        a = schedule(tg, cg, m).assignment
        res = simulate(tg, cg, a, 12, ExecutionSpec(semantics="async"))
        periods[m] = res.period
        syncs[m] = round_time(tg, cg, a)
        np.testing.assert_allclose(res.period, syncs[m], rtol=1e-9)
    order = sorted(periods, key=periods.get)
    assert order == sorted(syncs, key=syncs.get)


def test_async_staleness_positive_under_heterogeneity():
    tg, cg, a = _instance(5, n_tasks=10, n_machines=3, e=[0.3, 1.0, 3.0])
    res = simulate(tg, cg, a, 16, ExecutionSpec(semantics="async"))
    assert res.staleness_per_task.shape == (10,)
    assert np.all(res.staleness_per_task >= 0)
    assert res.staleness_mean > 0                  # fast machines run ahead
    assert res.staleness_max >= res.staleness_mean
    # async throughput is compute-bound: the slowest machine's load
    loads = np.zeros(3)
    np.add.at(loads, a, tg.p)
    np.testing.assert_allclose(res.period, np.max(loads / cg.e), rtol=1e-9)


def test_jitter_deterministic_and_perturbs():
    tg, cg, a = _instance(6)
    spec = ExecutionSpec(jitter_sigma=0.3, seed=9)
    r1 = simulate(tg, cg, a, 6, spec)
    r2 = simulate(tg, cg, a, 6, spec)
    np.testing.assert_array_equal(r1.round_times, r2.round_times)
    assert r1.round_times.std() > 0
    other = simulate(tg, cg, a, 6, dataclasses.replace(spec, seed=10))
    assert not np.array_equal(r1.round_times, other.round_times)


def test_per_machine_straggler_hits_only_that_machine():
    tg, cg, a = _instance(7)
    spec = ExecutionSpec(
        straggler_prob=(0.0, 0.0, 1.0), straggler_factor=5.0, seed=0
    )
    res = simulate(tg, cg, a, 4, spec)
    base = simulate(tg, cg, a, 4)
    np.testing.assert_allclose(res.busy[:, :2], base.busy[:, :2])
    np.testing.assert_allclose(res.busy[:, 2], base.busy[:, 2] * 5.0)


# ---------------------------------------------------------------------------
# control events: the elastic scheduling path through the queue
# ---------------------------------------------------------------------------


def test_control_events_require_sync():
    tg, cg, a = _instance(0)
    # Global kinds stay sync-only even under async...
    for kind, extra in (
        ("reschedule", {}),
        ("link_down", {"machine": 0, "peer": 1, "factor": 2.0}),
    ):
        with pytest.raises(ValueError, match="sync"):
            simulate(
                tg, cg, a, 4, ExecutionSpec(semantics="async"),
                control_events=(ControlEvent(round=1, kind=kind, **extra),),
            )
    # ...and overlap admits no control plane at all, not even the
    # machine-local kinds that async accepts.
    with pytest.raises(ValueError, match="sync"):
        simulate(
            tg, cg, a, 4, ExecutionSpec(semantics="overlap"),
            control_events=(
                ControlEvent(round=1, kind="fail", machine=0),
            ),
        )


def test_async_accepts_machine_local_control_events():
    """fail/recover compose with async: the machine freezes at its local
    round, rejoins via anti-entropy once the fleet frontier catches up,
    and every loss-bearing round still completes (finite completion)."""
    tg, cg, a = _instance(0)
    res = simulate(
        tg, cg, a, 6, ExecutionSpec(semantics="async"),
        control_events=(
            ControlEvent(round=1, kind="fail", machine=0),
            ControlEvent(round=3, kind="recover", machine=0),
        ),
    )
    assert np.all(np.isfinite(res.round_completion))
    assert res.barrier_stalls == 0
    assert res.machine_down is not None
    assert res.machine_down[1, 0] and res.machine_down[2, 0]
    assert not res.machine_down[3, 0] and not res.machine_down[0, 0]
    # the frozen machine's rounds 1-2 never ran: no busy entry
    assert np.isnan(res.busy[1, 0]) and np.isnan(res.busy[2, 0])
    assert np.isfinite(res.busy[3, 0])
    assert res.antientropy_msgs > 0
    assert list(res.fleet_size) == [3, 2, 2, 3, 3, 3]


def test_async_token_account_bounds_inflight_sends():
    """A capacity-1 account skips sends once the budget drains; sync
    rejects the combination outright."""
    tg, cg, a = _instance(0)
    spec = ExecutionSpec(
        semantics="async", token_capacity=1.0, token_refill=0.0
    )
    res = simulate(tg, cg, a, 4, spec)
    # after the initial token each machine can never send again
    assert res.send_skips > 0
    assert np.all(np.isfinite(res.round_completion))
    with pytest.raises(ValueError, match="async"):
        simulate(tg, cg, a, 4, ExecutionSpec(token_capacity=4.0))


def test_event_order_insertion_permutation_bit_identical():
    """Satellite: the queue's (t, kind, index, round) total order has no
    insertion sequence number, so permuting the order same-time events are
    pushed leaves SimResult bit-identical.  Exercised by permuting machine
    start order (round-0 events all share t=0) under async WITH jitter and
    overlap without."""
    import heapq
    import random as pyrandom

    from repro.sim import engine as engine_mod

    def run(seed, sem, shuffle_seed):
        tg, cg, a = _instance(seed)
        orig_heappush = heapq.heappush
        rng = pyrandom.Random(shuffle_seed)
        pending = []

        def chaotic_push(heap, item):
            # buffer pushes and flush in random order — heapq's pop order
            # only depends on the keys, but this also perturbs internal
            # tree shape, catching any hidden reliance on push order
            pending.append((heap, item))
            if len(pending) >= 3:
                rng.shuffle(pending)
                while pending:
                    h, it = pending.pop()
                    orig_heappush(h, it)

        spec = ExecutionSpec(
            semantics=sem,
            jitter_sigma=0.3 if sem == "async" else 0.0,
            seed=seed,
        )
        engine_mod.heapq.heappush = chaotic_push
        try:
            res = simulate(tg, cg, a, 5, spec)
        finally:
            engine_mod.heapq.heappush = orig_heappush
            while pending:
                h, it = pending.pop()
                orig_heappush(h, it)
        return res

    for sem in ("async", "overlap"):
        base = run(1, sem, 0)
        for shuffle_seed in (7, 99):
            other = run(1, sem, shuffle_seed)
            for f in dataclasses.fields(base):
                x, y = getattr(base, f.name), getattr(other, f.name)
                if isinstance(x, np.ndarray):
                    assert np.array_equal(x, y, equal_nan=True), (sem, f.name)
                else:
                    assert x == y, (sem, f.name)


def test_async_zero_delay_ties_deliver_before_boundary():
    """At equal timestamps arrivals settle before boundaries, so with
    zero link delay every mix is fresh: staleness 0 and
    mix_versions[r] == r on every edge."""
    rng = np.random.default_rng(3)
    tg = gossip_task_graph(rng, 8, degree_low=2, degree_high=3)
    a = rng.integers(0, 3, size=8)
    loads = np.zeros(3)
    np.add.at(loads, a, tg.p)
    # speeds == loads: every machine's round takes exactly 1.0, so all
    # round-r computes and their zero-delay deliveries share a timestamp
    cg = ComputeGraph(e=loads, C=np.zeros((3, 3)))
    res = simulate(tg, cg, a, 4, ExecutionSpec(semantics="async"))
    assert res.staleness_mean == 0.0
    assert res.mix_versions is not None
    for r in range(4):
        assert np.all(res.mix_versions[r] == r)


def test_fleet_size_constant_without_churn():
    tg, cg, a = _instance(0)
    for sem in ("sync", "overlap", "async"):
        res = simulate(tg, cg, a, 4, ExecutionSpec(semantics=sem))
        assert list(res.fleet_size) == [cg.num_machines] * 4, sem


def test_failure_and_drift_events_reproduce_elastic_history():
    """Failure + drift composed in one queue drive the SAME ElasticScheduler
    transitions the bespoke loops used to produce."""
    rng = np.random.default_rng(10)
    tg = ring_task_graph(6)
    C = delay_matrix("distance", rng, 4)
    cg = ComputeGraph(e=np.ones(4), C=C)
    drift = drifting_delays(rng, 4, base="distance")
    es = ElasticScheduler(tg, cg, method="greedy")

    def consult(tg_, cg_, r):
        if r == 2:
            es.on_failure(1)
        else:
            es.on_delay_update(drift.at(r))
        return es.current.assignment

    events = (
        ControlEvent(round=2, kind="fail", machine=1),
        ControlEvent(round=4, kind="delay_update", C=drift.at(4)),
        ControlEvent(round=4, kind="reschedule"),
    )
    res = simulate(
        tg, cg, es.current.assignment, 6,
        control_events=events, schedule_fn=consult,
    )
    assert res.reschedule_rounds == [2, 4]
    assert res.machine_ids == [0, 2, 3] == es.machine_ids
    hist = [h["event"] for h in es.history]
    assert hist[:2] == ["init", "fail:1"]
    assert hist[2] in ("migrate", "keep") and len(hist) == 3
    # the engine and the scheduler hold the same post-drift delay view
    np.testing.assert_allclose(
        es.compute_graph.C, drift.at(4)[np.ix_([0, 2, 3], [0, 2, 3])]
    )
    assert np.all(res.assignment < 3)
    assert np.isnan(res.busy[2:, 1]).all()
    assert np.isfinite(res.busy[:2, 1]).all()
    assert np.all(np.diff(res.round_completion) > 0)


def test_busy_feedback_updates_elastic_speed_estimates():
    """Engine-emitted busy times feed observe_round: a persistent
    straggler drags its speed estimate down via the EMA."""
    rng = np.random.default_rng(11)
    tg = gossip_task_graph(rng, 8, degree_low=2, degree_high=3)
    C = rng.uniform(0.1, 0.5, (3, 3))
    np.fill_diagonal(C, 0.0)
    cg = ComputeGraph(e=np.ones(3), C=C)
    # threshold high enough that the run never migrates: the loads stay
    # put, so the EMA sees the same straggler every round
    es = ElasticScheduler(tg, cg, method="greedy", reschedule_threshold=10.0)

    def on_round_end(r, busy):
        out = es.observe_round(busy)
        return None if out is None else out.assignment

    spec = ExecutionSpec(
        straggler_prob=(0.0, 0.0, 1.0), straggler_factor=4.0, seed=2
    )
    simulate(
        tg, cg, es.current.assignment, 5, spec, on_round_end=on_round_end,
    )
    assert es.compute_graph.e[2] < 0.6              # learned the straggler
    assert es.compute_graph.e[0] > 0.9              # healthy machine kept
    assert len(es.history) == 6                     # init + 5 observations


# ---------------------------------------------------------------------------
# scenario integration
# ---------------------------------------------------------------------------


def test_scenario_execution_validation():
    with pytest.raises(ValueError, match="execution semantics"):
        Scenario(name="x", topology="ring", num_tasks=8, execution="psychic")
    with pytest.raises(ValueError, match="execution parameter"):
        Scenario(name="x", topology="ring", num_tasks=8,
                 execution_params={"jitter": 0.1})       # typo
    with pytest.raises(ValueError, match="sync"):
        Scenario(name="x", topology="ring", num_tasks=8,
                 delay_model="drift", execution="async")
    with pytest.raises(ValueError, match="sync"):
        Scenario(name="x", topology="ring", num_tasks=8,
                 execution="overlap", fl=FLWorkload())


def test_run_scenario_records_async_throughput_and_staleness():
    sc = dataclasses.replace(
        get_scenario("ring_async"), schedulers=("heft", "greedy"), rounds=8,
    )
    rec = run_scenario(sc, quick=True)
    assert rec["axes"]["execution"] == "async"
    for m in sc.schedulers:
        entry = rec["methods"][m]
        assert entry["execution"] == "async"
        assert entry["throughput"] > 0
        assert entry["period"] == pytest.approx(1.0 / entry["throughput"])
        assert entry["staleness_mean"] >= 0.0
        assert entry["staleness_max"] >= entry["staleness_mean"]
        assert len(entry["staleness_per_task"]) == sc.num_tasks
        assert len(entry["round_times"]) == sc.rounds
        assert entry["total_time"] > 0


def test_run_scenario_overlap_period_never_above_sync():
    sc = dataclasses.replace(
        get_scenario("smallworld_overlap"),
        schedulers=("heft",), rounds=8, execution_params={},
    )
    rec = run_scenario(sc, quick=True)
    entry = rec["methods"]["heft"]
    assert entry["execution"] == "overlap"
    # pipelining dominates the barrier: cumulative time never above sync
    assert entry["mean_round_time"] <= entry["predicted_bottleneck"] + 1e-12
    assert entry["period"] > 0
    assert "staleness_mean" not in entry            # overlap never stale


def test_timeline_overlap_delegates_to_event_engine():
    from repro.fl.simulator import SimEvent, timeline

    tg, cg, _ = _instance(12)

    def sched(tg_, cg_):
        return schedule(tg_, cg_, "greedy").assignment

    sync_tl = timeline(tg, cg, sched, num_rounds=5)
    over_tl = timeline(tg, cg, sched, num_rounds=5, overlap=True)
    assert np.all(over_tl["cumulative_time"] <= sync_tl["cumulative_time"] + 1e-12)
    with pytest.raises(ValueError, match="overlap"):
        timeline(tg, cg, sched, num_rounds=5, overlap=True,
                 events=[SimEvent(round=2, kind="fail", machine=0)])
