"""Differential kernel harness: fused Pallas ops vs pure-jnp oracles.

Every fused kernel behind the scheduler/FL backend switches is pinned
three ways:

  1. ``assert_kernel_matches_ref`` sweeps shapes (block-ragged sizes,
     B ∈ {1, 8}, k ∈ {1, small, n}), dtypes (f32/bf16), and degenerate
     inputs (zero matrices, rank-1 Y, all-negative spectrum) against the
     oracles in ``repro.kernels.ref``;
  2. seeded end-to-end regressions: ``solve_sdp`` / ``solve_sdp_batch``
     with ``kernel_backend="pallas"`` reproduce the jnp path's iteration
     count and projection decisions exactly and the iterate to f32
     tolerance (mirroring ``tests/test_sdp_batch.py``), and the fused
     rounding with the one-hot bottleneck kernel returns the identical
     assignment;
  3. randomized-shape property tests live in ``tests/test_property.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ComputeGraph,
    SDPOptions,
    TaskGraph,
    build_factored_bqp,
    random_compute_graph,
    random_task_graph,
    randomized_rounding,
    solve_sdp,
    solve_sdp_batch,
)
from repro.kernels import ref as kref
from repro.kernels.bottleneck import bottleneck_eval_fwd
from repro.kernels.compress import int8_roundtrip_fwd, topk_mask_fwd
from repro.kernels.sdp_proj import rank_k_update_fwd, sdp_subspace_fwd

# float32 loop, two lowerings: agreement at a converged iterate is a few
# f32 ulps over n²-sized contractions (same constant as test_sdp_batch)
F32_ATOL = 1e-3

rng = np.random.default_rng(0)


def assert_kernel_matches_ref(kernel_fn, ref_fn, args, *, atol=1e-5,
                              rtol=1e-5, exact=False, kwargs=None):
    """Run kernel and oracle on ``args``; compare every output in f32.

    ``kwargs`` go to the kernel only (block sizes, ``interpret=True``);
    the oracle takes the math inputs alone.  ``exact=True`` demands
    bit-equality (selection/masking kernels have no roundoff freedom).
    """
    got = kernel_fn(*args, **(kwargs or {}))
    want = ref_fn(*args)
    if not isinstance(got, tuple):
        got, want = (got,), (want,)
    assert len(got) == len(want)
    for idx, (g, w) in enumerate(zip(got, want)):
        g = np.asarray(jnp.asarray(g).astype(jnp.float32))
        w = np.asarray(jnp.asarray(w).astype(jnp.float32))
        assert g.shape == w.shape, (idx, g.shape, w.shape)
        assert np.all(np.isfinite(w)), f"oracle output {idx} not finite"
        if exact:
            np.testing.assert_array_equal(g, w, err_msg=f"output {idx}")
        else:
            np.testing.assert_allclose(
                g, w, atol=atol, rtol=rtol, err_msg=f"output {idx}"
            )


def t(shape, dt=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dt)


# ---------------------------------------------------------------------------
# (a) SDP fused subspace projection + rank-k clip
# ---------------------------------------------------------------------------

SDP_SHAPES = [
    # (n, k, block_rows): ragged and aligned blockings, k ∈ {1, small, n}
    (5, 1, 2),
    (8, 3, 3),
    (16, 16, 16),
    (33, 4, 8),
    (7, 7, 256),   # block larger than the matrix
]


@pytest.mark.parametrize("n,k,bn", SDP_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_sdp_subspace_shapes(n, k, bn, dt):
    Y = t((n, n), dt)
    Y = Y + Y.T
    V = jnp.asarray(
        np.linalg.qr(rng.standard_normal((n, k)))[0], dt
    )
    assert_kernel_matches_ref(
        sdp_subspace_fwd, kref.sdp_subspace_ref, (Y, V),
        atol=1e-4 * n, rtol=1e-4,
        kwargs=dict(block_rows=bn, interpret=True),
    )


@pytest.mark.parametrize("n,k,bn", SDP_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_rank_k_update_shapes(n, k, bn, dt):
    Y, A, B = t((n, n), dt), t((n, k), dt), t((n, k), dt)
    atol = 0.05 if dt == jnp.bfloat16 else 1e-5
    assert_kernel_matches_ref(
        rank_k_update_fwd, kref.rank_k_update_ref, (Y, A, B),
        atol=atol, rtol=1e-4,
        kwargs=dict(block_rows=bn, interpret=True),
    )


def _degenerate_Y(kind, n):
    if kind == "zero":
        return jnp.zeros((n, n), jnp.float32)
    if kind == "rank1":
        u = rng.standard_normal(n)
        return jnp.asarray(np.outer(u, u), jnp.float32)
    # all-negative spectrum: -A Aᵀ - I forces every Ritz value negative
    A = rng.standard_normal((n, n))
    return jnp.asarray(-A @ A.T - np.eye(n), jnp.float32)


@pytest.mark.parametrize("kind", ["zero", "rank1", "negative"])
def test_sdp_subspace_degenerate(kind):
    n, k = 12, 3
    Y = _degenerate_Y(kind, n)
    V = jnp.asarray(np.linalg.qr(rng.standard_normal((n, k)))[0],
                    jnp.float32)
    assert_kernel_matches_ref(
        sdp_subspace_fwd, kref.sdp_subspace_ref, (Y, V),
        atol=1e-3, rtol=1e-4,
        kwargs=dict(block_rows=5, interpret=True),
    )
    assert_kernel_matches_ref(
        rank_k_update_fwd, kref.rank_k_update_ref, (Y, V, V),
        atol=1e-4, rtol=1e-4,
        kwargs=dict(block_rows=5, interpret=True),
    )


# ---------------------------------------------------------------------------
# (b) fused delta compression with error feedback
# ---------------------------------------------------------------------------

COMPRESS_SHAPES = [
    # (n_users, L, block_len): ragged tails, B ∈ {1, 8}, single-element L
    (1, 7, 3),
    (8, 100, 64),
    (8, 64, 64),
    (3, 1, 4),
]


@pytest.mark.parametrize("n,l,bl", COMPRESS_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kk", ["one", "small", "all"])
def test_topk_mask_shapes(n, l, bl, dt, kk):
    X = t((n, l), dt)
    kk = {"one": 1, "small": max(1, l // 10), "all": l}[kk]
    vals, _ = jax.lax.top_k(jnp.abs(X.astype(jnp.float32)), kk)
    thresh = vals[:, -1]
    # pure selection: the fused kernel must be bit-equal to the oracle
    assert_kernel_matches_ref(
        topk_mask_fwd, kref.topk_mask_ref, (X, thresh), exact=True,
        kwargs=dict(block_len=bl, interpret=True),
    )


@pytest.mark.parametrize("n,l,bl", COMPRESS_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_int8_roundtrip_shapes(n, l, bl, dt):
    X = t((n, l), dt)
    scale = (
        jnp.maximum(jnp.max(jnp.abs(X.astype(jnp.float32)), axis=1), 1e-12)
        / 127.0
    )
    # msgs bit-equal; the residual may differ by 1 ulp of |x| (FMA
    # contraction of q·scale into the subtraction — see compress.py)
    got = int8_roundtrip_fwd(X, scale, block_len=bl, interpret=True)
    want = kref.int8_roundtrip_ref(X, scale)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    atol = 0.05 if dt == jnp.bfloat16 else 2e-7
    np.testing.assert_allclose(
        np.asarray(got[1], np.float32), np.asarray(want[1], np.float32),
        atol=atol,
    )


def test_compress_degenerate_zero():
    X = jnp.zeros((4, 10), jnp.float32)
    assert_kernel_matches_ref(
        topk_mask_fwd, kref.topk_mask_ref, (X, jnp.zeros(4)), exact=True,
        kwargs=dict(block_len=4, interpret=True),
    )
    assert_kernel_matches_ref(
        int8_roundtrip_fwd, kref.int8_roundtrip_ref,
        (X, jnp.full(4, 1e-12 / 127.0)), exact=True,
        kwargs=dict(block_len=4, interpret=True),
    )


# ---------------------------------------------------------------------------
# (c) batched bottleneck evaluation (Eq. 2)
# ---------------------------------------------------------------------------


def _bottleneck_args(s, n_t, n_k, n_edges, seed=0):
    r = np.random.default_rng(seed)
    a = r.integers(0, n_k, size=(s, n_t))
    oh = jax.nn.one_hot(jnp.asarray(a), n_k, dtype=jnp.float32)
    p = jnp.asarray(r.uniform(0.1, 5.0, n_t), jnp.float32)
    e = jnp.asarray(r.uniform(0.5, 4.0, n_k), jnp.float32)
    C = jnp.asarray(r.uniform(0.0, 3.0, (n_k, n_k)), jnp.float32)
    if n_edges:
        src = jnp.asarray(r.integers(0, n_t, n_edges))
        dst = jnp.asarray(r.integers(0, n_t, n_edges))
        s_oh = jax.nn.one_hot(src, n_t, dtype=jnp.float32)
        d_oh = jax.nn.one_hot(dst, n_t, dtype=jnp.float32)
    else:
        s_oh = d_oh = jnp.zeros((0, n_t), jnp.float32)
    return (oh, p, e, C, s_oh, d_oh)


BOTTLENECK_SHAPES = [
    # (samples, tasks, machines, edges, block_samples)
    (1, 3, 2, 4, 1),
    (8, 7, 4, 14, 3),     # ragged sample padding
    (8, 5, 1, 10, 8),     # single machine: comm delays all C[0,0]=0
    (8, 6, 3, 0, 4),      # edge-free task graph (E = 0)
]


@pytest.mark.parametrize("s,n_t,n_k,n_e,bs", BOTTLENECK_SHAPES)
def test_bottleneck_eval_shapes(s, n_t, n_k, n_e, bs):
    args = _bottleneck_args(s, n_t, n_k, n_e)
    assert_kernel_matches_ref(
        bottleneck_eval_fwd, kref.bottleneck_eval_ref, args,
        atol=1e-5, rtol=1e-5,
        kwargs=dict(block_samples=bs, interpret=True),
    )


# ---------------------------------------------------------------------------
# Seeded end-to-end regressions: kernels on == kernels off
# ---------------------------------------------------------------------------

jax_backend = pytest.importorskip("jax")

# converging settings on a size where the partial-spectrum (kernel) path
# carries most iterations
E2E_OPTS = dict(max_iters=3000, check_every=50, tol=1e-4, backend="jax")


@pytest.fixture(scope="module")
def sdp_instance():
    r = np.random.default_rng(7)
    tg = random_task_graph(r, 12, degree_low=2, degree_high=4)
    cg = random_compute_graph(r, 4)
    return tg, cg


@pytest.fixture(scope="module")
def e2e_solutions(sdp_instance):
    tg, cg = sdp_instance
    bqp = build_factored_bqp(tg, cg)
    return bqp, {
        kb: solve_sdp(bqp, SDPOptions(**E2E_OPTS, kernel_backend=kb))
        for kb in ("jnp", "pallas")
    }


def test_solve_sdp_kernel_backend_regression(e2e_solutions):
    """Fused projection on/off: identical trajectory, same iterate."""
    _, sols = e2e_solutions
    a, b = sols["jnp"], sols["pallas"]
    assert a.converged and b.converged
    assert a.iterations == b.iterations
    assert a.stats["eig_full"] == b.stats["eig_full"]
    assert a.stats["eig_partial"] == b.stats["eig_partial"]
    # the partial (kernel) path must actually carry iterations, else this
    # test pins nothing
    assert a.stats["eig_partial"] > 0
    np.testing.assert_allclose(b.Y, a.Y, atol=F32_ATOL)
    assert np.isclose(b.residual, a.residual, atol=F32_ATOL)


def test_solve_sdp_batch_kernel_backend_regression(sdp_instance):
    """Batched lanes inherit the same on/off equivalence, lane by lane."""
    tg, _ = sdp_instance
    cgs = [random_compute_graph(np.random.default_rng(100 + i), 4)
           for i in range(2)]
    bqps = [build_factored_bqp(tg, cg) for cg in cgs]
    sols = {
        kb: solve_sdp_batch(bqps, SDPOptions(**E2E_OPTS, kernel_backend=kb))
        for kb in ("jnp", "pallas")
    }
    for a, b in zip(sols["jnp"], sols["pallas"]):
        assert a.iterations == b.iterations
        assert a.stats["eig_full"] == b.stats["eig_full"]
        assert a.stats["eig_partial"] == b.stats["eig_partial"]
        np.testing.assert_allclose(b.Y, a.Y, atol=F32_ATOL)


def test_rounding_kernel_backend_parity(e2e_solutions, sdp_instance):
    """The one-hot bottleneck kernel scores every sample like the gather
    path: identical argmin assignment and feasibility count."""
    tg, cg = sdp_instance
    bqp, sols = e2e_solutions
    sol = sols["jnp"]
    results = {
        kb: randomized_rounding(
            bqp, tg, cg, sol.Y, num_samples=256,
            rng=np.random.default_rng(0), backend="jax",
            Y_device=sol.Y_device, kernel_backend=kb,
        )
        for kb in ("jnp", "pallas")
    }
    a, b = results["jnp"], results["pallas"]
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert np.isclose(a.bottleneck, b.bottleneck, rtol=1e-6)
    assert a.num_feasible == b.num_feasible


def test_rounding_kernel_backend_parity_edge_free():
    """E = 0 lane: the kernel's inert padded edge row changes nothing."""
    r = np.random.default_rng(3)
    tg = TaskGraph(p=r.uniform(0.5, 3.0, 6), edges=())
    cg = random_compute_graph(r, 3)
    bqp = build_factored_bqp(tg, cg)
    sol = solve_sdp(bqp, SDPOptions(max_iters=1500, check_every=50,
                                    tol=1e-4, backend="jax"))
    results = {
        kb: randomized_rounding(
            bqp, tg, cg, sol.Y, num_samples=128,
            rng=np.random.default_rng(0), backend="jax",
            Y_device=sol.Y_device, kernel_backend=kb,
        )
        for kb in ("jnp", "pallas")
    }
    a, b = results["jnp"], results["pallas"]
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert np.isclose(a.bottleneck, b.bottleneck, rtol=1e-6)


def test_kernel_backend_rejects_unknown(sdp_instance):
    tg, cg = sdp_instance
    bqp = build_factored_bqp(tg, cg)
    with pytest.raises(ValueError, match="kernel.?backend"):
        solve_sdp(bqp, SDPOptions(**E2E_OPTS, kernel_backend="cuda"))
    with pytest.raises(ValueError, match="kernel.?backend"):
        randomized_rounding(
            bqp, tg, cg,
            np.eye(tg.num_tasks * cg.num_machines + 1),
            num_samples=8, rng=np.random.default_rng(0), backend="jax",
            kernel_backend="cuda",
        )
