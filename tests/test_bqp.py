"""BQP formulation: Q matrices must agree with the direct evaluator."""

import numpy as np
import pytest

from repro.core import (
    ComputeGraph,
    TaskGraph,
    bottleneck_time,
    bottleneck_time_batch,
    brute_force_optimum,
    build_bqp,
    random_compute_graph,
    random_task_graph,
)
from repro.core.bqp import (
    assignment_to_vec,
    quadratic_bottleneck,
    task_times,
    vec_to_assignment,
)


@pytest.fixture
def instance():
    rng = np.random.default_rng(7)
    tg = random_task_graph(rng, 7, degree_low=1, degree_high=3)
    cg = random_compute_graph(rng, 3)
    return tg, cg


def test_quadratic_matches_direct(instance):
    tg, cg = instance
    data = build_bqp(tg, cg)
    rng = np.random.default_rng(0)
    for _ in range(20):
        a = rng.integers(0, cg.num_machines, size=tg.num_tasks)
        m = assignment_to_vec(a, cg.num_machines)
        tc, _ = task_times(tg, cg, a)
        direct = max(tc[i] + cg.C[a[i], a[j]] for (i, j) in data.edges)
        assert np.isclose(quadratic_bottleneck(data, m), direct)


def test_homogenized_identity(instance):
    """(1/4)·x̃ᵀQ̃x̃ == mᵀQm for every feasible assignment (Eq. 16/19)."""
    tg, cg = instance
    data = build_bqp(tg, cg)
    rng = np.random.default_rng(1)
    for _ in range(10):
        a = rng.integers(0, cg.num_machines, size=tg.num_tasks)
        m = assignment_to_vec(a, cg.num_machines)
        xt = np.concatenate([2 * m - 1, [1.0]])
        for k in range(len(data.edges)):
            v1 = m @ data.Q[k] @ m
            v2 = 0.25 * xt @ data.Q_tilde[k] @ xt
            assert np.isclose(v1, v2), (k, v1, v2)


def test_assignment_constraints_hold(instance):
    tg, cg = instance
    data = build_bqp(tg, cg)
    a = np.zeros(tg.num_tasks, dtype=np.int64)
    m = assignment_to_vec(a, cg.num_machines)
    xt = np.concatenate([2 * m - 1, [1.0]])
    X = np.outer(xt, xt)
    for i in range(tg.num_tasks):
        assert abs(np.sum(data.A[i] * X)) < 1e-9


def test_batch_evaluator_matches_scalar(instance):
    tg, cg = instance
    rng = np.random.default_rng(3)
    batch = rng.integers(0, cg.num_machines, size=(32, tg.num_tasks))
    times = bottleneck_time_batch(tg, cg, batch)
    for i in range(32):
        assert np.isclose(times[i], bottleneck_time(tg, cg, batch[i]))


def test_vec_roundtrip(instance):
    tg, cg = instance
    a = np.array([0, 1, 2, 0, 1, 2, 1])
    m = assignment_to_vec(a, cg.num_machines)
    assert np.array_equal(vec_to_assignment(m, tg.num_tasks, cg.num_machines), a)


def test_sink_tasks_still_constrained():
    """A task with no successors must still bound the bottleneck (Eq. 7)."""
    tg = TaskGraph(p=np.array([10.0, 0.1]), edges=((1, 0),))
    cg = ComputeGraph(e=np.array([1.0, 1.0]), C=np.zeros((2, 2)))
    # task 0 (heavy) has no outgoing edge; bottleneck must still see it
    t = bottleneck_time(tg, cg, np.array([0, 1]))
    assert t >= 10.0
    data = build_bqp(tg, cg)
    assert any(i == 0 for (i, _) in data.edges)


def test_brute_force_is_minimum(instance):
    tg, cg = instance
    a_star, t_star = brute_force_optimum(tg, cg)
    rng = np.random.default_rng(5)
    rand = rng.integers(0, cg.num_machines, size=(200, tg.num_tasks))
    assert np.all(bottleneck_time_batch(tg, cg, rand) >= t_star - 1e-12)
