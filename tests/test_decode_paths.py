"""Decode-path edge cases: sliding-window ring buffer, long-position RoPE,
multi-step consistency between prefill-style forward and decode steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model


def test_sliding_window_ring_buffer_wraps():
    """Mixtral-style SWA decode: positions beyond the window must wrap the
    ring buffer and stay finite (the long_500k regime)."""
    cfg = get_smoke_config("mixtral-8x7b")     # window=64
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    b = 2
    cache = api.init_cache(b, 256)             # ring size = min(256, 64) = 64
    k_shape = jax.tree.leaves(cache["groups"])[0].shape
    step = jax.jit(lambda p, c, bt: api.decode_step(p, c, bt))
    logits_at = {}
    for pos in (0, 1, 63, 64, 65, 130):        # crosses the wrap twice
        batch = {"tokens": jnp.full((b,), 7, jnp.int32),
                 "pos": jnp.full((b,), pos, jnp.int32)}
        logits, cache = step(params, cache, batch)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), pos
        logits_at[pos] = np.asarray(logits, np.float32)
    # cache never grew beyond the window
    assert jax.tree.leaves(cache["groups"])[0].shape == k_shape


def test_decode_matches_forward_next_token():
    """Greedy next-token from decode steps == argmax of teacher-forced
    forward logits at the same position (cache correctness)."""
    cfg = get_smoke_config("granite-3-2b").replace(dtype=jnp.float32)
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(1))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    full_logits = api.forward(params, {"tokens": tokens})

    cache = api.init_cache(b, s)
    step = jax.jit(lambda p, c, bt: api.decode_step(p, c, bt))
    for pos in range(s):
        batch = {"tokens": tokens[:, pos], "pos": jnp.full((b,), pos, jnp.int32)}
        dec_logits, cache = step(params, cache, batch)
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(full_logits[:, pos], np.float32),
            atol=2e-3, rtol=2e-3,
        )


def test_long_position_rope_stable():
    """RoPE at position ~500k stays finite (long_500k decode regime)."""
    cfg = get_smoke_config("mamba2-1.3b")
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cache = api.init_cache(1, 128)
    batch = {"tokens": jnp.zeros((1,), jnp.int32),
             "pos": jnp.full((1,), 524_287, jnp.int32)}
    logits, _ = jax.jit(lambda p, c, bt: api.decode_step(p, c, bt))(
        params, cache, batch
    )
    assert np.isfinite(np.asarray(logits, np.float32)).all()
