"""Gossip FL engine: learning progress, aggregation, elastic scheduling."""

import numpy as np

from repro.core.graphs import ComputeGraph, TaskGraph, gossip_task_graph
from repro.data.synthetic import image_dataset
from repro.fl.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.fl.gossip import GossipConfig, GossipTrainer
from repro.fl.simulator import SimEvent, round_time, timeline
from repro.launch.elastic import ElasticScheduler
from repro.train.compression import TopK


def _mini_trainer(n_users=4, compressor=None, seed=0):
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n_users, degree_low=2, degree_high=3)
    train, test = image_dataset("mnist", 512, seed=seed)
    shards = train.split(n_users, rng)
    cfg = GossipConfig(local_steps=2, batch_size=32, lr=0.05,
                       compressor=compressor)
    trainer = GossipTrainer(
        tg, lambda k: init_cnn_params(k, (28, 28, 1), 10), cnn_loss,
        shards, cfg, seed=seed,
    )
    return trainer, tg, test


def test_gossip_loss_decreases():
    trainer, _, test = _mini_trainer()
    first = trainer.step_round()["mean_loss"]
    for _ in range(5):
        info = trainer.step_round()
    assert info["mean_loss"] < first, (first, info)
    acc = cnn_accuracy(trainer.params[0], test.x, test.y)
    assert acc > 0.15   # well above 10% chance


def test_gossip_aggregation_mixes_models():
    trainer, tg, _ = _mini_trainer()
    trainer.step_round()
    # after a round, any two users connected by an edge share information:
    # check params are not identical but also not independent (finite)
    p0 = np.concatenate([np.ravel(x) for x in
                         np.asarray(trainer.params[0]["fc3"]["w"])[None]])
    p1 = np.concatenate([np.ravel(x) for x in
                         np.asarray(trainer.params[1]["fc3"]["w"])[None]])
    assert np.isfinite(p0).all() and np.isfinite(p1).all()
    assert not np.allclose(p0, p1)


def test_gossip_with_compression_still_learns():
    trainer, _, _ = _mini_trainer(compressor=TopK(fraction=0.2))
    first = trainer.step_round()["mean_loss"]
    for _ in range(5):
        info = trainer.step_round()
    assert info["mean_loss"] < first * 1.05


def test_round_time_overlap_never_worse():
    rng = np.random.default_rng(3)
    tg = gossip_task_graph(rng, 6, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (3, 3))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(3), C=C)
    a = rng.integers(0, 3, size=6)
    assert round_time(tg, cg, a, overlap=True) <= round_time(tg, cg, a) + 1e-12


def test_timeline_reschedules_on_failure():
    rng = np.random.default_rng(4)
    tg = gossip_task_graph(rng, 6, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (4, 4))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(4), C=C)

    from repro.core.scheduler import schedule

    def sched(tg_, cg_):
        return schedule(tg_, cg_, "greedy").assignment

    out = timeline(
        tg, cg, sched, num_rounds=6,
        events=[SimEvent(round=3, kind="fail", machine=1)],
    )
    assert out["reschedule_rounds"] == [3]
    assert out["final_machines"] == [0, 2, 3]
    assert np.all((0 <= out["final_assignment"]) & (out["final_assignment"] < 3))
    assert np.all(np.diff(out["cumulative_time"]) > 0)


def test_elastic_failure_and_straggler():
    rng = np.random.default_rng(5)
    tg = gossip_task_graph(rng, 8, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (4, 4))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(4), C=C)
    es = ElasticScheduler(tg, cg, method="greedy")
    t0 = es.current.bottleneck
    es.on_failure(2)
    assert es.compute_graph.num_machines == 3
    assert np.all(es.current.assignment < 3)
    # simulate a severe straggler on machine 0: observed time 10x predicted
    loads = np.zeros(3)
    np.add.at(loads, es.current.assignment, tg.p)
    times = loads / es.compute_graph.e
    times[0] *= 10
    es.observe_round(times)
    assert es.compute_graph.e[0] < 1.0        # EMA pulled the speed down
    assert es.history[-1]["event"] in ("migrate", "keep")
