"""Gossip FL engine: learning progress, aggregation, elastic scheduling,
and stacked-vs-reference backend equivalence."""

import jax
import numpy as np
import pytest

from repro.core.graphs import ComputeGraph, TaskGraph, gossip_task_graph
from repro.data.synthetic import image_dataset
from repro.fl.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.fl.gossip import GossipConfig, GossipTrainer, mixing_arrays
from repro.fl.pilot import stacked_task_work
from repro.fl.simulator import SimEvent, round_time, timeline
from repro.launch.elastic import ElasticScheduler
from repro.train.compression import Int8, TopK


def _mini_trainer(n_users=4, compressor=None, seed=0, backend="auto"):
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n_users, degree_low=2, degree_high=3)
    train, test = image_dataset("mnist", 512, seed=seed)
    shards = train.split(n_users, rng)
    cfg = GossipConfig(local_steps=2, batch_size=32, lr=0.05,
                       compressor=compressor, backend=backend)
    trainer = GossipTrainer(
        tg, lambda k: init_cnn_params(k, (28, 28, 1), 10), cnn_loss,
        shards, cfg, seed=seed,
    )
    return trainer, tg, test


def test_gossip_loss_decreases():
    trainer, _, test = _mini_trainer()
    first = trainer.step_round()["mean_loss"]
    for _ in range(5):
        info = trainer.step_round()
    assert info["mean_loss"] < first, (first, info)
    acc = cnn_accuracy(trainer.params[0], test.x, test.y)
    assert acc > 0.15   # well above 10% chance


def test_gossip_aggregation_mixes_models():
    trainer, tg, _ = _mini_trainer()
    trainer.step_round()
    # after a round, any two users connected by an edge share information:
    # check params are not identical but also not independent (finite)
    p0 = np.concatenate([np.ravel(x) for x in
                         np.asarray(trainer.params[0]["fc3"]["w"])[None]])
    p1 = np.concatenate([np.ravel(x) for x in
                         np.asarray(trainer.params[1]["fc3"]["w"])[None]])
    assert np.isfinite(p0).all() and np.isfinite(p1).all()
    assert not np.allclose(p0, p1)


def test_gossip_with_compression_still_learns():
    trainer, _, _ = _mini_trainer(compressor=TopK(fraction=0.2))
    first = trainer.step_round()["mean_loss"]
    for _ in range(5):
        info = trainer.step_round()
    assert info["mean_loss"] < first * 1.05


def test_round_time_overlap_never_worse():
    rng = np.random.default_rng(3)
    tg = gossip_task_graph(rng, 6, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (3, 3))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(3), C=C)
    a = rng.integers(0, 3, size=6)
    assert round_time(tg, cg, a, overlap=True) <= round_time(tg, cg, a) + 1e-12


def test_timeline_reschedules_on_failure():
    rng = np.random.default_rng(4)
    tg = gossip_task_graph(rng, 6, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (4, 4))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(4), C=C)

    from repro.core.scheduler import schedule

    def sched(tg_, cg_):
        return schedule(tg_, cg_, "greedy").assignment

    out = timeline(
        tg, cg, sched, num_rounds=6,
        events=[SimEvent(round=3, kind="fail", machine=1)],
    )
    assert out["reschedule_rounds"] == [3]
    assert out["final_machines"] == [0, 2, 3]
    assert np.all((0 <= out["final_assignment"]) & (out["final_assignment"] < 3))
    assert np.all(np.diff(out["cumulative_time"]) > 0)


def test_elastic_failure_and_straggler():
    rng = np.random.default_rng(5)
    tg = gossip_task_graph(rng, 8, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (4, 4))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(4), C=C)
    es = ElasticScheduler(tg, cg, method="greedy")
    t0 = es.current.bottleneck
    es.on_failure(2)
    assert es.compute_graph.num_machines == 3
    assert np.all(es.current.assignment < 3)
    # simulate a severe straggler on machine 0: observed time 10x predicted
    loads = np.zeros(3)
    np.add.at(loads, es.current.assignment, tg.p)
    times = loads / es.compute_graph.e
    times[0] *= 10
    es.observe_round(times)
    assert es.compute_graph.e[0] < 1.0        # EMA pulled the speed down
    assert es.history[-1]["event"] in ("migrate", "keep")


def test_observe_round_clamps_absurd_implied_speeds():
    """A loaded machine reporting ~zero time must not poison the speed
    EMA with a loads/1e-12 spike: implied speeds are clamped to within
    ``speed_clamp``x of the current estimate (regression)."""
    rng = np.random.default_rng(6)
    tg = gossip_task_graph(rng, 8, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (3, 3))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(3), C=C)
    es = ElasticScheduler(tg, cg, method="greedy")
    j = int(es.current.assignment[0])          # a machine that has load
    loads = np.zeros(3)
    np.add.at(loads, es.current.assignment, tg.p)
    times = loads / es.compute_graph.e
    times[j] = 1e-15                           # absurd measurement
    es.observe_round(times)
    # EMA step capped at alpha * clamp: 0.7 * 1 + 0.3 * 10, not ~1e14
    assert es.compute_graph.e[j] <= 1.0 * (0.7 + 0.3 * es.speed_clamp) + 1e-9
    # symmetric clamp: an absurdly slow measurement cannot crater it
    times = loads / es.compute_graph.e
    times[j] = 1e15
    e_before = es.compute_graph.e[j]
    es.observe_round(times)
    assert es.compute_graph.e[j] >= e_before * (0.7 + 0.3 / es.speed_clamp) - 1e-9


# ---------------------------------------------------------------------------
# Stacked backend: equivalence with the per-user reference engine
# ---------------------------------------------------------------------------


def _paired_trainers(compressor=None, n_users=10, seed=0, num_samples=640,
                     mix_backend="auto", backends=("reference", "stacked")):
    """Trainers per requested backend over identical graph/data/seed."""
    out = []
    for backend in backends:
        rng = np.random.default_rng(seed)
        tg = gossip_task_graph(rng, n_users, degree_low=3, degree_high=4)
        train, _ = image_dataset("mnist", num_samples, seed=seed)
        shards = train.split(n_users, rng)
        cfg = GossipConfig(local_steps=2, batch_size=16,
                           compressor=compressor, backend=backend,
                           mix_backend=mix_backend)
        out.append(GossipTrainer(
            tg, lambda k: init_cnn_params(k, (28, 28, 1), 10), cnn_loss,
            shards, cfg, seed=seed,
        ))
    return out


def _max_param_diff(ta, tb):
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for i in range(ta.n)
        for x, y in zip(jax.tree.leaves(ta.user_params(i)),
                        jax.tree.leaves(tb.user_params(i)))
    )


@pytest.mark.parametrize(
    "compressor,loss_tol,param_tol",
    [(None, 1e-5, 1e-4), (TopK(fraction=0.2), 1e-5, 1e-4),
     (Int8(), 1e-3, 5e-3)],
    ids=["none", "topk", "int8"],
)
def test_stacked_matches_reference(compressor, loss_tol, param_tol):
    """Same seed -> same per-round mean loss (fp32 tolerance), 3 rounds
    spanning an epoch-wrap reshuffle; params stay aligned per user.
    (Int8 gets looser tolerances: fp32 reassociation moves values across
    quantization-bucket edges.)"""
    ta, tb = _paired_trainers(compressor)
    for _ in range(3):
        la = ta.step_round()["mean_loss"]
        lb = tb.step_round()["mean_loss"]
        np.testing.assert_allclose(la, lb, rtol=loss_tol, atol=loss_tol)
    assert _max_param_diff(ta, tb) < param_tol


def test_stacked_round_is_single_dispatch():
    (tb,) = _paired_trainers(n_users=4, num_samples=256,
                             backends=("stacked",))
    for _ in range(2):
        tb.step_round()
    assert tb.backend == "stacked"
    assert tb.last_round_dispatches == 1
    if hasattr(tb._round_jit, "_cache_size"):
        assert tb._round_jit._cache_size() == 1   # never retraced


def test_backends_do_not_mutate_caller_shards():
    """Epoch reshuffle must permute indices, not caller-owned buffers."""
    for backend in ("reference", "stacked"):
        rng = np.random.default_rng(3)
        tg = gossip_task_graph(rng, 4, degree_low=2, degree_high=3)
        train, _ = image_dataset("mnist", 256, seed=3)
        shards = train.split(4, rng)
        before = [(s.x.copy(), s.y.copy()) for s in shards]
        cfg = GossipConfig(local_steps=4, batch_size=32, backend=backend)
        tr = GossipTrainer(
            tg, lambda k: init_cnn_params(k, (28, 28, 1), 10), cnn_loss,
            shards, cfg, seed=3,
        )
        for _ in range(2):                       # crosses an epoch boundary
            tr.step_round()
        for s, (x0, y0) in zip(shards, before):
            np.testing.assert_array_equal(s.x, x0)
            np.testing.assert_array_equal(s.y, y0)


def test_mixing_arrays_isolated_and_zero_indegree_users():
    # user 0: no incoming edges (keeps its model); user 3: one incoming
    tg = TaskGraph(p=np.ones(4), edges=((0, 1), (0, 2), (1, 2), (2, 3)))
    self_w, src, dst, w_edge, W = mixing_arrays(tg, 0.5)
    np.testing.assert_allclose(self_w, [1.0, 0.5, 0.5, 0.5])
    assert np.all(W[0] == 0.0)                    # isolated receiver row
    np.testing.assert_allclose(W[2], [0.25, 0.25, 0.0, 0.0])
    np.testing.assert_allclose(W[3], [0.0, 0.0, 0.5, 0.0])
    np.testing.assert_allclose(W.sum(axis=1) + self_w, np.ones(4))
    # duplicate edges accumulate (TaskGraph does not dedupe): row stays
    # normalized and matches the per-edge multiplicity counting
    tg_dup = TaskGraph(p=np.ones(2), edges=((0, 1), (0, 1)))
    self_w2, _, _, _, W2 = mixing_arrays(tg_dup, 0.5)
    np.testing.assert_allclose(W2.sum(axis=1) + self_w2, np.ones(2))


def test_stacked_isolated_user_matches_reference():
    """Zero-in-degree users keep their locally-trained model on both
    backends (the stacked engine's W row is empty, self weight 1)."""
    edges = ((0, 1), (0, 2), (1, 2), (2, 3), (3, 1))   # user 0 isolated
    out = []
    for backend in ("reference", "stacked"):
        rng = np.random.default_rng(5)
        tg = TaskGraph(p=np.ones(4), edges=edges)
        train, _ = image_dataset("mnist", 256, seed=5)
        shards = train.split(4, rng)
        cfg = GossipConfig(local_steps=2, batch_size=16, backend=backend)
        tr = GossipTrainer(
            tg, lambda k: init_cnn_params(k, (28, 28, 1), 10), cnn_loss,
            shards, cfg, seed=5,
        )
        tr.step_round()
        out.append(tr)
    ta, tb = out
    assert _max_param_diff(ta, tb) < 1e-5


def test_stacked_pallas_mix_matches_segment_sum():
    (ta,) = _paired_trainers(n_users=5, num_samples=320,
                             mix_backend="segment_sum", backends=("stacked",))
    (tb,) = _paired_trainers(n_users=5, num_samples=320,
                             mix_backend="pallas", backends=("stacked",))
    for _ in range(2):
        la = ta.step_round()["mean_loss"]
        lb = tb.step_round()["mean_loss"]
        np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-5)
    assert _max_param_diff(ta, tb) < 2e-5


def test_compression_roundtrip_matches_compress_decompress():
    rng = np.random.default_rng(11)
    tree = {"a": np.asarray(rng.standard_normal((64,)), np.float32),
            "b": np.asarray(rng.standard_normal((8, 12)), np.float32)}
    for comp in (TopK(fraction=0.25), Int8()):
        via_pair = comp.decompress(comp.compress(tree)[0])
        via_rt = comp.roundtrip(tree)
        for x, y in zip(jax.tree.leaves(via_pair), jax.tree.leaves(via_rt)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)
        # error-feedback identity: residual == delta - roundtrip(delta)
        _, resid = comp.compress(tree)
        for r, d, m in zip(jax.tree.leaves(resid), jax.tree.leaves(tree),
                           jax.tree.leaves(via_rt)):
            np.testing.assert_allclose(np.asarray(r),
                                       np.asarray(d) - np.asarray(m),
                                       atol=1e-6)


def test_stacked_task_work_apportions_by_shard_size():
    p = stacked_task_work(2.0, [10, 10, 20], reference_speed=1.0)
    np.testing.assert_allclose(p, [0.5, 0.5, 1.0])
    with pytest.raises(ValueError):
        stacked_task_work(1.0, [4, 0])
