"""MoE dispatch correctness: capacity semantics, expert partitioning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.moe import (
    _moe_core_local,
    _moe_ffn_gspmd,
    init_moe_params,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmoe-1b-7b")   # 8 experts, top-2 (smoke)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model),
                          jnp.float32)
    return cfg, params, x


def test_core_local_full_range_matches_gspmd(setup):
    cfg, params, x = setup
    o1, a1 = _moe_ffn_gspmd(params, x, cfg, None)
    o2, a2 = _moe_core_local(params, x, cfg, 0, cfg.num_experts)
    np.testing.assert_allclose(o1, o2, atol=1e-6)
    np.testing.assert_allclose(a1, a2, atol=1e-6)


def test_expert_partition_sums_to_full(setup):
    """Σ over expert ranges == full computation — guards the trash-slot
    bug where dropped choices clobbered expert 0 / position 0."""
    cfg, params, x = setup
    o1, _ = _moe_ffn_gspmd(params, x, cfg, None)

    def sl(lo, hi):
        return {
            "router": params["router"],
            "w_gate": params["w_gate"][lo:hi],
            "w_up": params["w_up"][lo:hi],
            "w_down": params["w_down"][lo:hi],
        }

    e = cfg.num_experts
    for parts in (2, 4):
        span = e // parts
        total = sum(
            _moe_core_local(sl(i * span, (i + 1) * span), x, cfg,
                            i * span, span)[0]
            for i in range(parts)
        )
        np.testing.assert_allclose(o1, total, atol=1e-5)


def test_capacity_drops_tokens(setup):
    """With tiny capacity some tokens are dropped; outputs stay finite and
    dropped tokens produce zero output."""
    cfg, params, x = setup
    tiny = cfg.replace(capacity_factor=0.05)
    o, aux = _moe_ffn_gspmd(params, x, tiny, None)
    assert np.isfinite(np.asarray(o)).all()
    # some (but not all) rows are exactly zero
    row_norm = np.asarray(jnp.sum(jnp.abs(o), axis=-1))
    assert (row_norm == 0).any()
    assert (row_norm > 0).any()


def test_moe_grads_flow(setup):
    cfg, params, x = setup

    def loss(p):
        o, a = _moe_ffn_gspmd(p, x, cfg, None)
        return jnp.sum(o * o) + a

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        s = float(jnp.sum(jnp.abs(g[name])))
        assert np.isfinite(s) and s > 0, name
