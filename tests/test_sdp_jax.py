"""Device-resident SDP backend: numpy/jax equivalence, warm starts, bounds.

The jax backend runs the whole Douglas-Rachford loop in one jit (float32,
partial-spectrum cone projection), so the contract with the float64 numpy
reference is agreement to float32 tolerance on the final iterate — pinned
here on a scheduling instance (both constraint-operator kinds) and on a
MAXCUT-style SDP, plus the warm-start contract: a perturbed re-solve
converges in strictly fewer iterations than a cold start.
"""

import numpy as np
import pytest

from repro.core import (
    ComputeGraph,
    SDPOptions,
    build_bqp,
    build_factored_bqp,
    random_compute_graph,
    random_task_graph,
    schedule,
    solve_sdp,
)
from repro.core import scheduler as scheduler_mod

jax = pytest.importorskip("jax")

# float32 loop + float64 reference: agreement at steady state is a few
# ulps of float32 accumulated over hundreds of n²-sized contractions.
F32_ATOL = 1e-3


@pytest.fixture(scope="module")
def instance():
    rng = np.random.default_rng(42)
    tg = random_task_graph(rng, 6, degree_low=1, degree_high=3)
    cg = random_compute_graph(rng, 3)
    return tg, cg


def test_jax_matches_numpy_dense(instance):
    """Same instance, same options: csr-kind device loop == numpy."""
    tg, cg = instance
    data = build_bqp(tg, cg)
    opts = dict(max_iters=800, tol=0.0, check_every=25)  # fixed iterations
    sol_n = solve_sdp(data, SDPOptions(backend="numpy", **opts))
    sol_j = solve_sdp(data, SDPOptions(backend="jax", **opts))
    assert sol_n.stats["solver_backend"] == "numpy"
    assert sol_j.stats["solver_backend"] == "jax"
    assert sol_j.stats["constraint_kind"] == "csr"
    assert sol_j.iterations == sol_n.iterations
    np.testing.assert_allclose(sol_j.Y, sol_n.Y, atol=F32_ATOL)
    assert np.isclose(sol_j.t, sol_n.t, atol=F32_ATOL)
    assert np.isclose(sol_j.residual, sol_n.residual, atol=F32_ATOL)


def test_jax_matches_numpy_factored(instance):
    """The structured (Kronecker-factor) device operators == numpy."""
    tg, cg = instance
    data = build_factored_bqp(tg, cg)
    opts = dict(max_iters=800, tol=0.0, check_every=25)
    sol_n = solve_sdp(data, SDPOptions(backend="numpy", **opts))
    sol_j = solve_sdp(data, SDPOptions(backend="jax", **opts))
    assert sol_j.stats["constraint_kind"] == "factored"
    np.testing.assert_allclose(sol_j.Y, sol_n.Y, atol=F32_ATOL)
    assert np.isclose(sol_j.t, sol_n.t, atol=F32_ATOL)
    # device-resident normalized Y matches the host extraction
    assert sol_j.Y_device is not None
    np.testing.assert_allclose(
        np.asarray(sol_j.Y_device, dtype=np.float64), sol_j.Y, atol=F32_ATOL
    )


class _MaxCutSDP:
    """Duck-typed generic SDP: min t s.t. <-L, Y> - 4t + s = 0, diag = 1.

    At the optimum s = 0 and t = -max <L, Y>/4 — the (negated) MAXCUT SDP
    value — exercising the solver away from the scheduling constraint
    structure (no A rows, a single dense constraint edge).
    """

    def __init__(self, W: np.ndarray):
        n = W.shape[0]
        lap = np.diag(W.sum(axis=1)) - W
        Qt = np.zeros((1, n + 1, n + 1))
        Qt[0, :n, :n] = -lap
        self.n = n
        self.n_tasks = 0
        self.n_machines = 0
        self.edges = ((0, 0),)
        self.Q_tilde = Qt
        self.A = np.zeros((0, n + 1, n + 1))
        self.q_scale = float(np.abs(Qt).max()) or 1.0


def test_jax_matches_numpy_maxcut():
    rng = np.random.default_rng(7)
    W = rng.uniform(0.0, 1.0, size=(8, 8))
    W = np.triu(W, 1)
    W = W + W.T
    prob = _MaxCutSDP(W)
    opts = dict(max_iters=600, tol=0.0, check_every=25)
    sol_n = solve_sdp(prob, SDPOptions(backend="numpy", **opts))
    sol_j = solve_sdp(prob, SDPOptions(backend="jax", **opts))
    np.testing.assert_allclose(sol_j.Y, sol_n.Y, atol=F32_ATOL)
    assert np.isclose(sol_j.t, sol_n.t, atol=F32_ATOL)
    # sanity: the relaxation found a genuinely cut-like Y (t < 0 after
    # normalization means <L, Y> > 0)
    assert sol_n.t < 0.0


def test_warm_start_converges_faster(instance):
    """Perturbed re-solve from the cached state beats a cold start."""
    tg, cg = instance
    opts = SDPOptions(max_iters=4000, tol=2e-5, backend="numpy")
    data = build_bqp(tg, cg)
    cold = solve_sdp(data, opts)
    assert cold.converged

    # incremental topology change: one machine slows down by 10%
    e2 = cg.e.copy()
    e2[0] *= 0.9
    cg2 = ComputeGraph(e=e2, C=cg.C)
    data2 = build_bqp(tg, cg2)
    cold2 = solve_sdp(data2, opts)
    warm2 = solve_sdp(data2, opts, warm_start=cold.state)
    assert cold2.converged and warm2.converged
    assert warm2.stats["warm_started"]
    assert warm2.iterations < cold2.iterations

    # mismatched payloads are ignored, not crashed on
    bad = solve_sdp(data2, opts, warm_start={"w": np.zeros(3)})
    assert not bad.stats["warm_started"]


def test_warm_start_jax_backend(instance):
    tg, cg = instance
    data = build_factored_bqp(tg, cg)
    opts = SDPOptions(max_iters=4000, tol=2e-5, backend="jax")
    cold = solve_sdp(data, opts)
    assert cold.converged
    warm = solve_sdp(data, opts, warm_start=cold.state)
    assert warm.stats["warm_started"]
    assert warm.iterations < cold.iterations


def test_schedule_warm_start_cache(instance):
    """schedule(warm_start=True) reuses iterates across topology changes."""
    tg, cg = instance
    scheduler_mod._WARM_STARTS.clear()
    kw = dict(
        method="sdp",
        num_samples=200,
        sdp_options=SDPOptions(max_iters=4000, tol=2e-5),
        rounding_backend="numpy",
        warm_start=True,
    )
    s1 = schedule(tg, cg, **kw)
    assert not s1.info["warm_started"]

    e2 = cg.e.copy()
    e2[-1] *= 1.1
    s2 = schedule(tg, ComputeGraph(e=e2, C=cg.C), **kw)
    assert s2.info["warm_started"]
    assert s2.info["sdp_iterations"] < s1.info["sdp_iterations"]
    assert np.isfinite(s2.bottleneck)
    scheduler_mod._WARM_STARTS.clear()


def test_schedule_jax_solver_backend(instance):
    """solver_backend= plumbs through, hands Y_device to fused rounding."""
    tg, cg = instance
    kw = dict(
        method="sdp",
        seed=5,
        num_samples=300,
        sdp_options=SDPOptions(max_iters=400),
    )
    s_np = schedule(tg, cg, solver_backend="numpy", rounding_backend="numpy", **kw)
    s_jx = schedule(tg, cg, solver_backend="jax", rounding_backend="jax", **kw)
    assert s_np.info["solver_backend"] == "numpy"
    assert s_jx.info["solver_backend"] == "jax"
    # both backends land on equally good schedules of the same instance
    assert np.isfinite(s_jx.bottleneck)
    assert np.isclose(s_jx.bottleneck, s_np.bottleneck, rtol=0.15)


def test_uncertified_bound_not_reported_as_lower_bound(instance):
    """An unconverged iterate's Eq. 24 value must not masquerade as a bound."""
    tg, cg = instance
    data = build_bqp(tg, cg)
    sol = solve_sdp(data, SDPOptions(max_iters=5, check_every=5))
    assert not sol.converged
    assert not sol.bound_certified

    s = schedule(
        tg, cg,
        method="sdp",
        num_samples=200,
        sdp_options=SDPOptions(max_iters=5, check_every=5),
        rounding_backend="numpy",
    )
    assert not s.info["bound_certified"]
    assert "lower_bound" not in s.info
    assert "lower_bound_uncertified" in s.info


def test_certified_bound_reported(instance):
    tg, cg = instance
    s = schedule(
        tg, cg,
        method="sdp",
        num_samples=200,
        sdp_options=SDPOptions(max_iters=4000, tol=2e-5),
        rounding_backend="numpy",
    )
    assert s.info["bound_certified"]
    assert "lower_bound" in s.info
    assert "lower_bound_uncertified" not in s.info
