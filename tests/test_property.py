"""Hypothesis property tests on scheduling invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dependency: property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ComputeGraph, TaskGraph, bottleneck_time
from repro.core.bqp import bottleneck_time_batch, build_bqp, task_times
from repro.core.rounding import signs_to_assignments


@st.composite
def instances(draw):
    n_t = draw(st.integers(2, 8))
    n_k = draw(st.integers(2, 4))
    p = draw(
        st.lists(st.floats(0.01, 50.0), min_size=n_t, max_size=n_t)
    )
    e = draw(st.lists(st.floats(0.1, 20.0), min_size=n_k, max_size=n_k))
    edges = draw(
        st.lists(
            st.tuples(st.integers(0, n_t - 1), st.integers(0, n_t - 1)),
            max_size=n_t * 2,
        )
    )
    edges = tuple(sorted({(i, j) for (i, j) in edges if i != j}))
    c_seed = draw(st.integers(0, 2**31 - 1))
    C = np.random.default_rng(c_seed).uniform(0, 5, size=(n_k, n_k))
    np.fill_diagonal(C, 0.0)
    tg = TaskGraph(p=np.asarray(p), edges=edges)
    cg = ComputeGraph(e=np.asarray(e), C=C)
    a = np.asarray(
        draw(st.lists(st.integers(0, n_k - 1), min_size=n_t, max_size=n_t))
    )
    return tg, cg, a


@given(instances())
@settings(max_examples=60, deadline=None)
def test_batch_matches_scalar(inst):
    tg, cg, a = inst
    assert np.isclose(
        bottleneck_time(tg, cg, a), bottleneck_time_batch(tg, cg, a[None])[0]
    )


@given(instances(), st.floats(1.1, 4.0))
@settings(max_examples=40, deadline=None)
def test_speedup_monotone(inst, factor):
    """Uniformly faster machines can't increase the bottleneck (fixed A)."""
    tg, cg, a = inst
    t0 = bottleneck_time(tg, cg, a)
    faster = ComputeGraph(e=cg.e * factor, C=cg.C)
    assert bottleneck_time(tg, faster, a) <= t0 + 1e-9


@given(instances())
@settings(max_examples=40, deadline=None)
def test_extra_edge_monotone(inst):
    """Adding a dependency can only increase the bottleneck (fixed A)."""
    tg, cg, a = inst
    t0 = bottleneck_time(tg, cg, a)
    cand = [(i, j) for i in range(tg.num_tasks) for j in range(tg.num_tasks)
            if i != j and (i, j) not in tg.edges]
    if not cand:
        return
    tg2 = TaskGraph(p=tg.p, edges=tg.edges + (cand[0],))
    assert bottleneck_time(tg2, cg, a) >= t0 - 1e-9


@given(instances())
@settings(max_examples=40, deadline=None)
def test_comp_time_equals_machine_load(inst):
    tg, cg, a = inst
    t_comp, _ = task_times(tg, cg, a)
    loads = np.zeros(cg.num_machines)
    np.add.at(loads, a, tg.p)
    for i in range(tg.num_tasks):
        assert np.isclose(t_comp[i], loads[a[i]] / cg.e[a[i]])


@given(st.integers(2, 6), st.integers(2, 4), st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_rounding_repair_always_feasible(n_t, n_k, seed):
    """Any ±1 sample maps to a feasible one-machine-per-task assignment."""
    rng = np.random.default_rng(seed)
    z = rng.standard_normal((16, n_t * n_k + 1))
    signs = np.sign(z)
    signs[signs == 0] = 1
    assignments, _ = signs_to_assignments(signs, z, n_t, n_k)
    assert assignments.shape == (16, n_t)
    assert np.all((0 <= assignments) & (assignments < n_k))


@given(instances())
@settings(max_examples=30, deadline=None)
def test_bqp_scale_invariance(inst):
    """Scaling all Q̃ by q_scale must leave quadratic bottlenecks consistent."""
    tg, cg, a = inst
    data = build_bqp(tg, cg)
    assert data.q_scale > 0
    assert np.isfinite(data.Q_tilde).all()
