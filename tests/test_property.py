"""Property tests on scheduling, FL, and kernel invariants.

Uses the real ``hypothesis`` library when installed; otherwise falls back
to the seeded shim in ``tests/_minihypothesis.py`` (same API subset, no
shrinking) so the module runs everywhere instead of skipping.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    from _minihypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = False

from repro.core import ComputeGraph, TaskGraph, bottleneck_time
from repro.core.bqp import bottleneck_time_batch, build_bqp, task_times
from repro.core.rounding import signs_to_assignments


@st.composite
def instances(draw):
    n_t = draw(st.integers(2, 8))
    n_k = draw(st.integers(2, 4))
    p = draw(
        st.lists(st.floats(0.01, 50.0), min_size=n_t, max_size=n_t)
    )
    e = draw(st.lists(st.floats(0.1, 20.0), min_size=n_k, max_size=n_k))
    edges = draw(
        st.lists(
            st.tuples(st.integers(0, n_t - 1), st.integers(0, n_t - 1)),
            max_size=n_t * 2,
        )
    )
    edges = tuple(sorted({(i, j) for (i, j) in edges if i != j}))
    c_seed = draw(st.integers(0, 2**31 - 1))
    C = np.random.default_rng(c_seed).uniform(0, 5, size=(n_k, n_k))
    np.fill_diagonal(C, 0.0)
    tg = TaskGraph(p=np.asarray(p), edges=edges)
    cg = ComputeGraph(e=np.asarray(e), C=C)
    a = np.asarray(
        draw(st.lists(st.integers(0, n_k - 1), min_size=n_t, max_size=n_t))
    )
    return tg, cg, a


@given(instances())
@settings(max_examples=60, deadline=None)
def test_batch_matches_scalar(inst):
    tg, cg, a = inst
    assert np.isclose(
        bottleneck_time(tg, cg, a), bottleneck_time_batch(tg, cg, a[None])[0]
    )


@given(instances(), st.floats(1.1, 4.0))
@settings(max_examples=40, deadline=None)
def test_speedup_monotone(inst, factor):
    """Uniformly faster machines can't increase the bottleneck (fixed A)."""
    tg, cg, a = inst
    t0 = bottleneck_time(tg, cg, a)
    faster = ComputeGraph(e=cg.e * factor, C=cg.C)
    assert bottleneck_time(tg, faster, a) <= t0 + 1e-9


@given(instances())
@settings(max_examples=40, deadline=None)
def test_extra_edge_monotone(inst):
    """Adding a dependency can only increase the bottleneck (fixed A)."""
    tg, cg, a = inst
    t0 = bottleneck_time(tg, cg, a)
    cand = [(i, j) for i in range(tg.num_tasks) for j in range(tg.num_tasks)
            if i != j and (i, j) not in tg.edges]
    if not cand:
        return
    tg2 = TaskGraph(p=tg.p, edges=tg.edges + (cand[0],))
    assert bottleneck_time(tg2, cg, a) >= t0 - 1e-9


@given(instances())
@settings(max_examples=40, deadline=None)
def test_comp_time_equals_machine_load(inst):
    tg, cg, a = inst
    t_comp, _ = task_times(tg, cg, a)
    loads = np.zeros(cg.num_machines)
    np.add.at(loads, a, tg.p)
    for i in range(tg.num_tasks):
        assert np.isclose(t_comp[i], loads[a[i]] / cg.e[a[i]])


@given(st.integers(2, 6), st.integers(2, 4), st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_rounding_repair_always_feasible(n_t, n_k, seed):
    """Any ±1 sample maps to a feasible one-machine-per-task assignment."""
    rng = np.random.default_rng(seed)
    z = rng.standard_normal((16, n_t * n_k + 1))
    signs = np.sign(z)
    signs[signs == 0] = 1
    assignments, _ = signs_to_assignments(signs, z, n_t, n_k)
    assert assignments.shape == (16, n_t)
    assert np.all((0 <= assignments) & (assignments < n_k))


@given(instances())
@settings(max_examples=30, deadline=None)
def test_bqp_scale_invariance(inst):
    """Scaling all Q̃ by q_scale must leave quadratic bottlenecks consistent."""
    tg, cg, a = inst
    data = build_bqp(tg, cg)
    assert data.q_scale > 0
    assert np.isfinite(data.Q_tilde).all()


# ---------------------------------------------------------------------------
# Barrier-free FL invariants: staleness weights and token-account flow
# ---------------------------------------------------------------------------


@st.composite
def staleness_weights(draw):
    from repro.fl.staleness import STALENESS_KINDS, StalenessWeights

    kind = draw(st.sampled_from(STALENESS_KINDS))
    a = draw(st.floats(0.0, 10.0, allow_nan=False))
    b = draw(st.integers(0, 10)) if kind == "hinge" else 0
    return StalenessWeights(kind=kind, a=a, b=b)


@given(staleness_weights())
@settings(max_examples=60, deadline=None)
def test_staleness_fresh_snapshot_has_unit_weight(sw):
    """s(0) = 1 for every kind/parameterization — the degenerate anchor."""
    assert sw(np.array([0]))[0] == 1.0
    # negative lags (clock skew artifacts) clamp to the fresh weight
    assert sw(np.array([-3]))[0] == 1.0


@given(staleness_weights())
@settings(max_examples=60, deadline=None)
def test_staleness_monotone_nonincreasing_and_bounded(sw):
    lags = np.arange(0, 25)
    w = sw(lags)
    assert np.all(w <= 1.0 + 1e-12) and np.all(w > 0.0)
    assert np.all(np.diff(w) <= 1e-12), (sw, w)
    # the jax path computes the same weights (float32 roundoff)
    jw = np.asarray(sw.jax_weights(lags))
    np.testing.assert_allclose(jw, w.astype(np.float32), rtol=1e-6, atol=1e-7)


def test_staleness_rejects_bad_params():
    from repro.fl.staleness import StalenessWeights

    with pytest.raises(ValueError, match="kind"):
        StalenessWeights(kind="exp")
    with pytest.raises(ValueError, match="a"):
        StalenessWeights(kind="poly", a=-0.5)
    with pytest.raises(ValueError, match="b"):
        StalenessWeights(kind="hinge", b=-1)


@given(
    st.floats(1.0, 16.0, allow_nan=False),
    st.floats(0.0, 8.0, allow_nan=False),
    st.lists(st.sampled_from(["send", "replenish"]), max_size=60),
)
@settings(max_examples=60, deadline=None)
def test_token_account_invariants(capacity, refill, ops):
    """0 <= tokens <= capacity always; between any two replenishes at most
    floor(capacity) sends succeed; every try_send is tallied."""
    from repro.sim.flow import TokenAccount

    acct = TokenAccount(capacity=capacity, refill=refill)
    assert acct.tokens == capacity
    sends_since_replenish = 0
    tries = 0
    for op in ops:
        if op == "send":
            tries += 1
            if acct.try_send():
                sends_since_replenish += 1
            assert sends_since_replenish <= int(np.floor(capacity))
        else:
            acct.replenish()
            sends_since_replenish = 0
        assert 0.0 <= acct.tokens <= capacity + 1e-12
    assert acct.sent + acct.skipped == tries


def test_token_account_rejects_bad_config():
    from repro.sim.flow import TokenAccount

    with pytest.raises(ValueError, match="capacity"):
        TokenAccount(capacity=0.5)
    with pytest.raises(ValueError, match="refill"):
        TokenAccount(capacity=2.0, refill=-1.0)


# ---------------------------------------------------------------------------
# Fused-kernel invariants: the Pallas ops agree with Eq. 2 / the compressors
# on randomized shapes, not just the hand-picked sweeps in test_kernel_diff
# ---------------------------------------------------------------------------


@given(instances())
@settings(max_examples=25, deadline=None)
def test_kernel_bottleneck_matches_eq2(inst):
    """The one-hot bottleneck kernel == the index-gather gold evaluator."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.bottleneck import bottleneck_eval_fwd
    from repro.kernels.ref import bottleneck_eval_ref

    tg, cg, a = inst
    n_t, n_k = tg.num_tasks, cg.num_machines
    batch = np.stack([a, (a + 1) % n_k, (a + 2) % n_k])
    gold = bottleneck_time_batch(tg, cg, batch)

    oh = jax.nn.one_hot(jnp.asarray(batch), n_k, dtype=jnp.float32)
    if tg.edges:
        src = jnp.asarray([i for i, _ in tg.edges])
        dst = jnp.asarray([j for _, j in tg.edges])
        src_oh = jax.nn.one_hot(src, n_t, dtype=jnp.float32)
        dst_oh = jax.nn.one_hot(dst, n_t, dtype=jnp.float32)
    else:
        src_oh = dst_oh = jnp.zeros((0, n_t), jnp.float32)
    args = (oh, jnp.asarray(tg.p), jnp.asarray(cg.e), jnp.asarray(cg.C),
            src_oh, dst_oh)
    got = bottleneck_eval_fwd(*args, interpret=True)
    want = bottleneck_eval_ref(*args)
    np.testing.assert_allclose(np.asarray(got), gold, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(1, 200),
       st.floats(0.01, 1.0))
@settings(max_examples=25, deadline=None)
def test_kernel_compress_error_feedback(seed, n, l, frac):
    """Fused compress kernels: msgs + residual == delta (lossless feedback),
    top-k keeps >= k entries, int8 residual bounded by half a quantum."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.compress import int8_roundtrip_fwd, topk_mask_fwd
    from repro.kernels.ref import int8_roundtrip_ref, topk_mask_ref

    rng = np.random.default_rng(seed)
    delta = jnp.asarray(rng.standard_normal((n, l)), jnp.float32)
    bl = max(1, l // 3)  # force a ragged final block most of the time

    kk = max(1, int(frac * l))
    vals, _ = jax.lax.top_k(jnp.abs(delta), kk)
    thresh = vals[:, -1]
    msg, resid = topk_mask_fwd(delta, thresh, block_len=bl, interpret=True)
    rmsg, rresid = topk_mask_ref(delta, thresh)
    assert np.array_equal(np.asarray(msg), np.asarray(rmsg))
    assert np.array_equal(np.asarray(resid), np.asarray(rresid))
    assert np.array_equal(np.asarray(msg) + np.asarray(resid),
                          np.asarray(delta))
    assert np.all(np.count_nonzero(np.asarray(msg), axis=1) >= kk)

    scale = jnp.maximum(jnp.max(jnp.abs(delta), axis=1), 1e-12) / 127.0
    msg, resid = int8_roundtrip_fwd(delta, scale, block_len=bl,
                                    interpret=True)
    rmsg, rresid = int8_roundtrip_ref(delta, scale)
    assert np.array_equal(np.asarray(msg), np.asarray(rmsg))
    np.testing.assert_allclose(np.asarray(resid), np.asarray(rresid),
                               atol=2e-7)
    assert np.all(np.abs(np.asarray(resid))
                  <= np.asarray(scale)[:, None] * 0.5 + 1e-7)


@given(st.integers(0, 2**31 - 1), st.integers(2, 24), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_kernel_sdp_subspace_matches_ref(seed, n, k):
    """Fused subspace matvec + Gram + ||Y||^2 agree with the jnp oracle on
    random (n, k) including block-ragged n; rank-k downdate is exact."""
    import jax.numpy as jnp

    from repro.kernels.sdp_proj import rank_k_update_fwd, sdp_subspace_fwd
    from repro.kernels.ref import rank_k_update_ref, sdp_subspace_ref

    k = min(k, n)
    rng = np.random.default_rng(seed)
    Y = rng.standard_normal((n, n))
    Y = jnp.asarray(Y + Y.T, jnp.float32)
    V = jnp.asarray(np.linalg.qr(rng.standard_normal((n, k)))[0], jnp.float32)
    yv, g, ss = sdp_subspace_fwd(Y, V, block_rows=5, interpret=True)
    ryv, rg, rss = sdp_subspace_ref(Y, V)
    np.testing.assert_allclose(np.asarray(yv), np.asarray(ryv),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(ss), float(rss), rtol=1e-5)

    A = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    got = rank_k_update_fwd(Y, A, V, block_rows=5, interpret=True)
    want = rank_k_update_ref(Y, A, V)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
