"""Scheduler API: feasibility, baselines, paper-style comparisons."""

import numpy as np
import pytest

from repro.core import (
    METHODS,
    bottleneck_time,
    compare_methods,
    random_compute_graph,
    random_task_graph,
    schedule,
)
from repro.core.graphs import ComputeGraph, TaskGraph
from repro.sched import build_heft_dag, local_search_refine


@pytest.fixture(scope="module")
def instance():
    rng = np.random.default_rng(11)
    tg = random_task_graph(rng, 10, degree_low=2, degree_high=4)
    cg = random_compute_graph(rng, 4)
    return tg, cg


@pytest.mark.parametrize("method", METHODS)
def test_every_method_feasible(instance, method):
    tg, cg = instance
    s = schedule(tg, cg, method, num_samples=500, rounding_backend="numpy")
    assert s.assignment.shape == (tg.num_tasks,)
    assert np.all((0 <= s.assignment) & (s.assignment < cg.num_machines))
    assert np.isclose(s.bottleneck, bottleneck_time(tg, cg, s.assignment))


def test_sdp_beats_heft_on_paper_setting():
    """Fig. 4 regime: SDP randomized should beat HEFT on average."""
    wins = 0
    for seed in range(5):
        rng = np.random.default_rng(seed)
        tg = random_task_graph(rng, 12, degree_low=2, degree_high=4)
        cg = random_compute_graph(rng, 4)
        out = compare_methods(
            tg, cg, methods=("heft", "sdp"), num_samples=2000,
            rounding_backend="numpy",
        )
        if out["sdp"].bottleneck <= out["heft"].bottleneck * 1.001:
            wins += 1
    assert wins >= 4, f"SDP only beat HEFT {wins}/5 times"


def test_heft_dag_construction():
    """§4.1.1: S + tasks + one T_{i,j} per edge + D; acyclic."""
    tg = TaskGraph(p=np.ones(3), edges=((0, 1), (1, 2), (2, 0)))  # cycle!
    dag = build_heft_dag(tg)
    assert len(dag.nodes) == 1 + 3 + 3 + 1
    names = {n.name for n in dag.nodes}
    assert {"S", "D", "T0", "T1", "T2", "T0,1", "T1,2", "T2,0"} == names
    # acyclicity via topological sort
    n = len(dag.nodes)
    indeg = [0] * n
    for (_, b) in dag.edges:
        indeg[b] += 1
    stack = [u for u in range(n) if indeg[u] == 0]
    seen = 0
    while stack:
        u = stack.pop()
        seen += 1
        for (a, b) in dag.edges:
            if a == u:
                indeg[b] -= 1
                if indeg[b] == 0:
                    stack.append(b)
    assert seen == n


def test_theorem1_sorted_optimal():
    """Theorem 1: C=0, no deps, N_T == N_K -> sorted assignment optimal."""
    rng = np.random.default_rng(2)
    p = np.sort(rng.uniform(1, 10, size=4))[::-1]
    e = np.sort(rng.uniform(1, 10, size=4))[::-1]
    tg = TaskGraph(p=p, edges=())
    cg = ComputeGraph(e=e, C=np.zeros((4, 4)))
    s = schedule(tg, cg, "sorted")
    # optimal = max p_sorted / e_sorted when matched in order
    expected = np.max(np.sort(p)[::-1] / np.sort(e)[::-1])
    assert np.isclose(s.bottleneck, expected)
    # Theorem 1 claims optimality within one-task-per-machine assignments
    # (co-location on a fast machine can beat it under proportional
    # sharing, so compare against the permutation-restricted optimum).
    import itertools

    from repro.core import bottleneck_time

    best_perm = min(
        bottleneck_time(tg, cg, np.asarray(perm))
        for perm in itertools.permutations(range(4))
    )
    assert s.bottleneck <= best_perm + 1e-9


def test_local_search_never_hurts(instance):
    tg, cg = instance
    rng = np.random.default_rng(4)
    a = rng.integers(0, cg.num_machines, size=tg.num_tasks)
    t0 = bottleneck_time(tg, cg, a)
    refined = local_search_refine(tg, cg, a)
    assert bottleneck_time(tg, cg, refined) <= t0 + 1e-12


def test_compare_methods_shares_sdp(instance):
    tg, cg = instance
    out = compare_methods(
        tg, cg, methods=("sdp_naive", "sdp"), num_samples=500,
        rounding_backend="numpy",
    )
    assert out["sdp"].info["sdp_iterations"] == out["sdp_naive"].info["sdp_iterations"]


def test_warm_start_cache_is_true_lru(monkeypatch):
    """Eviction pops the least-recently-USED fingerprint: a hot structure
    re-hit on every re-solve survives arrivals of new ones (regression —
    the cache used to evict in FIFO insertion order)."""
    from repro.core import scheduler as sched_mod
    from repro.core.graphs import ring_task_graph
    from repro.core.sdp import SDPOptions

    monkeypatch.setattr(sched_mod, "_WARM_STARTS", {})
    monkeypatch.setattr(sched_mod, "_WARM_STARTS_MAX", 2)
    opts = SDPOptions(max_iters=10, check_every=5)

    def solve(n_tasks):
        rng = np.random.default_rng(n_tasks)
        tg = ring_task_graph(n_tasks)
        cg = random_compute_graph(rng, 3)
        schedule(tg, cg, "sdp", num_samples=50, sdp_options=opts,
                 rounding_backend="numpy", warm_start=True)
        return sched_mod._warm_fingerprint(tg, cg)

    fp_a = solve(4)
    fp_b = solve(5)
    assert list(sched_mod._WARM_STARTS) == [fp_a, fp_b]
    assert solve(4) == fp_a                       # hit: A becomes most recent
    assert list(sched_mod._WARM_STARTS) == [fp_b, fp_a]
    fp_c = solve(6)                               # evicts B, NOT the hot A
    assert list(sched_mod._WARM_STARTS) == [fp_a, fp_c]


def test_rounding_bound_kept_separate_from_solver_bound(instance):
    """The rounding pass's Eq. 24 re-evaluation must not overwrite the
    solver's value under the bound key (regression: double-write)."""
    from repro.core.sdp import SDPOptions

    tg, cg = instance
    s = schedule(
        tg, cg, "sdp", num_samples=200, rounding_backend="numpy",
        sdp_options=SDPOptions(max_iters=5, check_every=5),
    )
    assert not s.info["bound_certified"]
    assert "lower_bound" not in s.info
    assert np.isfinite(s.info["lower_bound_uncertified"])
    assert np.isfinite(s.info["rounding_lower_bound"])

    s2 = schedule(
        tg, cg, "sdp", num_samples=200, rounding_backend="numpy",
        sdp_options=SDPOptions(max_iters=4000, tol=2e-5),
    )
    assert s2.info["bound_certified"]
    # the certified key carries the SOLVER's Eq. 24 value...
    assert "lower_bound_uncertified" not in s2.info
    assert np.isfinite(s2.info["lower_bound"])
    # ...and the rounding diagnostic rides alongside, not over it
    assert "rounding_lower_bound" in s2.info
    assert s2.info["lower_bound"] <= s2.bottleneck + 1e-6
