"""Per-architecture smoke tests: reduced config, one train step + decode
step on CPU, asserting shapes and finiteness (assignment requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.models.flops import param_counts

B, S = 2, 128

PUBLISHED_PARAMS_B = {
    "qwen3-8b": 8.2,
    "mistral-nemo-12b": 12.2,
    "granite-3-2b": 2.6,
    "mistral-large-123b": 122.6,
    "mamba2-1.3b": 1.3,
    "mixtral-8x7b": 46.7,
    "olmoe-1b-7b": 6.9,
    "qwen2-vl-72b": 72.7,
}


def _train_batch(cfg):
    if cfg.family == "encdec":
        return {
            "enc_frames": jnp.ones((B, S, cfg.d_model), cfg.dtype),
            "dec_tokens": jnp.zeros((B, max(S // 4, 64)), jnp.int32),
            "labels": jnp.ones((B, max(S // 4, 64)), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "inputs_embeds": jnp.ones((B, S, cfg.d_model), cfg.dtype),
            "positions": jnp.zeros((3, B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    return {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = _train_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(lambda p, b: api.loss_fn(p, b)))(
        params, batch
    )
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cache = api.init_cache(B, S)
    batch = {"pos": jnp.full((B,), S - 1, jnp.int32)}
    if cfg.family == "vlm":
        batch["inputs_embeds"] = jnp.ones((B, 1, cfg.d_model), cfg.dtype)
    else:
        batch["tokens"] = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(lambda p, c, b: api.decode_step(p, c, b))(
        params, cache, batch
    )
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaNs"
    # cache must actually change (the new token was written)
    diff = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache))
    )
    assert diff > 0, f"{arch}: decode did not update cache"


@pytest.mark.parametrize("arch", sorted(PUBLISHED_PARAMS_B))
def test_full_config_param_count_matches_published(arch):
    cfg = get_config(arch)
    pc = param_counts(cfg)
    published = PUBLISHED_PARAMS_B[arch] * 1e9
    assert abs(pc.total - published) / published < 0.08, (
        f"{arch}: {pc.total/1e9:.2f}B vs published {published/1e9:.2f}B"
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_logits(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(1))
    batch = _train_batch(cfg)
    batch.pop("labels")
    logits = jax.jit(lambda p, b: api.forward(p, b))(params, batch)
    out = logits[0] if isinstance(logits, tuple) else logits
    assert out.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(out, np.float32)).all()
