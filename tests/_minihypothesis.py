"""Seeded fallback for the slice of the Hypothesis API this suite uses.

``tests/test_property.py`` historically skipped wholesale because the
container image has no ``hypothesis`` wheel and the environment forbids
installing one.  This shim implements just the strategy/driver subset the
property tests need — ``given``, ``settings``, and the ``strategies``
functions ``integers`` / ``floats`` / ``lists`` / ``tuples`` /
``sampled_from`` / ``composite`` — drawing every example from a PRNG
seeded by the test's qualified name, so runs are deterministic and
failures reproduce.

What it deliberately does NOT do: shrinking, example databases,
``assume``, or explicit ``@example`` pinning.  When the real library is
importable, ``test_property.py`` prefers it (see its import block); the
shim only keeps the invariants exercised where hypothesis is absent.
"""

from __future__ import annotations

import functools
import zlib

import numpy as np


class SearchStrategy:
    """A draw function wrapped so strategies compose like hypothesis's."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example_from(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.integers(min_value, max_value + 1))
        )

    @staticmethod
    def floats(
        min_value: float, max_value: float, allow_nan: bool = False
    ) -> SearchStrategy:
        lo, hi = float(min_value), float(max_value)

        def draw(rng):
            # Land on the endpoints sometimes — boundary values are where
            # monotonicity/clamping invariants actually break.
            r = rng.random()
            if r < 0.05:
                return lo
            if r < 0.10:
                return hi
            return float(rng.uniform(lo, hi))

        return SearchStrategy(draw)

    @staticmethod
    def lists(
        elements: SearchStrategy, min_size: int = 0, max_size: int = 10
    ) -> SearchStrategy:
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.example_from(rng) for _ in range(size)]

        return SearchStrategy(draw)

    @staticmethod
    def tuples(*elems: SearchStrategy) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: tuple(e.example_from(rng) for e in elems)
        )

    @staticmethod
    def sampled_from(seq) -> SearchStrategy:
        pool = list(seq)
        if not pool:
            raise ValueError("sampled_from needs a non-empty sequence")
        return SearchStrategy(lambda rng: pool[int(rng.integers(len(pool)))])

    @staticmethod
    def composite(fn):
        """``@composite def s(draw, *args)`` -> calling ``s(*args)`` builds a
        strategy whose draw threads one shared rng through inner draws."""

        @functools.wraps(fn)
        def make(*args, **kwargs):
            def draw_fn(rng):
                return fn(
                    lambda strat: strat.example_from(rng), *args, **kwargs
                )

            return SearchStrategy(draw_fn)

        return make


def settings(max_examples: int = 100, deadline=None):
    """Record the example budget on the test; ``deadline`` is accepted for
    API compatibility and ignored (no timing enforcement in the shim)."""

    def deco(fn):
        fn._mh_max_examples = int(max_examples)
        return fn

    return deco


def given(*strats: SearchStrategy):
    """Run the test once per drawn example, seeded by the test's name.

    Works under either decorator order (``@given`` above or below
    ``@settings``): ``functools.wraps`` carries ``_mh_max_examples``
    through, and ``settings`` applied on top mutates the wrapper.
    """

    def deco(fn):
        @functools.wraps(fn)
        def runner():
            n = getattr(runner, "_mh_max_examples", 100)
            seed = zlib.crc32(
                f"{fn.__module__}::{fn.__qualname__}".encode()
            )
            rng = np.random.default_rng(seed)
            for i in range(n):
                vals = [s.example_from(rng) for s in strats]
                try:
                    fn(*vals)
                except Exception as exc:  # noqa: BLE001 - annotate & re-raise
                    raise AssertionError(
                        f"falsifying example #{i} (seed {seed}): {vals!r}"
                    ) from exc

        # pytest resolves fixtures from the *wrapped* signature; the drawn
        # parameters are not fixtures, so hide fn behind a zero-arg facade.
        del runner.__wrapped__
        return runner

    return deco
