"""Device-resident analysis transforms and rounding-closure cache policy."""

import numpy as np
import pytest

import repro.core.rounding as rounding
from repro.core.bqp import build_bqp, build_factored_bqp
from repro.core.graphs import random_compute_graph, random_task_graph
from repro.core.rounding import (
    _fused_rounding_fn,
    analysis_bounds,
    expected_bottleneck,
    optimal_upper_bound,
    sdp_lower_bound,
)


def _instance(seed=0, n_tasks=12, n_machines=4):
    rng = np.random.default_rng(seed)
    tg = random_task_graph(rng, n_tasks, degree_low=2, degree_high=3)
    cg = random_compute_graph(rng, n_machines)
    return tg, cg


def _unit_diag_psd(n1, rng):
    A = rng.standard_normal((n1, n1))
    Y = (A @ A.T) / n1
    d = np.sqrt(np.diag(Y))
    return Y / np.outer(d, d)


def test_analysis_bounds_device_matches_host():
    import jax.numpy as jnp

    tg, cg = _instance()
    fbqp = build_factored_bqp(tg, cg)
    rng = np.random.default_rng(1)
    Y = _unit_diag_psd(fbqp.n + 1, rng)
    host = analysis_bounds(fbqp, Y)
    dev = analysis_bounds(fbqp, Y, Y_device=jnp.asarray(Y, jnp.float32))
    assert host == (
        expected_bottleneck(fbqp, Y),
        sdp_lower_bound(fbqp, Y),
        optimal_upper_bound(fbqp, Y),
    )
    for h, d in zip(host, dev):
        np.testing.assert_allclose(d, h, rtol=1e-4, atol=1e-5)


def test_analysis_bounds_dense_ignores_device():
    """Dense instances keep the float64 host path even with Y_device."""
    import jax.numpy as jnp

    tg, cg = _instance(seed=2, n_tasks=6, n_machines=3)
    dbqp = build_bqp(tg, cg)
    rng = np.random.default_rng(3)
    Y = _unit_diag_psd(dbqp.n + 1, rng)
    host = analysis_bounds(dbqp, Y)
    dev = analysis_bounds(dbqp, Y, Y_device=jnp.asarray(Y, jnp.float32))
    assert host == dev


def test_rounding_cache_lru_single_eviction(monkeypatch):
    """A cache-capacity+1-th instance evicts exactly the least-recently-used
    closure — recently used ones survive (no mass recompilation)."""
    monkeypatch.setattr(rounding, "_JAX_CACHE_MAX", 4)
    rounding._JAX_CACHE.clear()
    insts, fns = [], []
    for s in range(4):
        tg, cg = _instance(seed=10 + s, n_tasks=4, n_machines=2)
        insts.append((tg, cg))
        fns.append(_fused_rounding_fn(tg, cg, 4, 2, False))
    assert len(rounding._JAX_CACHE) == 4
    # touch instance 0 so instance 1 becomes the LRU entry
    assert _fused_rounding_fn(*insts[0], 4, 2, False) is fns[0]
    tg, cg = _instance(seed=99, n_tasks=4, n_machines=2)
    _fused_rounding_fn(tg, cg, 4, 2, False)
    assert len(rounding._JAX_CACHE) == 4
    assert _fused_rounding_fn(*insts[0], 4, 2, False) is fns[0]   # survived
    assert _fused_rounding_fn(*insts[2], 4, 2, False) is fns[2]
    assert _fused_rounding_fn(*insts[3], 4, 2, False) is fns[3]


def test_rounding_cache_strict_variants_coexist():
    tg, cg = _instance(seed=42, n_tasks=4, n_machines=2)
    f1 = _fused_rounding_fn(tg, cg, 4, 2, False)
    f2 = _fused_rounding_fn(tg, cg, 4, 2, True)
    assert f1 is not f2
    assert _fused_rounding_fn(tg, cg, 4, 2, False) is f1
