"""Barrier-free gossip FL: degenerate anchor, staleness weighting, churn
freeze/recover, scenario validation messages, responsiveness dimensions."""

import numpy as np
import pytest

from repro.core.graphs import gossip_task_graph
from repro.data.synthetic import image_dataset
from repro.fl.async_gossip import AsyncGossipTrainer
from repro.fl.cnn import cnn_loss, init_cnn_params
from repro.fl.gossip import GossipConfig, GossipTrainer
from repro.fl.runner import FLExperiment, run_fl_async
from repro.fl.staleness import StalenessWeights
from repro.launch.elastic import ElasticScheduler
from repro.scenarios import Scenario
from repro.scenarios.profiles import churn_trace
from repro.sim import ControlEvent, ExecutionSpec
from repro.train.compression import TopK


def _pair(n_users=4, compressor=None, seed=0, staleness=None, archive_depth=8):
    """A stacked GossipTrainer and an AsyncGossipTrainer on one instance."""
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n_users, degree_low=2, degree_high=3)
    train, _ = image_dataset("mnist", 512, seed=seed)
    shards = train.split(n_users, rng)
    cfg = GossipConfig(local_steps=2, batch_size=32, lr=0.05,
                       compressor=compressor, backend="stacked")
    init = lambda k: init_cnn_params(k, (28, 28, 1), 10)
    sync = GossipTrainer(tg, init, cnn_loss, shards, cfg, seed=seed)
    asyn = AsyncGossipTrainer(
        tg, init, cnn_loss, shards, cfg, seed=seed,
        staleness=staleness, archive_depth=archive_depth,
    )
    return sync, asyn, tg


# ---------------------------------------------------------------------------
# Degenerate anchor: all-active + fresh versions + s === 1 == stacked engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compressor", [None, TopK(fraction=0.5)])
def test_degenerate_anchor_reproduces_stacked_losses(compressor):
    sync, asyn, _ = _pair(compressor=compressor)
    for r in range(3):
        ls = sync.step_round()["mean_loss"]
        info = asyn.step_round()     # defaults: all active, fresh versions
        assert info["mean_loss"] == pytest.approx(ls, abs=1e-5), (
            f"round {r}: async degenerate loss diverged from stacked"
        )
        assert info["stale_mixes"] == 0
        assert info["invalid_edges"] == 0
    # and the replicas themselves agree to fp32 roundoff
    for u in range(len(sync.params)):
        a = np.concatenate([np.ravel(v) for v in
                            jax_leaves(asyn.params[u])])
        s = np.concatenate([np.ravel(v) for v in
                            jax_leaves(sync.params[u])])
        np.testing.assert_allclose(a, s, atol=1e-5)


def jax_leaves(tree):
    import jax
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def test_stale_versions_are_discounted_and_counted():
    _, asyn, tg = _pair(staleness=StalenessWeights(kind="hinge", a=1.0, b=0))
    asyn.step_round()
    n_edges = len(tg.edges)
    info = asyn.step_round(edge_versions=np.zeros(n_edges, dtype=np.int64))
    assert info["stale_mixes"] == n_edges       # every edge lagged by 1
    assert np.isfinite(info["mean_loss"])
    assert asyn.total_stale_mixes == n_edges


def test_never_delivered_edges_fall_back_to_self_weight():
    _, asyn, tg = _pair()
    info = asyn.step_round(
        edge_versions=np.full(len(tg.edges), -1, dtype=np.int64)
    )
    # nothing delivered: every edge invalid, no stale mixes, finite loss
    assert info["invalid_edges"] == len(tg.edges)
    assert info["stale_mixes"] == 0
    assert np.isfinite(info["mean_loss"])


def test_future_versions_rejected_at_the_trainer():
    _, asyn, tg = _pair()
    with pytest.raises(ValueError, match="cannot be delivered"):
        asyn.step_round(edge_versions=np.ones(len(tg.edges), dtype=np.int64))


# ---------------------------------------------------------------------------
# Churn: frozen replicas are bit-exact, recovery keeps training finite
# ---------------------------------------------------------------------------


def test_inactive_user_freezes_replica_bit_exact():
    _, asyn, _ = _pair()
    asyn.step_round()
    before = jax_leaves(asyn.params[2])
    active = np.ones(4, dtype=bool)
    active[2] = False
    info = asyn.step_round(active=active)
    after = jax_leaves(asyn.params[2])
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)
    assert np.isfinite(info["mean_loss"])
    # recovery round: everyone trains again, still finite
    info = asyn.step_round()
    assert np.isfinite(info["mean_loss"])


def test_run_fl_async_churn_trace_completes():
    exp = FLExperiment(
        num_users=8, num_machines=4, rounds=5, num_samples=512, seed=0,
        gossip=GossipConfig(local_steps=2, batch_size=32),
    )
    events = (
        ControlEvent(round=1, kind="fail", machine=0),
        ControlEvent(round=3, kind="recover", machine=0),
    )
    res = run_fl_async(
        exp, methods=("heft",),
        execution=ExecutionSpec(semantics="async", jitter_sigma=0.1),
        control_events=events,
        staleness=StalenessWeights(kind="poly", a=0.5),
    )
    rows = res["history"]["heft"]
    losses = [h["mean_loss"] for h in rows]
    assert all(np.isfinite(losses)), losses
    active = [h["active_users"] for h in rows]
    assert min(active) < 8, active          # the failure froze some users
    assert active[-1] == 8, active          # and recovery brought them back
    assert res["barrier_stalls"]["heft"] == 0
    sim = res["sim"]["heft"]
    assert sim.machine_down[1, 0] and not sim.machine_down[4, 0]


def test_run_fl_async_rejects_sync_spec():
    with pytest.raises(ValueError, match="async"):
        run_fl_async(FLExperiment(), execution=ExecutionSpec(semantics="sync"))


# ---------------------------------------------------------------------------
# Scenario validation: messages name the offending field + the legal config
# ---------------------------------------------------------------------------

_FL_KW = dict(
    topology="gossip", num_tasks=10, num_machines=4,
    machine_profile="uniform", delay_model="uniform",
    schedulers=("heft",), topology_params={"degree_low": 6, "degree_high": 7},
)


def _fl():
    from repro.scenarios.spec import FLWorkload
    return FLWorkload(rounds=2, num_samples=256)


def test_staleness_params_require_async_fl():
    with pytest.raises(ValueError, match="staleness_params.*async"):
        Scenario(name="x", fl=_fl(), execution="sync",
                 staleness_params={"kind": "hinge"}, **_FL_KW)
    with pytest.raises(ValueError, match="staleness_params"):
        Scenario(name="x", execution="async",
                 staleness_params={"kind": "hinge"}, **_FL_KW)


def test_token_params_require_async():
    with pytest.raises(ValueError, match="token_capacity.*async"):
        Scenario(name="x", execution="sync",
                 execution_params={"token_capacity": 4.0}, **_FL_KW)


def test_fl_overlap_rejected_with_legal_alternatives_named():
    with pytest.raises(ValueError, match="overlap.*(sync|async)"):
        Scenario(name="x", fl=_fl(), execution="overlap", **_FL_KW)


def test_churn_fl_requires_async_and_no_link_outages():
    with pytest.raises(ValueError, match="async"):
        Scenario(name="x", fl=_fl(), execution="sync", churn="markov",
                 churn_params={"p_fail": 0.1, "p_recover": 0.5}, **_FL_KW)
    with pytest.raises(ValueError, match="link_outages"):
        Scenario(name="x", fl=_fl(), execution="async", churn="markov",
                 churn_params={"p_fail": 0.1, "p_recover": 0.5,
                               "link_outages": 1}, **_FL_KW)


def test_async_fl_scenario_accepted():
    sc = Scenario(name="x", fl=_fl(), execution="async",
                  staleness_params={"kind": "hinge", "a": 0.5, "b": 1},
                  **_FL_KW)
    sw = sc.staleness_weights()
    assert sw.kind == "hinge" and sw(np.array([0]))[0] == 1.0


# ---------------------------------------------------------------------------
# Responsiveness/completeness churn dimensions + scheduler feedback
# ---------------------------------------------------------------------------


def test_churn_trace_responsiveness_dimensions():
    trace = churn_trace(
        np.random.default_rng(0), 4, 12, model="markov",
        p_fail=0.1, p_recover=0.5, p_slow=0.5, slow_factor=3.0,
        p_partial=0.5, partial_floor=0.5,
    )
    assert trace.slow_at.shape == (12, 4)
    assert set(np.unique(trace.slow_at)) <= {1.0, 3.0}
    assert trace.work_at.shape == (12, 4)
    assert np.all((trace.work_at >= 0.5) & (trace.work_at <= 1.0))
    bf = trace.busy_factors()
    np.testing.assert_allclose(bf, trace.slow_at * trace.work_at)


def test_responsiveness_draws_do_not_shift_legacy_event_stream():
    kw = dict(model="markov", p_fail=0.2, p_recover=0.5)
    legacy = churn_trace(np.random.default_rng(7), 4, 12, **kw)
    extended = churn_trace(
        np.random.default_rng(7), 4, 12, **kw,
        p_slow=0.3, slow_factor=2.0, p_partial=0.3,
    )
    assert legacy.control_events() == extended.control_events()
    assert legacy.busy_factors() is None


def test_observe_round_work_fraction_scales_implied_speed():
    from repro.core.graphs import ComputeGraph

    rng = np.random.default_rng(6)
    tg = gossip_task_graph(rng, 8, degree_low=2, degree_high=3)
    C = rng.uniform(0, 1, (3, 3))
    np.fill_diagonal(C, 0)
    cg = ComputeGraph(e=np.ones(3), C=C)
    es = ElasticScheduler(tg, cg, method="greedy")
    loads = np.zeros(3)
    np.add.at(loads, es.current.assignment, tg.p)
    times = loads / es.compute_graph.e
    # Each machine reports its nominal time but only HALF the work done:
    # the EMA must see loads * work_fraction, not the nominal load, or
    # partial rounds poison the speed estimates upward.
    es.observe_round(times, work_fraction=np.full(3, 0.5))
    assert np.all(es.compute_graph.e < 1.0)
    with pytest.raises(ValueError, match="work_fraction"):
        es.observe_round(times, work_fraction=np.array([1.0, 1.5, 1.0]))
    with pytest.raises(ValueError, match="work_fraction"):
        es.observe_round(times, work_fraction=np.array([1.0]))
