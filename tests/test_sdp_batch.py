"""Batched SDP solves: batched == sequential, masking, cache hygiene.

The batched Douglas-Rachford path (``solve_sdp_batch``) stacks B
same-shape instances into ONE jitted dispatch with per-instance
convergence masking.  Its contract with the sequential jax path is
per-lane equivalence: each lane's iterate, residual, reported iteration
count, and full/partial projection decisions match its own
``solve_sdp`` call to float32 tolerance.  The batched fused rounding and
the ``schedule_batch`` service wrapper inherit the same contract, and the
rounding jit cache must keep batched and single-instance closures from
evicting each other.
"""

import numpy as np
import pytest

from repro.core import (
    ComputeGraph,
    SDPOptions,
    build_factored_bqp,
    random_compute_graph,
    random_task_graph,
    randomized_rounding,
    randomized_rounding_batch,
    schedule,
    schedule_batch,
    solve_sdp,
    solve_sdp_batch,
)
from repro.core import rounding as rounding_mod
from repro.core import sdp as sdp_mod

jax = pytest.importorskip("jax")

# float32 loop, two lowerings (vmapped vs single ops): agreement at a
# converged iterate is a few f32 ulps over n²-sized contractions.
F32_ATOL = 1e-3

# Converging settings: every lane crosses tol well inside the budget, so
# per-lane freezing (not the global loop exit) determines each lane's
# reported iterate — exactly the semantics under test.
OPTS = SDPOptions(max_iters=6000, check_every=50, tol=1e-4, backend="jax")


@pytest.fixture(scope="module")
def fleet():
    """One task graph, 8 compute graphs differing only in weights."""
    rng = np.random.default_rng(42)
    tg = random_task_graph(rng, 6, degree_low=1, degree_high=3)
    cg = random_compute_graph(rng, 3)
    cgs = [
        ComputeGraph(
            e=cg.e * rng.uniform(0.6, 1.5, size=cg.e.shape),
            C=cg.C * rng.uniform(0.6, 1.5),
        )
        for _ in range(8)
    ]
    return tg, cgs


@pytest.fixture(scope="module")
def sequential_solutions(fleet):
    tg, cgs = fleet
    return [solve_sdp(build_factored_bqp(tg, cg), OPTS) for cg in cgs]


@pytest.mark.parametrize("B", [2, 8])
def test_batch_matches_sequential(fleet, sequential_solutions, B):
    tg, cgs = fleet
    bqps = [build_factored_bqp(tg, cg) for cg in cgs[:B]]
    before = sdp_mod._BATCH_RUN_CALLS
    sols = solve_sdp_batch(bqps, OPTS)
    assert sdp_mod._BATCH_RUN_CALLS == before + 1   # ONE jitted dispatch
    assert len(sols) == B
    for i, (got, want) in enumerate(zip(sols, sequential_solutions)):
        assert got.stats["solver_backend"] == "jax"
        assert got.stats["batch"] == B
        assert got.stats["batch_index"] == i
        assert got.stats["batch_dispatches"] == 1
        assert got.converged and want.converged
        # identical projection decisions -> identical iteration trajectory
        assert got.iterations == want.iterations
        assert got.stats["eig_full"] == want.stats["eig_full"]
        assert got.stats["eig_partial"] == want.stats["eig_partial"]
        np.testing.assert_allclose(got.Y, want.Y, atol=F32_ATOL)
        assert np.isclose(got.residual, want.residual, atol=F32_ATOL)
        assert got.residual <= OPTS.tol


def test_converged_lane_reports_first_crossing(fleet, sequential_solutions):
    """A frozen lane reports the iteration its residual first crossed tol.

    The batched while_loop runs until the SLOWEST lane finishes; a lane
    that converged earlier must report its own crossing iteration (the
    sequential path's ``iterations``), not the global loop count.
    """
    tg, cgs = fleet
    bqps = [build_factored_bqp(tg, cg) for cg in cgs]
    sols = solve_sdp_batch(bqps, OPTS)
    iters = [s.iterations for s in sols]
    # the fleet's perturbed weights make lanes converge at different
    # iterations — otherwise freezing would be untested
    assert len(set(iters)) > 1
    global_count = max(iters)
    for got, want in zip(sols, sequential_solutions):
        assert got.iterations == want.iterations
        assert got.iterations <= global_count


def test_batch_rejects_mismatched_shapes(fleet):
    tg, cgs = fleet
    rng = np.random.default_rng(7)
    other_tg = random_task_graph(rng, 9, degree_low=1, degree_high=3)
    other_cg = random_compute_graph(rng, 3)
    with pytest.raises(ValueError, match="same-shape"):
        solve_sdp_batch(
            [build_factored_bqp(tg, cgs[0]),
             build_factored_bqp(other_tg, other_cg)],
            OPTS,
        )


def test_batched_rounding_matches_single_fused(fleet, sequential_solutions):
    """Batched rounding == the single fused jax backend, lane by lane."""
    tg, cgs = fleet
    B = 4
    bqps = [build_factored_bqp(tg, cg) for cg in cgs[:B]]
    sols = sequential_solutions[:B]
    batched = randomized_rounding_batch(
        bqps, [tg] * B, cgs[:B], [s.Y for s in sols],
        num_samples=256,
        rngs=[np.random.default_rng(0) for _ in range(B)],
        backend="jax",
        Y_devices=[s.Y_device for s in sols],
    )
    for bqp, cg, sol, got in zip(bqps, cgs, sols, batched):
        want = randomized_rounding(
            bqp, tg, cg, sol.Y,
            num_samples=256,
            rng=np.random.default_rng(0),
            backend="jax",
            Y_device=sol.Y_device,
        )
        np.testing.assert_array_equal(got.assignment, want.assignment)
        assert np.isclose(got.bottleneck, want.bottleneck, rtol=1e-6)
        assert got.num_feasible == want.num_feasible


def test_rounding_cache_batch_and_single_coexist(fleet):
    """Satellite regression: the rounding jit cache keys carry the batch
    dimension, so batched and single closures of the SAME instance shape
    are distinct entries and re-requests are LRU hits, not recompiles."""
    tg, cgs = fleet
    cg = cgs[0]
    n_e = len(tg.constraint_edges())
    single = rounding_mod._fused_rounding_fn(
        tg, cg, tg.num_tasks, cg.num_machines, False
    )
    b2 = rounding_mod._fused_rounding_batch_fn(
        2, tg.num_tasks, cg.num_machines, n_e, False
    )
    b4 = rounding_mod._fused_rounding_batch_fn(
        4, tg.num_tasks, cg.num_machines, n_e, False
    )
    # distinct closures per (kind, B); stable identity on re-request
    assert b2 is not b4
    assert single is not b2
    assert rounding_mod._fused_rounding_batch_fn(
        2, tg.num_tasks, cg.num_machines, n_e, False
    ) is b2
    assert rounding_mod._fused_rounding_fn(
        tg, cg, tg.num_tasks, cg.num_machines, False
    ) is single
    # both key shapes live in the one LRU; batched keys are shape-keyed
    # and tagged, single keys are content-keyed; the trailing element is
    # the resolved kernel backend
    keys = list(rounding_mod._JAX_CACHE)
    batch_keys = [k for k in keys if k[0] == "batch"]
    assert ("batch", 2, tg.num_tasks, cg.num_machines, n_e, False,
            "jnp") in keys
    assert ("batch", 4, tg.num_tasks, cg.num_machines, n_e, False,
            "jnp") in keys
    assert len(batch_keys) < len(keys)


def test_schedule_batch_matches_schedule(fleet):
    """The service wrapper: per-lane Schedules == sequential schedule()."""
    tg, cgs = fleet
    B = 3
    opts = SDPOptions(max_iters=3000, check_every=50, tol=1e-4)
    batched = schedule_batch(
        [tg] * B, cgs[:B], "sdp",
        seed=0, num_samples=256, sdp_options=opts, solver_backend="jax",
    )
    for cg, got in zip(cgs[:B], batched):
        want = schedule(
            tg, cg, "sdp",
            seed=0, num_samples=256, sdp_options=opts, solver_backend="jax",
        )
        np.testing.assert_array_equal(got.assignment, want.assignment)
        assert np.isclose(got.bottleneck, want.bottleneck, rtol=1e-6)
        assert got.info["sdp_iterations"] == want.info["sdp_iterations"]
        assert got.info["solver_stats"]["batch"] == B
