"""Dense-vs-factored equivalence + memory bounds for the matrix-free core.

The ``FactoredBQP`` representation must be *indistinguishable* from the
dense ``BQPData`` oracle on instances small enough to build both: identical
constraint rows, identical SDP iterates, identical seeded rounding — while
never materializing an (|E|, n, n) tensor on instances where the dense
stacks would not fit (DESIGN.md §2).
"""

import numpy as np
import pytest

from repro.core import (
    SDPOptions,
    build_bqp,
    build_factored_bqp,
    dense_bytes_estimate,
    random_compute_graph,
    random_task_graph,
    schedule,
    solve_sdp,
)
from repro.core import bqp as bqp_mod
from repro.core.rounding import (
    expected_bottleneck,
    optimal_upper_bound,
    sdp_lower_bound,
)
from repro.core.scheduler import _pick_representation
from repro.core.sdp import _AffineProjector


@pytest.fixture(scope="module")
def small_pair():
    rng = np.random.default_rng(11)
    tg = random_task_graph(rng, 6, degree_low=1, degree_high=3)
    cg = random_compute_graph(rng, 3)
    return tg, cg, build_bqp(tg, cg), build_factored_bqp(tg, cg)


def test_same_edges_and_scale(small_pair):
    _, _, dense, fac = small_pair
    assert fac.edges == dense.edges
    assert np.isclose(fac.q_scale, dense.q_scale, rtol=1e-12)


def test_constraint_rows_match_dense(small_pair):
    """Every factored CSR row densifies to the exact dense Q̃_e."""
    _, _, dense, fac = small_pair
    n1 = dense.n + 1
    for k in range(len(dense.edges)):
        idx, vals = fac.constraint_row(k)
        row = np.zeros(n1 * n1)
        row[idx] = vals
        np.testing.assert_allclose(
            row, dense.Q_tilde[k].reshape(-1), atol=1e-12
        )


def test_border_and_apply_match_dense(small_pair):
    _, _, dense, fac = small_pair
    n, n1 = dense.n, dense.n + 1
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n1)
    for k in range(len(dense.edges)):
        np.testing.assert_allclose(
            fac.border(k), dense.Q_tilde[k, :n, n], atol=1e-12
        )
        np.testing.assert_allclose(
            fac.apply(k, x), dense.Q_tilde[k] @ x, atol=1e-10
        )


def test_inner_matches_dense_einsum(small_pair):
    """<Q̃_e, Y> within 1e-9 of the dense einsum (acceptance criterion)."""
    _, _, dense, fac = small_pair
    rng = np.random.default_rng(1)
    for _ in range(5):
        F = rng.standard_normal((dense.n + 1, dense.n + 1))
        F = 0.5 * (F + F.T)
        want = np.einsum("eij,ij->e", dense.Q_tilde, F)
        np.testing.assert_allclose(fac.inner(F), want, atol=1e-9)


def test_bound_formulas_match(small_pair):
    _, _, dense, fac = small_pair
    rng = np.random.default_rng(2)
    Y = rng.standard_normal((dense.n + 1, dense.n + 1))
    Y = 0.5 * (Y + Y.T)
    np.fill_diagonal(Y, 1.0)
    assert np.isclose(sdp_lower_bound(fac, Y), sdp_lower_bound(dense, Y))
    assert np.isclose(
        expected_bottleneck(fac, Y), expected_bottleneck(dense, Y)
    )
    assert np.isclose(
        optimal_upper_bound(fac, Y), optimal_upper_bound(dense, Y)
    )


def test_projector_rows_match(small_pair):
    """The factored CSR constraint system equals the dense projector's."""
    _, _, dense, fac = small_pair
    pd = _AffineProjector(dense, sparse=False)
    pf = _AffineProjector(fac)
    Lf = np.asarray(pf.L.todense())
    np.testing.assert_allclose(Lf, pd.L, atol=1e-12)
    np.testing.assert_allclose(pf.b, pd.b, atol=1e-15)


def test_sdp_iterates_match(small_pair):
    """Same solver trajectory from both representations (tiny instance)."""
    _, _, dense, fac = small_pair
    opts = SDPOptions(max_iters=200, tol=0.0)  # fixed iteration count
    sol_d = solve_sdp(dense, opts)
    sol_f = solve_sdp(fac, opts)
    assert sol_d.iterations == sol_f.iterations
    np.testing.assert_allclose(sol_f.Y, sol_d.Y, atol=1e-9)
    assert np.isclose(sol_f.t, sol_d.t, atol=1e-9)
    assert sol_d.stats["representation"] == "dense"
    assert sol_f.stats["representation"] == "factored"


def test_seeded_rounding_same_assignment(small_pair):
    """Identical assignments from seeded rounding (acceptance criterion)."""
    tg, cg, _, _ = small_pair
    kw = dict(
        method="sdp",
        seed=3,
        num_samples=500,
        sdp_options=SDPOptions(max_iters=800),
        rounding_backend="numpy",
    )
    s_d = schedule(tg, cg, representation="dense", **kw)
    s_f = schedule(tg, cg, representation="factored", **kw)
    assert s_d.info["representation"] == "dense"
    assert s_f.info["representation"] == "factored"
    np.testing.assert_array_equal(s_d.assignment, s_f.assignment)
    assert np.isclose(s_d.bottleneck, s_f.bottleneck)


def test_auto_representation_switch():
    rng = np.random.default_rng(5)
    tg_small = random_task_graph(rng, 8, degree_low=1, degree_high=2)
    cg_small = random_compute_graph(rng, 3)
    assert _pick_representation(tg_small, cg_small, "auto") == "dense"
    tg_big = random_task_graph(rng, 128, degree_low=2, degree_high=4)
    cg_big = random_compute_graph(rng, 16)
    assert dense_bytes_estimate(tg_big, cg_big) > 100_000_000
    assert _pick_representation(tg_big, cg_big, "auto") == "factored"
    with pytest.raises(ValueError):
        _pick_representation(tg_small, cg_small, "bogus")


def test_memory_bound_no_dense_stack(monkeypatch):
    """N_T=64, N_K=8 (n=512) schedules without any (|E|, n, n) array.

    ``build_bqp`` (the only constructor of dense stacks) is poisoned, and
    the solver's own accounting must stay far below the dense footprint.
    """
    rng = np.random.default_rng(7)
    tg = random_task_graph(rng, 64, degree_low=2, degree_high=4)
    cg = random_compute_graph(rng, 8)

    def _poisoned(*a, **k):  # pragma: no cover - failure path
        raise AssertionError("dense build_bqp called on factored-only path")

    monkeypatch.setattr(bqp_mod, "build_bqp", _poisoned)

    s = schedule(
        tg,
        cg,
        method="sdp",
        representation="factored",
        num_samples=256,
        sdp_options=SDPOptions(max_iters=40, check_every=10),
        rounding_backend="numpy",
        seed=0,
    )
    assert s.info["representation"] == "factored"
    assert np.all((0 <= s.assignment) & (s.assignment < 8))
    assert np.isfinite(s.bottleneck)
    stats = s.info["solver_stats"]
    dense_bytes = dense_bytes_estimate(tg, cg)
    # factored peak must be far below the dense stacks it replaces
    assert stats["peak_tensor_bytes"] < dense_bytes / 10
    # n=512 pushes the constraint count past the Cholesky threshold
    assert stats["constraint_rows"] > 512
