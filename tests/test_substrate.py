"""Substrate tests: optimizer, data pipeline, checkpointing, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.synthetic import LMStream, image_dataset
from repro.train.compression import Int8, TopK, message_bytes
from repro.train.optim import AdamW, SGDM, cosine_warmup_schedule, global_norm


def test_adamw_optimizes_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2
    assert int(state.step) == 150


def test_sgdm_optimizes_quadratic():
    opt = SGDM(learning_rate=0.05, momentum=0.9)
    params = {"w": jnp.asarray([2.0, -1.0])}
    state = opt.init(params)
    for _ in range(100):
        params, state, _ = opt.update({"w": 2 * params["w"]}, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_cosine_schedule_shape():
    fn = cosine_warmup_schedule(1e-3, 10, 100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert np.isclose(float(fn(jnp.asarray(10))), 1e-3, rtol=0.1)
    assert float(fn(jnp.asarray(100))) < 2e-4


def test_grad_clip_bounds_update():
    opt = AdamW(learning_rate=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.asarray([1e6, 0, 0])}, state, params)
    assert float(gnorm) > 1e5  # reported norm is pre-clip


def test_lm_stream_deterministic_and_sharded():
    s = LMStream(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    b1 = s.batch(5, shard=0, num_shards=2)
    b2 = s.batch(5, shard=0, num_shards=2)
    b3 = s.batch(5, shard=1, num_shards=2)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].max() < 97


def test_image_dataset_learnable_structure():
    train, test = image_dataset("mnist", 512, seed=1)
    assert train.x.shape[1:] == (28, 28, 1)
    ctrain, _ = image_dataset("cifar10", 256, seed=1)
    assert ctrain.x.shape[1:] == (32, 32, 3)
    # class templates distinct: same-class distance < cross-class distance
    m0 = train.x[train.y == 0].mean(0)
    m1 = train.x[train.y == 1].mean(0)
    assert np.abs(m0 - m1).mean() > 0.01


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "step": jnp.asarray(7),
    }
    mgr.save(7, state, metadata={"note": "t"})
    mgr.save(9, state)
    mgr.save(11, state)
    assert mgr.all_steps() == [9, 11]          # keep=2 garbage-collects
    loaded, manifest = mgr.load(state)
    assert manifest["step"] == 11
    np.testing.assert_array_equal(loaded["params"]["w"], state["params"]["w"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((2, 2))})
    try:
        mgr.load({"w": jnp.zeros((3, 3))})
        raise AssertionError("should have raised")
    except ValueError:
        pass


def test_topk_compression_error_feedback():
    comp = TopK(fraction=0.25)
    x = {"a": jnp.asarray(np.random.default_rng(0).standard_normal(64))}
    c, resid = comp.compress(x)
    dec = comp.decompress(c)
    np.testing.assert_allclose(
        np.asarray(dec["a"] + resid["a"]), np.asarray(x["a"]), atol=1e-6
    )
    assert comp.compressed_bytes(x) < message_bytes(x)


def test_int8_compression_small_error():
    comp = Int8()
    x = {"a": jnp.asarray(np.random.default_rng(0).standard_normal(128) * 3)}
    c, resid = comp.compress(x)
    dec = comp.decompress(c)
    err = float(jnp.max(jnp.abs(dec["a"] - x["a"])))
    assert err <= float(jnp.max(jnp.abs(x["a"]))) / 127 + 1e-6
    assert comp.compressed_bytes(x) < message_bytes(x)
