"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(assignment requirement c)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.gossip_mix import gossip_mix_all_fwd, gossip_mix_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd

rng = np.random.default_rng(0)


def t(shape, dt=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dt)


FLASH_CASES = [
    (1, 256, 4, 2, 64, True, 0, jnp.float32),
    (2, 512, 4, 1, 32, True, 128, jnp.float32),
    (1, 256, 2, 2, 128, False, 0, jnp.float32),
    (1, 256, 4, 4, 64, True, 0, jnp.bfloat16),
    (2, 128, 8, 2, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,hkv,d,causal,window,dt", FLASH_CASES)
def test_flash_kernel_vs_ref(b, s, h, hkv, d, causal, window, dt):
    q, k, v = t((b, h, s, d), dt), t((b, hkv, s, d), dt), t((b, hkv, s, d), dt)
    got = flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=128, block_k=128,
        interpret=True,
    )
    want = kref.flash_attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=atol
    )


DECODE_CASES = [
    (2, 512, 8, 2, 64, jnp.float32),
    (1, 1024, 4, 4, 32, jnp.float32),
    (2, 256, 4, 1, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,hkv,d,dt", DECODE_CASES)
def test_decode_kernel_vs_ref(b, s, h, hkv, d, dt):
    q, kc, vc = t((b, h, d), dt), t((b, s, hkv, d), dt), t((b, s, hkv, d), dt)
    vl = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    got = decode_attention_fwd(q, kc, vc, vl, block_k=128, interpret=True)
    want = kref.decode_attention_ref(q, kc, vc, vl)
    atol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=atol
    )


@pytest.mark.parametrize("r,d,dt", [(256, 768, jnp.float32),
                                    (512, 1024, jnp.bfloat16),
                                    (128, 4096, jnp.float32)])
def test_rmsnorm_kernel_vs_ref(r, d, dt):
    x = t((r, d), dt)
    w = t((d,)) * 0.1
    got = rmsnorm_fwd(x, w, block_rows=64, interpret=True)
    want = kref.rmsnorm_ref(x, w)
    atol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=atol
    )


@pytest.mark.parametrize("n,l", [(4, 65536), (9, 131072), (2, 8192)])
def test_gossip_mix_kernel_vs_ref(n, l):
    st = t((n, l))
    w = jnp.abs(t((n,)))
    w = w / jnp.sum(w)
    got = gossip_mix_fwd(st, w, block_len=8192, interpret=True)
    want = kref.gossip_mix_ref(st, w)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("n,l,bl", [(8, 32768, 8192), (16, 16384, 16384),
                                    (5, 4096, 4096)])
def test_gossip_mix_all_kernel_vs_refs(n, l, bl):
    """Batched all-receivers mixing == dense oracle == segment_sum ref,
    including an isolated receiver (empty W row)."""
    st = t((n, l))
    erng = np.random.default_rng(7)
    deg = 3
    src = np.repeat(np.arange(n), deg).astype(np.int32)
    dst = erng.integers(0, n, size=n * deg).astype(np.int32)
    keep = dst != 0                       # receiver 0 stays isolated
    src, dst = src[keep], dst[keep]
    w_edge = erng.random(src.size).astype(np.float32)
    W = np.zeros((n, n), np.float32)
    np.add.at(W, (dst, src), w_edge)
    got = gossip_mix_all_fwd(st, jnp.asarray(W), block_len=bl, interpret=True)
    want = kref.gossip_mix_all_ref(st, jnp.asarray(W))
    seg = kref.gossip_mix_segment_ref(
        st, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w_edge), n
    )
    np.testing.assert_allclose(got, want, atol=2e-4)
    np.testing.assert_allclose(np.asarray(seg), np.asarray(want), atol=2e-4)
    assert np.all(np.asarray(got)[0] == 0.0)      # empty row -> zero mix


def test_ops_wrappers_roundtrip():
    """Public ops accept model layout (B, S, H, D)."""
    q, k, v = t((1, 256, 4, 2 * 32)).reshape(1, 256, 4, 64), \
        t((1, 256, 2, 64)), t((1, 256, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True)
    assert out.shape == q.shape
    x = t((4, 128, 256))
    w = t((256,)) * 0.1
    assert ops.rmsnorm(x, w).shape == x.shape
