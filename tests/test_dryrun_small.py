"""Integration: the dry-run machinery on a small forced-device mesh.

Runs in a subprocess so the 16 fake CPU devices don't leak into the main
pytest process (jax locks the device count at first init).
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, jax
import repro.launch.dryrun as dr

def small_mesh(multi_pod=False):
    if multi_pod:
        return jax.make_mesh((2, 2, 4), ("pod", "data", "model"))
    return jax.make_mesh((4, 4), ("data", "model"))

dr.make_production_mesh = small_mesh
out = []
for arch, shape, mp in [
    ("granite-3-2b", "train_4k", False),
    ("granite-3-2b", "decode_32k", False),
    ("olmoe-1b-7b", "train_4k", True),
]:
    rec = dr.run_cell(arch, shape, mp, "")
    out.append({k: rec.get(k) for k in
                ("arch", "shape", "status", "error", "la_flops_per_device",
                 "la_link_bytes_per_device", "dominant",
                 "useful_flops_ratio")})
print("RESULT::" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[0][len("RESULT::"):])


def test_all_cells_compile(results):
    for rec in results:
        assert rec["status"] == "ok", rec


def test_flops_and_collectives_recorded(results):
    for rec in results:
        assert rec["la_flops_per_device"] > 0
        assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")
    train = results[0]
    assert train["la_link_bytes_per_device"] > 0   # sharded training communicates


def test_useful_ratio_sane(results):
    train = results[0]
    assert 0.2 < train["useful_flops_ratio"] < 3.0, train
