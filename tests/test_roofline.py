"""``benchmarks.roofline.sdp_batch_profile`` on a tiny instance: every
documented field present, finite, and internally consistent."""

import math

import numpy as np
import pytest

pytest.importorskip("jax")

from benchmarks.roofline import sdp_batch_profile  # noqa: E402

FLOAT_FIELDS = (
    "matvec_seconds",
    "cone_partial_seconds",
    "cone_partial_fused_seconds",
    "matvec_gflops",
    "intensity_flops_per_byte",
    "fused_traffic_ratio",
    "cone_intensity_jnp",
    "cone_intensity_fused",
    "peak_gemm_gflops",
    "peak_stream_gbs",
    "machine_balance_flops_per_byte",
)


@pytest.fixture(scope="module")
def row():
    # tiny probe: n1 = 4·2 + 1 = 9, one warm rep — seconds, not minutes
    return sdp_batch_profile(num_tasks=4, num_machines=2, batch=2, reps=1)


def test_profile_fields_finite(row):
    assert row is not None
    for f in FLOAT_FIELDS:
        assert f in row, f
        assert math.isfinite(row[f]) and row[f] > 0, (f, row[f])
    assert row["n1"] == 9 and row["batch"] == 2
    # k clamps below n1 on tiny instances (qr well-posedness)
    assert 1 <= row["k"] < row["n1"]
    assert row["verdict"] in ("memory_bound", "compute_bound")
    assert row["pallas_item5"] in ("go", "no_go")
    assert row["fused_mode"] in ("interpret", "compiled")


def test_profile_traffic_model_consistent(row):
    """Fused streams < jnp streams; intensities scale with the ratio."""
    assert row["y_slab_streams_fused"] < row["y_slab_streams_jnp"]
    assert row["fused_traffic_ratio"] == pytest.approx(
        row["y_slab_streams_jnp"] / row["y_slab_streams_fused"]
    )
    assert row["cone_intensity_fused"] > row["cone_intensity_jnp"]
    assert row["cone_intensity_fused"] == pytest.approx(
        row["cone_intensity_jnp"] * row["fused_traffic_ratio"]
    )
    # verdict is derived from the recorded quantities
    want = (
        "memory_bound"
        if row["intensity_flops_per_byte"]
        < row["machine_balance_flops_per_byte"]
        else "compute_bound"
    )
    assert row["verdict"] == want


def test_profile_does_not_write_json(tmp_path, row):
    """record_json defaults off: probing (e.g. from tests) must not touch
    BENCH_scheduler_scaling.json."""
    import pathlib

    import benchmarks.roofline as rl

    path = pathlib.Path(rl.__file__).resolve().parent.parent / (
        "BENCH_scheduler_scaling.json"
    )
    before = path.read_text() if path.exists() else None
    sdp_batch_profile(num_tasks=4, num_machines=2, batch=1, reps=1)
    after = path.read_text() if path.exists() else None
    assert before == after


def test_profile_numpy_free_of_nan(row):
    assert np.isfinite(
        [row[f] for f in FLOAT_FIELDS]
    ).all()
