"""SDP relaxation + randomized rounding: the paper's bound sandwich."""

import numpy as np
import pytest

from repro.core import (
    SDPOptions,
    brute_force_optimum,
    build_bqp,
    expected_bottleneck,
    naive_rounding,
    optimal_upper_bound,
    randomized_rounding,
    random_compute_graph,
    random_task_graph,
    sdp_lower_bound,
    solve_sdp,
)
from repro.core.bqp import bottleneck_time


@pytest.fixture(scope="module")
def solved():
    rng = np.random.default_rng(42)
    tg = random_task_graph(rng, 6, degree_low=1, degree_high=3)
    cg = random_compute_graph(rng, 3)
    data = build_bqp(tg, cg)
    sol = solve_sdp(data, SDPOptions(max_iters=4000, tol=1e-7))
    _, t_star = brute_force_optimum(tg, cg)
    return tg, cg, data, sol, t_star


def test_solution_is_valid_covariance(solved):
    _, _, _, sol, _ = solved
    Y = sol.Y
    assert np.allclose(np.diag(Y), 1.0, atol=1e-6)
    w = np.linalg.eigvalsh(0.5 * (Y + Y.T))
    assert w.min() > -1e-6


def test_bound_sandwich(solved):
    """Eq. 24/27: SDP lower bound <= OPT <= best rounded <= paper UB region."""
    tg, cg, data, sol, t_star = solved
    res = randomized_rounding(
        data, tg, cg, sol.Y, num_samples=4000,
        rng=np.random.default_rng(0), backend="numpy",
    )
    assert res.lower_bound <= t_star * 1.05 + 1e-6   # first-order slack
    assert t_star <= res.bottleneck + 1e-9
    assert res.bottleneck <= res.expected_bottleneck * 1.5 + 1e-6


def test_rounding_near_optimal_small(solved):
    tg, cg, data, sol, t_star = solved
    res = randomized_rounding(
        data, tg, cg, sol.Y, num_samples=4000,
        rng=np.random.default_rng(0), backend="numpy",
    )
    assert res.bottleneck <= t_star * 1.35 + 1e-9


def test_naive_rounding_feasible(solved):
    tg, cg, data, sol, _ = solved
    a = naive_rounding(data, sol.Y)
    assert a.shape == (tg.num_tasks,)
    assert np.all((0 <= a) & (a < cg.num_machines))
    assert np.isfinite(bottleneck_time(tg, cg, a))


def test_expected_value_formula_matches_monte_carlo(solved):
    """Appendix A arcsin identity vs empirical sign-sample average."""
    tg, cg, data, sol, _ = solved
    rng = np.random.default_rng(9)
    w, V = np.linalg.eigh(sol.Y)
    root = V * np.sqrt(np.clip(w, 0, None))
    z = rng.standard_normal((200_000, sol.Y.shape[0])) @ root.T
    s = np.sign(z)
    k = np.argmax([np.sum(np.abs(q)) for q in data.Q_tilde])
    emp = np.mean(np.einsum("ni,ij,nj->n", s, data.Q_tilde[k], s)) / 4.0
    asin = (2 / np.pi) * np.sum(
        data.Q_tilde[k] * np.arcsin(np.clip(sol.Y, -1, 1))
    ) / 4.0
    assert np.isclose(emp, asin, rtol=0.05)


def test_jax_rounding_backend_matches_numpy(solved):
    tg, cg, data, sol, _ = solved
    r_np = randomized_rounding(
        data, tg, cg, sol.Y, num_samples=1000,
        rng=np.random.default_rng(3), backend="numpy",
    )
    r_jx = randomized_rounding(
        data, tg, cg, sol.Y, num_samples=1000,
        rng=np.random.default_rng(3), backend="jax",
    )
    assert np.isclose(r_np.bottleneck, r_jx.bottleneck, rtol=1e-4)
