PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test smoke churn_smoke async_fl_smoke kernel_diff_smoke shard_fl_smoke ci docs-check bench-scheduler bench-gossip bench-kernels bench-scenarios bench-async bench-churn bench-async-fl

# Tier-1 verification (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# Fast scheduler smoke benchmark: small-instance backends + a two-point
# scaling sweep exercising both the dense and the factored representation,
# plus the jax-solver smoke (asserts the device SDP path didn't silently
# fall back to numpy), the stacked-gossip smoke (a 2-round stacked MNIST
# gossip run asserting the single-jit round path took effect), and the
# sync-equivalence smoke (asserts the event engine's sync semantics still
# reproduces Eq. 2 round times to 1e-9 — the engine cannot drift from the
# paper's model), the batched-solver smoke (asserts a B=8 stacked SDP
# solve is ONE jitted dispatch with all lanes converged), and the churn
# smoke (a short injected-timeout churn trace: arrivals re-solve, the
# heft fallback activates, regret vs the oracle stays finite), and the
# async-FL smoke (the barrier-free trainer's degenerate anchor reproduces
# the stacked losses to fp32, and a straggler replay mixes stale
# snapshots with zero barrier stalls), and the kernel-diff smoke (every
# fused Pallas kernel matches its jnp oracle in interpret mode, and a
# tiny seeded SDP solve with the fused projection on vs off follows the
# identical iteration trajectory), and the shard-FL smoke (the
# mesh-sharded engine on 2 fake host devices reproduces the stacked
# per-round losses to fp32 with ONE jitted dispatch per round — a fresh
# interpreter because the forced device count must precede jax's first
# init).
smoke:
	$(PYTHON) -c "import benchmarks.scheduler_bench as b; \
	b.small_instance_backends(quick=True); \
	[b.emit('smoke_nt%d' % r['n_tasks'], r['solve_seconds'] * 1e6, \
	        'rep=%s;peak_mb=%.1f' % (r['representation'], r['peak_tensor_bytes'] / 1e6)) \
	 for r in (b._sweep_point(8, 8, max_iters=150, num_samples=256), \
	           b._sweep_point(40, 8, max_iters=60, num_samples=256))]; \
	b.jax_solver_smoke(); \
	b.batched_solver_smoke()"
	$(PYTHON) -c "import benchmarks.fig6_gossip_fl as f; f.stacked_smoke()"
	$(PYTHON) -c "import benchmarks.async_bench as a; a.sync_equivalence_smoke()"
	$(PYTHON) -c "import benchmarks.churn_bench as c; c.churn_smoke()"
	$(PYTHON) -c "import benchmarks.async_fl_bench as a; a.async_fl_smoke()"
	$(PYTHON) -c "import benchmarks.kernels_bench as k; k.kernel_diff_smoke()"
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	$(PYTHON) -c "import benchmarks.fig6_gossip_fl as f; f.sharded_smoke()"

# Shard-FL smoke alone: mesh=2 (fake host devices) sharded engine vs the
# stacked backend — per-round loss equivalence to fp32, one dispatch per
# round, no retracing.
shard_fl_smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	$(PYTHON) -c "import benchmarks.fig6_gossip_fl as f; f.sharded_smoke()"

# Churn smoke alone: a short injected-timeout churn trace asserting that
# arrivals trigger elastic re-solves, a stalled SDP degrades to the heft
# fallback instead of wedging the trace, and regret vs the oracle stays
# finite.
churn_smoke:
	$(PYTHON) -c "import benchmarks.churn_bench as c; c.churn_smoke()"

# Async-FL smoke alone: the degenerate anchor (all-active + fresh
# versions + s === 1 reproduces the stacked per-round losses to fp32) and
# a straggler replay that mixes stale snapshots with zero barrier stalls.
async_fl_smoke:
	$(PYTHON) -c "import benchmarks.async_fl_bench as a; a.async_fl_smoke()"

# Kernel-diff smoke alone: every fused Pallas kernel (SDP subspace
# projection, rank-k clip, top-k/int8 delta compression, one-hot
# bottleneck evaluation) vs its jnp oracle in interpret mode, plus a
# tiny seeded solve_sdp with kernel_backend on vs off asserting the
# identical iteration trajectory.
kernel_diff_smoke:
	$(PYTHON) -c "import benchmarks.kernels_bench as k; k.kernel_diff_smoke()"

# Docs health: intra-repo markdown links resolve and the documented
# quickstart command still runs (see scripts/check_docs.py).
docs-check:
	$(PYTHON) scripts/check_docs.py

# Regenerate the BENCH_*.json records (schemas: docs/benchmarks.md)
bench-scheduler:
	$(PYTHON) -c "import benchmarks.scheduler_bench as b; \
	b.scaling_sweep(quick=False); b.batch_sweep(quick=False)"

# SHARDED=1 additionally records the population-scale mesh-sharded sweep
# (N_T up to 10k over 8 fake host devices) under the "sharded" key.
bench-gossip:
	$(PYTHON) -c "import benchmarks.fig6_gossip_fl as f; f.sweep()"
ifneq ($(SHARDED),)
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHON) -c "import benchmarks.fig6_gossip_fl as f; f.sharded_sweep()"
endif

bench-kernels:
	$(PYTHON) -c "import benchmarks.kernels_bench as k; \
	k.main(quick=False, record_json=True)"
	$(PYTHON) -c "import benchmarks.roofline as r; \
	r.sdp_batch_profile(batch=8, record_json=True)"

bench-scenarios:
	$(PYTHON) -c "import benchmarks.scenarios_bench as s; s.main(quick=True, resume=False)"

bench-async:
	$(PYTHON) -c "import benchmarks.async_bench as a; a.main(quick=True, resume=False)"

bench-churn:
	$(PYTHON) -c "import benchmarks.churn_bench as c; c.main(quick=True, resume=False)"

bench-async-fl:
	$(PYTHON) -c "import benchmarks.async_fl_bench as a; a.main(quick=True)"

ci: test smoke
