"""repro: bottleneck-time-minimizing scheduling for distributed iterative
training (Kiamari & Krishnamachari 2021) as a first-class feature of a
JAX training/serving framework."""

__version__ = "1.0.0"
