"""Version compatibility shims for JAX APIs used across the repo.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way.  Call sites in this repo use
the modern spelling (``from repro.compat import shard_map`` with
``check_vma=...``); this module translates for whichever JAX is installed.
"""

from __future__ import annotations

import inspect

try:  # modern JAX
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg auto-translated."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
