"""Version compatibility shims for JAX APIs used across the repo.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way.  Call sites in this repo use
the modern spelling (``from repro.compat import shard_map`` with
``check_vma=...``); this module translates for whichever JAX is installed.

It also hosts the dependency gates the control-plane code uses to degrade
gracefully when JAX is absent (``jax_available``) and a ``segment_sum``
re-export: the device-resident SDP solver builds its CSR matvecs on it, and
``jax.ops.segment_sum`` has moved namespaces before, so the import is
funneled through here with a scatter-add fallback.
"""

from __future__ import annotations

import functools
import inspect

try:  # modern JAX
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg auto-translated."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


@functools.lru_cache(maxsize=1)
def jax_available() -> bool:
    """True when JAX imports cleanly.

    Control-plane code (the SDP solver backends, the fused rounding path)
    gates its device paths on this instead of importing eagerly, so the
    numpy float64 reference paths keep working in a JAX-less environment.
    """
    try:
        import jax  # noqa: F401
    except Exception:
        return False
    return True


def segment_sum(data, segment_ids, num_segments):
    """``jax.ops.segment_sum`` for whichever JAX is installed.

    Falls back to an explicit scatter-add when ``jax.ops`` no longer ships
    the helper (it has migrated namespaces before); both spellings lower to
    the same scatter-add HLO.
    """
    import jax

    seg = getattr(getattr(jax, "ops", None), "segment_sum", None)
    if seg is not None:
        return seg(data, segment_ids, num_segments=num_segments)
    import jax.numpy as jnp

    out = jnp.zeros((num_segments,) + data.shape[1:], dtype=data.dtype)
    return out.at[segment_ids].add(data)
