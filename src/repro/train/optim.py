"""Optimizers and LR schedules in pure JAX (no optax in this environment).

AdamW with fp32 moments; state is a pytree mirroring params so any param
sharding (FSDP/TP) applies ZeRO-style to the optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray           # () int32
    m: dict                     # pytree like params, f32
    v: dict                     # pytree like params, f32


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9)) \
            if self.grad_clip else 1.0

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


@dataclasses.dataclass(frozen=True)
class SGDM:
    """SGD with momentum — used by the gossip-FL CNN experiments.

    ``update`` is a pure pytree map, so it composes with ``jax.vmap`` /
    ``lax.scan`` — the stacked gossip engine vmaps it across users inside
    one jitted round (DESIGN.md §8).
    """

    learning_rate: float = 0.05
    momentum: float = 0.9

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(self, grads, state, params):
        new_b = jax.tree.map(
            lambda g, b: self.momentum * b + g.astype(jnp.float32), grads, state
        )
        new_p = jax.tree.map(
            lambda p, b: (
                p.astype(jnp.float32) - self.learning_rate * b
            ).astype(p.dtype),
            params,
            new_b,
        )
        return new_p, new_b, global_norm(grads)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def cosine_warmup_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.1
):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
