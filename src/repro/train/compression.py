"""Gossip-message compression: top-k sparsification and int8 quantization.

The scheduler's delay matrix is C[j,j'] = message_bytes / bandwidth, so
compression shrinks C proportionally — ``compressed_bytes`` feeds straight
back into re-scheduling (DESIGN.md §8).  Compression is applied to the
*delta* from the previous round (error feedback keeps the residual).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TopK:
    """Keep the top ``fraction`` entries (by magnitude) of each leaf."""

    fraction: float = 0.05

    def compress(self, tree: Any) -> tuple[Any, Any]:
        """-> (compressed repr, residual)."""

        def one(x):
            flat = x.reshape(-1)
            k = max(1, int(self.fraction * flat.size))
            vals, idx = jax.lax.top_k(jnp.abs(flat), k)
            kept = flat[idx]
            mask = jnp.zeros_like(flat).at[idx].set(kept)
            return (idx, kept, x.shape), (flat - mask).reshape(x.shape)

        leaves, treedef = jax.tree_util.tree_flatten(tree)
        outs = [one(l) for l in leaves]
        comp = treedef.unflatten([o[0] for o in outs])
        resid = treedef.unflatten([o[1] for o in outs])
        return comp, resid

    def decompress(self, comp: Any) -> Any:
        def one(c):
            idx, kept, shape = c
            flat = jnp.zeros(int(np.prod(shape)), kept.dtype).at[idx].set(kept)
            return flat.reshape(shape)

        leaves, treedef = jax.tree_util.tree_flatten(
            comp, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        )
        return treedef.unflatten([one(l) for l in leaves])

    def compressed_bytes(self, tree: Any) -> int:
        n = sum(l.size for l in jax.tree_util.tree_leaves(tree))
        k = int(self.fraction * n)
        return k * (4 + 4)          # int32 index + f32 value

    def roundtrip(self, tree: Any) -> Any:
        """decompress(compress(tree)) as one array-only pytree map.

        The stacked gossip engine vmaps this across users inside its jitted
        round; the error-feedback residual is ``tree - roundtrip(tree)``
        (identical to the residual ``compress`` returns).
        """

        def one(x):
            flat = x.reshape(-1)
            k = max(1, int(self.fraction * flat.size))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(x.shape)

        return jax.tree.map(one, tree)


@dataclasses.dataclass(frozen=True)
class Int8:
    """Symmetric per-leaf int8 quantization with f32 scale."""

    def compress(self, tree: Any) -> tuple[Any, Any]:
        def one(x):
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            return (q, scale), x - q.astype(x.dtype) * scale

        leaves, treedef = jax.tree_util.tree_flatten(tree)
        outs = [one(l) for l in leaves]
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )

    def decompress(self, comp: Any) -> Any:
        def one(c):
            q, scale = c
            return q.astype(jnp.float32) * scale

        leaves, treedef = jax.tree_util.tree_flatten(
            comp, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        )
        return treedef.unflatten([one(l) for l in leaves])

    def compressed_bytes(self, tree: Any) -> int:
        n = sum(l.size for l in jax.tree_util.tree_leaves(tree))
        return n + 4 * len(jax.tree_util.tree_leaves(tree))

    def roundtrip(self, tree: Any) -> Any:
        """decompress(compress(tree)) as one array-only pytree map (see TopK)."""

        def one(x):
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            return (q.astype(x.dtype) * scale).astype(x.dtype)

        return jax.tree.map(one, tree)


def message_bytes(tree: Any, compressor=None) -> int:
    if compressor is not None:
        return compressor.compressed_bytes(tree)
    return int(sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree)))
