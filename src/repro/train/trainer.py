"""Train/serve step builders shared by the launcher, dry-run, and tests.

``TrainState`` is a plain dict pytree {"params", "opt"} so partition specs
mirror cleanly (ZeRO-3: optimizer moments inherit the param shardings).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.api import ModelAPI
from repro.train.optim import AdamW, AdamWState


def init_train_state(api: ModelAPI, optimizer: AdamW, rng) -> dict:
    params = api.init_params(rng)
    return {"params": params, "opt": optimizer.init(params)}


def make_train_step(api: ModelAPI, optimizer: AdamW, rules=None,
                    microbatches: int | None = None) -> Callable:
    """(state, batch) -> (state, metrics).  Pure; jit with donate_argnums=0.

    ``microbatches`` > 1 enables gradient accumulation: the global batch is
    split along its batch dim and scanned, bounding saved activations to
    one microbatch (required to fit the 88-layer/123B cells in HBM).
    """
    mb = microbatches if microbatches is not None else api.cfg.train_microbatches
    cfg = api.cfg

    def cast(params):
        """Mixed precision: bf16 compute copies of the f32 masters, cast
        once per step so FSDP all-gathers move bf16 (2x fewer bytes).  The
        cast is linear, so grads w.r.t. the bf16 copies are the master
        grads up to bf16 rounding (standard mixed-precision training)."""
        if not cfg.cast_params_once:
            return params
        return jax.tree.map(
            lambda p: p.astype(cfg.dtype)
            if p.dtype == jnp.float32
            else p,
            params,
        )

    def loss_of(params, batch):
        return api.loss_fn(params, batch, rules)

    if mb <= 1:
        def train_step(state, batch):
            loss, grads = jax.value_and_grad(loss_of)(cast(state["params"]), batch)
            new_params, new_opt, gnorm = optimizer.update(
                grads, state["opt"], state["params"]
            )
            metrics = {
                "loss": loss.astype(jnp.float32),
                "grad_norm": gnorm.astype(jnp.float32),
                "step": new_opt.step,
            }
            return {"params": new_params, "opt": new_opt}, metrics

        return train_step

    def split(x):
        # positions carry a leading (3,) M-RoPE axis; scan axis must lead
        if x.ndim >= 2 and x.shape[0] == 3:
            r = x.reshape((3, mb, x.shape[1] // mb) + x.shape[2:])
            return jnp.swapaxes(r, 0, 1)
        return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

    def unsplit(x):
        if x.ndim >= 3 and x.shape[1] == 3:
            return jnp.swapaxes(x, 0, 1)
        return x

    def train_step(state, batch):
        micro = jax.tree.map(split, batch)
        params_c = cast(state["params"])

        def body(carry, mbatch):
            grads_acc, loss_acc = carry
            mbatch = jax.tree.map(unsplit, mbatch)
            loss, grads = jax.value_and_grad(loss_of)(params_c, mbatch)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads
            )
            return (grads_acc, loss_acc + loss.astype(jnp.float32)), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
        )
        (grads, loss), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro
        )
        grads = jax.tree.map(lambda g: g / mb, grads)
        loss = loss / mb
        new_params, new_opt, gnorm = optimizer.update(
            grads, state["opt"], state["params"]
        )
        metrics = {
            "loss": loss,
            "grad_norm": gnorm.astype(jnp.float32),
            "step": new_opt.step,
        }
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(api: ModelAPI, rules=None) -> Callable:
    def prefill_step(params, batch):
        return api.forward(params, batch, rules)

    return prefill_step


def make_decode_step(api: ModelAPI, rules=None) -> Callable:
    """(params, cache, batch) -> (logits, cache).  Donate the cache."""

    def decode_step(params, cache, batch):
        return api.decode_step(params, cache, batch, rules)

    return decode_step
