"""Deterministic synthetic data pipelines (offline environment).

Two families:
  - ``lm_batches``: an infinite, deterministic, shardable LM token stream
    with enough structure (Markov bigram chains) that cross-entropy falls
    during training — used by the end-to-end LM driver.
  - ``image_dataset``: class-conditional Gaussian-blob images with the
    MNIST / CIFAR-10 shapes for the gossip-FL reproduction (the paper's
    bottleneck-time claims depend only on (G_task, G_compute, p, e, C);
    the dataset only needs to make accuracy measurably rise).
"""

from __future__ import annotations

import dataclasses

import numpy as np


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LMStream:
    """Deterministic Markov-chain token stream.

    The same (seed, step, shard) always yields the same batch — restart
    safety comes for free, and each data-parallel shard reads its slice.
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 4          # bigram fan-out; lower => more learnable

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._next = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, self.branch)
        )

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard
        )
        tokens = np.empty((b, self.seq_len + 1), dtype=np.int32)
        tokens[:, 0] = rng.integers(0, self.vocab_size, size=b)
        choices = rng.integers(0, self.branch, size=(b, self.seq_len))
        for t in range(self.seq_len):
            tokens[:, t + 1] = self._next[tokens[:, t], choices[:, t]]
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


# ---------------------------------------------------------------------------
# Synthetic image classification (MNIST / CIFAR-10 stand-ins)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ImageDataset:
    x: np.ndarray            # (N, H, W, C) float32 in [0, 1]
    y: np.ndarray            # (N,) int32
    num_classes: int

    def split(self, num_shards: int, rng: np.random.Generator) -> list["ImageDataset"]:
        """Even IID split across FL users (the paper divides data evenly)."""
        idx = rng.permutation(len(self.y))
        shards = np.array_split(idx, num_shards)
        return [
            ImageDataset(self.x[s], self.y[s], self.num_classes) for s in shards
        ]


def stack_shards(shards: list[ImageDataset]) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-user shards into ``(N_T, chunk, H, W, C)`` / ``(N_T, chunk)``.

    The stacked gossip engine keeps every user's data in one device array,
    so shards are truncated to the common minimum length (``np.array_split``
    shards differ by at most one sample).  Returns *copies* — the engine
    never mutates caller-owned shard buffers.
    """
    if not shards:
        raise ValueError("need at least one shard")
    chunk = min(len(s.y) for s in shards)
    xs = np.stack([s.x[:chunk] for s in shards], axis=0)
    ys = np.stack([s.y[:chunk].astype(np.int32) for s in shards], axis=0)
    return xs, ys


def image_dataset(
    name: str = "mnist",
    num_samples: int = 4096,
    seed: int = 0,
    noise: float = 0.35,
) -> tuple[ImageDataset, ImageDataset]:
    """(train, test) with MNIST (28x28x1) or CIFAR-10 (32x32x3) geometry.

    Each class is a smooth random template + per-sample noise: linearly
    separable enough that a small CNN visibly learns, hard enough that
    accuracy starts near 10%.
    """
    if name == "mnist":
        h, w, c = 28, 28, 1
    elif name == "cifar10":
        h, w, c = 32, 32, 3
    else:
        raise ValueError(name)
    k = 10
    rng = np.random.default_rng(seed)
    # smooth class templates: low-frequency random fields ...
    freq = rng.normal(size=(k, 4, 4, c))
    templates = np.stack(
        [_upsample(freq[i], h, w) for i in range(k)], axis=0
    )  # (k, h, w, c)
    templates = (templates - templates.min()) / np.ptp(templates)
    # ... plus a class "barcode": class i lights up coarse cell i of a
    # 2x5 grid — guarantees separability with margin (MNIST-digit-like
    # localized strokes) while the smooth field adds realistic variation.
    grid_h, grid_w = 2, 5
    ch, cw = h // grid_h, w // grid_w
    for i in range(k):
        r, col = divmod(i, grid_w)
        templates[i] *= 0.5
        templates[i, r * ch : (r + 1) * ch, col * cw : (col + 1) * cw] += 0.5

    def make(n):
        y = rng.integers(0, k, size=n).astype(np.int32)
        x = templates[y] + rng.normal(scale=noise, size=(n, h, w, c))
        return ImageDataset(np.clip(x, 0, 1).astype(np.float32), y, k)

    return make(num_samples), make(max(num_samples // 4, 256))


def _upsample(field: np.ndarray, h: int, w: int) -> np.ndarray:
    """Bilinear upsample a (fh, fw, c) field to (h, w, c)."""
    fh, fw, c = field.shape
    ys = np.linspace(0, fh - 1, h)
    xs = np.linspace(0, fw - 1, w)
    y0 = np.floor(ys).astype(int)
    x0 = np.floor(xs).astype(int)
    y1 = np.minimum(y0 + 1, fh - 1)
    x1 = np.minimum(x0 + 1, fw - 1)
    wy = (ys - y0)[:, None, None]
    wx = (xs - x0)[None, :, None]
    a = field[y0][:, x0]
    b = field[y0][:, x1]
    cc = field[y1][:, x0]
    d = field[y1][:, x1]
    return a * (1 - wy) * (1 - wx) + b * (1 - wy) * wx + cc * wy * (1 - wx) + d * wy * wx
