"""Throughput-HEFT baseline (the paper's "TP HEFT" [12]).

Gallet, Marchal & Vivien (IPDPS'09) schedule *collections* of task graphs
for steady-state throughput — the reciprocal of the iteration period, which
for an iterative process is exactly the bottleneck time.  The variant the
paper benchmarks against keeps HEFT's rank-ordered task sweep but replaces
the earliest-finish-time criterion with a throughput (period) criterion:
each task is placed on the machine that minimizes the *bottleneck time of
the partial assignment* — i.e. greedy period minimization with full
knowledge of per-link communication costs.
"""

from __future__ import annotations

import numpy as np

from repro.core.graphs import ComputeGraph, TaskGraph
from repro.sched.heft import _upward_ranks, build_heft_dag


def _partial_bottleneck(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    assignment: np.ndarray,
    assigned: np.ndarray,
) -> float:
    """Bottleneck over the already-assigned subset of tasks/edges."""
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    loads = np.zeros(compute_graph.num_machines)
    idx = np.where(assigned)[0]
    np.add.at(loads, assignment[idx], p[idx])
    t = 0.0
    for i in idx:
        ti = loads[assignment[i]] / e[assignment[i]]
        for (a, b) in task_graph.edges:
            if a == i and assigned[b]:
                ti = max(ti, loads[assignment[i]] / e[assignment[i]]
                         + C[assignment[i], assignment[b]])
        t = max(t, ti)
    return t


def tp_heft_assignment(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> np.ndarray:
    """Rank-ordered greedy period minimization (see module docstring)."""
    dag = build_heft_dag(task_graph)
    rank = _upward_ranks(dag, compute_graph)
    # order original tasks by their DAG upward rank (highest first)
    task_rank = np.zeros(task_graph.num_tasks)
    for u, node in enumerate(dag.nodes):
        if node.task_id is not None:
            task_rank[node.task_id] = rank[u]
    order = np.argsort(-task_rank)

    n_k = compute_graph.num_machines
    assignment = np.zeros(task_graph.num_tasks, dtype=np.int64)
    assigned = np.zeros(task_graph.num_tasks, dtype=bool)
    for i in order:
        best_j, best_t = 0, np.inf
        for j in range(n_k):
            assignment[i] = j
            assigned[i] = True
            t = _partial_bottleneck(task_graph, compute_graph, assignment, assigned)
            if t < best_t:
                best_j, best_t = j, t
        assignment[i] = best_j
    return assignment
