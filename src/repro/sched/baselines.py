"""Simple scheduling baselines: random, round-robin, greedy, Theorem-1 sort."""

from __future__ import annotations

import numpy as np

from repro.core.bqp import bottleneck_time
from repro.core.graphs import ComputeGraph, TaskGraph


def random_assignment(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    rng = rng or np.random.default_rng(0)
    return rng.integers(0, compute_graph.num_machines, size=task_graph.num_tasks)


def round_robin_assignment(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> np.ndarray:
    return np.arange(task_graph.num_tasks) % compute_graph.num_machines


def greedy_bottleneck_assignment(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> np.ndarray:
    """Place tasks (largest work first) where the running bottleneck grows least."""
    order = np.argsort(-task_graph.p)
    assignment = np.zeros(task_graph.num_tasks, dtype=np.int64)
    placed = []
    for i in order:
        best_j, best_t = 0, np.inf
        for j in range(compute_graph.num_machines):
            assignment[i] = j
            sub = placed + [i]
            # evaluate on the full graph but only already-placed tasks matter;
            # unplaced tasks sit on machine `assignment[k]`=0 — to avoid bias,
            # evaluate the partial instance directly.
            t = _partial(task_graph, compute_graph, assignment, sub)
            if t < best_t:
                best_j, best_t = j, t
        assignment[i] = best_j
        placed.append(i)
    return assignment


def _partial(task_graph, compute_graph, assignment, placed) -> float:
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    loads = np.zeros(compute_graph.num_machines)
    pset = set(int(x) for x in placed)
    for i in pset:
        loads[assignment[i]] += p[i]
    t = 0.0
    for i in pset:
        ti = loads[assignment[i]] / e[assignment[i]]
        for (a, b) in task_graph.edges:
            if a == i and b in pset:
                ti = max(ti, loads[assignment[i]] / e[assignment[i]]
                         + C[assignment[i], assignment[b]])
        t = max(t, ti)
    return t


def sorted_assignment(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> np.ndarray:
    """Theorem 1: sorted tasks -> sorted machines (optimal when C=0, no deps,
    and at most one task per machine; applied cyclically otherwise)."""
    task_order = np.argsort(-task_graph.p)
    machine_order = np.argsort(-compute_graph.e)
    assignment = np.zeros(task_graph.num_tasks, dtype=np.int64)
    for rank, i in enumerate(task_order):
        assignment[i] = machine_order[rank % compute_graph.num_machines]
    return assignment


def local_search_refine(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    assignment: np.ndarray,
    *,
    max_rounds: int = 10,
) -> np.ndarray:
    """Beyond-paper: 1-move hill-climb on the exact bottleneck objective.

    Repeatedly move the single (task -> machine) reassignment that most
    reduces bottleneck time; stop at a local optimum.  Cheap (O(rounds ·
    N_T · N_K) evaluations) and strictly improves any scheduler's output.
    """
    best = assignment.copy()
    best_t = bottleneck_time(task_graph, compute_graph, best)
    for _ in range(max_rounds):
        improved = False
        for i in range(task_graph.num_tasks):
            orig = best[i]
            for j in range(compute_graph.num_machines):
                if j == orig:
                    continue
                best[i] = j
                t = bottleneck_time(task_graph, compute_graph, best)
                if t < best_t - 1e-12:
                    best_t = t
                    orig = j
                    improved = True
            best[i] = orig
        if not improved:
            break
    return best
