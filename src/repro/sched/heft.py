"""HEFT baseline [Topcuoglu et al. 2002] + the paper's cyclic->DAG rewrite.

HEFT is makespan-oriented and DAG-only; the paper (§4.1.1) constructs a DAG
from the general directed task graph so HEFT-family schedulers can run:

    S -> T_i                for every task i
    T_i -> T_{i,j} -> D     for every task-graph edge (i, j)

``T_{i,j}`` are zero-work communication vertices: the edge T_i -> T_{i,j}
carries the data transfer of task i's output toward consumer j, so HEFT's
EFT machinery accounts for every communication edge individually.  (The
paper's formal definition also lists the original edges in E_DAG; keeping
them would preserve cycles, so — like its Fig. 3 — we replace each original
edge by its intermediate vertex.)  After HEFT schedules the DAG we read the
machine assignment off the original task vertices and evaluate the true
bottleneck time with the exact evaluator.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graphs import ComputeGraph, TaskGraph


@dataclasses.dataclass(frozen=True)
class DagNode:
    name: str
    work: float                 # required computation (0 for S/D/intermediates)
    task_id: int | None         # original task index, None for scaffolding


@dataclasses.dataclass
class Dag:
    nodes: list[DagNode]
    edges: list[tuple[int, int]]        # indices into ``nodes``
    comm_weight: dict[tuple[int, int], float]  # 1.0 => full message, 0 => free

    def successors(self, u: int) -> list[int]:
        return [b for (a, b) in self.edges if a == u]

    def predecessors(self, u: int) -> list[int]:
        return [a for (a, b) in self.edges if b == u]


def build_heft_dag(task_graph: TaskGraph) -> Dag:
    """Paper §4.1.1 construction (see module docstring)."""
    nodes: list[DagNode] = [DagNode("S", 0.0, None)]
    index: dict[str, int] = {"S": 0}
    for i in range(task_graph.num_tasks):
        index[f"T{i}"] = len(nodes)
        nodes.append(DagNode(f"T{i}", float(task_graph.p[i]), i))
    for (i, j) in task_graph.edges:
        index[f"T{i},{j}"] = len(nodes)
        nodes.append(DagNode(f"T{i},{j}", 0.0, None))
    index["D"] = len(nodes)
    nodes.append(DagNode("D", 0.0, None))

    edges: list[tuple[int, int]] = []
    comm: dict[tuple[int, int], float] = {}
    for i in range(task_graph.num_tasks):
        e = (index["S"], index[f"T{i}"])
        edges.append(e)
        comm[e] = 0.0                       # source fan-out is free
    for (i, j) in task_graph.edges:
        e = (index[f"T{i}"], index[f"T{i},{j}"])
        edges.append(e)
        comm[e] = 1.0                       # the actual data transfer
        e2 = (index[f"T{i},{j}"], index["D"])
        edges.append(e2)
        comm[e2] = 0.0
    return Dag(nodes=nodes, edges=edges, comm_weight=comm)


def _upward_ranks(dag: Dag, compute_graph: ComputeGraph) -> np.ndarray:
    """rank_u(i) = w̄_i + max_succ (c̄_edge + rank_u(succ)).

    HEFT uses *average* compute cost (w̄_i = p_i * mean(1/e)) and *average*
    communication cost over machine pairs — exactly the weakness the paper
    exploits (it only sees mean link quality).
    """
    inv_e_mean = float(np.mean(1.0 / compute_graph.e))
    off = ~np.eye(compute_graph.num_machines, dtype=bool)
    c_mean = float(np.mean(compute_graph.C[off])) if off.any() else 0.0

    n = len(dag.nodes)
    succ = {u: dag.successors(u) for u in range(n)}
    rank = np.zeros(n)
    # reverse topological order via DFS post-order
    order: list[int] = []
    seen = [False] * n
    def visit(u: int):
        seen[u] = True
        for v in succ[u]:
            if not seen[v]:
                visit(v)
        order.append(u)
    for u in range(n):
        if not seen[u]:
            visit(u)
    for u in order:                          # children already final
        w_bar = dag.nodes[u].work * inv_e_mean
        best = 0.0
        for v in succ[u]:
            c_bar = c_mean * dag.comm_weight[(u, v)]
            best = max(best, c_bar + rank[v])
        rank[u] = w_bar + best
    return rank


def heft_schedule_dag(dag: Dag, compute_graph: ComputeGraph) -> dict[int, int]:
    """Classic HEFT: rank-ordered EFT assignment with insertion policy.

    Returns {dag node index -> machine}.
    """
    e, C = compute_graph.e, compute_graph.C
    n_k = compute_graph.num_machines
    rank = _upward_ranks(dag, compute_graph)
    order = sorted(range(len(dag.nodes)), key=lambda u: -rank[u])

    busy: list[list[tuple[float, float]]] = [[] for _ in range(n_k)]  # per machine
    aft: dict[int, float] = {}
    where: dict[int, int] = {}
    preds = {u: dag.predecessors(u) for u in range(len(dag.nodes))}

    def earliest_slot(machine: int, ready: float, dur: float) -> float:
        """Insertion-based policy: first gap on ``machine`` fitting ``dur``."""
        slots = sorted(busy[machine])
        start = ready
        for (s, f) in slots:
            if start + dur <= s:
                break
            start = max(start, f)
        return start

    for u in order:
        best_machine, best_eft, best_start = 0, np.inf, 0.0
        for j in range(n_k):
            ready = 0.0
            for pmd in preds[u]:
                c = dag.comm_weight[(pmd, u)]
                delay = 0.0 if where[pmd] == j else c * C[where[pmd], j]
                ready = max(ready, aft[pmd] + delay)
            dur = dag.nodes[u].work / e[j]
            start = earliest_slot(j, ready, dur)
            eft = start + dur
            if eft < best_eft:
                best_machine, best_eft, best_start = j, eft, start
        where[u] = best_machine
        aft[u] = best_eft
        busy[best_machine].append((best_start, best_eft))
    return where


def heft_assignment(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> np.ndarray:
    """Full pipeline: cyclic graph -> DAG -> HEFT -> original-task assignment."""
    dag = build_heft_dag(task_graph)
    where = heft_schedule_dag(dag, compute_graph)
    out = np.zeros(task_graph.num_tasks, dtype=np.int64)
    for u, node in enumerate(dag.nodes):
        if node.task_id is not None:
            out[node.task_id] = where[u]
    return out
