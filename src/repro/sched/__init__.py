"""Classical scheduling baselines (HEFT family + simple heuristics)."""

from repro.sched.baselines import (
    greedy_bottleneck_assignment,
    local_search_refine,
    random_assignment,
    round_robin_assignment,
    sorted_assignment,
)
from repro.sched.heft import build_heft_dag, heft_assignment
from repro.sched.tp_heft import tp_heft_assignment

__all__ = [
    "build_heft_dag",
    "greedy_bottleneck_assignment",
    "heft_assignment",
    "local_search_refine",
    "random_assignment",
    "round_robin_assignment",
    "sorted_assignment",
    "tp_heft_assignment",
]
