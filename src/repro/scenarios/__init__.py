"""Declarative scenario engine: topology × heterogeneity × dynamics sweeps.

A scenario composes four axes — task-graph family, machine profile, delay
model, scheduler set — plus an optional gossip-FL workload or churn trace
(trace-driven fleet dynamics with per-policy regret vs an oracle
re-solve), and runs them through one generate → schedule → simulate →
record pipeline (DESIGN.md §4, §10).  Paper figures (fig4/fig5/fig6) are
presets over the same engine; ``scripts/sweep.py`` is the CLI.
"""

from repro.scenarios.engine import (
    build_compute_graph,
    build_task_graph,
    run_scenario,
    run_sweep,
)
from repro.scenarios.profiles import (
    CHURN_MODELS,
    DELAY_MODELS,
    MACHINE_PROFILES,
    ChurnTrace,
    DelayDrift,
    churn_trace,
    delay_matrix,
    drifting_delays,
    machine_speeds,
)
from repro.scenarios.spec import (
    CHURN_POLICIES,
    FLWorkload,
    Scenario,
    get_scenario,
    list_scenarios,
    register,
)

__all__ = [
    "CHURN_MODELS",
    "CHURN_POLICIES",
    "ChurnTrace",
    "DELAY_MODELS",
    "DelayDrift",
    "FLWorkload",
    "MACHINE_PROFILES",
    "Scenario",
    "build_compute_graph",
    "build_task_graph",
    "churn_trace",
    "delay_matrix",
    "drifting_delays",
    "get_scenario",
    "list_scenarios",
    "machine_speeds",
    "register",
    "run_scenario",
    "run_sweep",
]
