"""Declarative scenario engine: topology × heterogeneity × dynamics sweeps.

A scenario composes four axes — task-graph family, machine profile, delay
model, scheduler set — plus an optional gossip-FL workload, and runs them
through one generate → schedule → simulate → record pipeline (DESIGN.md
§4).  Paper figures (fig4/fig5/fig6) are presets over the same engine;
``scripts/sweep.py`` is the CLI.
"""

from repro.scenarios.engine import (
    build_compute_graph,
    build_task_graph,
    run_scenario,
    run_sweep,
)
from repro.scenarios.profiles import (
    DELAY_MODELS,
    MACHINE_PROFILES,
    DelayDrift,
    delay_matrix,
    drifting_delays,
    machine_speeds,
)
from repro.scenarios.spec import (
    FLWorkload,
    Scenario,
    get_scenario,
    list_scenarios,
    register,
)

__all__ = [
    "DELAY_MODELS",
    "DelayDrift",
    "FLWorkload",
    "MACHINE_PROFILES",
    "Scenario",
    "build_compute_graph",
    "build_task_graph",
    "delay_matrix",
    "drifting_delays",
    "get_scenario",
    "list_scenarios",
    "machine_speeds",
    "register",
    "run_scenario",
    "run_sweep",
]
