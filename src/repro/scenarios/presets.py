"""Registered scenario presets: paper figures + new grid combinations.

Importing this module populates the registry (``spec.get_scenario`` /
``spec.list_scenarios`` trigger the import lazily).  Three groups:

  - ``fig4_nt{N}`` / ``fig5_deg{L}_{H}`` — the paper's §4.1.2 sweeps as
    single-seed scenarios.  Generation consumes the rng exactly like
    ``benchmarks.common.paper_instance``, so per-seed bottlenecks match
    the pre-engine figure benchmarks; the benchmarks loop seeds via
    ``Scenario.with_seed`` and average.
  - ``fig6`` — the §4.2 gossip-FL experiment; ``FLWorkload.paper_setting``
    delegates instance generation to ``fl/runner.run_fl`` so the learning
    curve is bit-identical to the legacy fig6 path.
  - New combinations (``NEW_COMBINATIONS``) — one scenario per distinct
    topology family crossed with heterogeneity and delay structure,
    including a delay-drift run with mid-run re-scheduling and a gossip-FL
    workload on a small-world graph.
  - Event-engine combinations (``ASYNC_COMBINATIONS``) — the same grid
    replayed under non-barrier execution semantics (``repro.sim``):
    ``async`` scenarios record staleness + steady-state throughput next
    to the sync ``predicted_bottleneck``, and one ``overlap`` scenario
    records the pipelined period.  ``benchmarks/async_bench.py``
    (``make bench-async``) sweeps them into ``BENCH_scenarios.json``.
  - Churn combinations (``CHURN_COMBINATIONS``) — trace-driven fleet
    dynamics (Markov flapping, Weibull sessions, intermittent links,
    and one preset with an injected zero solve budget that forces the
    elastic policy through its heft fallback), each comparing
    ``sdp_elastic`` / ``sdp_static`` / ``heft`` against an oracle
    per-event cold re-solve.  ``benchmarks/churn_bench.py``
    (``make bench-churn``) sweeps them into ``BENCH_scenarios.json``.
"""

from __future__ import annotations

from repro.scenarios.spec import FLWorkload, Scenario, register

PAPER_SCHEDULERS = ("heft", "tp_heft", "sdp_naive", "sdp", "sdp_ls")
DEFAULT_SCHEDULERS = ("sdp", "heft", "tp_heft", "random")

# -- paper figure presets ----------------------------------------------------

FIG4_SIZES = (5, 10, 15, 20, 25, 30)
for _n in FIG4_SIZES:
    register(Scenario(
        name=f"fig4_nt{_n}",
        topology="random",
        num_tasks=_n,
        num_machines=4,
        machine_profile="paper",
        delay_model="paper",
        schedulers=PAPER_SCHEDULERS,
        topology_params={"degree_low": 2, "degree_high": 4},
    ))

FIG5_DEGREES = ((2, 4), (4, 6), (6, 8), (8, 10))
for (_dl, _dh) in FIG5_DEGREES:
    register(Scenario(
        name=f"fig5_deg{_dl}_{_dh}",
        topology="random",
        num_tasks=21,
        num_machines=4,
        machine_profile="paper",
        delay_model="paper",
        schedulers=PAPER_SCHEDULERS,
        topology_params={"degree_low": _dl, "degree_high": _dh},
    ))

FIG6 = register(Scenario(
    name="fig6",
    topology="gossip",
    num_tasks=10,
    num_machines=4,
    machine_profile="uniform",
    delay_model="uniform",
    schedulers=("heft", "tp_heft", "sdp_naive", "sdp"),
    topology_params={"degree_low": 6, "degree_high": 7},
    fl=FLWorkload(
        dataset="mnist", rounds=3, local_steps=2, batch_size=32,
        num_samples=1024, backend="stacked", paper_setting=True,
    ),
))

# -- new topology × heterogeneity × dynamics combinations --------------------

NEW_COMBINATIONS = (
    # Baseline structured topology on a homogeneous fleet.
    register(Scenario(
        name="ring_uniform",
        topology="ring",
        num_tasks=12,
        num_machines=4,
        machine_profile="uniform",
        delay_model="uniform",
        schedulers=DEFAULT_SCHEDULERS,
    )),
    # 4x4 torus across two datacenter racks with a few fast machines.
    register(Scenario(
        name="torus_cluster",
        topology="torus",
        num_tasks=16,
        num_machines=6,
        machine_profile="bimodal",
        delay_model="cluster",
        schedulers=DEFAULT_SCHEDULERS,
        topology_params={"rows": 4},
        machine_params={"fast": 4.0, "slow": 1.0, "fast_fraction": 0.34},
        delay_params={"clusters": 2, "intra": 0.1, "inter": 1.0},
    )),
    # Sparse random gossip over geographically spread edge devices.
    register(Scenario(
        name="er_bimodal_distance",
        topology="erdos_renyi",
        num_tasks=16,
        num_machines=4,
        machine_profile="bimodal",
        delay_model="distance",
        schedulers=DEFAULT_SCHEDULERS,
        topology_params={"edge_prob": 0.15, "p_sigma": 1.0},
    )),
    # Hub-dominated gossip on a long-tailed heterogeneous fleet.
    register(Scenario(
        name="scalefree_lognormal",
        topology="scale_free",
        num_tasks=20,
        num_machines=4,
        machine_profile="lognormal",
        delay_model="distance",
        schedulers=DEFAULT_SCHEDULERS,
        topology_params={"attach": 2},
        machine_params={"sigma": 0.75},
    )),
    # Small-world gossip under drifting network delays: re-schedule every
    # 4 rounds via the warm-started SDP cache.
    register(Scenario(
        name="smallworld_drift",
        topology="small_world",
        num_tasks=16,
        num_machines=4,
        machine_profile="uniform",
        delay_model="drift",
        schedulers=DEFAULT_SCHEDULERS,
        rounds=16,
        reschedule_every=4,
        topology_params={"k": 4, "rewire_prob": 0.2},
        delay_params={"base": "distance", "amplitude": 0.6, "period": 8.0},
    )),
    # Layered pipeline DAG on an edge/cloud split with clustered delays.
    register(Scenario(
        name="layered_cloud",
        topology="layered_dag",
        num_tasks=16,
        num_machines=4,
        machine_profile="bimodal",
        delay_model="cluster",
        schedulers=DEFAULT_SCHEDULERS,
        topology_params={"layers": 4, "edge_prob": 0.5, "p_sigma": 1.0},
        delay_params={"clusters": 2, "intra": 0.05, "inter": 0.8},
    )),
    # Gossip-FL training on a small-world topology with the engine's own
    # instance (exercises run_fl with an injected task/compute graph).
    register(Scenario(
        name="smallworld_fl",
        topology="small_world",
        num_tasks=8,
        num_machines=4,
        machine_profile="uniform",
        delay_model="uniform",
        schedulers=("heft", "tp_heft", "sdp"),
        topology_params={"k": 4, "rewire_prob": 0.1},
        fl=FLWorkload(
            dataset="mnist", rounds=2, local_steps=2, batch_size=32,
            num_samples=512, backend="stacked",
        ),
    )),
    # Hierarchical edge -> region gossip: dense intra-cluster exchange,
    # only cluster heads on the sparse global ring (the population-scale
    # topology the sharded engine partitions along, DESIGN.md §13).
    register(Scenario(
        name="cluster_hier",
        topology="cluster",
        num_tasks=24,
        num_machines=4,
        machine_profile="bimodal",
        delay_model="cluster",
        schedulers=DEFAULT_SCHEDULERS,
        topology_params={
            "clusters": 4, "inner_topology": "dense",
            "head_topology": "ring", "heads_per_cluster": 2,
        },
        delay_params={"clusters": 2, "intra": 0.1, "inter": 1.0},
    )),
)

# -- event-engine combinations: sync-vs-async/overlap on the same grids ------

ASYNC_SCHEDULERS = ("sdp", "heft", "tp_heft")

ASYNC_COMBINATIONS = (
    # Long-tailed fleet on a ring: barrier-free execution decouples the
    # round period from the slow links, staleness absorbs the delays.
    register(Scenario(
        name="ring_async",
        topology="ring",
        num_tasks=12,
        num_machines=4,
        machine_profile="lognormal",
        delay_model="uniform",
        schedulers=ASYNC_SCHEDULERS,
        rounds=24,
        execution="async",
        execution_params={"jitter_sigma": 0.1},
    )),
    # Edge/cloud torus across two racks — the bimodal speeds make the
    # fast machines run rounds ahead of the edge devices.
    register(Scenario(
        name="torus_cluster_async",
        topology="torus",
        num_tasks=16,
        num_machines=6,
        machine_profile="bimodal",
        delay_model="cluster",
        schedulers=ASYNC_SCHEDULERS,
        rounds=24,
        execution="async",
        topology_params={"rows": 4},
        machine_params={"fast": 4.0, "slow": 1.0, "fast_fraction": 0.34},
        delay_params={"clusters": 2, "intra": 0.1, "inter": 1.0},
        execution_params={"jitter_sigma": 0.1},
    )),
    # Hub-dominated gossip with stragglers: per-round 3x slowdowns hit
    # 10% of machine-rounds, the hub tasks accumulate staleness.
    register(Scenario(
        name="scalefree_async",
        topology="scale_free",
        num_tasks=20,
        num_machines=4,
        machine_profile="lognormal",
        delay_model="distance",
        schedulers=ASYNC_SCHEDULERS,
        rounds=24,
        execution="async",
        topology_params={"attach": 2},
        execution_params={
            "jitter_sigma": 0.15,
            "straggler_prob": 0.1,
            "straggler_factor": 3.0,
        },
    )),
    # Pipelined (overlap) execution on the small-world grid: sends of
    # round r overlap compute of r+1, the period drops below Eq. 2
    # without introducing staleness.
    register(Scenario(
        name="smallworld_overlap",
        topology="small_world",
        num_tasks=16,
        num_machines=4,
        machine_profile="lognormal",
        delay_model="distance",
        schedulers=ASYNC_SCHEDULERS,
        rounds=24,
        execution="overlap",
        topology_params={"k": 4, "rewire_prob": 0.2},
    )),
)

# -- barrier-free FL combinations: async training on the event engine --------

ASYNC_FL_COMBINATIONS = (
    # fig6-style gossip instance trained barrier-free under a straggler
    # profile: per-round 3x slowdowns hit 15% of machine-rounds, hinge
    # staleness weights discount the late snapshots.  The sync twin of
    # this preset (same instance, execution="sync") is what
    # benchmarks/async_fl_bench.py compares against at equal simulated
    # time.
    register(Scenario(
        name="gossip_async_fl",
        topology="gossip",
        num_tasks=10,
        num_machines=4,
        machine_profile="lognormal",
        delay_model="uniform",
        schedulers=("sdp", "heft"),
        execution="async",
        execution_params={
            "jitter_sigma": 0.1,
            "straggler_prob": 0.15,
            "straggler_factor": 3.0,
        },
        topology_params={"degree_low": 6, "degree_high": 7},
        staleness_params={"kind": "hinge", "a": 0.5, "b": 1},
        fl=FLWorkload(
            dataset="mnist", rounds=6, local_steps=2, batch_size=32,
            num_samples=1024,
        ),
    )),
    # Small-world users on an edge/cloud fleet: the bimodal speeds make
    # the cloud machines run rounds ahead, polynomial staleness decay
    # absorbs the version gap.
    register(Scenario(
        name="smallworld_async_fl",
        topology="small_world",
        num_tasks=8,
        num_machines=4,
        machine_profile="bimodal",
        delay_model="distance",
        schedulers=("sdp", "heft"),
        execution="async",
        execution_params={
            "jitter_sigma": 0.1,
            "straggler_prob": 0.1,
            "straggler_factor": 3.0,
        },
        topology_params={"k": 4, "rewire_prob": 0.1},
        machine_params={"fast": 4.0, "slow": 1.0, "fast_fraction": 0.25},
        staleness_params={"kind": "poly", "a": 0.5},
        fl=FLWorkload(
            dataset="mnist", rounds=6, local_steps=2, batch_size=32,
            num_samples=512,
        ),
    )),
    # Churn×FL: Markov flapping freezes replicas mid-training; the
    # barrier-free trainer recovers them via anti-entropy with bounded
    # in-flight sends.  Evidence target: finite losses, frozen-then-
    # recovered replicas, zero barrier stalls.
    register(Scenario(
        name="gossip_churn_fl",
        topology="gossip",
        num_tasks=10,
        num_machines=4,
        machine_profile="uniform",
        delay_model="uniform",
        schedulers=("sdp", "heft"),
        execution="async",
        execution_params={"token_capacity": 8.0, "token_refill": 4.0},
        topology_params={"degree_low": 6, "degree_high": 7},
        staleness_params={"kind": "hinge", "a": 1.0, "b": 2},
        churn="markov",
        churn_params={
            "p_fail": 0.15, "p_recover": 0.5, "min_up": 2,
            "p_slow": 0.2, "slow_factor": 2.0,
        },
        fl=FLWorkload(
            dataset="mnist", rounds=8, local_steps=2, batch_size=32,
            num_samples=1024, archive_depth=10,
        ),
    )),
)

# -- churn combinations: trace-driven fleet dynamics --------------------------

CHURN_COMBINATIONS = (
    # Memoryless flapping on a small-world gossip graph: one machine
    # begins the trace absent (a mid-trace *join*), two links flap with a
    # 4x outage penalty.
    register(Scenario(
        name="smallworld_churn_markov",
        topology="small_world",
        num_tasks=16,
        num_machines=6,
        machine_profile="lognormal",
        delay_model="distance",
        schedulers=("sdp",),
        rounds=24,
        topology_params={"k": 4, "rewire_prob": 0.2},
        churn="markov",
        churn_params={
            "p_fail": 0.08, "p_recover": 0.35,
            "start_down_fraction": 0.2, "min_up": 3,
            "link_outages": 2, "outage_len": 4, "outage_factor": 4.0,
        },
    )),
    # Weibull up/down sessions on an edge/cloud torus: shape_down < 1
    # mixes quick blips with long absences, clustered delays make the
    # re-solve's machine choice matter.
    register(Scenario(
        name="torus_churn_weibull",
        topology="torus",
        num_tasks=16,
        num_machines=6,
        machine_profile="bimodal",
        delay_model="cluster",
        schedulers=("sdp",),
        rounds=24,
        topology_params={"rows": 4},
        machine_params={"fast": 4.0, "slow": 1.0, "fast_fraction": 0.34},
        delay_params={"clusters": 2, "intra": 0.1, "inter": 1.0},
        churn="weibull",
        churn_params={
            "shape_up": 1.5, "scale_up": 10.0,
            "shape_down": 0.8, "scale_down": 3.0,
            "start_down_fraction": 0.2, "min_up": 3,
            "link_outages": 2, "outage_len": 4, "outage_factor": 3.0,
        },
    )),
    # Degraded-mode drill: a zero wall-clock solve budget makes EVERY
    # elastic SDP attempt fail (warm and cold retry), so the policy runs
    # the whole trace on its heft fallback — the record pins that a
    # stalled solver costs regret but never wedges the trace.
    register(Scenario(
        name="er_churn_degraded",
        topology="erdos_renyi",
        num_tasks=14,
        num_machines=6,
        machine_profile="lognormal",
        delay_model="uniform",
        schedulers=("sdp",),
        rounds=20,
        topology_params={"edge_prob": 0.2},
        churn="markov",
        churn_params={
            "p_fail": 0.1, "p_recover": 0.4,
            "start_down_fraction": 0.2, "min_up": 2,
            "link_outages": 1, "outage_len": 5, "outage_factor": 4.0,
            "solve_timeout": 0.0,
        },
    )),
)
