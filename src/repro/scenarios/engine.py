"""Scenario execution: generate → schedule → simulate → record.

``run_scenario`` turns one :class:`~repro.scenarios.spec.Scenario` into a
JSON-serializable record:

  1. **generate** — the task graph from the topology family
     (``core/graphs.py``) and the compute graph from the machine profile +
     delay model (``scenarios/profiles.py``), all from one
     ``default_rng(scenario.seed)`` stream (so ``fig4_*`` / ``fig5_*``
     presets reproduce ``benchmarks.common.paper_instance`` exactly);
  2. **schedule** — every scheduler in ``scenario.schedulers`` via
     ``core.scheduler.schedule`` (the sdp family shares one solve through
     ``compare_methods``'s cache);
  3. **simulate** — the discrete-event engine (``repro.sim``) replays
     the schedule under the scenario's ``execution`` semantics: ``sync``
     reproduces Eq. 2 per round exactly, ``overlap`` pipelines sends
     into the next round's compute, ``async`` runs barrier-free and
     records staleness + steady-state throughput.  Under the ``drift``
     delay model the per-round delay updates and the periodic
     ``ElasticScheduler.on_delay_update`` consults enter the engine's
     queue as control events, and when the scenario perturbs machines
     (``execution_params`` jitter/stragglers) the engine's measured
     busy times feed ``ElasticScheduler.observe_round`` every round.
     Under a ``churn`` axis a seeded :class:`ChurnTrace` drives
     fail / join / recover / link-outage events through the engine, each
     churn POLICY (``sdp_elastic`` / ``sdp_static`` / ``heft``) reacts at
     the consult, and the record carries each policy's bottleneck-time
     regret against an oracle per-event cold re-solve;
  4. **train** (optional) — the gossip-FL workload on the stacked engine
     (``fl/runner.run_fl``), either on the engine's instance or — for the
     fig6 preset — delegating generation to the legacy §4.2 path so the
     learning curves are bit-identical to the pre-engine benchmark.

``run_sweep`` executes many scenarios with resumable JSON output: the
file is rewritten after every record and completed
``(scenario, seed, quick)`` triples are skipped on re-entry.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Callable, Iterable

import numpy as np

from repro.core.graphs import (
    ComputeGraph,
    TaskGraph,
    cluster_task_graph,
    erdos_renyi_task_graph,
    gossip_task_graph,
    layered_dag_task_graph,
    random_task_graph,
    ring_task_graph,
    scale_free_task_graph,
    small_world_task_graph,
    torus_task_graph,
)
from repro.core.scheduler import compare_methods
from repro.core.sdp import SDPOptions
from repro.scenarios.profiles import (
    ChurnTrace,
    DelayDrift,
    churn_trace,
    delay_matrix,
    drifting_delays,
    machine_speeds,
)
from repro.scenarios.spec import CHURN_POLICY_KEYS, Scenario
from repro.sim import ControlEvent, simulate

_SDP_FAMILY = ("sdp", "sdp_naive", "sdp_ls")


def budget_quick(scenario: Scenario, quick: bool) -> bool:
    """The budget a run of ``scenario`` actually uses.

    ``paper_setting`` FL scenarios always execute the legacy full-budget
    §4.2 path (that is what makes them bit-identical to the pre-engine
    fig6), so quick mode does not apply to them — their records carry
    ``quick: false`` under any invocation and one record serves both
    sweeps.
    """
    paper = scenario.fl is not None and scenario.fl.paper_setting
    return bool(quick) and not paper


def scenario_key(scenario: Scenario, quick: bool) -> tuple:
    """The resume/dedup identity of a run: (name, seed, effective budget)."""
    return (scenario.name, scenario.seed, budget_quick(scenario, quick))


def record_key(rec: dict) -> tuple:
    """The stored-record counterpart of ``scenario_key``."""
    return (rec["scenario"], rec["seed"], rec.get("quick"))


# ---------------------------------------------------------------------------
# Generation
# ---------------------------------------------------------------------------


def build_task_graph(scenario: Scenario, rng: np.random.Generator) -> TaskGraph:
    """Instantiate the scenario's topology family.

    ``topology_params["p_sigma"]`` overrides the family's default unit
    work with folded-normal heterogeneous work.  The ``random`` family
    takes it natively (forwarded to ``random_task_graph``, preserving its
    rng draw order); the other families draw the work vector after edge
    generation.
    """
    tp = dict(scenario.topology_params)
    p_sigma = tp.pop("p_sigma", None)
    if scenario.topology == "random" and p_sigma is not None:
        tp["p_sigma"] = float(p_sigma)
        p_sigma = None
    n = scenario.num_tasks
    if scenario.topology == "ring":
        g = ring_task_graph(n, **tp)
    elif scenario.topology == "torus":
        rows = int(tp.pop("rows", int(np.sqrt(n))))
        cols = n // rows
        if rows * cols != n:
            raise ValueError(f"num_tasks={n} not divisible into rows={rows}")
        g = torus_task_graph(rows, cols, **tp)
    elif scenario.topology == "erdos_renyi":
        g = erdos_renyi_task_graph(rng, n, **tp)
    elif scenario.topology == "scale_free":
        g = scale_free_task_graph(rng, n, **tp)
    elif scenario.topology == "small_world":
        g = small_world_task_graph(rng, n, **tp)
    elif scenario.topology == "layered_dag":
        layers = int(tp.pop("layers", 4))
        if n % layers:
            raise ValueError(f"num_tasks={n} not divisible into layers={layers}")
        g = layered_dag_task_graph(rng, layers, n // layers, **tp)
    elif scenario.topology == "cluster":
        g = cluster_task_graph(rng, n, **tp)
    elif scenario.topology == "gossip":
        g = gossip_task_graph(rng, n, **tp)
    elif scenario.topology == "random":
        g = random_task_graph(rng, n, **tp)
    else:  # pragma: no cover — Scenario.__post_init__ validates
        raise ValueError(scenario.topology)
    if p_sigma is not None:
        p = np.abs(rng.normal(0.0, float(p_sigma), size=n)) + 1e-3
        g = TaskGraph(p=p, edges=g.edges)
    return g


def build_compute_graph(
    scenario: Scenario, rng: np.random.Generator
) -> tuple[ComputeGraph, DelayDrift | None]:
    """Machine profile + delay model -> (ComputeGraph, optional drift).

    Speeds are drawn before delays (the ``paper`` × ``paper`` combination
    therefore consumes the rng exactly like ``random_compute_graph``).
    For ``drift`` the returned compute graph carries ``drift.at(0)``.
    """
    e = machine_speeds(
        scenario.machine_profile, rng, scenario.num_machines,
        **scenario.machine_params,
    )
    if scenario.delay_model == "drift":
        drift = drifting_delays(rng, scenario.num_machines, **scenario.delay_params)
        return ComputeGraph(e=e, C=drift.at(0)), drift
    C = delay_matrix(
        scenario.delay_model, rng, scenario.num_machines, **scenario.delay_params
    )
    return ComputeGraph(e=e, C=C), None


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------


def _schedule_kwargs(scenario: Scenario, quick: bool) -> dict:
    sp = dict(scenario.schedule_params)
    num_samples = int(sp.pop("num_samples", 512 if quick else 2000))
    max_iters = sp.pop("max_iters", None)
    kw = {"num_samples": num_samples, "seed": scenario.seed, **sp}
    # An explicit sdp_options wins outright (including its iteration
    # budget — quick mode does not second-guess explicit solver config);
    # an explicit max_iters adjusts it rather than replacing it wholesale.
    # The quick-mode 400-iteration default applies only when neither was
    # given.
    if "sdp_options" in kw:
        if max_iters is not None:
            kw["sdp_options"] = dataclasses.replace(
                kw["sdp_options"], max_iters=int(max_iters)
            )
    else:
        if max_iters is None and quick:
            max_iters = 400
        if max_iters is not None:
            kw["sdp_options"] = SDPOptions(max_iters=int(max_iters))
    return kw


def _sim_entry(scenario: Scenario, res) -> dict:
    """JSON-serializable simulation fields of a ``SimResult``."""
    entry = {
        "execution": res.semantics,
        "mean_round_time": float(np.mean(res.round_times)),
        "total_time": float(res.total_time),
        "num_reschedules": len(res.reschedule_rounds),
        "num_migrations": 0,
    }
    if res.semantics != "sync":
        entry["period"] = float(res.period)
        entry["throughput"] = float(res.throughput)
    if res.semantics == "async":
        entry["staleness_mean"] = float(res.staleness_mean)
        entry["staleness_max"] = int(res.staleness_max)
        entry["staleness_per_task"] = [
            float(s) for s in res.staleness_per_task
        ]
        entry["barrier_stalls"] = int(res.barrier_stalls)
        entry["send_skips"] = int(res.send_skips)
        entry["antientropy_msgs"] = int(res.antientropy_msgs)
    if res.semantics != "sync" or scenario.execution_spec().perturbed:
        entry["round_times"] = [float(t) for t in res.round_times]
    return entry


def _simulate_static(
    scenario: Scenario,
    tg: TaskGraph,
    cg: ComputeGraph,
    assignment: np.ndarray,
    rounds: int,
) -> dict:
    """Event-engine replay of a fixed schedule (no drift, no failures).

    ``sync`` with no perturbation reproduces the analytic per-round
    Eq. 2 value exactly (achieved == predicted every round); ``overlap``
    and ``async`` report pipelined / barrier-free timings instead.
    """
    res = simulate(tg, cg, assignment, rounds, scenario.execution_spec())
    return _sim_entry(scenario, res)


def _simulate_drift(
    scenario: Scenario,
    tg: TaskGraph,
    cg: ComputeGraph,
    drift: DelayDrift,
    method: str,
    kw: dict,
):
    """Event-engine run under moving delays with elastic re-scheduling.

    Returns ``(sim_record, initial Schedule)`` — the ElasticScheduler owns
    the only solve for this method (no separate ``compare_methods`` pass),
    re-solving warm-started on every ``on_delay_update``.  The per-round
    delay updates and the periodic re-schedule consults are control
    events in the engine's queue; when the scenario perturbs machine
    speeds, the engine's measured busy times additionally feed
    ``observe_round`` after every barrier.  Any warm-start state left by
    an earlier run of the same structure is cleared first so the record
    is a function of (scenario, seed) alone.
    """
    from repro.core.scheduler import clear_warm_start
    from repro.launch.elastic import ElasticScheduler

    clear_warm_start(tg, cg)
    es = ElasticScheduler(
        tg, cg, method=method, seed=scenario.seed,
        schedule_kwargs={k: v for k, v in kw.items() if k != "seed"},
    )
    initial = es.current
    events = [
        ControlEvent(round=r, kind="delay_update", C=drift.at(r))
        for r in range(1, scenario.rounds)
    ]
    if scenario.reschedule_every > 0:
        events += [
            ControlEvent(round=r, kind="reschedule")
            for r in range(1, scenario.rounds)
            if r % scenario.reschedule_every == 0
        ]

    last_consult = {"round": 0}

    def consult(tg_, cg_, r):
        # cg_ carries the drift.at(r) the engine already applied.  Every
        # delay snapshot since the previous consult goes into ONE batched
        # warm-started re-solve (``on_delay_updates``): the lanes share
        # structure and differ only in C, the last lane IS the current
        # network state, and the ElasticScheduler adopts the best lane's
        # assignment under it only if it clears the migration threshold.
        lo = last_consult["round"] + 1
        backlog = [drift.at(rr) for rr in range(lo, r)][-7:] + [cg_.C]
        last_consult["round"] = r
        es.on_delay_updates(backlog)
        return es.current.assignment

    spec = scenario.execution_spec()
    on_round_end = None
    if spec.perturbed:
        def on_round_end(r, busy):
            migrated = es.observe_round(busy)
            return None if migrated is None else migrated.assignment

    res = simulate(
        tg, cg, es.current.assignment, scenario.rounds, spec,
        control_events=tuple(events), schedule_fn=consult,
        on_round_end=on_round_end,
    )
    entry = _sim_entry(scenario, res)
    entry["num_migrations"] = sum(
        1 for h in es.history if h["event"] == "migrate"
    )
    entry["round_times"] = [float(t) for t in res.round_times]
    return entry, initial


# ---------------------------------------------------------------------------
# Churn execution
# ---------------------------------------------------------------------------


def _churn_trace_for(scenario: Scenario, rounds: int | None = None) -> ChurnTrace:
    """The scenario's churn trace — a pure function of (scenario, seed).

    Drawn from the DERIVED stream ``(seed, 2)``: stream ``seed`` generates
    the instance and ``(seed, 1)`` the execution jitter, so the fleet
    dynamics must not replay either's variates.  ``rounds`` overrides the
    trace length (churn×FL traces span the FL round count, which defines
    the simulated timeline there).
    """
    trace_params = {
        k: v for k, v in scenario.churn_params.items()
        if k not in CHURN_POLICY_KEYS
    }
    return churn_trace(
        np.random.default_rng((scenario.seed, 2)),
        scenario.num_machines,
        scenario.rounds if rounds is None else rounds,
        model=scenario.churn,
        **trace_params,
    )


def _churn_control_events(trace: ChurnTrace) -> tuple:
    """Trace -> engine event stream.  Link transitions do not re-schedule
    by themselves, so link-only rounds get an explicit ``reschedule``
    event — every fleet or connectivity change consults the policy."""
    events = trace.control_events()
    membership_rounds = {
        ev.round for ev in events if ev.kind in ("fail", "join", "recover")
    }
    link_only = sorted(
        {ev.round for ev in events if ev.kind in ("link_down", "link_up")}
        - membership_rounds
    )
    return tuple(
        events + [ControlEvent(round=r, kind="reschedule") for r in link_only]
    )


def _policy_kwargs(scenario: Scenario) -> dict:
    """The sdp_elastic degraded-mode budgets riding in ``churn_params``."""
    p = {k: scenario.churn_params[k] for k in CHURN_POLICY_KEYS
         if k in scenario.churn_params}
    p.setdefault("fallback", "heft")
    return p


def _repair_assignment(
    tg: TaskGraph, assign_lab: np.ndarray, live: list, e_live: np.ndarray
) -> int:
    """Greedy in-place repair of a label-space assignment after churn:
    tasks on live machines stay put; orphans go (heaviest first) to the
    machine with the least resulting compute load.  Communication is
    deliberately ignored — this is the ``sdp_static`` "no re-solve"
    lower bar the elastic policy is measured against.  Returns the
    number of migrated tasks."""
    idx = {m: j for j, m in enumerate(live)}
    loads = np.zeros(len(live))
    orphans = []
    for t in range(tg.num_tasks):
        j = idx.get(int(assign_lab[t]))
        if j is None:
            orphans.append(t)
        else:
            loads[j] += tg.p[t] / e_live[j]
    for t in sorted(orphans, key=lambda t: -tg.p[t]):
        j = int(np.argmin(loads + tg.p[t] / e_live))
        loads[j] += tg.p[t] / e_live[j]
        assign_lab[t] = live[j]
    return len(orphans)


def _simulate_churn(
    scenario: Scenario,
    tg: TaskGraph,
    cg: ComputeGraph,
    policy: str,
    kw: dict,
    trace: ChurnTrace,
    events: tuple,
):
    """Run one churn policy through the trace; returns ``(entry, SimResult)``.

    All policies replay the SAME engine event stream; they differ only in
    how the ``schedule_fn`` consult reacts:

      - ``sdp_elastic`` mirrors the fleet into an :class:`ElasticScheduler`
        (warm-started incremental re-solves, heft fallback under the solve
        budget) and folds the engine's live effective delays — link-outage
        penalties included — back into it on every consult;
      - ``sdp_static`` keeps the initial SDP assignment and only repairs
        orphaned tasks greedily;
      - ``heft`` re-solves the combinatorial heuristic from scratch at
        every consult.
    """
    from repro.core.scheduler import clear_warm_start, schedule
    from repro.launch.elastic import ElasticScheduler

    spec = scenario.execution_spec()
    stats = {"num_consults": 0}

    def live_at(r):
        return [int(m) for m in np.flatnonzero(trace.up_at[r])]

    if policy == "sdp_elastic":
        clear_warm_start()   # records are a function of (scenario, seed)
        es = ElasticScheduler(
            tg, cg, method="sdp", seed=scenario.seed,
            schedule_kwargs={k: v for k, v in kw.items() if k != "seed"},
            **_policy_kwargs(scenario),
        )
        initial = es.current

        def consult(tg_, cg_live, r):
            stats["num_consults"] += 1
            live = live_at(r)
            current = set(es.machine_ids)
            for m in sorted(set(live) - current):
                es.on_recovery(m, round=r)
            for m in sorted(current - set(live)):
                es.on_failure(m, round=r)
            # cg_live.C carries the engine's effective delays (link-outage
            # penalties applied); fold any difference back into the
            # scheduler so outage windows influence the re-solve.
            if not np.array_equal(es.compute_graph.C, cg_live.C):
                es.on_delay_update(cg_live.C, round=r)
            return es.current.assignment

    elif policy == "sdp_static":
        clear_warm_start()
        initial = schedule(tg, cg, "sdp", **kw)
        labels0 = np.arange(cg.num_machines)
        assign_lab = labels0[initial.assignment].copy()
        stats["num_migrated_tasks"] = 0

        def consult(tg_, cg_live, r):
            stats["num_consults"] += 1
            live = live_at(r)
            stats["num_migrated_tasks"] += _repair_assignment(
                tg, assign_lab, live, cg_live.e
            )
            idx = {m: j for j, m in enumerate(live)}
            return np.array([idx[int(l)] for l in assign_lab])

    elif policy == "heft":
        initial = schedule(tg, cg, "heft", seed=scenario.seed)

        def consult(tg_, cg_live, r):
            stats["num_consults"] += 1
            return schedule(tg_, cg_live, "heft", seed=scenario.seed).assignment

    else:  # pragma: no cover — Scenario.__post_init__ validates
        raise ValueError(policy)

    # Responsiveness/completeness device states (slow-responder and
    # partial-work rounds) perturb the engine's busy times for every
    # policy; the elastic policy additionally observes the measured times,
    # told which fraction of the work each machine completed so a
    # partial-work round is not mistaken for a fast machine.
    bf = trace.busy_factors()
    on_round_end = None
    if policy == "sdp_elastic" and bf is not None:
        def on_round_end(r, busy):
            live = live_at(r)
            if list(es.machine_ids) != live:   # pragma: no cover — guard
                return None
            wf = (
                trace.work_at[r, live] if trace.work_at is not None else None
            )
            migrated = es.observe_round(busy, round=r, work_fraction=wf)
            return None if migrated is None else migrated.assignment

    res = simulate(
        tg, cg, initial.assignment, scenario.rounds, spec,
        control_events=events, schedule_fn=consult,
        on_round_end=on_round_end, busy_factors=bf,
    )
    entry = {**_method_entry(initial), **_sim_entry(scenario, res)}
    entry["policy"] = policy
    entry["num_consults"] = stats["num_consults"]
    entry["final_fleet"] = [int(m) for m in res.machine_ids]
    if policy == "sdp_elastic":
        entry["fallback_count"] = es.fallback_count
        entry["num_migrations"] = sum(
            1 for h in es.history if h["event"] == "migrate"
        )
        entry["num_elastic_resolves"] = sum(
            1 for h in es.history
            if h["event"].startswith(("fail:", "recover:", "join:"))
        )
    if policy == "sdp_static":
        entry["num_migrated_tasks"] = stats["num_migrated_tasks"]
    return entry, res


def _churn_oracle(
    scenario: Scenario, tg: TaskGraph, cg: ComputeGraph, kw: dict,
    events: tuple, busy_factors=None,
) -> float:
    """Total time of the oracle: a COLD full SDP re-solve at every event,
    always adopted.  This is the quality ceiling a reactive policy could
    reach with unlimited solve budget; ``regret_vs_oracle`` measures how
    much of it the warm-started / degraded policies give up."""
    from repro.core.scheduler import clear_warm_start, schedule

    clear_warm_start()

    def consult(tg_, cg_live, r):
        clear_warm_start(tg_, cg_live)
        return schedule(tg_, cg_live, "sdp", **kw).assignment

    s0 = schedule(tg, cg, "sdp", **kw)
    res = simulate(
        tg, cg, s0.assignment, scenario.rounds, scenario.execution_spec(),
        control_events=events, schedule_fn=consult,
        busy_factors=busy_factors,
    )
    return float(res.total_time)


def _run_fl(scenario: Scenario, tg, cg, schedules=None) -> dict:
    """Run the FL workload; ``tg``/``cg`` None = legacy §4.2 generation.

    ``schedules`` hands the engine's already-computed solves through so a
    record never carries two disagreeing schedules of one instance.
    """
    from repro.fl.gossip import GossipConfig
    from repro.fl.runner import FLExperiment, run_fl

    fl = scenario.fl
    # The paper_setting path generates its own gossip graph inside run_fl:
    # forward the scenario's degree parameters so the record's axes still
    # describe the actual run.
    tp = scenario.topology_params
    exp = FLExperiment(
        dataset=fl.dataset,
        num_users=scenario.num_tasks,
        num_machines=scenario.num_machines,
        degree_low=int(tp.get("degree_low", 6)),
        degree_high=int(tp.get("degree_high", 7)),
        rounds=fl.rounds,
        num_samples=fl.num_samples,
        seed=scenario.seed,
        backend=fl.backend,
        gossip=GossipConfig(local_steps=fl.local_steps, batch_size=fl.batch_size),
    )
    return run_fl(
        exp, methods=scenario.schedulers, compute_graph=cg, task_graph=tg,
        schedules=schedules,
    )


def _run_fl_async(
    scenario: Scenario, tg, cg, schedules, trace: ChurnTrace | None
) -> dict:
    """Barrier-free FL on the engine's instance (DESIGN.md §11).

    ``fl.runner.run_fl_async`` replays each method's assignment through
    the async event engine and trains an ``AsyncGossipTrainer`` on the
    recorded deliveries.  A churn trace contributes its machine events
    (fail/join/recover — machine-local, async-legal; link outages are
    rejected at Scenario construction) and its responsiveness /
    completeness busy factors.
    """
    from repro.fl.gossip import GossipConfig
    from repro.fl.runner import FLExperiment, run_fl_async

    fl = scenario.fl
    exp = FLExperiment(
        dataset=fl.dataset,
        num_users=scenario.num_tasks,
        num_machines=scenario.num_machines,
        rounds=fl.rounds,
        num_samples=fl.num_samples,
        seed=scenario.seed,
        gossip=GossipConfig(local_steps=fl.local_steps, batch_size=fl.batch_size),
    )
    control: tuple = ()
    busy = None
    if trace is not None:
        control = tuple(
            ev for ev in trace.control_events()
            if ev.kind in ("fail", "join", "recover")
        )
        busy = trace.busy_factors()
    return run_fl_async(
        exp,
        methods=scenario.schedulers,
        compute_graph=cg,
        task_graph=tg,
        schedules=schedules,
        execution=scenario.execution_spec(),
        control_events=control,
        staleness=scenario.staleness_weights(),
        archive_depth=fl.archive_depth,
        busy_factors=busy,
    )


def _fl_async_summary(scenario: Scenario, res: dict) -> dict:
    """Async-FL record: per-method loss-vs-simulated-wall-clock curves
    (unlike the sync path, training DIFFERS per method — each assignment
    delivers snapshots on a different timetable)."""
    sw = scenario.staleness_weights()
    return {
        "mode": "async",
        "staleness": {"kind": sw.kind, "a": float(sw.a), "b": int(sw.b)},
        "per_method": {
            m: {
                "losses": [float(h["mean_loss"]) for h in rows],
                "accuracy_user0": [
                    float(h["accuracy_user0"]) for h in rows
                ],
                "sim_time": [float(h["sim_time"]) for h in rows],
                "active_users": [int(h["active_users"]) for h in rows],
                "stale_mixes": int(res["stale_mixes"][m]),
                "invalid_edges": int(
                    sum(h["invalid_edges"] for h in rows)
                ),
                "barrier_stalls": int(res["barrier_stalls"][m]),
            }
            for m, rows in res["history"].items()
        },
    }


def _fl_summary(res: dict) -> dict:
    return {
        "backend": res["backend"],
        "losses": [float(h["mean_loss"]) for h in res["history"]],
        "accuracy_user0": [float(h["accuracy_user0"]) for h in res["history"]],
        "bottleneck_per_round": {
            m: float(t) for m, t in res["bottleneck_per_round"].items()
        },
        "cumulative_time_final": {
            m: float(v[-1]) for m, v in res["cumulative_time"].items()
        },
    }


def _graph_stats(tg: TaskGraph, cg: ComputeGraph) -> dict:
    return {
        "num_tasks": tg.num_tasks,
        "num_edges": len(tg.edges),
        "constraint_edges": len(tg.constraint_edges()),
        "is_dag": tg.validate_is_dag(),
        "num_machines": cg.num_machines,
        "speed_min": float(cg.e.min()),
        "speed_max": float(cg.e.max()),
        "delay_mean": float(cg.C[~np.eye(cg.num_machines, dtype=bool)].mean()),
    }


def _method_entry(s) -> dict:
    entry: dict = {
        "predicted_bottleneck": float(s.bottleneck),
        "assignment": [int(a) for a in s.assignment],
    }
    if s.method in _SDP_FAMILY:
        info = s.info
        entry["sdp_converged"] = bool(info.get("sdp_converged", False))
        entry["representation"] = info.get("representation")
        entry["solver_backend"] = info.get("solver_backend")
        entry["sdp_seconds"] = float(info.get("sdp_seconds", 0.0))
        stats = info.get("solver_stats") or {}
        if "batch" in stats:
            entry["solve_batch"] = int(stats["batch"])
        for key in ("lower_bound", "lower_bound_uncertified",
                    "rounding_lower_bound", "upper_bound",
                    "expected_bottleneck"):
            if key in info:
                entry[key] = float(info[key])
    return entry


def run_scenario(
    scenario: Scenario, *, quick: bool = False, _presolved: dict | None = None
) -> dict:
    """Execute one scenario end to end; returns a JSON-serializable record.

    ``_presolved`` is ``run_sweep``'s batched-solve hand-off: a
    ``compare_methods`` SDP cache (``{"bqp", "sol", "representation"}``)
    whose solution came out of a ``solve_sdp_batch`` over same-shape
    scenarios — the static path consumes it instead of re-solving.
    """
    t0 = time.perf_counter()
    kw = _schedule_kwargs(scenario, quick)
    fl = scenario.fl

    flres = None
    if fl is not None and fl.paper_setting:
        # Legacy §4.2 path: run_fl generates the instance AND schedules
        # every method itself — reuse its schedules instead of solving a
        # second time, and report ITS instance's stats.
        flres = _run_fl(scenario, None, None)
        tg, cg = flres["task_graph"], flres["compute_graph"]
        drift = None
        schedules = flres["schedules"]
    else:
        rng = np.random.default_rng(scenario.seed)
        tg = build_task_graph(scenario, rng)
        cg, drift = build_compute_graph(scenario, rng)
        # Under drift each method's only solve lives in its
        # ElasticScheduler (below), and under sync churn each POLICY owns
        # its solves; static scenarios — including barrier-free FL, where
        # the assignment is fixed and churn only freezes machines — share
        # one SDP solve across the sdp family through compare_methods'
        # cache (possibly pre-filled by run_sweep's batched solve).
        dynamic = drift is not None or (
            scenario.churn is not None and fl is None
        )
        schedules = None if dynamic else compare_methods(
            tg, cg, methods=tuple(scenario.schedulers),
            _sdp_cache=_presolved, **kw
        )

    # An FL workload defines the round count; the simulated totals and the
    # trainer's cumulative times then describe the same run.  (fl + drift
    # is rejected by Scenario.__post_init__, so drift always simulates
    # scenario.rounds.)
    sim_rounds = fl.rounds if fl is not None else scenario.rounds

    record: dict = {
        "scenario": scenario.name,
        "seed": scenario.seed,
        "quick": budget_quick(scenario, quick),
        "rounds": sim_rounds,
        "axes": scenario.axes(),
        "graph": _graph_stats(tg, cg),
        "methods": {},
    }

    if scenario.churn is not None and fl is None:
        trace = _churn_trace_for(scenario)
        events = _churn_control_events(trace)
        oracle_total = _churn_oracle(
            scenario, tg, cg, kw, events, busy_factors=trace.busy_factors()
        )
        record["churn"] = {
            "model": scenario.churn,
            "counts": trace.counts,
            "num_events": len(trace.machine_events) + len(trace.link_events),
            "min_live": int(trace.up_at.sum(axis=1).min()),
            "oracle_total_time": oracle_total,
        }
        for pol in scenario.churn_policies:
            entry, _ = _simulate_churn(
                scenario, tg, cg, pol, kw, trace, events
            )
            entry["regret_vs_oracle"] = (
                entry["total_time"] / oracle_total - 1.0
                if oracle_total > 0 else float("nan")
            )
            record["methods"][pol] = entry
    elif drift is not None:
        for m in scenario.schedulers:
            sim, initial = _simulate_drift(scenario, tg, cg, drift, m, kw)
            record["methods"][m] = {**_method_entry(initial), **sim}
    elif fl is not None and scenario.execution == "async":
        # Barrier-free FL: one async sim + one AsyncGossipTrainer run per
        # method (training differs per assignment), optionally under a
        # churn trace spanning the FL rounds.
        trace = (
            _churn_trace_for(scenario, rounds=fl.rounds)
            if scenario.churn is not None else None
        )
        flres = _run_fl_async(scenario, tg, cg, schedules, trace)
        for m, s in schedules.items():
            record["methods"][m] = {
                **_method_entry(s),
                **_sim_entry(scenario, flres["sim"][m]),
            }
        if trace is not None:
            record["churn"] = {
                "model": scenario.churn,
                "counts": trace.counts,
                "num_events": len(trace.machine_events),
                "min_live": int(trace.up_at.sum(axis=1).min()),
            }
    else:
        for m, s in schedules.items():
            record["methods"][m] = {
                **_method_entry(s),
                **_simulate_static(scenario, tg, cg, s.assignment, sim_rounds),
            }

    if fl is not None:
        if scenario.execution == "async":
            record["fl"] = _fl_async_summary(scenario, flres)
        else:
            if flres is None:
                flres = _run_fl(scenario, tg, cg, schedules=schedules)
            record["fl"] = _fl_summary(flres)

    record["elapsed_seconds"] = time.perf_counter() - t0
    return record


# ---------------------------------------------------------------------------
# Sweep execution (resumable)
# ---------------------------------------------------------------------------


def _presolve_groups(pending, quick: bool) -> dict:
    """Batch the SDP solves of same-shape pending scenarios.

    Groups static (no drift, no paper-setting FL) scenarios that request
    an sdp-family scheduler by the shape the batched solver requires —
    (num_tasks, num_machines, constraint-edge count) plus the resolved
    representation, solver backend, and options — and runs each group of
    two or more through ONE ``solve_sdp_batch`` dispatch.  The instances
    are generated exactly as ``run_scenario`` will regenerate them (same
    ``default_rng(seed)`` stream), and the backend is resolved per
    instance with the same rule ``solve_sdp`` applies, so a record
    computed through a batch is the record the sequential path produces.

    Returns ``{scenario_key: sdp-cache dict}`` for the batched scenarios;
    everything else solves inside its own ``run_scenario`` as before.
    """
    from repro.core import bqp as bqp_mod
    from repro.core.scheduler import _pick_representation
    from repro.core.sdp import _resolve_backend, solve_sdp_batch

    groups: dict[tuple, list] = {}
    for sc in pending:
        if not any(m in _SDP_FAMILY for m in sc.schedulers):
            continue
        if sc.fl is not None and sc.fl.paper_setting:
            continue
        if sc.delay_model == "drift":
            continue
        if sc.churn is not None:
            # Churn policies own their solves (warm-started or per-event);
            # a pre-solved static relaxation has no consumer there.
            continue
        kw = _schedule_kwargs(sc, quick)
        rng = np.random.default_rng(sc.seed)
        tg = build_task_graph(sc, rng)
        cg, drift = build_compute_graph(sc, rng)
        if drift is not None:
            continue
        rep = _pick_representation(tg, cg, kw.get("representation", "auto"))
        opts = kw.get("sdp_options") or SDPOptions()
        if kw.get("solver_backend") is not None:
            opts = dataclasses.replace(opts, backend=kw["solver_backend"])
        opts = dataclasses.replace(
            opts,
            backend=_resolve_backend(opts, tg.num_tasks * cg.num_machines + 1),
        )
        gkey = (
            tg.num_tasks,
            cg.num_machines,
            len(tg.constraint_edges()),
            rep,
            opts,
        )
        groups.setdefault(gkey, []).append((sc, tg, cg))

    out: dict = {}
    for (n_t, n_k, n_e, rep, opts), items in groups.items():
        if len(items) < 2:
            continue
        build = (
            bqp_mod.build_factored_bqp
            if rep == "factored"
            else bqp_mod.build_bqp
        )
        bqps = [build(tg, cg) for _, tg, cg in items]
        try:
            sols = solve_sdp_batch(bqps, opts)
        except (ValueError, ImportError):   # pragma: no cover — shape drift
            continue
        for (sc, tg, cg), bqp, sol in zip(items, bqps, sols):
            out[scenario_key(sc, quick)] = {
                "bqp": bqp,
                "sol": sol,
                "representation": rep,
            }
    return out


def run_sweep(
    scenarios: Iterable[Scenario],
    out_path: str | pathlib.Path = "BENCH_scenarios.json",
    *,
    quick: bool = False,
    resume: bool = True,
    progress: Callable[[str], None] | None = None,
    batch_solves: bool = True,
) -> dict:
    """Run scenarios in order, persisting after every record.

    The output JSON (schema: ``docs/benchmarks.md``) is rewritten after
    each scenario completes, and on re-entry records whose
    ``(scenario, seed, quick)`` already exist in the file are skipped — a
    killed sweep resumes where it left off, and quick-budget records never
    masquerade as (or block) full-budget ones.  ``resume=False`` starts
    fresh.

    With ``batch_solves`` (the default) pending same-shape static
    scenarios have their SDP relaxations solved up front in batched
    ``solve_sdp_batch`` dispatches (``_presolve_groups``); each
    ``run_scenario`` then consumes its pre-solved relaxation instead of
    solving alone.
    """
    path = pathlib.Path(out_path)
    records: list[dict] = []
    if resume and path.exists():
        records = json.loads(path.read_text()).get("records", [])
    done = {record_key(r) for r in records}

    scenarios = list(scenarios)
    presolved: dict = {}
    if batch_solves:
        pending = [sc for sc in scenarios if scenario_key(sc, quick) not in done]
        presolved = _presolve_groups(pending, quick)
        if presolved and progress:
            progress(f"batched {len(presolved)} same-shape SDP solves")

    payload = {"bench": "scenario_sweep", "records": records}
    for sc in scenarios:
        key = scenario_key(sc, quick)
        if key in done:
            if progress:
                progress(f"skip {sc.name} seed={sc.seed} (already recorded)")
            continue
        if progress:
            progress(f"run {sc.name} seed={sc.seed} ...")
        rec = run_scenario(sc, quick=quick, _presolved=presolved.get(key))
        records.append(rec)
        done.add(key)
        _write_atomic(path, payload)
        if progress:
            best = min(
                rec["methods"].items(), key=lambda kv: kv[1]["predicted_bottleneck"]
            )
            progress(
                f"  {sc.name}: best={best[0]} "
                f"bottleneck={best[1]['predicted_bottleneck']:.3f} "
                f"({rec['elapsed_seconds']:.1f}s)"
            )
    _write_atomic(path, payload)
    return payload


def _write_atomic(path: pathlib.Path, payload: dict) -> None:
    """Write-then-rename so a kill mid-write (the resume case this file
    exists for) never truncates previously completed records."""
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(payload, indent=2) + "\n")
    os.replace(tmp, path)
