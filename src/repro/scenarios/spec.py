"""Scenario = topology × machine profile × delay model × schedulers (+ FL).

A :class:`Scenario` is a declarative, frozen description of one experiment
on the bottleneck-time pipeline: which task-graph family to generate
(``core/graphs.py`` topology families), how heterogeneous the machines
are, how delays are structured (possibly time-varying), which schedulers
compete, and optionally a gossip-FL workload to train on the stacked
engine.  ``repro.scenarios.engine.run_scenario`` turns one into a
JSON-serializable record; the registry maps preset names (``fig4_nt10``,
``fig6``, ``torus_cluster``, ...) to scenarios so paper figures and new
sweeps share one code path.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.graphs import TOPOLOGY_FAMILIES
from repro.core.scheduler import METHODS
from repro.fl.staleness import StalenessWeights
from repro.scenarios.profiles import (
    CHURN_MODELS,
    CHURN_TRACE_PARAMS,
    DELAY_MODELS,
    MACHINE_PROFILES,
    _take,
)
from repro.sim import SEMANTICS, ExecutionSpec

_EXECUTION_PARAM_KEYS = (
    "jitter_sigma", "straggler_prob", "straggler_factor",
    "token_capacity", "token_refill",
)

# Churn policies are NOT plain scheduler methods — they are strategies for
# reacting to trace events, each anchored on a method:
#   - ``sdp_elastic``: warm-started ElasticScheduler re-solves at every
#     fleet/link transition, with heft fallback under the solve budget.
#   - ``sdp_static``:  one initial SDP solve; on fleet changes only the
#     orphaned tasks are greedily repaired (no re-solve) — the "do
#     nothing clever" lower bar.
#   - ``heft``:        full combinatorial heft re-solve at every event —
#     cheap, always converges, but never benefits from the SDP rounding.
CHURN_POLICIES = ("sdp_elastic", "sdp_static", "heft")

# ``churn_params`` keys that configure the sdp_elastic POLICY (degraded
# mode budgets) rather than the trace generator — split off before the
# params reach ``churn_trace``.
CHURN_POLICY_KEYS = (
    "fallback", "solve_timeout", "solver_max_iters", "require_converged",
)


@dataclasses.dataclass(frozen=True)
class FLWorkload:
    """Optional gossip-FL training riding on a scenario.

    ``paper_setting=True`` delegates instance generation AND scheduling to
    ``repro.fl.runner.run_fl`` (the §4.2 code path — exactly what the fig6
    benchmark ran before the scenario engine existed): the scenario's
    ``degree_low``/``degree_high`` topology params are forwarded, but its
    ``machine_params``/``delay_params``/``schedule_params`` are NOT — the
    legacy path's homogeneous machines, Unif(0,1) delays, and default
    solver budgets are what make it bit-identical to the pre-engine fig6.
    Otherwise the engine's (task graph, compute graph, schedules) drive
    the trainer.
    """

    dataset: str = "mnist"
    rounds: int = 3
    local_steps: int = 2
    batch_size: int = 32
    num_samples: int = 1024
    backend: str = "stacked"
    paper_setting: bool = False
    # Barrier-free training (execution="async" scenarios): ring-buffer
    # depth of the AsyncGossipTrainer's message archive — snapshots older
    # than this many rounds are evicted and their edges fall back to
    # self-weight (DESIGN.md §11).
    archive_depth: int = 8


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of the topology × heterogeneity × dynamics grid.

    ``topology_params`` / ``machine_params`` / ``delay_params`` are passed
    through to the corresponding generator (see ``core/graphs.py`` and
    ``scenarios/profiles.py`` for the accepted keys); ``schedule_params``
    tunes the scheduler call (``num_samples``, ``max_iters``).
    ``reschedule_every`` only matters for the ``drift`` delay model: the
    engine refreshes C and offers a warm-started re-schedule every that
    many rounds (``ElasticScheduler.on_delay_update``).

    ``execution`` picks the event-engine semantics the scenario is
    simulated under (``repro.sim``): ``sync`` (Eq. 2 round barrier —
    the default, and the only semantics compatible with ``drift`` /
    failure control events), ``overlap`` (send/compute pipelining), or
    ``async`` (barrier-free; records staleness + steady-state
    throughput).  ``execution_params`` feeds the per-machine
    perturbation model (``jitter_sigma``, ``straggler_prob``,
    ``straggler_factor`` — scalars or per-machine sequences).  Under
    the ``drift`` delay model with perturbations the engine's measured
    busy times are additionally fed to
    ``ElasticScheduler.observe_round`` after every round, closing the
    elastic speed-estimation loop (static scenarios have no
    ElasticScheduler in the loop — they record the noisy timings as
    measured).
    """

    name: str
    topology: str
    num_tasks: int
    num_machines: int = 4
    machine_profile: str = "uniform"
    delay_model: str = "uniform"
    schedulers: tuple[str, ...] = ("sdp", "heft", "tp_heft", "random")
    rounds: int = 8
    seed: int = 0
    reschedule_every: int = 4
    execution: str = "sync"
    execution_params: Mapping = dataclasses.field(default_factory=dict)
    topology_params: Mapping = dataclasses.field(default_factory=dict)
    machine_params: Mapping = dataclasses.field(default_factory=dict)
    delay_params: Mapping = dataclasses.field(default_factory=dict)
    schedule_params: Mapping = dataclasses.field(default_factory=dict)
    fl: FLWorkload | None = None
    # -- churn axis ---------------------------------------------------------
    # A churn model name activates trace-driven fleet dynamics: a seeded
    # ChurnTrace (stream (seed, 2)) drives fail/join/recover/link events
    # through the sync engine, each churn policy reacts per its strategy,
    # and the record carries bottleneck-time regret vs an oracle per-event
    # cold re-solve.  Mutually exclusive with drift delays and FL (one
    # record = one dynamics regime).
    churn: str | None = None
    churn_params: Mapping = dataclasses.field(default_factory=dict)
    churn_policies: tuple[str, ...] = CHURN_POLICIES
    # Staleness-weight family for barrier-free FL (``repro.fl.staleness``
    # keys: kind/a/b).  Only meaningful with fl + execution="async" —
    # under sync every mix is fresh, so s(Δτ) never fires.
    staleness_params: Mapping = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.topology not in TOPOLOGY_FAMILIES:
            raise ValueError(
                f"unknown topology {self.topology!r}; "
                f"choose from {TOPOLOGY_FAMILIES}"
            )
        if self.machine_profile not in MACHINE_PROFILES:
            raise ValueError(
                f"unknown machine profile {self.machine_profile!r}; "
                f"choose from {MACHINE_PROFILES}"
            )
        if self.delay_model not in DELAY_MODELS:
            raise ValueError(
                f"unknown delay model {self.delay_model!r}; "
                f"choose from {DELAY_MODELS}"
            )
        for m in self.schedulers:
            if m not in METHODS:
                raise ValueError(f"unknown scheduler {m!r}; choose from {METHODS}")
        if self.num_tasks < 2 or self.num_machines < 2:
            raise ValueError("need >= 2 tasks and >= 2 machines")
        if self.execution not in SEMANTICS:
            raise ValueError(
                f"unknown execution semantics {self.execution!r}; "
                f"choose from {SEMANTICS}"
            )
        unknown = set(self.execution_params) - set(_EXECUTION_PARAM_KEYS)
        if unknown:
            raise ValueError(
                f"unknown execution parameter(s) {sorted(unknown)}; "
                f"accepted: {sorted(_EXECUTION_PARAM_KEYS)}"
            )
        self.execution_spec()  # validate parameter values eagerly
        if (
            self.execution_params.get("token_capacity") is not None
            and self.execution != "async"
        ):
            raise ValueError(
                f"execution_params['token_capacity'] requires "
                f"execution='async' (got execution={self.execution!r}): "
                f"under sync/overlap every send is a dependency, so a "
                f"skipped send would deadlock its consumer; nearest legal "
                f"config: execution='async', or drop token_capacity"
            )
        if self.delay_model == "drift" and self.execution != "sync":
            raise ValueError(
                f"delay_model='drift' requires execution='sync' (got "
                f"execution={self.execution!r}): drift re-schedules at "
                f"round barriers, which barrier-free semantics do not "
                f"have; nearest legal config: execution='sync', or "
                f"delay_model='distance' with execution={self.execution!r}"
            )
        if self.fl is not None and self.execution == "overlap":
            raise ValueError(
                f"fl with execution='overlap' is not supported: the "
                f"pipelined engine overlaps sends with compute but still "
                f"consumes every input fresh, which no trainer models; "
                f"nearest legal config: execution='sync' "
                f"(GossipTrainer barriers) or execution='async' "
                f"(AsyncGossipTrainer on delivered snapshots)"
            )
        if self.fl is not None and self.delay_model == "drift":
            raise ValueError(
                "an FL workload cannot ride on the drift delay model: the "
                "FL timeline assumes static delays, so one record would "
                "describe two different runs"
            )
        if (
            self.fl is not None
            and self.fl.paper_setting
            and self.execution != "sync"
        ):
            raise ValueError(
                f"fl.paper_setting=True with execution={self.execution!r} "
                f"is not supported: the paper_setting path replays the "
                f"legacy synchronous §4.2 benchmark bit-for-bit; nearest "
                f"legal config: execution='sync', or paper_setting=False "
                f"for barrier-free training on the engine's instance"
            )
        if self.staleness_params and (
            self.fl is None or self.execution != "async"
        ):
            raise ValueError(
                f"staleness_params only apply to barrier-free FL training "
                f"(got fl={'set' if self.fl is not None else None}, "
                f"execution={self.execution!r}): under sync every mix is "
                f"fresh, so s(Δτ) never fires; nearest legal config: "
                f"execution='async' with an fl workload, or drop "
                f"staleness_params"
            )
        # Validate the family eagerly — a bad kind/a/b must fail at
        # construction, not when the trainer first mixes.
        StalenessWeights(**dict(self.staleness_params))
        if self.churn is not None:
            if self.churn not in CHURN_MODELS:
                raise ValueError(
                    f"unknown churn model {self.churn!r}; "
                    f"choose from {CHURN_MODELS}"
                )
            if self.fl is None and self.execution != "sync":
                raise ValueError(
                    f"churn={self.churn!r} without an fl workload requires "
                    f"execution='sync' (got execution={self.execution!r}): "
                    f"the churn policies re-schedule at round barriers; "
                    f"nearest legal config: execution='sync', or add an fl "
                    f"workload with execution='async' for barrier-free "
                    f"churn-tolerant training"
                )
            if self.fl is not None and self.execution != "async":
                raise ValueError(
                    f"churn={self.churn!r} composed with fl requires "
                    f"execution='async' (got execution={self.execution!r}): "
                    f"only the barrier-free AsyncGossipTrainer freezes and "
                    f"recovers replicas mid-training; nearest legal config: "
                    f"execution='async', or drop fl to run the sync churn "
                    f"policies"
                )
            if self.delay_model == "drift":
                raise ValueError(
                    "churn and drift are separate dynamics axes; compose "
                    "link outages via churn_params instead of drift delays"
                )
            if not self.churn_policies:
                raise ValueError("churn scenarios need >= 1 churn policy")
            # Validate parameter NAMES eagerly — a misspelled churn knob
            # must fail at construction, not mid-sweep.  Policy keys
            # (solver budgets) ride in churn_params but never reach the
            # trace generator.
            trace_params = {
                k: v for k, v in self.churn_params.items()
                if k not in CHURN_POLICY_KEYS
            }
            _take(self.churn, trace_params, CHURN_TRACE_PARAMS[self.churn])
            if self.fl is not None and int(
                trace_params.get("link_outages", 0)
            ) != 0:
                raise ValueError(
                    f"churn_params['link_outages']="
                    f"{trace_params['link_outages']} cannot compose with "
                    f"fl: link events are a sync-only control kind (the "
                    f"async engine has no barrier at which to swap the "
                    f"delay matrix); nearest legal config: "
                    f"link_outages=0, or drop fl for the sync churn "
                    f"policies"
                )
            for pol in self.churn_policies:
                if pol not in CHURN_POLICIES:
                    raise ValueError(
                        f"unknown churn policy {pol!r}; "
                        f"choose from {CHURN_POLICIES}"
                    )

    def with_seed(self, seed: int) -> "Scenario":
        return dataclasses.replace(self, seed=seed)

    def execution_spec(self) -> ExecutionSpec:
        """The event-engine spec this scenario simulates under.

        Jitter/straggler draws are a pure function of the scenario seed,
        but through a DERIVED stream ``(seed, 1)`` — reusing the bare
        seed would replay the exact PRNG variates that generated the
        instance (speeds, delays, topology), correlating the execution
        noise with the heterogeneity it is supposed to perturb.
        """
        params = {
            k: tuple(v) if isinstance(v, (list, tuple)) else v
            for k, v in self.execution_params.items()
        }
        return ExecutionSpec(
            semantics=self.execution, seed=(self.seed, 1), **params
        )

    def staleness_weights(self) -> StalenessWeights:
        """The validated ``s(Δτ)`` family for barrier-free FL training
        (the constant family — no discount — when unset)."""
        return StalenessWeights(**dict(self.staleness_params))

    def axes(self) -> dict:
        """The scenario's grid coordinates (for sweep records / --list)."""
        return {
            "topology": self.topology,
            "num_tasks": self.num_tasks,
            "num_machines": self.num_machines,
            "machine_profile": self.machine_profile,
            "delay_model": self.delay_model,
            "schedulers": list(self.schedulers),
            "execution": self.execution,
            "fl": self.fl is not None,
            "churn": self.churn,
            "churn_policies": (
                list(self.churn_policies) if self.churn is not None else []
            ),
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    """Register a scenario under its name (last registration wins)."""
    _REGISTRY[scenario.name] = scenario
    return scenario


def _ensure_presets_loaded() -> None:
    from repro.scenarios import presets  # noqa: F401  (registers on import)


def get_scenario(name: str) -> Scenario:
    _ensure_presets_loaded()
    if name not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}")
    return _REGISTRY[name]


def list_scenarios() -> dict[str, Scenario]:
    _ensure_presets_loaded()
    return dict(sorted(_REGISTRY.items()))
