"""Scenario = topology × machine profile × delay model × schedulers (+ FL).

A :class:`Scenario` is a declarative, frozen description of one experiment
on the bottleneck-time pipeline: which task-graph family to generate
(``core/graphs.py`` topology families), how heterogeneous the machines
are, how delays are structured (possibly time-varying), which schedulers
compete, and optionally a gossip-FL workload to train on the stacked
engine.  ``repro.scenarios.engine.run_scenario`` turns one into a
JSON-serializable record; the registry maps preset names (``fig4_nt10``,
``fig6``, ``torus_cluster``, ...) to scenarios so paper figures and new
sweeps share one code path.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.graphs import TOPOLOGY_FAMILIES
from repro.core.scheduler import METHODS
from repro.scenarios.profiles import (
    CHURN_MODELS,
    CHURN_TRACE_PARAMS,
    DELAY_MODELS,
    MACHINE_PROFILES,
    _take,
)
from repro.sim import SEMANTICS, ExecutionSpec

_EXECUTION_PARAM_KEYS = ("jitter_sigma", "straggler_prob", "straggler_factor")

# Churn policies are NOT plain scheduler methods — they are strategies for
# reacting to trace events, each anchored on a method:
#   - ``sdp_elastic``: warm-started ElasticScheduler re-solves at every
#     fleet/link transition, with heft fallback under the solve budget.
#   - ``sdp_static``:  one initial SDP solve; on fleet changes only the
#     orphaned tasks are greedily repaired (no re-solve) — the "do
#     nothing clever" lower bar.
#   - ``heft``:        full combinatorial heft re-solve at every event —
#     cheap, always converges, but never benefits from the SDP rounding.
CHURN_POLICIES = ("sdp_elastic", "sdp_static", "heft")

# ``churn_params`` keys that configure the sdp_elastic POLICY (degraded
# mode budgets) rather than the trace generator — split off before the
# params reach ``churn_trace``.
CHURN_POLICY_KEYS = (
    "fallback", "solve_timeout", "solver_max_iters", "require_converged",
)


@dataclasses.dataclass(frozen=True)
class FLWorkload:
    """Optional gossip-FL training riding on a scenario.

    ``paper_setting=True`` delegates instance generation AND scheduling to
    ``repro.fl.runner.run_fl`` (the §4.2 code path — exactly what the fig6
    benchmark ran before the scenario engine existed): the scenario's
    ``degree_low``/``degree_high`` topology params are forwarded, but its
    ``machine_params``/``delay_params``/``schedule_params`` are NOT — the
    legacy path's homogeneous machines, Unif(0,1) delays, and default
    solver budgets are what make it bit-identical to the pre-engine fig6.
    Otherwise the engine's (task graph, compute graph, schedules) drive
    the trainer.
    """

    dataset: str = "mnist"
    rounds: int = 3
    local_steps: int = 2
    batch_size: int = 32
    num_samples: int = 1024
    backend: str = "stacked"
    paper_setting: bool = False


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of the topology × heterogeneity × dynamics grid.

    ``topology_params`` / ``machine_params`` / ``delay_params`` are passed
    through to the corresponding generator (see ``core/graphs.py`` and
    ``scenarios/profiles.py`` for the accepted keys); ``schedule_params``
    tunes the scheduler call (``num_samples``, ``max_iters``).
    ``reschedule_every`` only matters for the ``drift`` delay model: the
    engine refreshes C and offers a warm-started re-schedule every that
    many rounds (``ElasticScheduler.on_delay_update``).

    ``execution`` picks the event-engine semantics the scenario is
    simulated under (``repro.sim``): ``sync`` (Eq. 2 round barrier —
    the default, and the only semantics compatible with ``drift`` /
    failure control events), ``overlap`` (send/compute pipelining), or
    ``async`` (barrier-free; records staleness + steady-state
    throughput).  ``execution_params`` feeds the per-machine
    perturbation model (``jitter_sigma``, ``straggler_prob``,
    ``straggler_factor`` — scalars or per-machine sequences).  Under
    the ``drift`` delay model with perturbations the engine's measured
    busy times are additionally fed to
    ``ElasticScheduler.observe_round`` after every round, closing the
    elastic speed-estimation loop (static scenarios have no
    ElasticScheduler in the loop — they record the noisy timings as
    measured).
    """

    name: str
    topology: str
    num_tasks: int
    num_machines: int = 4
    machine_profile: str = "uniform"
    delay_model: str = "uniform"
    schedulers: tuple[str, ...] = ("sdp", "heft", "tp_heft", "random")
    rounds: int = 8
    seed: int = 0
    reschedule_every: int = 4
    execution: str = "sync"
    execution_params: Mapping = dataclasses.field(default_factory=dict)
    topology_params: Mapping = dataclasses.field(default_factory=dict)
    machine_params: Mapping = dataclasses.field(default_factory=dict)
    delay_params: Mapping = dataclasses.field(default_factory=dict)
    schedule_params: Mapping = dataclasses.field(default_factory=dict)
    fl: FLWorkload | None = None
    # -- churn axis ---------------------------------------------------------
    # A churn model name activates trace-driven fleet dynamics: a seeded
    # ChurnTrace (stream (seed, 2)) drives fail/join/recover/link events
    # through the sync engine, each churn policy reacts per its strategy,
    # and the record carries bottleneck-time regret vs an oracle per-event
    # cold re-solve.  Mutually exclusive with drift delays and FL (one
    # record = one dynamics regime).
    churn: str | None = None
    churn_params: Mapping = dataclasses.field(default_factory=dict)
    churn_policies: tuple[str, ...] = CHURN_POLICIES

    def __post_init__(self):
        if self.topology not in TOPOLOGY_FAMILIES:
            raise ValueError(
                f"unknown topology {self.topology!r}; "
                f"choose from {TOPOLOGY_FAMILIES}"
            )
        if self.machine_profile not in MACHINE_PROFILES:
            raise ValueError(
                f"unknown machine profile {self.machine_profile!r}; "
                f"choose from {MACHINE_PROFILES}"
            )
        if self.delay_model not in DELAY_MODELS:
            raise ValueError(
                f"unknown delay model {self.delay_model!r}; "
                f"choose from {DELAY_MODELS}"
            )
        for m in self.schedulers:
            if m not in METHODS:
                raise ValueError(f"unknown scheduler {m!r}; choose from {METHODS}")
        if self.num_tasks < 2 or self.num_machines < 2:
            raise ValueError("need >= 2 tasks and >= 2 machines")
        if self.execution not in SEMANTICS:
            raise ValueError(
                f"unknown execution semantics {self.execution!r}; "
                f"choose from {SEMANTICS}"
            )
        unknown = set(self.execution_params) - set(_EXECUTION_PARAM_KEYS)
        if unknown:
            raise ValueError(
                f"unknown execution parameter(s) {sorted(unknown)}; "
                f"accepted: {sorted(_EXECUTION_PARAM_KEYS)}"
            )
        self.execution_spec()  # validate parameter values eagerly
        if self.delay_model == "drift" and self.execution != "sync":
            raise ValueError(
                "the drift delay model re-schedules at round barriers, so "
                "it requires sync execution semantics"
            )
        if self.fl is not None and self.execution != "sync":
            raise ValueError(
                "an FL workload requires sync execution semantics: the "
                "gossip trainer runs synchronous rounds, so one record "
                "would describe two different execution regimes"
            )
        if self.fl is not None and self.delay_model == "drift":
            raise ValueError(
                "an FL workload cannot ride on the drift delay model: the "
                "FL timeline assumes static delays, so one record would "
                "describe two different runs"
            )
        if self.churn is not None:
            if self.churn not in CHURN_MODELS:
                raise ValueError(
                    f"unknown churn model {self.churn!r}; "
                    f"choose from {CHURN_MODELS}"
                )
            if self.execution != "sync":
                raise ValueError(
                    "churn events fire at round barriers, so a churn trace "
                    "requires sync execution semantics"
                )
            if self.delay_model == "drift":
                raise ValueError(
                    "churn and drift are separate dynamics axes; compose "
                    "link outages via churn_params instead of drift delays"
                )
            if self.fl is not None:
                raise ValueError(
                    "an FL workload cannot ride on a churn trace: the FL "
                    "timeline assumes a fixed fleet"
                )
            if not self.churn_policies:
                raise ValueError("churn scenarios need >= 1 churn policy")
            # Validate parameter NAMES eagerly — a misspelled churn knob
            # must fail at construction, not mid-sweep.  Policy keys
            # (solver budgets) ride in churn_params but never reach the
            # trace generator.
            trace_params = {
                k: v for k, v in self.churn_params.items()
                if k not in CHURN_POLICY_KEYS
            }
            _take(self.churn, trace_params, CHURN_TRACE_PARAMS[self.churn])
            for pol in self.churn_policies:
                if pol not in CHURN_POLICIES:
                    raise ValueError(
                        f"unknown churn policy {pol!r}; "
                        f"choose from {CHURN_POLICIES}"
                    )

    def with_seed(self, seed: int) -> "Scenario":
        return dataclasses.replace(self, seed=seed)

    def execution_spec(self) -> ExecutionSpec:
        """The event-engine spec this scenario simulates under.

        Jitter/straggler draws are a pure function of the scenario seed,
        but through a DERIVED stream ``(seed, 1)`` — reusing the bare
        seed would replay the exact PRNG variates that generated the
        instance (speeds, delays, topology), correlating the execution
        noise with the heterogeneity it is supposed to perturb.
        """
        params = {
            k: tuple(v) if isinstance(v, (list, tuple)) else v
            for k, v in self.execution_params.items()
        }
        return ExecutionSpec(
            semantics=self.execution, seed=(self.seed, 1), **params
        )

    def axes(self) -> dict:
        """The scenario's grid coordinates (for sweep records / --list)."""
        return {
            "topology": self.topology,
            "num_tasks": self.num_tasks,
            "num_machines": self.num_machines,
            "machine_profile": self.machine_profile,
            "delay_model": self.delay_model,
            "schedulers": list(self.schedulers),
            "execution": self.execution,
            "fl": self.fl is not None,
            "churn": self.churn,
            "churn_policies": (
                list(self.churn_policies) if self.churn is not None else []
            ),
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    """Register a scenario under its name (last registration wins)."""
    _REGISTRY[scenario.name] = scenario
    return scenario


def _ensure_presets_loaded() -> None:
    from repro.scenarios import presets  # noqa: F401  (registers on import)


def get_scenario(name: str) -> Scenario:
    _ensure_presets_loaded()
    if name not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}")
    return _REGISTRY[name]


def list_scenarios() -> dict[str, Scenario]:
    _ensure_presets_loaded()
    return dict(sorted(_REGISTRY.items()))
