"""Machine-heterogeneity profiles and delay models (scenario axes).

A scenario composes a task-graph family with a *machine profile* (the
speed vector ``e``) and a *delay model* (the pairwise delay matrix ``C``).
Both are pure functions of an ``np.random.Generator`` so scenario records
are reproducible from ``(scenario, seed)`` alone; the time-varying
``drift`` model wraps a static base model in a :class:`DelayDrift` whose
``at(round)`` yields the per-round matrix.

Profiles (``machine_speeds``):
  - ``uniform``   — homogeneous machines (``speed``, default 1).
  - ``bimodal``   — edge/cloud split: ``ceil(fast_fraction · N_K)`` cloud
    machines at ``fast`` speed, the rest edge devices at ``slow``.
  - ``lognormal`` — ``e ~ LogNormal(mu, sigma)``: a long-tailed fleet.
  - ``paper``     — the §4.1.2 setting ``e ~ |N(0, √15)|``.

Delay models (``delay_matrix``):
  - ``uniform``  — ``C ~ Unif(0, c_max)`` i.i.d. (the §4.2 FL setting).
  - ``distance`` — machines at uniform points of the unit square,
    ``C = base + scale · euclidean distance`` (symmetric).
  - ``cluster``  — machines split into ``clusters`` groups; intra-cluster
    links cost ``intra``, inter-cluster links ``inter``, with a symmetric
    multiplicative jitter (datacenter racks / geo regions).
  - ``paper``    — the §4.1.2 setting ``C ~ |N(0, √10)|``.
  - ``drift``    — time-varying: a static ``base`` model modulated per
    round (see :class:`DelayDrift`); the engine re-schedules mid-run via
    ``ElasticScheduler.on_delay_update``.

Churn traces (``churn_trace``): seeded per-machine up↔down state machines
plus intermittent-link outage windows, emitted as a round-indexed
:class:`ChurnTrace` the scenario engine turns into ``ControlEvent``
streams.  Models:

  - ``markov``  — geometric dwell times: each round an up machine fails
    with probability ``p_fail`` and a down machine returns with
    probability ``p_recover`` (memoryless flapping).
  - ``weibull`` — alternating up/down dwell durations drawn from Weibull
    distributions (``shape_up``/``scale_up``, ``shape_down``/
    ``scale_down``); ``shape > 1`` concentrates session lengths,
    ``shape < 1`` gives the heavy-tailed mix of long-lived and flappy
    machines seen in real device fleets.

Both models share ``start_down_fraction`` (machines that begin the trace
absent and later *join*), a ``min_up`` floor (a fail that would drop the
live fleet below it is postponed — the trace never strands the engine
without machines), and intermittent links: ``link_outages`` windows, each
multiplying one pair's delay by ``outage_factor`` for a sampled number of
rounds (non-overlapping per pair).
"""

from __future__ import annotations

import dataclasses

import numpy as np

MACHINE_PROFILES = ("uniform", "bimodal", "lognormal", "paper")
DELAY_MODELS = ("uniform", "distance", "cluster", "paper", "drift")
CHURN_MODELS = ("markov", "weibull")

_CHURN_COMMON = {
    "start_down_fraction": 0.0,
    "min_up": 1,
    "link_outages": 0,
    "outage_len": 6,
    "outage_factor": 4.0,
    # FLGo-style device-state dimensions on top of up/down availability:
    # responsiveness (a slow-responder round multiplies the machine's
    # busy time by ``slow_factor``) and completeness (a partial-work
    # round completes only a ``[partial_floor, 1)`` fraction of the
    # round's work — busy time shrinks proportionally, and the elastic
    # speed estimator must be told or the shortened round poisons its
    # EMA — ``ElasticScheduler.observe_round(work_fraction=...)``).
    "p_slow": 0.0,
    "slow_factor": 3.0,
    "p_partial": 0.0,
    "partial_floor": 0.5,
}
CHURN_TRACE_PARAMS = {
    "markov": {"p_fail": 0.05, "p_recover": 0.25, **_CHURN_COMMON},
    "weibull": {
        "shape_up": 1.5, "scale_up": 24.0,
        "shape_down": 1.0, "scale_down": 6.0,
        **_CHURN_COMMON,
    },
}


def _take(kind: str, params: dict, defaults: dict) -> dict:
    """Resolve ``params`` against ``defaults``, rejecting unknown keys —
    a misspelled parameter must fail loudly, not silently fall back to the
    default while the sweep record's axes claim it was applied."""
    unknown = set(params) - set(defaults)
    if unknown:
        raise ValueError(
            f"unknown {kind} parameter(s) {sorted(unknown)}; "
            f"accepted: {sorted(defaults)}"
        )
    return {k: params.get(k, v) for k, v in defaults.items()}


def machine_speeds(
    profile: str, rng: np.random.Generator, num_machines: int, **params
) -> np.ndarray:
    """Speed vector ``e`` (num_machines,) for a named heterogeneity profile."""
    if profile == "uniform":
        p = _take(profile, params, {"speed": 1.0})
        return np.full(num_machines, float(p["speed"]))
    if profile == "bimodal":
        p = _take(profile, params,
                  {"fast": 4.0, "slow": 1.0, "fast_fraction": 0.25})
        n_fast = max(1, int(np.ceil(float(p["fast_fraction"]) * num_machines)))
        e = np.full(num_machines, float(p["slow"]))
        e[rng.choice(num_machines, size=n_fast, replace=False)] = float(p["fast"])
        return e
    if profile == "lognormal":
        p = _take(profile, params, {"mu": 0.0, "sigma": 0.75})
        return rng.lognormal(float(p["mu"]), float(p["sigma"]), size=num_machines)
    if profile == "paper":
        p = _take(profile, params, {"e_sigma": np.sqrt(15.0)})
        return np.abs(rng.normal(0.0, float(p["e_sigma"]), size=num_machines)) + 1e-2
    raise ValueError(
        f"unknown machine profile {profile!r}; choose from {MACHINE_PROFILES}"
    )


def delay_matrix(
    model: str, rng: np.random.Generator, num_machines: int, **params
) -> np.ndarray:
    """Delay matrix ``C`` (num_machines, num_machines), zero diagonal."""
    m = num_machines
    if model == "uniform":
        p = _take(model, params, {"c_max": 1.0})
        C = rng.uniform(0.0, float(p["c_max"]), size=(m, m))
    elif model == "distance":
        p = _take(model, params, {"base": 0.05, "scale": 1.0})
        pos = rng.uniform(0.0, 1.0, size=(m, 2))
        dist = np.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)
        C = float(p["base"]) + float(p["scale"]) * dist
    elif model == "cluster":
        p = _take(model, params,
                  {"clusters": 2, "intra": 0.1, "inter": 1.0, "jitter": 0.1})
        jitter = float(p["jitter"])
        label = rng.integers(0, int(p["clusters"]), size=m)
        same = label[:, None] == label[None, :]
        C = np.where(same, float(p["intra"]), float(p["inter"])).astype(np.float64)
        if jitter > 0:
            noise = rng.uniform(-jitter, jitter, size=(m, m))
            noise = 0.5 * (noise + noise.T)          # keep C symmetric
            C = C * (1.0 + noise)
    elif model == "paper":
        p = _take(model, params, {"c_sigma": np.sqrt(10.0)})
        C = np.abs(rng.normal(0.0, float(p["c_sigma"]), size=(m, m)))
    else:
        raise ValueError(
            f"unknown delay model {model!r}; choose from {DELAY_MODELS}"
        )
    np.fill_diagonal(C, 0.0)
    return C


@dataclasses.dataclass(frozen=True)
class DelayDrift:
    """Time-varying delay: sinusoidal per-link modulation of a base matrix.

    ``at(r) = base · (1 + amplitude · sin(2π r / period + phase))`` with an
    i.i.d. per-link phase (symmetrized so symmetric bases stay symmetric),
    clipped at zero, zero diagonal.  ``at(0) != base`` in general — the
    engine schedules against ``at(0)`` so round 0 is consistent.
    """

    base: np.ndarray
    amplitude: float
    period: float
    phase: np.ndarray

    def at(self, round_idx: int) -> np.ndarray:
        mod = 1.0 + self.amplitude * np.sin(
            2.0 * np.pi * round_idx / self.period + self.phase
        )
        C = np.clip(self.base * mod, 0.0, None)
        np.fill_diagonal(C, 0.0)
        return C


def drifting_delays(
    rng: np.random.Generator, num_machines: int, **params
) -> DelayDrift:
    """Build the ``drift`` delay model: base model + per-link modulation."""
    base_model = str(params.get("base", "distance"))
    if base_model == "drift":
        raise ValueError("drift cannot be its own base model")
    base_params = {k: v for k, v in params.items()
                   if k not in ("base", "amplitude", "period")}
    base = delay_matrix(base_model, rng, num_machines, **base_params)
    phase = rng.uniform(0.0, 2.0 * np.pi, size=(num_machines, num_machines))
    phase = 0.5 * (phase + phase.T)
    return DelayDrift(
        base=base,
        amplitude=float(params.get("amplitude", 0.5)),
        period=float(params.get("period", 16.0)),
        phase=phase,
    )


@dataclasses.dataclass(frozen=True)
class ChurnTrace:
    """A round-indexed fleet-dynamics trace.

    Attributes:
      num_rounds / num_machines: trace dimensions (original labels).
      machine_events: tuple of ``(round, kind, machine)`` with kind in
        {``fail``, ``join``, ``recover``} — ``join`` is the FIRST arrival
        of a machine that began the trace down, ``recover`` a return
        after a mid-trace failure (the engine treats them identically;
        the distinction is for trace analytics).  Within a round,
        arrivals precede failures so the ``min_up`` floor composes.
      link_events: tuple of ``(round, kind, machine, peer, factor)`` with
        kind in {``link_down``, ``link_up``} — outage windows whose
        ``link_up`` end falls inside the trace are closed explicitly.
      up_at: (R, K) bool — liveness of each machine during round r,
        AFTER that round's events (what the engine's fleet looks like).
      slow_at: (R, K) float or None — responsiveness state: the
        multiplicative busy-time factor of machine k in round r
        (``slow_factor`` in slow-responder rounds, 1 otherwise).  None
        when the trace was generated without the dimension
        (``p_slow = p_partial = 0``), keeping legacy traces bit-identical.
      work_at: (R, K) float or None — completeness state: the fraction of
        round r's work machine k actually performs (< 1 in partial-work
        rounds).  Busy time scales by the same fraction; feed it to
        ``ElasticScheduler.observe_round(work_fraction=...)`` so the
        shortened round is not mistaken for a speedup.
    """

    num_rounds: int
    num_machines: int
    machine_events: tuple
    link_events: tuple
    up_at: np.ndarray
    slow_at: np.ndarray | None = None
    work_at: np.ndarray | None = None

    def busy_factors(self) -> np.ndarray | None:
        """The (R, K) multiplicative busy-time matrix the event engine
        applies (``simulate(busy_factors=...)``): slow-responder factor ×
        completed-work fraction.  None when neither dimension is active."""
        if self.slow_at is None and self.work_at is None:
            return None
        out = np.ones((self.num_rounds, self.num_machines))
        if self.slow_at is not None:
            out = out * self.slow_at
        if self.work_at is not None:
            out = out * self.work_at
        return out

    @property
    def counts(self) -> dict:
        """Event tallies: fails / joins / recovers / link_downs."""
        c = {"fail": 0, "join": 0, "recover": 0}
        for _, kind, _ in self.machine_events:
            c[kind] += 1
        c["link_down"] = sum(
            1 for _, kind, *_ in self.link_events if kind == "link_down"
        )
        return c

    def control_events(self) -> list:
        """Materialize the trace as ``sim.ControlEvent`` objects, sorted by
        round with arrivals before failures before link transitions."""
        from repro.sim.events import ControlEvent

        order = {"join": 0, "recover": 0, "fail": 1, "link_down": 2, "link_up": 2}
        merged = sorted(
            [(r, kind, m, -1, 1.0) for (r, kind, m) in self.machine_events]
            + list(self.link_events),
            key=lambda ev: (ev[0], order[ev[1]]),
        )
        return [
            ControlEvent(round=r, kind=kind, machine=m, peer=peer, factor=factor)
            for (r, kind, m, peer, factor) in merged
        ]


def _dwell(rng: np.random.Generator, shape: float, scale: float) -> int:
    """One Weibull dwell duration, in whole rounds (>= 1)."""
    return max(1, int(round(rng.weibull(shape) * scale)))


def churn_trace(
    rng: np.random.Generator,
    num_machines: int,
    num_rounds: int,
    model: str = "markov",
    **params,
) -> ChurnTrace:
    """Generate a seeded churn trace (see module docstring for models).

    The trace is a pure function of ``(rng state, arguments)``.  Machines
    that begin the trace down are emitted as ``fail`` events at round 0 —
    the engine starts from the full universe, so round 0 is where the
    initial absence is applied.
    """
    if model not in CHURN_MODELS:
        raise ValueError(
            f"unknown churn model {model!r}; choose from {CHURN_MODELS}"
        )
    p = _take(model, params, CHURN_TRACE_PARAMS[model])
    min_up = int(p["min_up"])
    if not (1 <= min_up <= num_machines):
        raise ValueError(
            f"min_up must be in [1, {num_machines}], got {min_up}"
        )
    n_down0 = min(
        int(np.floor(float(p["start_down_fraction"]) * num_machines)),
        num_machines - min_up,
    )
    start_down = set(
        int(m)
        for m in rng.choice(num_machines, size=n_down0, replace=False)
    ) if n_down0 > 0 else set()

    up = np.array([m not in start_down for m in range(num_machines)])
    ever_up = up.copy()
    events = [(0, "fail", m) for m in sorted(start_down)]
    up_at = np.zeros((num_rounds, num_machines), dtype=bool)

    if model == "weibull":
        # Next transition round per machine: starting-up machines fail
        # after an up-dwell, starting-down machines arrive after a
        # down-dwell.
        next_t = np.array([
            _dwell(rng, float(p["shape_up"]), float(p["scale_up"]))
            if up[m] else
            _dwell(rng, float(p["shape_down"]), float(p["scale_down"]))
            for m in range(num_machines)
        ])

    for r in range(num_rounds):
        if r > 0:
            if model == "markov":
                arrive = [
                    m for m in range(num_machines)
                    if not up[m] and rng.random() < float(p["p_recover"])
                ]
                depart = [
                    m for m in range(num_machines)
                    if up[m] and rng.random() < float(p["p_fail"])
                ]
            else:
                arrive = [
                    m for m in range(num_machines)
                    if not up[m] and next_t[m] <= r
                ]
                depart = [
                    m for m in range(num_machines)
                    if up[m] and next_t[m] <= r
                ]
            for m in arrive:
                up[m] = True
                events.append((r, "join" if not ever_up[m] else "recover", m))
                ever_up[m] = True
                if model == "weibull":
                    next_t[m] = r + _dwell(
                        rng, float(p["shape_up"]), float(p["scale_up"])
                    )
            for m in depart:
                if int(np.sum(up)) <= min_up:
                    # Postpone: under weibull the pending transition fires
                    # at the next round with headroom; under markov the
                    # machine simply re-rolls next round.
                    continue
                up[m] = False
                events.append((r, "fail", m))
                if model == "weibull":
                    next_t[m] = r + _dwell(
                        rng, float(p["shape_down"]), float(p["scale_down"])
                    )
        up_at[r] = up

    link_events = []
    n_outages = int(p["link_outages"])
    if n_outages > 0 and num_machines >= 2 and num_rounds >= 2:
        factor = float(p["outage_factor"])
        if factor <= 1.0:
            raise ValueError("outage_factor must be > 1 (a delay penalty)")
        mean_len = max(1, int(p["outage_len"]))
        occupied: dict[tuple, list] = {}
        for _ in range(n_outages):
            for _try in range(20):
                i, j = rng.choice(num_machines, size=2, replace=False)
                pair = (min(int(i), int(j)), max(int(i), int(j)))
                r0 = int(rng.integers(0, num_rounds - 1))
                length = int(rng.integers(1, 2 * mean_len + 1))
                r1 = min(r0 + length, num_rounds)
                if all(
                    r1 <= a or r0 >= b for (a, b) in occupied.get(pair, [])
                ):
                    occupied.setdefault(pair, []).append((r0, r1))
                    link_events.append(
                        (r0, "link_down", pair[0], pair[1], factor)
                    )
                    if r1 < num_rounds:
                        link_events.append(
                            (r1, "link_up", pair[0], pair[1], 1.0)
                        )
                    break
    link_events.sort(key=lambda ev: ev[0])

    # Responsiveness/completeness states draw LAST, and only when active:
    # traces generated with the legacy parameter set consume exactly the
    # legacy rng stream and stay bit-identical.
    slow_at = work_at = None
    p_slow, p_partial = float(p["p_slow"]), float(p["p_partial"])
    if not (0.0 <= p_slow <= 1.0 and 0.0 <= p_partial <= 1.0):
        raise ValueError(
            f"p_slow/p_partial must be probabilities, got "
            f"{p_slow}/{p_partial}"
        )
    if p_slow > 0.0:
        slow_factor = float(p["slow_factor"])
        if slow_factor <= 1.0:
            raise ValueError(
                f"slow_factor must be > 1 (a busy-time penalty), got "
                f"{slow_factor}"
            )
        mask = rng.random((num_rounds, num_machines)) < p_slow
        slow_at = np.where(mask, slow_factor, 1.0)
    if p_partial > 0.0:
        floor = float(p["partial_floor"])
        if not 0.0 < floor < 1.0:
            raise ValueError(
                f"partial_floor must be in (0, 1), got {floor}"
            )
        mask = rng.random((num_rounds, num_machines)) < p_partial
        frac = rng.uniform(floor, 1.0, size=(num_rounds, num_machines))
        work_at = np.where(mask, frac, 1.0)

    return ChurnTrace(
        num_rounds=num_rounds,
        num_machines=num_machines,
        machine_events=tuple(events),
        link_events=tuple(link_events),
        up_at=up_at,
        slow_at=slow_at,
        work_at=work_at,
    )
