"""Machine-heterogeneity profiles and delay models (scenario axes).

A scenario composes a task-graph family with a *machine profile* (the
speed vector ``e``) and a *delay model* (the pairwise delay matrix ``C``).
Both are pure functions of an ``np.random.Generator`` so scenario records
are reproducible from ``(scenario, seed)`` alone; the time-varying
``drift`` model wraps a static base model in a :class:`DelayDrift` whose
``at(round)`` yields the per-round matrix.

Profiles (``machine_speeds``):
  - ``uniform``   — homogeneous machines (``speed``, default 1).
  - ``bimodal``   — edge/cloud split: ``ceil(fast_fraction · N_K)`` cloud
    machines at ``fast`` speed, the rest edge devices at ``slow``.
  - ``lognormal`` — ``e ~ LogNormal(mu, sigma)``: a long-tailed fleet.
  - ``paper``     — the §4.1.2 setting ``e ~ |N(0, √15)|``.

Delay models (``delay_matrix``):
  - ``uniform``  — ``C ~ Unif(0, c_max)`` i.i.d. (the §4.2 FL setting).
  - ``distance`` — machines at uniform points of the unit square,
    ``C = base + scale · euclidean distance`` (symmetric).
  - ``cluster``  — machines split into ``clusters`` groups; intra-cluster
    links cost ``intra``, inter-cluster links ``inter``, with a symmetric
    multiplicative jitter (datacenter racks / geo regions).
  - ``paper``    — the §4.1.2 setting ``C ~ |N(0, √10)|``.
  - ``drift``    — time-varying: a static ``base`` model modulated per
    round (see :class:`DelayDrift`); the engine re-schedules mid-run via
    ``ElasticScheduler.on_delay_update``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

MACHINE_PROFILES = ("uniform", "bimodal", "lognormal", "paper")
DELAY_MODELS = ("uniform", "distance", "cluster", "paper", "drift")


def _take(kind: str, params: dict, defaults: dict) -> dict:
    """Resolve ``params`` against ``defaults``, rejecting unknown keys —
    a misspelled parameter must fail loudly, not silently fall back to the
    default while the sweep record's axes claim it was applied."""
    unknown = set(params) - set(defaults)
    if unknown:
        raise ValueError(
            f"unknown {kind} parameter(s) {sorted(unknown)}; "
            f"accepted: {sorted(defaults)}"
        )
    return {k: params.get(k, v) for k, v in defaults.items()}


def machine_speeds(
    profile: str, rng: np.random.Generator, num_machines: int, **params
) -> np.ndarray:
    """Speed vector ``e`` (num_machines,) for a named heterogeneity profile."""
    if profile == "uniform":
        p = _take(profile, params, {"speed": 1.0})
        return np.full(num_machines, float(p["speed"]))
    if profile == "bimodal":
        p = _take(profile, params,
                  {"fast": 4.0, "slow": 1.0, "fast_fraction": 0.25})
        n_fast = max(1, int(np.ceil(float(p["fast_fraction"]) * num_machines)))
        e = np.full(num_machines, float(p["slow"]))
        e[rng.choice(num_machines, size=n_fast, replace=False)] = float(p["fast"])
        return e
    if profile == "lognormal":
        p = _take(profile, params, {"mu": 0.0, "sigma": 0.75})
        return rng.lognormal(float(p["mu"]), float(p["sigma"]), size=num_machines)
    if profile == "paper":
        p = _take(profile, params, {"e_sigma": np.sqrt(15.0)})
        return np.abs(rng.normal(0.0, float(p["e_sigma"]), size=num_machines)) + 1e-2
    raise ValueError(
        f"unknown machine profile {profile!r}; choose from {MACHINE_PROFILES}"
    )


def delay_matrix(
    model: str, rng: np.random.Generator, num_machines: int, **params
) -> np.ndarray:
    """Delay matrix ``C`` (num_machines, num_machines), zero diagonal."""
    m = num_machines
    if model == "uniform":
        p = _take(model, params, {"c_max": 1.0})
        C = rng.uniform(0.0, float(p["c_max"]), size=(m, m))
    elif model == "distance":
        p = _take(model, params, {"base": 0.05, "scale": 1.0})
        pos = rng.uniform(0.0, 1.0, size=(m, 2))
        dist = np.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)
        C = float(p["base"]) + float(p["scale"]) * dist
    elif model == "cluster":
        p = _take(model, params,
                  {"clusters": 2, "intra": 0.1, "inter": 1.0, "jitter": 0.1})
        jitter = float(p["jitter"])
        label = rng.integers(0, int(p["clusters"]), size=m)
        same = label[:, None] == label[None, :]
        C = np.where(same, float(p["intra"]), float(p["inter"])).astype(np.float64)
        if jitter > 0:
            noise = rng.uniform(-jitter, jitter, size=(m, m))
            noise = 0.5 * (noise + noise.T)          # keep C symmetric
            C = C * (1.0 + noise)
    elif model == "paper":
        p = _take(model, params, {"c_sigma": np.sqrt(10.0)})
        C = np.abs(rng.normal(0.0, float(p["c_sigma"]), size=(m, m)))
    else:
        raise ValueError(
            f"unknown delay model {model!r}; choose from {DELAY_MODELS}"
        )
    np.fill_diagonal(C, 0.0)
    return C


@dataclasses.dataclass(frozen=True)
class DelayDrift:
    """Time-varying delay: sinusoidal per-link modulation of a base matrix.

    ``at(r) = base · (1 + amplitude · sin(2π r / period + phase))`` with an
    i.i.d. per-link phase (symmetrized so symmetric bases stay symmetric),
    clipped at zero, zero diagonal.  ``at(0) != base`` in general — the
    engine schedules against ``at(0)`` so round 0 is consistent.
    """

    base: np.ndarray
    amplitude: float
    period: float
    phase: np.ndarray

    def at(self, round_idx: int) -> np.ndarray:
        mod = 1.0 + self.amplitude * np.sin(
            2.0 * np.pi * round_idx / self.period + self.phase
        )
        C = np.clip(self.base * mod, 0.0, None)
        np.fill_diagonal(C, 0.0)
        return C


def drifting_delays(
    rng: np.random.Generator, num_machines: int, **params
) -> DelayDrift:
    """Build the ``drift`` delay model: base model + per-link modulation."""
    base_model = str(params.get("base", "distance"))
    if base_model == "drift":
        raise ValueError("drift cannot be its own base model")
    base_params = {k: v for k, v in params.items()
                   if k not in ("base", "amplitude", "period")}
    base = delay_matrix(base_model, rng, num_machines, **base_params)
    phase = rng.uniform(0.0, 2.0 * np.pi, size=(num_machines, num_machines))
    phase = 0.5 * (phase + phase.T)
    return DelayDrift(
        base=base,
        amplitude=float(params.get("amplitude", 0.5)),
        period=float(params.get("period", 16.0)),
        phase=phase,
    )
