"""Barrier-free gossip training on delivered snapshots (DESIGN.md §11).

:class:`AsyncGossipTrainer` couples the stacked gossip engine
(``repro.fl.gossip``) to the discrete-event simulator's barrier-free
timing (``repro.sim``): instead of every user mixing with its neighbors'
CURRENT round-``r`` messages, each edge mixes the *latest delivered*
snapshot — the per-(round, edge) version the engine recorded in
``SimResult.mix_versions`` — weighted by a staleness discount ``s(Δτ)``
(``repro.fl.staleness``).

Mechanics, all inside one jitted round:

  - a ring-buffer **message archive** with a leading ``(N_T, S, …)`` axis
    keeps each user's last ``S`` published (possibly compressed) gossip
    messages; version ``v`` lives in slot ``v mod S`` and a ``(N_T, S)``
    version table detects eviction — an edge whose delivered version was
    evicted (or never delivered, ``v = -1``) contributes nothing and its
    mixing mass returns to the receiver's self-weight;
  - **staleness-weighted aggregation**: edge ``e`` into user ``j`` mixes
    with effective weight ``w_e · s(r - v_e)``, and the discounted mass
    is refunded to ``j``'s self-weight (``deficit_j``), so every mixing
    row still sums to one and a user cut off from fresh snapshots decays
    to plain local SGD instead of shrinking its parameters;
  - **churn freezing**: users on a machine the engine marked down for the
    round skip local training, publishing, and mixing entirely — their
    replica, optimizer moments, data cursor, and compression
    error-feedback residual are frozen bit-for-bit until recovery (the
    engine's anti-entropy then re-delivers their archived snapshot to
    neighbors and refreshes their mailbox).

Degenerate anchor (pinned in ``tests/test_async_fl.py`` and the
``async_fl_smoke`` CI target): all users active, every edge fresh
(``v_e = r``), ``s ≡ 1`` makes the archive gather return exactly this
round's messages with exactly the stacked mixing weights — the update is
the stacked engine's, so per-round losses reproduce to fp32 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import TaskGraph
from repro.fl.gossip import GossipConfig, GossipTrainer
from repro.fl.staleness import StalenessWeights


class AsyncGossipTrainer(GossipTrainer):
    """Stacked gossip trainer whose exchange runs on delivered versions.

    Public API on top of :class:`GossipTrainer`:

    ``step_round(active=None, edge_versions=None)``
        One barrier-free round.  ``active`` is an ``(N_T,)`` bool mask of
        users whose machine is up this round (default all); ``edge_versions``
        an ``(|E|,)`` int array of the snapshot version delivered on each
        task-graph edge, in ``task_graph.edges`` order — exactly one row
        of ``SimResult.mix_versions`` (default: this round's own version,
        the degenerate fresh case).  Returns the usual round record plus
        ``stale_mixes`` (edges mixed with Δτ > 0), ``invalid_edges``
        (versions never delivered or evicted from the archive), and
        ``mix_lag_hist`` — the round's per-edge staleness histogram
        (index Δτ = rounds behind, never-delivered edges excluded); a
        cumulative copy accrues in ``self.lag_hist``, the measurement a
        staleness-ADAPTIVE mixing policy would adapt on.

    ``archive_depth``
        Ring-buffer depth ``S``: snapshots older than ``S`` rounds are
        evicted, so it bounds both the archive memory (``S`` extra model
        copies per user) and the maximum usable staleness.
    """

    def __init__(
        self,
        task_graph: TaskGraph,
        init_params,
        loss_fn,
        shards,
        cfg: GossipConfig | None = None,
        seed: int = 0,
        staleness: StalenessWeights | None = None,
        archive_depth: int = 8,
    ):
        if archive_depth < 1:
            raise ValueError(f"archive_depth must be >= 1 (got {archive_depth})")
        self.staleness = staleness if staleness is not None else StalenessWeights()
        self.archive_depth = int(archive_depth)
        self.total_stale_mixes = 0
        # Cumulative per-edge lag histogram: lag_hist[d] = mixes observed
        # at staleness Δτ = d across all rounds so far.
        self.lag_hist = np.zeros(1, dtype=np.int64)
        super().__init__(
            task_graph, init_params, loss_fn, shards, cfg, seed,
            backend="stacked",
        )

    def _build_stacked_round(self):
        # Called by GossipTrainer.__init__; builds the async round instead.
        cfg = self.cfg
        n, n_e = self.n, len(self._src)
        S = self.archive_depth
        comp = cfg.compressor
        self._data = (jnp.asarray(self._xs), jnp.asarray(self._ys))
        user_keys = self._user_keys
        self_w = jnp.asarray(self._self_w)
        src = jnp.asarray(self._src)
        dst = jnp.asarray(self._dst)
        w_edge = jnp.asarray(self._w_edge)
        local_scan = self._make_local_scan()
        compress_stage = None if comp is None else self._make_compress_stage()
        s_of = self.staleness.jax_weights

        def sel(mask, new, old):
            """Per-user select across a pytree (mask is (N_T,) bool)."""
            return jax.tree.map(
                lambda a, b: jnp.where(
                    mask.reshape((n,) + (1,) * (a.ndim - 1)), a, b
                ),
                new, old,
            )

        def round_fn(state, xs, ys, active, edge_ver, r):
            (params, opt_state, cursor, epoch, perm, residual,
             archive, arch_ver) = state
            frozen = (params, opt_state, cursor, epoch, perm, residual)
            # Local training runs for every user (vmap computes all lanes
            # anyway); down users' state is then frozen by selection.
            (params, opt_state, cursor, epoch, perm), losses = local_scan(
                params, opt_state, cursor, epoch, perm, xs, ys, user_keys
            )
            if comp is None:
                msgs = params
            else:
                msgs, residual = compress_stage(params, residual)
                residual = sel(active, residual, frozen[5])
            params = sel(active, params, frozen[0])
            opt_state = sel(active, opt_state, frozen[1])
            cursor = jnp.where(active, cursor, frozen[2])
            epoch = jnp.where(active, epoch, frozen[3])
            perm = sel(active, perm, frozen[4])

            # Publish version r into ring slot r mod S (active users only).
            slot = r % S
            archive = jax.tree.map(
                lambda arch, m: arch.at[:, slot].set(
                    jnp.where(
                        active.reshape((n,) + (1,) * (m.ndim - 1)), m,
                        arch[:, slot],
                    )
                ),
                archive, msgs,
            )
            arch_ver = arch_ver.at[:, slot].set(
                jnp.where(active, r, arch_ver[:, slot])
            )

            if n_e:
                # Per-edge gather of the delivered version from the ring.
                v = edge_ver
                e_slot = jnp.maximum(v, 0) % S
                stored = arch_ver[src, e_slot]
                valid = (v >= 0) & (stored == v)
                lag = r - v
                s_w = s_of(lag)
                w_eff = jnp.where(
                    valid & active[dst], w_edge * s_w, 0.0
                ).astype(jnp.float32)

                def mix_leaf(p, arch):
                    flat = arch.reshape(n, S, -1)
                    contrib = (
                        flat[src, e_slot].astype(jnp.float32)
                        * w_eff[:, None]
                    )
                    inc = jax.ops.segment_sum(contrib, dst, num_segments=n)
                    return inc.reshape(p.shape).astype(p.dtype)

                incoming = jax.tree.map(mix_leaf, params, archive)
                # Refund discounted/invalid mass to the self-weight so the
                # mixing row still sums to one; inactive receivers keep
                # their frozen params untouched.
                deficit = jax.ops.segment_sum(
                    jnp.where(active[dst], w_edge - w_eff, 0.0),
                    dst, num_segments=n,
                ).astype(jnp.float32)
                row_self = self_w + deficit
                mixed = jax.tree.map(
                    lambda p, m: (
                        row_self.reshape((n,) + (1,) * (p.ndim - 1)) * p + m
                    ),
                    params, incoming,
                )
                params = sel(active, mixed, params)
                stale = jnp.sum(valid & (lag > 0) & active[dst])
                invalid = jnp.sum(~valid & active[dst])
            else:
                stale = jnp.zeros((), jnp.int32)
                invalid = jnp.zeros((), jnp.int32)

            act_steps = jnp.maximum(jnp.sum(active), 1) * cfg.local_steps
            mean_loss = jnp.sum(losses * active[None, :]) / act_steps
            state = (params, opt_state, cursor, epoch, perm, residual,
                     archive, arch_ver)
            return state, (mean_loss, stale, invalid)

        # Extend the inherited state tuple with the archive + versions.
        params0 = self._state[0]
        msg_like = params0  # messages share the params pytree structure
        archive0 = jax.tree.map(
            lambda l: jnp.zeros((n, S) + l.shape[1:], l.dtype), msg_like
        )
        arch_ver0 = jnp.full((n, S), -1, jnp.int32)
        self._state = self._state + (archive0, arch_ver0)

        donate = () if jax.default_backend() == "cpu" else (0,)
        return jax.jit(round_fn, donate_argnums=donate)

    def step_round(self, active=None, edge_versions=None) -> dict:
        """One barrier-free gossip round on delivered snapshot versions."""
        n_e = len(self._src)
        if active is None:
            active = np.ones(self.n, dtype=bool)
        else:
            active = np.asarray(active, dtype=bool)
            if active.shape != (self.n,):
                raise ValueError(
                    f"active mask shape {active.shape} != ({self.n},)"
                )
        if edge_versions is None:
            edge_versions = np.full(n_e, self.round, dtype=np.int64)
        else:
            edge_versions = np.asarray(edge_versions, dtype=np.int64)
            if edge_versions.shape != (n_e,):
                raise ValueError(
                    f"edge_versions shape {edge_versions.shape} != ({n_e},) "
                    f"— one delivered version per task-graph edge"
                )
            if np.any(edge_versions > self.round):
                raise ValueError(
                    f"edge_versions reference round "
                    f"{int(edge_versions.max())} > current round "
                    f"{self.round} — a snapshot cannot be delivered before "
                    f"it is published"
                )
        # Per-edge lag histogram (host-side: n_e ints/round, negligible
        # next to the jitted round).  Never-delivered edges (v = -1) are
        # invalid_edges, not lags.
        delivered = edge_versions[edge_versions >= 0]
        lag_hist = np.bincount(
            (self.round - delivered).astype(np.int64), minlength=1
        )
        if len(lag_hist) > len(self.lag_hist):
            self.lag_hist = np.pad(
                self.lag_hist, (0, len(lag_hist) - len(self.lag_hist))
            )
        self.lag_hist[: len(lag_hist)] += lag_hist

        calls_before = self._jit_calls
        self._state, (mean_loss, stale, invalid) = self._dispatch(
            self._round_jit,
            self._state,
            *self._data,
            jnp.asarray(active),
            jnp.asarray(edge_versions, dtype=jnp.int32),
            jnp.int32(self.round),
        )
        self.last_round_dispatches = self._jit_calls - calls_before
        self.round += 1
        stale = int(stale)
        self.total_stale_mixes += stale
        return {
            "round": self.round,
            "mean_loss": float(mean_loss),
            "stale_mixes": stale,
            "invalid_edges": int(invalid),
            "mix_lag_hist": lag_hist.tolist(),
            "dropped_samples": self.dropped_samples,
        }
