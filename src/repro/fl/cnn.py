"""The paper's CNN (§4.2): two conv layers + three fully-connected layers.

Pure-JAX functional model for the gossip-FL MNIST / CIFAR-10 experiments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_cnn_params(rng, input_shape=(28, 28, 1), num_classes: int = 10) -> dict:
    h, w, c = input_shape
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)

    def conv_init(key, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(key, shape) * np.sqrt(2.0 / fan_in)

    def fc_init(key, shape):
        return jax.random.normal(key, shape) * np.sqrt(2.0 / shape[0])

    h2, w2 = h // 2, w // 2
    h4, w4 = h2 // 2, w2 // 2
    flat = h4 * w4 * 64
    return {
        "conv1": {"w": conv_init(k1, (3, 3, c, 32)), "b": jnp.zeros(32)},
        "conv2": {"w": conv_init(k2, (3, 3, 32, 64)), "b": jnp.zeros(64)},
        "fc1": {"w": fc_init(k3, (flat, 128)), "b": jnp.zeros(128)},
        "fc2": {"w": fc_init(k4, (128, 64)), "b": jnp.zeros(64)},
        "fc3": {"w": fc_init(k5, (64, num_classes)), "b": jnp.zeros(num_classes)},
    }


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jax.nn.relu(y + p["b"])


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, H, W, C) -> (B, num_classes) logits."""
    x = x - 0.5                     # center [0, 1] inputs
    x = _pool(_conv(x, params["conv1"]))
    x = _pool(_conv(x, params["conv2"]))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


def cnn_loss(params: dict, batch: dict) -> jnp.ndarray:
    logits = cnn_forward(params, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# Module-level jitted forward: ``jax.jit(cnn_forward)`` inside the function
# would build a fresh jit wrapper — and retrace — on every accuracy call.
_cnn_forward_jit = jax.jit(cnn_forward)


def cnn_accuracy(params: dict, x: np.ndarray, y: np.ndarray, batch: int = 512) -> float:
    correct = 0
    for i in range(0, len(y), batch):
        logits = _cnn_forward_jit(params, jnp.asarray(x[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y[i : i + batch])))
    return correct / len(y)
