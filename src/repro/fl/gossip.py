"""Gossip-based federated learning (paper §2.1 / §4.2).

Users = vertices of the task graph.  Each round every user trains on its
next data chunk, ships its parameters to its out-neighbors, and aggregates
the models it received (weighted average including its own).  Optional
delta-compression (top-k / int8) with error feedback shrinks the gossip
message — and therefore the scheduler's C matrix.

Three interchangeable engines run the learning (DESIGN.md §8, §13):

  - ``backend="reference"`` — the per-user Python loop: one jitted grad
    call per user per local step, edge-by-edge aggregation with
    ``jax.tree.map``.  Clear, slow, and the equivalence oracle.
  - ``backend="stacked"`` (the ``"auto"`` default) — all user replicas
    live in ONE pytree with a leading ``(N_T, …)`` axis; a whole gossip
    round (``local_steps`` of SGDM via ``lax.scan`` + ``vmap`` across
    users, delta compression with error feedback, and the gossip exchange
    as a multiplication by the row-normalized sparse mixing matrix W) is a
    single jitted call — no per-user or per-edge Python dispatch, no
    host↔device round-trips inside a round.
  - ``backend="sharded"`` — the population-scale engine: the same round
    body built PER SHARD under ``shard_map`` over a 1-D ``"users"``
    device mesh (``launch/sharding.py::UserMesh``/``FLSharding``).  The
    ``(N_T, …)`` replica pytree splits into contiguous user blocks
    (padded with inert users when ``N_T % shards != 0``); local SGD and
    compression are embarrassingly parallel, and the mixing matrix is
    partitioned into intra-shard blocks (local ``segment_sum`` or the
    block-local Pallas kernel) plus a sparse cross-shard halo: only the
    BOUNDARY rows — senders with an out-edge into another shard — are
    ``all_gather``-ed, so the exchange ships ``S·B`` rows per round
    instead of the full ``N_T`` of a dense all-pairs collective.  Still
    one jitted dispatch per round; per-round losses match the stacked
    backend to fp32 at any mesh size (pinned in tests/test_shard_fl.py).

Both engines draw identical data: shards are stacked to ``(N_T, chunk, …)``
at construction and batches are index-gathers through a per-user epoch
permutation derived from the jax PRNG (``fold_in(data_key, user, epoch)``),
so the engines consume the same samples in the same order and caller-owned
shard buffers are never mutated.

The *execution timing* of a round on networked machines is what the
scheduler optimizes; ``repro.fl.simulator`` turns an assignment into
bottleneck time while this module performs the actual learning.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import TaskGraph
from repro.data.synthetic import ImageDataset, stack_shards
from repro.kernels.gossip_mix import gossip_mix_all_fwd, gossip_mix_block_fwd
from repro.kernels.ref import gossip_mix_segment_ref
from repro.train.optim import SGDM

BACKENDS = ("auto", "reference", "stacked", "sharded")
MIX_BACKENDS = ("auto", "segment_sum", "pallas")
COMPRESS_BACKENDS = ("auto", "jnp", "pallas")


@dataclasses.dataclass
class GossipConfig:
    local_steps: int = 4          # minibatch steps per round (one chunk)
    batch_size: int = 64
    lr: float = 0.05
    momentum: float = 0.9
    aggregate_self_weight: float = 0.5   # weight of own model in the average
    compressor: Any = None        # repro.train.compression.TopK / Int8 / None
    backend: str = "auto"         # "reference"|"stacked"|"sharded"|"auto"(=stacked)
    # Sharded engine only: user-mesh shard count (None = every visible
    # device).  On a host-only platform force the device count with
    # XLA_FLAGS=--xla_force_host_platform_device_count=N before jax loads.
    num_shards: int | None = None
    mix_backend: str = "auto"     # stacked exchange: "segment_sum" | "pallas"
    mix_block_len: int = 65536    # L-block of the all-receivers Pallas kernel
    # Stacked delta-compression stage: "pallas" fuses the top-k/int8
    # quantization with the error-feedback residual into one stream of the
    # stacked delta (kernels/compress.py, DESIGN.md §12); "jnp" keeps the
    # vmapped roundtrip + subtract.  "auto" = jnp on CPU, pallas on
    # accelerators (mirrors mix_backend).
    compress_backend: str = "auto"


def mixing_arrays(
    task_graph: TaskGraph, self_weight: float, *, dense_w: bool = True
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Row-normalized gossip mixing built from ``TaskGraph.edges``.

    Edge (i, j) means user i sends to user j.  Receiver j averages its own
    model with weight ``self_weight`` and its indeg(j) incoming messages
    with weight ``(1 - self_weight) / indeg(j)``; a user with no incoming
    edges keeps its model (self weight 1, empty row in W).

    Returns ``(self_w (N,), src (|E|,), dst (|E|,), w_edge (|E|,), W (N, N))``
    where ``W[j, i] = w_edge`` for each edge — the incoming-message part
    only, so the same arrays serve compressed gossip (messages ≠ params):
    ``new_params = diag(self_w) · params + W · messages``.

    ``dense_w=False`` skips materializing W (returned as ``None``): only
    the stacked engine's all-receivers Pallas mix consumes it, and at
    population scale (N_T = 10k) the (N, N) float32 is 400 MB of dead
    weight for the edge-list paths.
    """
    n = task_graph.num_tasks
    indeg = np.zeros(n, dtype=np.int64)
    for (_, j) in task_graph.edges:
        indeg[j] += 1
    self_w = np.where(indeg > 0, self_weight, 1.0).astype(np.float32)
    src = np.asarray([i for (i, _) in task_graph.edges], dtype=np.int32)
    dst = np.asarray([j for (_, j) in task_graph.edges], dtype=np.int32)
    w_edge = (
        (1.0 - self_weight) / np.maximum(indeg[dst], 1)
    ).astype(np.float32) if len(task_graph.edges) else np.zeros(0, np.float32)
    W = None
    if dense_w:
        W = np.zeros((n, n), dtype=np.float32)
        if len(task_graph.edges):
            # accumulate, not assign: TaskGraph does not dedupe edges, and
            # the per-edge paths (segment_sum, reference loop) count
            # multiplicity
            np.add.at(W, (dst, src), w_edge)
    return self_w, src, dst, w_edge, W


class GossipTrainer:
    """Holds per-user replicas and runs gossip rounds.

    Public API: ``step_round() -> {"round", "mean_loss"}``, ``params`` /
    ``user_params(i)`` for reading replicas, ``backend`` for the resolved
    engine, and ``last_round_dispatches`` (jitted calls issued by the last
    round — exactly 1 on the stacked path).

    Backend switch: the ``backend`` constructor argument overrides
    ``cfg.backend``; either may be "reference", "stacked", "sharded", or
    "auto" (= stacked).  All engines produce fp32-equivalent per-round
    losses and parameters (pinned in ``tests/test_fl.py`` and
    ``tests/test_shard_fl.py``), so the choice is purely a dispatch- and
    memory-cost trade-off — see DESIGN.md §8/§13.  The exchange
    additionally picks ``cfg.mix_backend`` ("auto" = segment_sum on CPU,
    the all-receivers / block-local Pallas kernel on accelerators).

    The sharded engine partitions users over a 1-D ``"users"`` device mesh
    (pass ``user_mesh=`` or set ``cfg.num_shards``); ``halo_stats`` then
    reports the cross-shard exchange volume (boundary rows gathered per
    round vs. the dense all-pairs alternative).

    ``dropped_samples`` counts samples truncated away by the even-chunk
    stacking of uneven shards (0 when all shards have equal length).
    """

    def __init__(
        self,
        task_graph: TaskGraph,
        init_params: Callable[[jax.Array], Any],
        loss_fn: Callable[[Any, dict], jnp.ndarray],
        shards: list[ImageDataset],
        cfg: GossipConfig | None = None,
        seed: int = 0,
        backend: str | None = None,
        user_mesh: Any = None,   # launch.sharding.UserMesh ("sharded" only)
    ):
        self.g = task_graph
        self.cfg = cfg or GossipConfig()
        self.n = task_graph.num_tasks
        assert len(shards) == self.n
        self.shards = shards
        self.backend = self._resolve_backend(backend or self.cfg.backend)
        self.mix_backend = self._resolve_mix_backend(self.cfg.mix_backend)
        self.compress_backend = self._resolve_compress_backend(
            self.cfg.compress_backend
        )

        # Stacked data: (N_T, chunk, …) copies; batches are index-gathers so
        # the caller's shard buffers are never reordered in place.  BOTH
        # engines consume this layout (that is what makes them sample-for-
        # sample equivalent), so shards are truncated to the common minimum
        # length — loud when that drops more than the ±1 of an even split.
        self._xs, self._ys = stack_shards(shards)
        self._chunk = int(self._ys.shape[1])
        # Satellite bookkeeping: how many samples the even-chunk truncation
        # dropped (surfaces in every step_round info dict).
        self.dropped_samples = int(
            sum(len(s.y) - self._chunk for s in shards)
        )
        longest = max(len(s.y) for s in shards)
        if longest - self._chunk > 1:
            warnings.warn(
                f"uneven shards truncated to the minimum length {self._chunk} "
                f"(longest holds {longest}); pass equal-size shards to train "
                "on all samples",
                stacklevel=2,
            )
        if self._chunk < self.cfg.batch_size:
            raise ValueError(
                f"shard chunk {self._chunk} < batch_size {self.cfg.batch_size}"
            )

        # All users start from a COMMON initialization (standard FL — early
        # averaging of independently-initialized models is destructive).
        key0 = jax.random.PRNGKey(seed)
        common = init_params(key0)
        # Epoch-reshuffle PRNG, shared by both engines: the permutation of
        # user u's shard in epoch e is permutation(fold_in(key_u, e)).
        data_key = jax.random.fold_in(key0, 0x0DA7A)
        self._data_key = data_key
        # vmapped fold_in is bit-identical to the per-user loop and O(1)
        # dispatches at population scale
        self._user_keys = jax.vmap(
            lambda u: jax.random.fold_in(data_key, u)
        )(jnp.arange(self.n, dtype=jnp.uint32))

        self.opt = SGDM(learning_rate=self.cfg.lr, momentum=self.cfg.momentum)
        self._loss_fn = loss_fn
        (
            self._self_w, self._src, self._dst, self._w_edge, self._W
        ) = mixing_arrays(
            task_graph, self.cfg.aggregate_self_weight,
            # Only the stacked pallas mix multiplies by the dense (N, N) W;
            # every other path works off the edge lists.
            dense_w=(
                self.backend == "stacked" and self.mix_backend == "pallas"
            ),
        )
        self.round = 0
        # Measured per-round count of trainer-issued jitted calls (every
        # call site routes through ``_dispatch``): 1 on the stacked path,
        # N_T·local_steps on the reference path.
        self.last_round_dispatches = 0
        self._jit_calls = 0

        if self.backend == "stacked":
            stacked = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (self.n,) + l.shape), common
            )
            residual = (
                None if self.cfg.compressor is None
                else jax.tree.map(jnp.zeros_like, stacked)
            )
            self._state = (
                stacked,
                self.opt.init(stacked),
                jnp.zeros(self.n, jnp.int32),                        # cursor
                jnp.zeros(self.n, jnp.int32),                        # epoch
                jnp.tile(jnp.arange(self._chunk, dtype=jnp.int32), (self.n, 1)),
                residual,
            )
            self._round_jit = self._build_stacked_round()
        elif self.backend == "sharded":
            self._init_sharded(common, user_mesh)
            self._round_jit = self._build_sharded_round()
        else:
            self._params = [jax.tree.map(jnp.copy, common) for _ in range(self.n)]
            self.opt_state = [self.opt.init(p) for p in self._params]
            self.residual = [None] * self.n
            self._cursor = [0] * self.n
            self._epoch = [0] * self.n
            self._perm = [np.arange(self._chunk) for _ in range(self.n)]
            self._grad = jax.jit(jax.value_and_grad(loss_fn))

    def _dispatch(self, fn, *args):
        """Issue a jitted call, counting it toward ``last_round_dispatches``."""
        self._jit_calls += 1
        return fn(*args)

    # -- backend resolution -------------------------------------------------
    @staticmethod
    def _resolve_backend(backend: str) -> str:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
        return "stacked" if backend == "auto" else backend

    @staticmethod
    def _resolve_mix_backend(mix_backend: str) -> str:
        if mix_backend not in MIX_BACKENDS:
            raise ValueError(
                f"unknown mix backend {mix_backend!r}; choose from {MIX_BACKENDS}"
            )
        if mix_backend == "auto":
            # The Pallas kernel wins on accelerators; on CPU it would run in
            # interpret mode, so the segment_sum path is the fast default.
            return "segment_sum" if jax.default_backend() == "cpu" else "pallas"
        return mix_backend

    @staticmethod
    def _resolve_compress_backend(compress_backend: str) -> str:
        if compress_backend not in COMPRESS_BACKENDS:
            raise ValueError(
                f"unknown compress backend {compress_backend!r}; "
                f"choose from {COMPRESS_BACKENDS}"
            )
        if compress_backend == "auto":
            # Same trade-off as the mix: interpret mode on CPU is exact but
            # slow, so the fused kernel is opt-in off-accelerator.
            return "jnp" if jax.default_backend() == "cpu" else "pallas"
        return compress_backend

    def _make_compress_stage(self):
        """The delta-compression stage of one stacked round (both engines).

        Returns ``compress(params, residual) -> (msgs, residual)`` with
        error feedback: ``delta = params + residual``, ``msgs`` is what the
        wire carries, and the new residual is ``delta - msgs``.  On the
        pallas lane the sparsify/quantize decision and the residual come
        out of ONE stream of the stacked delta per leaf
        (``kernels/compress.py``); the per-row statistics (top-k threshold,
        int8 scale) are tiny jnp reductions.  Compressors without a fused
        kernel fall back to the jnp path.
        """
        from repro.train.compression import Int8, TopK

        comp = self.cfg.compressor
        use_kernel = self.compress_backend == "pallas" and isinstance(
            comp, (TopK, Int8)
        )

        if not use_kernel:
            def compress(params, residual):
                delta = jax.tree.map(jnp.add, params, residual)
                msgs = jax.vmap(comp.roundtrip)(delta)
                return msgs, jax.tree.map(jnp.subtract, delta, msgs)

            return compress

        from repro.kernels.compress import int8_roundtrip_fwd, topk_mask_fwd

        interpret = jax.default_backend() == "cpu"
        is_topk = isinstance(comp, TopK)

        def one_leaf(x):
            # Leading axis is whatever population this stage sees: all N_T
            # users (stacked) or one shard's block (sharded).
            rows = x.shape[0]
            flat = x.reshape(rows, -1)
            L = flat.shape[1]
            # Same on-chip budget as the mix kernel: (rows, bl) in + two
            # (rows, bl) out blocks stay a few MB regardless of user count.
            bl = min(65536, max(1024, (1 << 20) // rows), L)
            if is_topk:
                kk = max(1, int(comp.fraction * L))
                vals, _ = jax.lax.top_k(jnp.abs(flat), kk)
                msg, resid = topk_mask_fwd(
                    flat, vals[:, -1], block_len=bl, interpret=interpret
                )
            else:
                scale = jnp.maximum(
                    jnp.max(jnp.abs(flat), axis=1), 1e-12
                ) / 127.0
                msg, resid = int8_roundtrip_fwd(
                    flat, scale, block_len=bl, interpret=interpret
                )
            return msg.reshape(x.shape), resid.reshape(x.shape)

        def compress(params, residual):
            delta = jax.tree.map(jnp.add, params, residual)
            leaves, treedef = jax.tree.flatten(delta)
            outs = [one_leaf(l) for l in leaves]
            msgs = treedef.unflatten([o[0] for o in outs])
            resid = treedef.unflatten([o[1] for o in outs])
            return msgs, resid

        return compress

    # -- replica access (both backends) ------------------------------------
    def user_params(self, i: int) -> Any:
        if self.backend == "reference":
            return self._params[i]
        return jax.tree.map(lambda l: l[i], self._state[0])

    @property
    def params(self) -> list:
        """Per-user parameter pytrees (materialized per user when stacked)."""
        if self.backend == "reference":
            return self._params
        return [self.user_params(i) for i in range(self.n)]

    # -- shared data pipeline ----------------------------------------------
    def _host_epoch_perm(self, i: int, epoch: int) -> np.ndarray:
        """Host-side twin of the in-jit reshuffle (identical permutation)."""
        return np.asarray(
            jax.random.permutation(
                jax.random.fold_in(self._user_keys[i], epoch), self._chunk
            )
        )

    # ======================================================================
    # Reference engine: per-user Python loop (the equivalence oracle)
    # ======================================================================

    def _local_round(self, i: int) -> float:
        cfg = self.cfg
        losses = []
        for _ in range(cfg.local_steps):
            lo = self._cursor[i]
            if lo + cfg.batch_size > self._chunk:     # new epoch, reshuffle
                self._epoch[i] += 1
                self._perm[i] = self._host_epoch_perm(i, self._epoch[i])
                lo = 0
            idx = self._perm[i][lo : lo + cfg.batch_size]
            batch = {
                "x": jnp.asarray(self._xs[i][idx]),
                "y": jnp.asarray(self._ys[i][idx]),
            }
            self._cursor[i] = lo + cfg.batch_size
            loss, grads = self._dispatch(self._grad, self._params[i], batch)
            self._params[i], self.opt_state[i], _ = self.opt.update(
                grads, self.opt_state[i], self._params[i]
            )
            losses.append(float(loss))
        return float(np.mean(losses))

    def _messages(self) -> list[Any]:
        """What each user broadcasts this round (possibly compressed delta)."""
        comp = self.cfg.compressor
        if comp is None:
            return self._params
        out = []
        for i in range(self.n):
            delta = self._params[i] if self.residual[i] is None else jax.tree.map(
                lambda p, r: p + r, self._params[i], self.residual[i]
            )
            compressed, resid = comp.compress(delta)
            self.residual[i] = resid
            out.append(comp.decompress(compressed))   # receiver view
        return out

    def _step_round_reference(self) -> float:
        losses = [self._local_round(i) for i in range(self.n)]
        msgs = self._messages()
        incoming: list[list[Any]] = [[] for _ in range(self.n)]
        for (i, j) in self.g.edges:
            incoming[j].append(msgs[i])

        new_params = []
        w_self = self.cfg.aggregate_self_weight
        for i in range(self.n):
            if not incoming[i]:
                new_params.append(self._params[i])
                continue
            w_nb = (1.0 - w_self) / len(incoming[i])
            agg = jax.tree.map(lambda p: w_self * p, self._params[i])
            for m in incoming[i]:
                agg = jax.tree.map(lambda a, q: a + w_nb * q, agg, m)
            new_params.append(agg)
        self._params = new_params
        return float(np.mean(losses))

    # ======================================================================
    # Stacked engine: one jitted call per round
    # ======================================================================

    def _make_local_scan(self):
        """The shared local-training stage of one stacked round.

        Returns ``local_scan(params, opt_state, cursor, epoch, perm, xs,
        ys, keys) -> ((params, opt_state, cursor, epoch, perm), losses)``
        — ``cfg.local_steps`` of vmapped SGDM with the in-jit epoch
        reshuffle, fully unrolled.  The per-user reshuffle keys ride in as
        an ARGUMENT (not a closure) so the sharded engine can feed each
        shard its own key block under ``shard_map``.  Extracted so the
        barrier-free trainer (``repro.fl.async_gossip``) traces the
        IDENTICAL math: that is what makes its degenerate case reproduce
        this engine's losses.
        """
        cfg = self.cfg
        chunk, batch = self._chunk, cfg.batch_size
        opt = self.opt
        grad_fn = jax.value_and_grad(self._loss_fn)

        def one_user(p, o, cur, ep, pm, x_u, y_u, key_u):
            wrap = cur + batch > chunk
            ep = ep + wrap.astype(ep.dtype)
            # The refresh runs every step (a vmapped branch would execute
            # both sides anyway): O(N_T·chunk·log chunk) of PRNG+sort per
            # step, negligible next to the gradient compute, and it keeps
            # the wrap schedule out of the trace — no per-round retracing.
            pm_new = jax.random.permutation(
                jax.random.fold_in(key_u, ep), chunk
            ).astype(pm.dtype)
            pm = jnp.where(wrap, pm_new, pm)
            cur = jnp.where(wrap, 0, cur)
            idx = jax.lax.dynamic_slice(pm, (cur,), (batch,))
            loss, g = grad_fn(
                p, {"x": jnp.take(x_u, idx, axis=0), "y": jnp.take(y_u, idx, axis=0)}
            )
            p, o, _ = opt.update(g, o, p)
            return p, o, cur + batch, ep, pm, loss

        def local_step(xs, ys, keys, carry):
            params, opt_state, cursor, epoch, perm = carry
            params, opt_state, cursor, epoch, perm, losses = jax.vmap(one_user)(
                params, opt_state, cursor, epoch, perm, xs, ys, keys
            )
            return (params, opt_state, cursor, epoch, perm), losses

        def local_scan(params, opt_state, cursor, epoch, perm, xs, ys, keys):
            # Full unroll: XLA CPU optimizes loop bodies poorly (a rolled
            # scan body runs ~5x slower here); local_steps is single-digit,
            # so straight-line code costs little compile time and lets XLA
            # fuse across steps.
            return jax.lax.scan(
                lambda carry, _: local_step(xs, ys, keys, carry),
                (params, opt_state, cursor, epoch, perm),
                None,
                length=cfg.local_steps,
                unroll=cfg.local_steps,
            )

        return local_scan

    def _build_stacked_round(self):
        cfg = self.cfg
        n = self.n
        comp = cfg.compressor
        # The dataset is a jit ARGUMENT, not a closure constant: closed-over
        # arrays get inlined into the compiled executable (a second copy of
        # the full training set, again on every retrace).
        self._data = (jnp.asarray(self._xs), jnp.asarray(self._ys))
        user_keys = self._user_keys
        self_w = jnp.asarray(self._self_w)
        src = jnp.asarray(self._src)
        dst = jnp.asarray(self._dst)
        w_edge = jnp.asarray(self._w_edge)
        W = None if self._W is None else jnp.asarray(self._W)
        mix_backend = self.mix_backend
        interpret = jax.default_backend() == "cpu"
        local_scan = self._make_local_scan()
        compress_stage = None if comp is None else self._make_compress_stage()

        def mix_segment(msgs):
            def seg(m):
                out = gossip_mix_segment_ref(
                    m.reshape(n, -1), src, dst, w_edge, n
                )
                return out.reshape(m.shape)

            return jax.tree.map(seg, msgs)

        def mix_pallas(msgs):
            leaves, treedef = jax.tree.flatten(msgs)
            flats = [l.reshape(n, -1) for l in leaves]
            sizes = [f.shape[1] for f in flats]
            X = jnp.concatenate(flats, axis=1)
            L = X.shape[1]
            # Budget the (n, bl) input + (n, bl) output blocks to ~8 MB of
            # on-chip memory regardless of user count; a fixed 64k block at
            # N_T=128 would want 64 MB of VMEM/shared memory.
            bl_cap = max(1024, (1 << 20) // n)
            bl = min(cfg.mix_block_len, bl_cap, L)
            pad = (-L) % bl
            if pad:
                X = jnp.pad(X, ((0, 0), (0, pad)))
            out = gossip_mix_all_fwd(X, W, block_len=bl, interpret=interpret)[:, :L]
            offs = np.cumsum([0] + sizes)
            parts = [
                out[:, offs[k] : offs[k + 1]].reshape(leaves[k].shape).astype(
                    leaves[k].dtype
                )
                for k in range(len(leaves))
            ]
            return treedef.unflatten(parts)

        mix = mix_segment if mix_backend == "segment_sum" else mix_pallas

        def round_fn(state, xs, ys):
            params, opt_state, cursor, epoch, perm, residual = state
            (params, opt_state, cursor, epoch, perm), losses = local_scan(
                params, opt_state, cursor, epoch, perm, xs, ys, user_keys
            )
            if comp is None:
                msgs = params
            else:
                msgs, residual = compress_stage(params, residual)
            incoming = mix(msgs)
            params = jax.tree.map(
                lambda p, m: self_w.reshape((n,) + (1,) * (p.ndim - 1)) * p + m,
                params,
                incoming,
            )
            state = (params, opt_state, cursor, epoch, perm, residual)
            return state, jnp.mean(losses)

        # Buffer donation halves peak replica memory; the CPU backend does
        # not implement donation and would warn on every call.
        donate = () if jax.default_backend() == "cpu" else (0,)
        return jax.jit(round_fn, donate_argnums=donate)

    def _step_round_stacked(self) -> float:
        self._state, mean_loss = self._dispatch(
            self._round_jit, self._state, *self._data
        )
        return float(mean_loss)

    # ======================================================================
    # Sharded engine: the stacked round under shard_map over a user mesh
    # ======================================================================

    def _init_sharded(self, common, user_mesh) -> None:
        """Place the population on the user mesh (DESIGN.md §13).

        Contiguous user blocks of ``ceil(N_T / shards)``; when the split is
        uneven the tail slots are INERT padding users — zero data, reshuffle
        keys from the same ``fold_in`` stream (so real slots match the
        stacked engine bit-for-bit), self-weight 1, no edges, and a loss
        mask of 0 — they train on zeros into the void and are never read.
        """
        from repro.launch.sharding import FLSharding, UserMesh

        if user_mesh is None:
            user_mesh = UserMesh.build(self.cfg.num_shards)
        self._fls = fls = FLSharding(user_mesh=user_mesh, num_users=self.n)
        n_pad = fls.num_padded

        data_key = self._data_key
        keys = jax.vmap(
            lambda u: jax.random.fold_in(data_key, u)
        )(jnp.arange(n_pad, dtype=jnp.uint32))
        args = (
            jnp.asarray(fls.pad_users(self._xs)),
            jnp.asarray(fls.pad_users(self._ys)),
            keys,
            jnp.asarray(fls.pad_users(self._self_w, fill=1.0)),
            jnp.asarray(fls.valid_mask().astype(np.float32)),
        )
        ec = self._shard_edge_arrays()
        self._sharded_args = fls.shard(args) + (
            fls.shard_blocks({k: jnp.asarray(v) for k, v in ec.items()}),
        )

        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_pad,) + l.shape), common
        )
        residual = (
            None if self.cfg.compressor is None
            else jax.tree.map(jnp.zeros_like, stacked)
        )
        self._state = fls.shard((
            stacked,
            self.opt.init(stacked),
            jnp.zeros(n_pad, jnp.int32),                         # cursor
            jnp.zeros(n_pad, jnp.int32),                         # epoch
            jnp.tile(jnp.arange(self._chunk, dtype=jnp.int32), (n_pad, 1)),
            residual,
        ))

    def _shard_edge_arrays(self) -> dict:
        """Host-side partition of the mixing edges per receiver shard.

        Every array has a leading SHARD axis (so it device_puts with the
        same ``P("users")`` spec as the user-stacked tensors and arrives
        per-shard under shard_map); ragged per-shard lists are padded to a
        common width with index 0 / weight 0 — exact no-ops in the mix.

          - intra edges (``i_src``, ``i_dst``, ``i_w``): both endpoints on
            the shard, indices LOCAL to its block;
          - boundary senders (``b_idx``): local indices of users with an
            out-edge leaving the shard — the only rows the halo all_gather
            ships;
          - cross edges (``x_src``, ``x_dst``, ``x_w``): ``x_src`` indexes
            the gathered ``(S·B, L)`` halo (sender's shard · B + its
            position in that shard's boundary list), ``x_dst`` is local;
          - pallas lane only: dense per-shard mixing blocks ``Wb``
            (S, m, m) and ``Wh`` (S, m, S·B) for the block-local kernel.

        Also records ``halo_stats`` — the measured exchange volume the
        benchmark reports against dense all-pairs gathering.
        """
        from repro.launch.sharding import pad_edge_lists

        fls = self._fls
        S, m = fls.num_shards, fls.block_size
        src, dst, w = self._src, self._dst, self._w_edge
        s_src = src // m
        s_dst = dst // m
        intra = s_src == s_dst
        cross = ~intra

        def pad_f32(rows):
            e_max = max((len(r) for r in rows), default=0)
            out = np.zeros((len(rows), e_max), np.float32)
            for s, r in enumerate(rows):
                out[s, : len(r)] = r
            return out

        def per_dst(vals, sel, localize):
            return [
                vals[sel & (s_dst == s)] - (s * m if localize else 0)
                for s in range(S)
            ]

        i_src, _ = pad_edge_lists(per_dst(src, intra, True))
        i_dst, _ = pad_edge_lists(per_dst(dst, intra, True))
        i_w = pad_f32(per_dst(w, intra, False))

        bnd = [
            np.unique(src[cross & (s_src == s)]) - s * m for s in range(S)
        ]
        b_idx, _ = pad_edge_lists(bnd)
        b = b_idx.shape[1]
        # halo row of global sender u = (u's shard) · B + u's position in
        # that shard's boundary list
        halo_pos = np.full(fls.num_padded, -1, np.int64)
        for s in range(S):
            halo_pos[s * m + bnd[s]] = s * b + np.arange(len(bnd[s]))
        x_src, _ = pad_edge_lists(
            [halo_pos[src[cross & (s_dst == s)]] for s in range(S)]
        )
        x_dst, _ = pad_edge_lists(per_dst(dst, cross, True))
        x_w = pad_f32(per_dst(w, cross, False))

        self.halo_stats = {
            "num_shards": S,
            "block_size": m,
            "intra_edges": int(np.sum(intra)),
            "cross_edges": int(np.sum(cross)),
            "boundary_rows": int(sum(len(r) for r in bnd)),
            # rows each shard RECEIVES per round (padded all_gather width)
            "halo_rows_per_shard": S * b,
            # rows the dense all-pairs alternative would receive
            "dense_rows_per_shard": fls.num_padded,
        }

        ec = {
            "i_src": i_src, "i_dst": i_dst, "i_w": i_w, "b_idx": b_idx,
            "x_src": x_src, "x_dst": x_dst, "x_w": x_w,
        }
        if self.mix_backend == "pallas":
            wb = np.zeros((S, m, m), np.float32)
            wh = np.zeros((S, m, S * b), np.float32)
            if intra.any():
                np.add.at(
                    wb, (s_dst[intra], dst[intra] % m, src[intra] % m),
                    w[intra],
                )
            if cross.any():
                np.add.at(
                    wh, (s_dst[cross], dst[cross] % m, halo_pos[src[cross]]),
                    w[cross],
                )
            ec["Wb"], ec["Wh"] = wb, wh
        return ec

    def _build_sharded_round(self):
        """One gossip round as ONE jitted shard_map dispatch.

        Per shard: local-SGD scan and delta compression on the (m, …)
        block (embarrassingly parallel), then the sparse mixing — intra
        edges via local segment_sum (or the block-local Pallas kernel),
        cross edges against the ``(S·B, L)`` halo of boundary rows
        all_gather-ed from every shard.  The round loss is the psum of the
        mask-weighted per-shard loss sums.
        """
        from jax.sharding import PartitionSpec as P

        from repro.launch.sharding import USER_AXIS

        cfg = self.cfg
        fls = self._fls
        m = fls.block_size
        n = self.n
        comp = cfg.compressor
        mix_backend = self.mix_backend
        interpret = jax.default_backend() == "cpu"
        local_scan = self._make_local_scan()
        compress_stage = None if comp is None else self._make_compress_stage()
        halo_rows = self.halo_stats["halo_rows_per_shard"]

        def body(state, xs, ys, keys, self_w, mask, ec):
            # Every leading axis here is this shard's block: m for the
            # user-stacked tensors, 1 for the shard-constant edge arrays.
            params, opt_state, cursor, epoch, perm, residual = state
            (params, opt_state, cursor, epoch, perm), losses = local_scan(
                params, opt_state, cursor, epoch, perm, xs, ys, keys
            )
            if comp is None:
                msgs = params
            else:
                msgs, residual = compress_stage(params, residual)

            b_idx = ec["b_idx"][0]

            def gather_halo(flat):
                # (B, Lf) boundary rows -> (S·B, Lf) halo from every shard
                rows = jnp.take(flat, b_idx, axis=0)
                return jax.lax.all_gather(
                    rows, USER_AXIS, axis=0, tiled=False
                ).reshape(halo_rows, flat.shape[1])

            if mix_backend == "segment_sum":
                i_src, i_dst, i_w = ec["i_src"][0], ec["i_dst"][0], ec["i_w"][0]
                x_src, x_dst, x_w = ec["x_src"][0], ec["x_dst"][0], ec["x_w"][0]

                def mix_leaf(msg):
                    flat = msg.reshape(m, -1)
                    inc = gossip_mix_segment_ref(flat, i_src, i_dst, i_w, m)
                    if halo_rows:
                        inc = inc + gossip_mix_segment_ref(
                            gather_halo(flat), x_src, x_dst, x_w, m
                        )
                    return inc.reshape(msg.shape)

                incoming = jax.tree.map(mix_leaf, msgs)
            else:
                wb = ec["Wb"][0]
                leaves, treedef = jax.tree.flatten(msgs)
                flats = [l.reshape(m, -1) for l in leaves]
                sizes = [f.shape[1] for f in flats]
                X = jnp.concatenate(flats, axis=1)
                L = X.shape[1]
                # Same on-chip budget as the stacked pallas mix, counting
                # the halo slab that now streams alongside the local one.
                bl_cap = max(1024, (1 << 20) // max(m + halo_rows, 1))
                bl = min(cfg.mix_block_len, bl_cap, L)
                pad = (-L) % bl
                if pad:
                    X = jnp.pad(X, ((0, 0), (0, pad)))
                if halo_rows:
                    out = gossip_mix_block_fwd(
                        X, wb, gather_halo(X), ec["Wh"][0],
                        block_len=bl, interpret=interpret,
                    )[:, :L]
                else:
                    out = gossip_mix_all_fwd(
                        X, wb, block_len=bl, interpret=interpret
                    )[:, :L]
                offs = np.cumsum([0] + sizes)
                incoming = treedef.unflatten([
                    out[:, offs[k]: offs[k + 1]]
                    .reshape(leaves[k].shape).astype(leaves[k].dtype)
                    for k in range(len(leaves))
                ])

            params = jax.tree.map(
                lambda p, inc: (
                    self_w.reshape((m,) + (1,) * (p.ndim - 1)) * p + inc
                ),
                params, incoming,
            )
            # Padding users trained on zeros; the mask drops them from the
            # round loss, and every real user contributes exactly once.
            loss_sum = jax.lax.psum(
                jnp.sum(losses * mask[None, :]), USER_AXIS
            )
            state = (params, opt_state, cursor, epoch, perm, residual)
            return state, loss_sum / (n * cfg.local_steps)

        sharded = fls.user_mesh.shard_map(
            body,
            in_specs=(P(USER_AXIS),) * 7,
            out_specs=(P(USER_AXIS), P()),
        )
        donate = () if jax.default_backend() == "cpu" else (0,)
        # Pin the output shardings: on a 1-device mesh jax canonicalizes
        # P("users") to P(), so round r+1's state would key a fresh trace.
        return jax.jit(
            sharded,
            donate_argnums=donate,
            out_shardings=(fls.user_mesh.sharding(), fls.user_mesh.replicated()),
        )

    def _step_round_sharded(self) -> float:
        self._state, mean_loss = self._dispatch(
            self._round_jit, self._state, *self._sharded_args
        )
        return float(mean_loss)

    # -- public entry point --------------------------------------------------
    def step_round(self) -> dict:
        """One gossip round: local training + exchange + aggregate."""
        calls_before = self._jit_calls
        if self.backend == "stacked":
            mean_loss = self._step_round_stacked()
        elif self.backend == "sharded":
            mean_loss = self._step_round_sharded()
        else:
            mean_loss = self._step_round_reference()
        self.last_round_dispatches = self._jit_calls - calls_before
        self.round += 1
        return {
            "round": self.round,
            "mean_loss": mean_loss,
            "dropped_samples": self.dropped_samples,
        }
