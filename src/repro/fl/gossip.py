"""Gossip-based federated learning (paper §2.1 / §4.2).

Users = vertices of the task graph.  Each round every user trains on its
next data chunk, ships its parameters to its out-neighbors, and aggregates
the models it received (weighted average including its own).  Optional
delta-compression (top-k / int8) with error feedback shrinks the gossip
message — and therefore the scheduler's C matrix.

The *execution timing* of a round on networked machines is what the
scheduler optimizes; ``repro.fl.simulator`` turns an assignment into
bottleneck time while this module performs the actual learning.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import TaskGraph
from repro.data.synthetic import ImageDataset
from repro.train.optim import SGDM


@dataclasses.dataclass
class GossipConfig:
    local_steps: int = 4          # minibatch steps per round (one chunk)
    batch_size: int = 64
    lr: float = 0.05
    momentum: float = 0.9
    aggregate_self_weight: float = 0.5   # weight of own model in the average
    compressor: Any = None        # repro.train.compression.TopK / Int8 / None


class GossipTrainer:
    """Holds per-user replicas and runs gossip rounds."""

    def __init__(
        self,
        task_graph: TaskGraph,
        init_params: Callable[[jax.Array], Any],
        loss_fn: Callable[[Any, dict], jnp.ndarray],
        shards: list[ImageDataset],
        cfg: GossipConfig | None = None,
        seed: int = 0,
    ):
        self.g = task_graph
        self.cfg = cfg or GossipConfig()
        self.n = task_graph.num_tasks
        assert len(shards) == self.n
        self.shards = shards
        # All users start from a COMMON initialization (standard FL — early
        # averaging of independently-initialized models is destructive).
        key0 = jax.random.PRNGKey(seed)
        common = init_params(key0)
        self.params = [jax.tree.map(jnp.copy, common) for _ in range(self.n)]
        self.opt = SGDM(learning_rate=self.cfg.lr, momentum=self.cfg.momentum)
        self.opt_state = [self.opt.init(p) for p in self.params]
        self.residual = [None] * self.n
        self._rng = np.random.default_rng(seed)
        self._cursor = [0] * self.n
        self._loss_fn = loss_fn
        self._grad = jax.jit(jax.value_and_grad(loss_fn))
        self.round = 0

    # -- local training ----------------------------------------------------
    def _local_round(self, i: int) -> float:
        cfg = self.cfg
        shard = self.shards[i]
        losses = []
        for _ in range(cfg.local_steps):
            lo = self._cursor[i]
            hi = lo + cfg.batch_size
            if hi > len(shard.y):                # new epoch, reshuffle
                perm = self._rng.permutation(len(shard.y))
                shard.x[:] = shard.x[perm]
                shard.y[:] = shard.y[perm]
                self._cursor[i] = 0
                lo, hi = 0, cfg.batch_size
            batch = {
                "x": jnp.asarray(shard.x[lo:hi]),
                "y": jnp.asarray(shard.y[lo:hi]),
            }
            self._cursor[i] = hi
            loss, grads = self._grad(self.params[i], batch)
            self.params[i], self.opt_state[i], _ = self.opt.update(
                grads, self.opt_state[i], self.params[i]
            )
            losses.append(float(loss))
        return float(np.mean(losses))

    # -- gossip exchange ----------------------------------------------------
    def _messages(self) -> list[Any]:
        """What each user broadcasts this round (possibly compressed delta)."""
        comp = self.cfg.compressor
        if comp is None:
            return self.params
        out = []
        for i in range(self.n):
            delta = self.params[i] if self.residual[i] is None else jax.tree.map(
                lambda p, r: p + r, self.params[i], self.residual[i]
            )
            compressed, resid = comp.compress(delta)
            self.residual[i] = resid
            out.append(comp.decompress(compressed))   # receiver view
        return out

    def step_round(self) -> dict:
        """One gossip round: local training + exchange + aggregate."""
        losses = [self._local_round(i) for i in range(self.n)]
        msgs = self._messages()
        incoming: list[list[Any]] = [[] for _ in range(self.n)]
        for (i, j) in self.g.edges:
            incoming[j].append(msgs[i])

        new_params = []
        w_self = self.cfg.aggregate_self_weight
        for i in range(self.n):
            if not incoming[i]:
                new_params.append(self.params[i])
                continue
            w_nb = (1.0 - w_self) / len(incoming[i])
            agg = jax.tree.map(lambda p: w_self * p, self.params[i])
            for m in incoming[i]:
                agg = jax.tree.map(lambda a, q: a + w_nb * q, agg, m)
            new_params.append(agg)
        self.params = new_params
        self.round += 1
        return {"round": self.round, "mean_loss": float(np.mean(losses))}
