"""Pilot phase (paper §4.2): estimate task work ``p`` before scheduling.

Each user trains on a small pilot slice of its data on a reference
machine; measured wall-clock × machine speed gives the work estimate.
For LM replicas the analytic FLOPs module provides ``p`` directly
(``repro.models.flops``) — both paths feed the same scheduler.
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np


def measure_task_work(
    run_pilot: Callable[[int], None],
    num_tasks: int,
    reference_speed: float = 1.0,
    repeats: int = 1,
) -> np.ndarray:
    """Time ``run_pilot(i)`` per task -> work units p_i = t_i · e_ref."""
    p = np.zeros(num_tasks)
    for i in range(num_tasks):
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            run_pilot(i)
            best = min(best, time.perf_counter() - t0)
        p[i] = best * reference_speed
    return p


def lm_task_work(cfg, local_steps: int, tokens_per_step: int) -> float:
    """Analytic work of one gossip round of LM training (FLOPs)."""
    from repro.models.flops import param_counts

    counts = param_counts(cfg)
    return 6.0 * counts.active * tokens_per_step * local_steps


def stacked_task_work(
    round_seconds: float,
    shard_sizes: "np.ndarray | list[int]",
    reference_speed: float = 1.0,
) -> np.ndarray:
    """Per-user work estimates from ONE fused stacked-round timing.

    The stacked gossip engine executes every user's local steps in a single
    jitted call, so users cannot be timed individually the way
    ``measure_task_work`` does.  Instead the measured round wall-clock is
    apportioned by shard size — local-step work is proportional to samples
    processed, and the paper's §4.2 setting splits data evenly, so this
    reduces to the uniform ``p`` the FL runner uses.
    """
    sizes = np.asarray(shard_sizes, dtype=np.float64)
    if np.any(sizes <= 0):
        raise ValueError("shard sizes must be positive")
    return round_seconds * reference_speed * sizes / sizes.sum()


def ema_update(current: np.ndarray, observed: np.ndarray, alpha: float = 0.3):
    """Straggler tracking: blend observed speeds into the compute graph."""
    return (1 - alpha) * current + alpha * observed
