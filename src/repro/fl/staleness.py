"""Staleness-weight families for barrier-free gossip aggregation.

Under async execution a user mixes with the *latest delivered* neighbor
snapshot, which may be ``Δτ`` rounds behind the synchronous reference.
FedAsync-style staleness weighting discounts those stale contributions by
a factor ``s(Δτ)`` applied to the gossip mixing weight of the edge (the
discounted mass is returned to the receiving user's self-weight, so each
mixing row still sums to one — ``repro.fl.async_gossip``):

  ``constant``    s(Δτ) = 1                         (no discount)
  ``hinge``       s(Δτ) = 1 if Δτ <= b else 1 / (a·(Δτ − b) + 1)
  ``poly``        s(Δτ) = (Δτ + 1)^(−a)

All families satisfy ``s(0) = 1`` (a fresh snapshot is never discounted)
and are monotonically non-increasing in ``Δτ`` for valid parameters
(``a >= 0``; property-tested in ``tests/test_property.py``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

STALENESS_KINDS = ("constant", "hinge", "poly")


@dataclasses.dataclass(frozen=True)
class StalenessWeights:
    """A validated ``s(Δτ)`` family (picklable, hashable scenario knob).

    ``a`` is the decay rate (hinge slope / polynomial exponent, >= 0);
    ``b`` the hinge tolerance in rounds (>= 0, hinge only — snapshots at
    most ``b`` rounds stale mix at full weight).
    """

    kind: str = "constant"
    a: float = 0.5
    b: int = 0

    def __post_init__(self):
        if self.kind not in STALENESS_KINDS:
            raise ValueError(
                f"unknown staleness kind {self.kind!r}; choose from "
                f"{STALENESS_KINDS}"
            )
        if not self.a >= 0.0:
            raise ValueError(
                f"staleness decay rate a must be >= 0 (got {self.a}); a "
                f"negative rate would AMPLIFY stale snapshots"
            )
        if self.kind == "hinge" and not self.b >= 0:
            raise ValueError(
                f"hinge tolerance b must be >= 0 rounds (got {self.b})"
            )

    def __call__(self, delta_tau):
        """``s(Δτ)`` for a scalar or array of round lags (numpy path).

        Negative lags (a snapshot FRESHER than the sync reference, which
        a fast neighbor can produce) clamp to 0: never discounted.
        """
        d = np.maximum(np.asarray(delta_tau, dtype=np.float64), 0.0)
        if self.kind == "constant":
            return np.ones_like(d)
        if self.kind == "hinge":
            over = np.maximum(d - float(self.b), 0.0)
            return 1.0 / (self.a * over + 1.0)
        return np.power(d + 1.0, -self.a)

    def jax_weights(self, delta_tau):
        """``s(Δτ)`` on a JAX array — same math, traceable inside the
        jitted async round (``AsyncGossipTrainer``)."""
        import jax.numpy as jnp

        d = jnp.maximum(delta_tau.astype(jnp.float32), 0.0)
        if self.kind == "constant":
            return jnp.ones_like(d)
        if self.kind == "hinge":
            over = jnp.maximum(d - float(self.b), 0.0)
            return 1.0 / (jnp.float32(self.a) * over + 1.0)
        return jnp.power(d + 1.0, -jnp.float32(self.a))
