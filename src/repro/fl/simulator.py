"""Execution-time simulation of gossip rounds on networked machines.

Bottleneck time of one round under an assignment is exactly the paper's
Eq. (2) (``repro.core.bqp.bottleneck_time``).  The simulator adds:

  - multi-round timelines (cumulative wall-clock per round),
  - machine failures (machine disappears at a given round),
  - stragglers (a machine's effective speed drops by a factor),
  - communication/computation overlap (beyond-paper: the gossip send of
    round r overlaps the local compute of round r+1, so round time is
    max(comp, comm) instead of comp + comm per task).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bqp import task_times
from repro.core.graphs import ComputeGraph, TaskGraph


@dataclasses.dataclass
class SimEvent:
    round: int
    kind: str            # "fail" | "slowdown"
    machine: int
    factor: float = 1.0  # for slowdown: speed multiplier


def round_time(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    assignment: np.ndarray,
    overlap: bool = False,
) -> float:
    t_comp, t_comm = task_times(task_graph, compute_graph, assignment)
    if overlap:
        return float(np.max(np.maximum(t_comp, t_comm)))
    return float(np.max(t_comp + t_comm))


def apply_event(compute_graph: ComputeGraph, ev: SimEvent) -> ComputeGraph:
    e = compute_graph.e.copy()
    C = compute_graph.C.copy()
    if ev.kind == "slowdown":
        e[ev.machine] *= ev.factor
        return ComputeGraph(e=e, C=C)
    if ev.kind == "fail":
        keep = [j for j in range(len(e)) if j != ev.machine]
        return ComputeGraph(e=e[keep], C=C[np.ix_(keep, keep)])
    raise ValueError(ev.kind)


def timeline(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    schedule_fn,
    num_rounds: int,
    events: list[SimEvent] = (),
    overlap: bool = False,
) -> dict:
    """Cumulative time per round with re-scheduling on events.

    ``schedule_fn(task_graph, compute_graph) -> assignment`` is called at
    round 0 and after every event (elastic re-scheduling).
    """
    cg = compute_graph
    assignment = schedule_fn(task_graph, cg)
    times, cum, reschedules = [], 0.0, []
    ev_by_round = {}
    for ev in events:
        ev_by_round.setdefault(ev.round, []).append(ev)
    machine_ids = list(range(cg.num_machines))   # live machine labels
    for r in range(num_rounds):
        if r in ev_by_round:
            for ev in ev_by_round[r]:
                if ev.kind == "fail":
                    local = machine_ids.index(ev.machine)
                    cg = apply_event(cg, SimEvent(r, "fail", local))
                    machine_ids.pop(local)
                else:
                    local = machine_ids.index(ev.machine)
                    cg = apply_event(cg, SimEvent(r, "slowdown", local, ev.factor))
            assignment = schedule_fn(task_graph, cg)
            reschedules.append(r)
        cum += round_time(task_graph, cg, assignment, overlap=overlap)
        times.append(cum)
    return {
        "cumulative_time": np.asarray(times),
        "final_assignment": assignment,
        "reschedule_rounds": reschedules,
        "final_machines": machine_ids,
    }
