"""Execution-time simulation of gossip rounds on networked machines.

Bottleneck time of one round under an assignment is exactly the paper's
Eq. (2) (``repro.core.bqp.bottleneck_time``).  ``round_time`` is the
analytic single-round evaluator (with a crude ``overlap`` upper-bound
variant kept as a reference); ``timeline`` delegates multi-round runs
with failures/slowdowns to the discrete-event engine (``repro.sim``),
whose queue replays re-scheduling as control events — the bespoke loop
this module used to carry.  For jitter, stragglers, pipelined overlap,
or barrier-free async semantics, call ``repro.sim.simulate`` directly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bqp import task_times
from repro.core.graphs import ComputeGraph, TaskGraph


@dataclasses.dataclass
class SimEvent:
    round: int
    kind: str            # "fail" | "slowdown"
    machine: int
    factor: float = 1.0  # for slowdown: speed multiplier


def round_time(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    assignment: np.ndarray,
    overlap: bool = False,
) -> float:
    t_comp, t_comm = task_times(task_graph, compute_graph, assignment)
    if overlap:
        return float(np.max(np.maximum(t_comp, t_comm)))
    return float(np.max(t_comp + t_comm))


def timeline(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    schedule_fn,
    num_rounds: int,
    events: list[SimEvent] = (),
    overlap: bool = False,
) -> dict:
    """Cumulative time per round with re-scheduling on events.

    ``schedule_fn(task_graph, compute_graph) -> assignment`` is called at
    round 0 and after every event round (elastic re-scheduling).  The
    rounds are replayed by the discrete-event engine: failures and
    slowdowns become ``repro.sim.ControlEvent`` entries in its queue.
    ``overlap=True`` simulates the engine's pipelined semantics (the
    send of round r overlapping the compute of round r+1 — a real
    dependency model, not the old per-round ``max(comp, comm)``
    shortcut) and is incompatible with events: pipelined machines have
    no common barrier at which a failure could re-schedule.
    """
    from repro.sim import ControlEvent, ExecutionSpec, simulate

    ctrl = []
    for ev in events:
        if ev.kind not in ("fail", "slowdown"):
            raise ValueError(ev.kind)
        ctrl.append(ControlEvent(
            round=ev.round, kind=ev.kind, machine=ev.machine,
            factor=ev.factor,
        ))
    if overlap and ctrl:
        raise ValueError(
            "overlap timelines cannot re-schedule on events; use "
            "repro.sim.simulate with sync semantics instead"
        )
    assignment = schedule_fn(task_graph, compute_graph)
    res = simulate(
        task_graph, compute_graph, assignment, num_rounds,
        ExecutionSpec(semantics="overlap" if overlap else "sync"),
        control_events=tuple(ctrl),
        schedule_fn=lambda tg, cg, r: schedule_fn(tg, cg),
    )
    return {
        "cumulative_time": res.round_completion,
        "final_assignment": res.assignment,
        "reschedule_rounds": res.reschedule_rounds,
        "final_machines": res.machine_ids,
    }
