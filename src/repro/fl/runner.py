"""Scheduler-integrated gossip-FL driver (the paper's §4.2 experiment).

Builds a gossip instance (users, topology, data shards), schedules it on a
machine set with any method, trains for R rounds, and reports BOTH:
  - learning curves (loss / accuracy per round), and
  - execution timelines (cumulative bottleneck time per round under each
    scheduler) — multiplying out to "accuracy vs wall-clock".
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core.graphs import ComputeGraph, TaskGraph, gossip_task_graph
from repro.core.scheduler import compare_methods
from repro.data.synthetic import image_dataset
from repro.fl.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.fl.gossip import GossipConfig, GossipTrainer
from repro.fl.pilot import stacked_task_work
from repro.fl.simulator import round_time


@dataclasses.dataclass
class FLExperiment:
    dataset: str = "mnist"
    num_users: int = 10
    num_machines: int = 4
    degree_low: int = 6
    degree_high: int = 7
    rounds: int = 8
    num_samples: int = 2048
    seed: int = 0
    # Gossip engine override: None defers to gossip.backend ("auto"=stacked).
    backend: str | None = None
    gossip: GossipConfig = dataclasses.field(default_factory=GossipConfig)


def run_fl(
    exp: FLExperiment,
    methods: tuple[str, ...] = ("heft", "tp_heft", "sdp_naive", "sdp"),
    compute_graph: ComputeGraph | None = None,
    task_graph: TaskGraph | None = None,
    schedules: dict[str, Any] | None = None,
) -> dict[str, Any]:
    """Train gossip FL under every scheduler and report curves + timelines.

    With ``task_graph``/``compute_graph`` omitted, generates the paper's
    §4.2 instance from ``exp.seed`` (the legacy fig6 path — the scenario
    engine's fig6 preset delegates here unchanged).  The scenario engine
    passes both to train the same FL workload on any topology × machine
    profile × delay combination (``task_graph.num_tasks`` must equal
    ``exp.num_users``), plus ``schedules`` it already computed so one
    record never carries two disagreeing solves of the same instance.
    """
    rng = np.random.default_rng(exp.seed)
    # paper §4.2: equal data shards -> equal p; C ~ Unif(0,1); homogeneous e
    if task_graph is None:
        tg = gossip_task_graph(
            rng, exp.num_users,
            degree_low=exp.degree_low, degree_high=exp.degree_high,
        )
    else:
        if task_graph.num_tasks != exp.num_users:
            raise ValueError(
                f"task_graph has {task_graph.num_tasks} tasks, "
                f"exp.num_users is {exp.num_users}"
            )
        tg = task_graph
    if compute_graph is None:
        C = rng.uniform(0.0, 1.0, size=(exp.num_machines, exp.num_machines))
        np.fill_diagonal(C, 0.0)
        compute_graph = ComputeGraph(e=np.ones(exp.num_machines), C=C)

    train, test = image_dataset(exp.dataset, exp.num_samples, seed=exp.seed)
    shards = train.split(exp.num_users, rng)
    shape = train.x.shape[1:]

    trainer = GossipTrainer(
        tg,
        lambda k: init_cnn_params(k, shape, train.num_classes),
        cnn_loss,
        shards,
        exp.gossip,
        seed=exp.seed,
        backend=exp.backend,
    )

    # One shared SDP solve across the sdp-family methods, and warm-start
    # enabled so re-pilots on the same gossip topology (speed updates,
    # repeated run_fl invocations) resume from the cached iterate.
    if schedules is None:
        schedules = compare_methods(
            tg, compute_graph, methods=tuple(methods),
            seed=exp.seed, warm_start=True,
        )
    per_round_time = {
        m: round_time(tg, compute_graph, s.assignment) for m, s in schedules.items()
    }

    history = []
    round_seconds = []
    for _ in range(exp.rounds):
        t0 = time.perf_counter()
        info = trainer.step_round()
        round_seconds.append(time.perf_counter() - t0)
        acc = cnn_accuracy(trainer.user_params(0), test.x, test.y)
        info["accuracy_user0"] = acc
        history.append(info)

    # Pilot estimate from measured engine time (stacked rounds can't be
    # timed per user; apportion by shard size — uniform here, paper §4.2).
    pilot_p = stacked_task_work(
        float(np.median(round_seconds)), [len(s.y) for s in shards]
    )

    return {
        "task_graph": tg,
        "compute_graph": compute_graph,
        "schedules": schedules,
        "bottleneck_per_round": per_round_time,
        "history": history,
        "backend": trainer.backend,
        "round_seconds": round_seconds,
        "pilot_work": pilot_p,
        "cumulative_time": {
            m: [t * (r + 1) for r in range(exp.rounds)]
            for m, t in per_round_time.items()
        },
    }
