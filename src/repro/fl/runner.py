"""Scheduler-integrated gossip-FL driver (the paper's §4.2 experiment).

Builds a gossip instance (users, topology, data shards), schedules it on a
machine set with any method, trains for R rounds, and reports BOTH:
  - learning curves (loss / accuracy per round), and
  - execution timelines (cumulative bottleneck time per round under each
    scheduler) — multiplying out to "accuracy vs wall-clock".
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core.graphs import ComputeGraph, TaskGraph, gossip_task_graph
from repro.core.scheduler import compare_methods
from repro.data.synthetic import image_dataset
from repro.fl.async_gossip import AsyncGossipTrainer
from repro.fl.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.fl.gossip import GossipConfig, GossipTrainer
from repro.fl.pilot import stacked_task_work
from repro.fl.simulator import round_time
from repro.fl.staleness import StalenessWeights
from repro.sim import ExecutionSpec, simulate


@dataclasses.dataclass
class FLExperiment:
    dataset: str = "mnist"
    num_users: int = 10
    num_machines: int = 4
    degree_low: int = 6
    degree_high: int = 7
    rounds: int = 8
    num_samples: int = 2048
    seed: int = 0
    # Gossip engine override: None defers to gossip.backend ("auto"=stacked).
    backend: str | None = None
    gossip: GossipConfig = dataclasses.field(default_factory=GossipConfig)


def run_fl(
    exp: FLExperiment,
    methods: tuple[str, ...] = ("heft", "tp_heft", "sdp_naive", "sdp"),
    compute_graph: ComputeGraph | None = None,
    task_graph: TaskGraph | None = None,
    schedules: dict[str, Any] | None = None,
) -> dict[str, Any]:
    """Train gossip FL under every scheduler and report curves + timelines.

    With ``task_graph``/``compute_graph`` omitted, generates the paper's
    §4.2 instance from ``exp.seed`` (the legacy fig6 path — the scenario
    engine's fig6 preset delegates here unchanged).  The scenario engine
    passes both to train the same FL workload on any topology × machine
    profile × delay combination (``task_graph.num_tasks`` must equal
    ``exp.num_users``), plus ``schedules`` it already computed so one
    record never carries two disagreeing solves of the same instance.
    """
    rng = np.random.default_rng(exp.seed)
    # paper §4.2: equal data shards -> equal p; C ~ Unif(0,1); homogeneous e
    if task_graph is None:
        tg = gossip_task_graph(
            rng, exp.num_users,
            degree_low=exp.degree_low, degree_high=exp.degree_high,
        )
    else:
        if task_graph.num_tasks != exp.num_users:
            raise ValueError(
                f"task_graph has {task_graph.num_tasks} tasks, "
                f"exp.num_users is {exp.num_users}"
            )
        tg = task_graph
    if compute_graph is None:
        C = rng.uniform(0.0, 1.0, size=(exp.num_machines, exp.num_machines))
        np.fill_diagonal(C, 0.0)
        compute_graph = ComputeGraph(e=np.ones(exp.num_machines), C=C)

    train, test = image_dataset(exp.dataset, exp.num_samples, seed=exp.seed)
    shards = train.split(exp.num_users, rng)
    shape = train.x.shape[1:]

    trainer = GossipTrainer(
        tg,
        lambda k: init_cnn_params(k, shape, train.num_classes),
        cnn_loss,
        shards,
        exp.gossip,
        seed=exp.seed,
        backend=exp.backend,
    )

    # One shared SDP solve across the sdp-family methods, and warm-start
    # enabled so re-pilots on the same gossip topology (speed updates,
    # repeated run_fl invocations) resume from the cached iterate.
    if schedules is None:
        schedules = compare_methods(
            tg, compute_graph, methods=tuple(methods),
            seed=exp.seed, warm_start=True,
        )
    per_round_time = {
        m: round_time(tg, compute_graph, s.assignment) for m, s in schedules.items()
    }

    history = []
    round_seconds = []
    for _ in range(exp.rounds):
        t0 = time.perf_counter()
        info = trainer.step_round()
        round_seconds.append(time.perf_counter() - t0)
        acc = cnn_accuracy(trainer.user_params(0), test.x, test.y)
        info["accuracy_user0"] = acc
        history.append(info)

    # Pilot estimate from measured engine time (stacked rounds can't be
    # timed per user; apportion by shard size — uniform here, paper §4.2).
    pilot_p = stacked_task_work(
        float(np.median(round_seconds)), [len(s.y) for s in shards]
    )

    return {
        "task_graph": tg,
        "compute_graph": compute_graph,
        "schedules": schedules,
        "bottleneck_per_round": per_round_time,
        "history": history,
        "backend": trainer.backend,
        "round_seconds": round_seconds,
        "pilot_work": pilot_p,
        "cumulative_time": {
            m: [t * (r + 1) for r in range(exp.rounds)]
            for m, t in per_round_time.items()
        },
    }


def run_fl_async(
    exp: FLExperiment,
    methods: tuple[str, ...] = ("heft", "sdp"),
    compute_graph: ComputeGraph | None = None,
    task_graph: TaskGraph | None = None,
    schedules: dict[str, Any] | None = None,
    execution: ExecutionSpec | None = None,
    control_events: tuple = (),
    staleness: StalenessWeights | None = None,
    archive_depth: int = 8,
    busy_factors: np.ndarray | None = None,
) -> dict[str, Any]:
    """Barrier-free gossip FL: train on the event engine's delivery record.

    For each scheduler method the assignment is replayed through
    ``repro.sim.simulate`` under async semantics (jitter/stragglers from
    ``execution``, optional fail/recover churn from ``control_events``),
    and an :class:`AsyncGossipTrainer` then consumes, round by round, the
    per-edge delivered versions (``SimResult.mix_versions``) and the
    machine up/down mask mapped to users through the assignment — so the
    model updates flow exactly as the simulated network delivered them
    (DESIGN.md §11).  The returned history carries loss vs SIMULATED
    wall-clock (``sim_time`` = the engine's round completion), which is
    the async-vs-sync comparison axis of ``benchmarks/async_fl_bench.py``.
    """
    spec = execution if execution is not None else ExecutionSpec(semantics="async")
    if spec.semantics != "async":
        raise ValueError(
            f"run_fl_async requires async execution semantics (got "
            f"{spec.semantics!r}); use run_fl for the barriered path"
        )
    rng = np.random.default_rng(exp.seed)
    if task_graph is None:
        tg = gossip_task_graph(
            rng, exp.num_users,
            degree_low=exp.degree_low, degree_high=exp.degree_high,
        )
    else:
        if task_graph.num_tasks != exp.num_users:
            raise ValueError(
                f"task_graph has {task_graph.num_tasks} tasks, "
                f"exp.num_users is {exp.num_users}"
            )
        tg = task_graph
    if compute_graph is None:
        C = rng.uniform(0.0, 1.0, size=(exp.num_machines, exp.num_machines))
        np.fill_diagonal(C, 0.0)
        compute_graph = ComputeGraph(e=np.ones(exp.num_machines), C=C)

    train, test = image_dataset(exp.dataset, exp.num_samples, seed=exp.seed)
    shards = train.split(exp.num_users, rng)
    shape = train.x.shape[1:]

    if schedules is None:
        schedules = compare_methods(
            tg, compute_graph, methods=tuple(methods),
            seed=exp.seed, warm_start=True,
        )

    history: dict[str, list] = {}
    sims: dict[str, Any] = {}
    lag_hists: dict[str, list] = {}
    for m, sched in schedules.items():
        a = np.asarray(sched.assignment, dtype=np.int64)
        res = simulate(
            tg, compute_graph, a, exp.rounds, spec,
            control_events=tuple(control_events),
            busy_factors=busy_factors,
        )
        sims[m] = res
        trainer = AsyncGossipTrainer(
            tg,
            lambda k: init_cnn_params(k, shape, train.num_classes),
            cnn_loss,
            shards,
            exp.gossip,
            seed=exp.seed,
            staleness=staleness,
            archive_depth=archive_depth,
        )
        rows = []
        for r in range(exp.rounds):
            active = (
                ~res.machine_down[r, a] if res.machine_down is not None
                else np.ones(exp.num_users, dtype=bool)
            )
            # The engine can deliver versions AHEAD of the destination's
            # local round (a fast neighbor computed round v > r before the
            # slow dst hit its boundary r).  The stacked replay advances
            # every user in lockstep, so clamp to the current round: src's
            # round-r snapshot existed even earlier than round v, keeping
            # the replay causal with lag 0 (the -1 "never delivered"
            # sentinel passes through the minimum unchanged).
            versions = (
                np.minimum(res.mix_versions[r], r)
                if res.mix_versions is not None else None
            )
            info = trainer.step_round(active=active, edge_versions=versions)
            info["sim_time"] = float(res.round_completion[r])
            info["active_users"] = int(active.sum())
            info["accuracy_user0"] = cnn_accuracy(
                trainer.user_params(0), test.x, test.y
            )
            rows.append(info)
        history[m] = rows
        # Cumulative per-edge staleness histogram across the run: index
        # Δτ = rounds behind — the empirical input a staleness-adaptive
        # mixing policy would tune s(Δτ) against.
        lag_hists[m] = trainer.lag_hist.tolist()

    return {
        "task_graph": tg,
        "compute_graph": compute_graph,
        "schedules": schedules,
        "sim": sims,
        "history": history,
        "cumulative_time": {
            m: [float(t) for t in sims[m].round_completion] for m in sims
        },
        "stale_mixes": {
            m: int(sum(row["stale_mixes"] for row in history[m])) for m in history
        },
        "mix_lag_hist": lag_hists,
        "barrier_stalls": {m: int(sims[m].barrier_stalls) for m in sims},
    }
