"""Pallas fused projection kernels for the SDP's partial-spectrum cone step.

The Douglas-Rachford hot loop (``repro.core.sdp``, DESIGN.md §3) spends its
time in the subspace iteration of ``cone_partial``: per sweep it streams the
dense (n, n) Gram iterate ``Y`` for the matvec ``Y @ V``, then again for the
Rayleigh-Ritz Gram matrix ``Vᵀ(YV)``, and once more for the Frobenius norm
and the final rank-k clip update.  ``roofline.py::sdp_batch_profile``
measured this loop at ~7.8 flops/byte against a machine balance of ~32 —
memory-bound, so fewer streams of ``Y`` is wall-clock (ROADMAP item 5).

Two kernels cover the loop:

  - ``sdp_subspace_fwd``: one pass over row-blocks of ``Y`` emits the
    matvec ``YV``, the small Gram ``G = VᵀYV`` (the Rayleigh-Ritz
    small-solve input), and ``ss = ΣY²`` (the shift ``σ = ‖Y‖_F``) —
    three reductions for ONE stream of ``Y`` instead of three.
  - ``rank_k_update_fwd``: the clip epilogue ``Yp = Y − A Bᵀ`` (caller
    passes ``A = W·θ⁻``, ``B = W``) fused into the same row-blocked
    stream, so the rank-k outer product is never materialized.

Inputs may be f32 or bf16; all arithmetic is f32 (the solver's working
precision).  ``sdp_subspace_fwd`` returns f32 (its outputs feed the f32
``eigh``/``qr`` epilogue); ``rank_k_update_fwd`` casts back to ``Y.dtype``.
Rows are padded to the block size with zeros — zero rows of ``Y``/``V``
contribute nothing to any of the reductions — and sliced off the outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_rows(x: jnp.ndarray, rows: int) -> jnp.ndarray:
    pad = rows - x.shape[0]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


def _subspace_kernel(y_ref, vfull_ref, vblk_ref, yv_ref, g_ref, ss_ref):
    i = pl.program_id(0)
    y = y_ref[...].astype(jnp.float32)            # (bn, np)
    yv = y @ vfull_ref[...].astype(jnp.float32)   # (bn, k)
    yv_ref[...] = yv

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)

    g_ref[...] += vblk_ref[...].astype(jnp.float32).T @ yv
    ss_ref[...] += jnp.sum(y * y)


def sdp_subspace_fwd(
    Y: jnp.ndarray,   # (n, n) symmetric iterate
    V: jnp.ndarray,   # (n, k) subspace basis
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One stream of ``Y`` -> (``YV`` (n, k), ``G = VᵀYV`` (k, k), ``ΣY²``).

    ``V`` rides along twice: the full (n, k) block for the matvec and the
    row-block aligned with ``Y``'s rows for the ``G`` accumulation — both
    KiB-scale next to the (bn, n) slab of ``Y`` streamed once per step.
    """
    n = Y.shape[0]
    k = V.shape[1]
    assert Y.shape == (n, n), Y.shape
    assert V.shape == (n, k), (V.shape, n)
    bn = min(block_rows, n)
    n_pad = -(-n // bn) * bn
    Yp = _pad_rows(Y, n_pad)
    if n_pad != n:
        Yp = jnp.pad(Yp, ((0, 0), (0, n_pad - n)))
    Vp = _pad_rows(V, n_pad)
    yv, g, ss = pl.pallas_call(
        _subspace_kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((n_pad, k), lambda i: (0, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((k, k), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(Yp, Vp, Vp)
    return yv[:n], g, ss[0, 0]


def _rank_k_kernel(y_ref, ablk_ref, bfull_ref, o_ref):
    y = y_ref[...].astype(jnp.float32)            # (bn, np)
    a = ablk_ref[...].astype(jnp.float32)         # (bn, k)
    b = bfull_ref[...].astype(jnp.float32)        # (np, k)
    o_ref[...] = (y - a @ b.T).astype(o_ref.dtype)


def rank_k_update_fwd(
    Y: jnp.ndarray,   # (n, n)
    A: jnp.ndarray,   # (n, k) — e.g. W · θ⁻ (the negative Ritz pairs, scaled)
    B: jnp.ndarray,   # (n, k) — e.g. W
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Rank-k downdate ``Y − A Bᵀ`` without materializing the outer product."""
    n = Y.shape[0]
    k = A.shape[1]
    assert Y.shape == (n, n), Y.shape
    assert A.shape == (n, k) and B.shape == (n, k), (A.shape, B.shape)
    bn = min(block_rows, n)
    n_pad = -(-n // bn) * bn
    Yp = _pad_rows(Y, n_pad)
    if n_pad != n:
        Yp = jnp.pad(Yp, ((0, 0), (0, n_pad - n)))
    Ap = _pad_rows(A, n_pad)
    Bp = _pad_rows(B, n_pad)
    out = pl.pallas_call(
        _rank_k_kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((n_pad, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, n_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), Y.dtype),
        interpret=interpret,
    )(Yp, Ap, Bp)
    return out[:n, :n]
