"""Pallas batched bottleneck evaluation over rounding samples.

The fused rounding backend (``repro.core.rounding``, DESIGN.md §6) scores
every repaired Gaussian sample with Eq. 2 — per sample: machine loads,
per-task compute times, per-dependency communication delays, max.  The jnp
path vmaps a gather-based evaluator over samples; this kernel evaluates a
whole block of samples per grid step as dense one-hot contractions, keeping
the (bs, T, K) assignment slab in on-chip memory for all four reductions.

All gathers become products with exact one-hot f32 factors, so every
per-sample quantity except the machine-load sum is reproduced bit-for-bit
(the load reduction may differ in summation order by f32 ulps).

Inputs:
  - ``onehot``  (S, T, K) f32 one-hot of the sampled assignments;
  - ``p`` (T,) task workloads, ``e`` (K,) machine speeds, ``C`` (K, K)
    inter-machine delays;
  - ``src_onehot`` / ``dst_onehot`` (E, T) f32 one-hot of each dependency
    edge's endpoint tasks.  All-zero rows are inert (used to pad E=0 up to
    one row), matching the jnp path where edge-free tasks have zero
    communication time.

Output: (S,) f32 bottleneck times (Eq. 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bottleneck_kernel(oh_ref, p_ref, e_ref, c_ref, src_ref, dst_ref, t_ref):
    A = oh_ref[...].astype(jnp.float32)          # (bs, T, K)
    p = p_ref[...].astype(jnp.float32)           # (T,)
    e = e_ref[...].astype(jnp.float32)           # (K,)
    C = c_ref[...].astype(jnp.float32)           # (K, K)
    S = src_ref[...].astype(jnp.float32)         # (E, T)
    D = dst_ref[...].astype(jnp.float32)         # (E, T)
    loads = jnp.einsum("stk,t->sk", A, p)                     # machine loads
    per_machine = loads / e                                   # (bs, K)
    t_comp = jnp.einsum("stk,sk->st", A, per_machine)         # (loads/e)[a]
    m_src = jnp.einsum("et,stk->sek", S, A)                   # one_hot(a[src])
    m_dst = jnp.einsum("et,stk->sek", D, A)
    delays = jnp.einsum("sek,kl,sel->se", m_src, C, m_dst)    # C[a[src],a[dst]]
    comm = jnp.max(delays[:, :, None] * S[None, :, :], axis=1)  # .at[src].max
    t_ref[...] = jnp.max(t_comp + comm, axis=1).astype(t_ref.dtype)


def bottleneck_eval_fwd(
    onehot: jnp.ndarray,       # (S, T, K) one-hot assignments
    p: jnp.ndarray,            # (T,)
    e: jnp.ndarray,            # (K,)
    C: jnp.ndarray,            # (K, K)
    src_onehot: jnp.ndarray,   # (E, T) one-hot edge sources (E may be 0)
    dst_onehot: jnp.ndarray,   # (E, T) one-hot edge destinations
    *,
    block_samples: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    s, t, k = onehot.shape
    assert p.shape == (t,) and e.shape == (k,), (p.shape, e.shape)
    assert C.shape == (k, k), C.shape
    if src_onehot.shape[0] == 0:
        # one inert all-zero edge row: zero delay, zero comm contribution
        src_onehot = jnp.zeros((1, t), jnp.float32)
        dst_onehot = jnp.zeros((1, t), jnp.float32)
    n_e = src_onehot.shape[0]
    assert src_onehot.shape == dst_onehot.shape == (n_e, t)
    if block_samples is None:
        # keep the (bs, T, K) slab ≈ 1 MiB of f32 on-chip
        block_samples = max(1, (1 << 18) // max(1, t * k))
    bs = min(block_samples, s)
    pad = (-s) % bs
    if pad:
        onehot = jnp.pad(onehot, ((0, pad), (0, 0), (0, 0)))
    sp = s + pad
    times = pl.pallas_call(
        _bottleneck_kernel,
        grid=(sp // bs,),
        in_specs=[
            pl.BlockSpec((bs, t, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((n_e, t), lambda i: (0, 0)),
            pl.BlockSpec((n_e, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.float32),
        interpret=interpret,
    )(onehot, p, e, C, src_onehot, dst_onehot)
    return times[:s]
