"""Pure-jnp oracles for every Pallas kernel (the test ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,      # (B, H, S, D)
    k: jnp.ndarray,      # (B, Hkv, S, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    valid_len: jnp.ndarray,
) -> jnp.ndarray:
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    mask = jnp.arange(s)[None] < jnp.reshape(valid_len, (-1, 1))
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


def gossip_mix_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    return (
        weights.astype(jnp.float32) @ stacked.astype(jnp.float32)
    ).astype(stacked.dtype)


def gossip_mix_all_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """All-receivers dense oracle: (M, N) @ (N, L) -> (M, L) — the same
    matmul as the one-receiver oracle, batched over weight rows."""
    return gossip_mix_ref(stacked, weights)


def sdp_subspace_ref(
    Y: jnp.ndarray, V: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused subspace-iteration oracle: (Y@V, Vᵀ(Y@V), ΣY²) in f32."""
    Yf = Y.astype(jnp.float32)
    Vf = V.astype(jnp.float32)
    YV = Yf @ Vf
    return YV, Vf.T @ YV, jnp.sum(Yf * Yf)


def rank_k_update_ref(
    Y: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray
) -> jnp.ndarray:
    """Rank-k downdate oracle: Y − A Bᵀ (f32 math, Y.dtype out)."""
    out = Y.astype(jnp.float32) - A.astype(jnp.float32) @ B.astype(jnp.float32).T
    return out.astype(Y.dtype)


def topk_mask_ref(
    X: jnp.ndarray, thresh: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Threshold-sparsification oracle with error feedback (per-row thresh)."""
    Xf = X.astype(jnp.float32)
    msg = jnp.where(jnp.abs(Xf) >= thresh.astype(jnp.float32)[:, None], Xf, 0.0)
    return msg.astype(X.dtype), (Xf - msg).astype(X.dtype)


def int8_roundtrip_ref(
    X: jnp.ndarray, scale: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantize→dequantize oracle with error feedback."""
    Xf = X.astype(jnp.float32)
    s = scale.astype(jnp.float32)[:, None]
    msg = jnp.clip(jnp.round(Xf / s), -127.0, 127.0) * s
    return msg.astype(X.dtype), (Xf - msg).astype(X.dtype)


def bottleneck_eval_ref(
    onehot: jnp.ndarray,       # (S, T, K) one-hot assignments
    p: jnp.ndarray,            # (T,)
    e: jnp.ndarray,            # (K,)
    C: jnp.ndarray,            # (K, K)
    src_onehot: jnp.ndarray,   # (E, T) one-hot edge sources (all-zero = inert)
    dst_onehot: jnp.ndarray,   # (E, T)
) -> jnp.ndarray:
    """Eq. 2 over samples as dense one-hot contractions (the kernel contract).

    Semantic equivalence to the index-gather evaluator
    (``bottleneck_time_batch``) is pinned separately in the property suite.
    """
    if src_onehot.shape[0] == 0:
        src_onehot = jnp.zeros((1, onehot.shape[1]), jnp.float32)
        dst_onehot = jnp.zeros((1, onehot.shape[1]), jnp.float32)
    A = onehot.astype(jnp.float32)
    S = src_onehot.astype(jnp.float32)
    D = dst_onehot.astype(jnp.float32)
    loads = jnp.einsum("stk,t->sk", A, p.astype(jnp.float32))
    per_machine = loads / e.astype(jnp.float32)
    t_comp = jnp.einsum("stk,sk->st", A, per_machine)
    m_src = jnp.einsum("et,stk->sek", S, A)
    m_dst = jnp.einsum("et,stk->sek", D, A)
    delays = jnp.einsum("sek,kl,sel->se", m_src, C.astype(jnp.float32), m_dst)
    comm = jnp.max(delays[:, :, None] * S[None, :, :], axis=1)
    return jnp.max(t_comp + comm, axis=1)


def gossip_mix_segment_ref(
    stacked: jnp.ndarray,    # (N, L) flat sender vectors
    src: jnp.ndarray,        # (|E|,) sender index per edge
    dst: jnp.ndarray,        # (|E|,) receiver index per edge
    w_edge: jnp.ndarray,     # (|E|,) per-edge mixing weight
    num_receivers: int,
) -> jnp.ndarray:
    """Sparse-mix reference: scatter-add the weighted sender rows per edge.

    Materializes the (|E|, L) gather, so it moves ~(2|E| + M)·L words —
    the baseline the all-receivers Pallas kernel is measured against.
    """
    contrib = stacked[src].astype(jnp.float32) * w_edge[:, None].astype(jnp.float32)
    out = jax.ops.segment_sum(contrib, dst, num_segments=num_receivers)
    return out.astype(stacked.dtype)
