"""Pure-jnp oracles for every Pallas kernel (the test ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,      # (B, H, S, D)
    k: jnp.ndarray,      # (B, Hkv, S, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    valid_len: jnp.ndarray,
) -> jnp.ndarray:
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    mask = jnp.arange(s)[None] < jnp.reshape(valid_len, (-1, 1))
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


def gossip_mix_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    return (
        weights.astype(jnp.float32) @ stacked.astype(jnp.float32)
    ).astype(stacked.dtype)


def gossip_mix_all_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """All-receivers dense oracle: (M, N) @ (N, L) -> (M, L) — the same
    matmul as the one-receiver oracle, batched over weight rows."""
    return gossip_mix_ref(stacked, weights)


def gossip_mix_segment_ref(
    stacked: jnp.ndarray,    # (N, L) flat sender vectors
    src: jnp.ndarray,        # (|E|,) sender index per edge
    dst: jnp.ndarray,        # (|E|,) receiver index per edge
    w_edge: jnp.ndarray,     # (|E|,) per-edge mixing weight
    num_receivers: int,
) -> jnp.ndarray:
    """Sparse-mix reference: scatter-add the weighted sender rows per edge.

    Materializes the (|E|, L) gather, so it moves ~(2|E| + M)·L words —
    the baseline the all-receivers Pallas kernel is measured against.
    """
    contrib = stacked[src].astype(jnp.float32) * w_edge[:, None].astype(jnp.float32)
    out = jax.ops.segment_sum(contrib, dst, num_segments=num_receivers)
    return out.astype(stacked.dtype)
