"""Pallas fused RMSNorm kernel: one HBM round-trip per row block.

x (R, D) -> x * rsqrt(mean(x², -1) + eps) * (1 + scale).  Row blocks of
``block_rows`` keep (block_rows, D) in VMEM (D ≤ 12288 f32 = 48 KB/row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w)).astype(o_ref.dtype)


def rmsnorm_fwd(
    x: jnp.ndarray,      # (R, D)
    scale: jnp.ndarray,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    r, d = x.shape
    br = min(block_rows, r)
    assert r % br == 0, (r, br)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale)
