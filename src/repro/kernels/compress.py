"""Pallas fused delta-compression kernels with error feedback.

The stacked gossip engine (``repro.fl.gossip``, DESIGN.md §8) compresses
each round's parameter delta and keeps the error-feedback residual:

    delta    = params + residual
    msgs     = roundtrip(delta)          # what the wire carries
    residual = delta - msgs              # fed back next round

On the jnp path that is two full passes over the stacked (N_T, L) delta
(roundtrip, then the subtraction).  These kernels fuse the quantize /
sparsify decision with the residual into ONE stream per L-block: the delta
slab is read once and both ``msgs`` and ``residual`` come out of the same
pass.

The data-dependent per-row statistics (the top-k magnitude threshold, the
int8 scale) are tiny (N_T,) reductions computed by the caller in plain jnp
— the kernels take them as inputs, mirroring how ``gossip_mix_all_fwd``
takes the precomputed mixing matrix.

Contracts (element-wise in f32, cast back to ``X.dtype``):

  - ``topk_mask_fwd``:  msg = x · [|x| ≥ thresh_row],  resid = x − msg.
    With ``thresh_row`` = the row's k-th largest |x| this reproduces
    ``TopK.roundtrip`` exactly on tie-free rows (ties keep ≥ k entries —
    measure zero on training deltas).
  - ``int8_roundtrip_fwd``:  q = clip(round(x / scale_row), ±127),
    msg = q · scale_row,  resid = x − msg — msgs bit-equal to
    ``Int8.roundtrip`` for f32 inputs given the same per-row scale; the
    residual may differ by 1 ulp of |x| (XLA may contract q·scale into the
    subtraction as an FMA on either path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(x_ref, t_ref, m_ref, r_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, bl)
    thr = t_ref[...].astype(jnp.float32)        # (N,)
    msg = jnp.where(jnp.abs(x) >= thr[:, None], x, 0.0)
    m_ref[...] = msg.astype(m_ref.dtype)
    r_ref[...] = (x - msg).astype(r_ref.dtype)


def _int8_kernel(x_ref, s_ref, m_ref, r_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, bl)
    scale = s_ref[...].astype(jnp.float32)[:, None]
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    msg = q * scale
    m_ref[...] = msg.astype(m_ref.dtype)
    r_ref[...] = (x - msg).astype(r_ref.dtype)


def _blocked_rowstat_call(kernel, X, row_stat, *, block_len, interpret):
    n, l = X.shape
    assert row_stat.shape == (n,), (row_stat.shape, n)
    bl = min(block_len, l)
    pad = (-l) % bl
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
    lp = l + pad
    msg, resid = pl.pallas_call(
        kernel,
        grid=(lp // bl,),
        in_specs=[
            pl.BlockSpec((n, bl), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n, bl), lambda i: (0, i)),
            pl.BlockSpec((n, bl), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, lp), X.dtype),
            jax.ShapeDtypeStruct((n, lp), X.dtype),
        ],
        interpret=interpret,
    )(X, row_stat)
    return msg[:, :l], resid[:, :l]


def topk_mask_fwd(
    X: jnp.ndarray,        # (N, L) stacked per-user flat deltas
    thresh: jnp.ndarray,   # (N,) per-row keep threshold (k-th largest |x|)
    *,
    block_len: int = 65536,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One stream of X -> (sparsified msgs, error-feedback residual)."""
    return _blocked_rowstat_call(
        _topk_kernel, X, thresh, block_len=block_len, interpret=interpret
    )


def int8_roundtrip_fwd(
    X: jnp.ndarray,        # (N, L) stacked per-user flat deltas
    scale: jnp.ndarray,    # (N,) per-row symmetric quantization scale (> 0)
    *,
    block_len: int = 65536,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One stream of X -> (dequantized int8 msgs, error-feedback residual)."""
    return _blocked_rowstat_call(
        _int8_kernel, X, scale, block_len=block_len, interpret=interpret
    )
