"""Pallas TPU flash-attention forward kernel (GQA, causal/windowed).

Layout: q (B, H, S, D), k/v (B, Hkv, S, D).  Grid (B, H, nQ, nK) — the kv
axis is innermost so the (m, l, acc) VMEM scratch carries across kv blocks
of one query block (standard TPU flash structure).  Block shapes keep the
working set in VMEM: q (bq, D), k/v (bk, D), acc (bq, D) f32 — with
bq = bk = 512, D = 128 that is ~0.9 MB << 16 MB v5e VMEM, and the matmul
dims are multiples of 128 for the MXU.

Validated against ``ref.flash_attention_ref`` in interpret mode on CPU
(tests sweep shapes/dtypes); on TPU this is the training/prefill hot-spot.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int, bq: int, bk: int, nk: int,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (bq, bk)

    iq = pl.program_id(2)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,      # (B, H, S, D)
    k: jnp.ndarray,      # (B, Hkv, S, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
