"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; see EXAMPLE.md and DESIGN.md §12 for the kernel/ops/ref
structure)."""

from repro.kernels.ops import (
    bottleneck_eval,
    compress_int8,
    compress_topk,
    decode_attention,
    flash_attention,
    gossip_mix,
    rank_k_update,
    rmsnorm,
    sdp_subspace,
)

__all__ = [
    "bottleneck_eval",
    "compress_int8",
    "compress_topk",
    "decode_attention",
    "flash_attention",
    "gossip_mix",
    "rank_k_update",
    "rmsnorm",
    "sdp_subspace",
]
