"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; see EXAMPLE.md for the kernel/ops/ref structure)."""

from repro.kernels.ops import (
    decode_attention,
    flash_attention,
    gossip_mix,
    rmsnorm,
)

__all__ = ["decode_attention", "flash_attention", "gossip_mix", "rmsnorm"]
