"""Pallas fused gossip aggregation: out = Σ_n w[n] · params[n] in one pass.

The gossip step averages N neighbor models (paper §2.1).  Naively that is
N-1 separate AXPY sweeps (2(N-1) HBM round-trips of the full parameter
vector); this kernel streams the stacked (N, L) neighbor buffer once and
writes the mix — bandwidth-bound at (N+1)/(2(N-1))× fewer bytes.

Inputs: stacked flat params (N, L), weights (N,).  Grid over L chunks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, bl)
    w = w_ref[...].astype(jnp.float32)          # (N,)
    o_ref[...] = (w @ x).astype(o_ref.dtype)


def gossip_mix_fwd(
    stacked: jnp.ndarray,   # (N, L) neighbor parameter vectors (incl. self)
    weights: jnp.ndarray,   # (N,) aggregation weights (sum to 1)
    *,
    block_len: int = 65536,
    interpret: bool = False,
) -> jnp.ndarray:
    n, l = stacked.shape
    bl = min(block_len, l)
    assert l % bl == 0, (l, bl)
    return pl.pallas_call(
        _mix_kernel,
        grid=(l // bl,),
        in_specs=[
            pl.BlockSpec((n, bl), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)
