"""Pallas fused gossip aggregation: out = Σ_n w[n] · params[n] in one pass.

The gossip step averages N neighbor models (paper §2.1).  Naively that is
N-1 separate AXPY sweeps (2(N-1) HBM round-trips of the full parameter
vector); this kernel streams the stacked (N, L) neighbor buffer once and
writes the mix — bandwidth-bound at (N+1)/(2(N-1))× fewer bytes.

Three entry points:
  - ``gossip_mix_fwd``: one receiver — stacked (N, L) · weights (N,) -> (L,).
  - ``gossip_mix_block_fwd``: one SHARD of receivers of the mesh-sharded
    engine — the shard's local (m, L) sender slab under the intra-shard
    mixing block (m, m) plus the gathered boundary-row halo (H, L) under
    the cross-shard block (m, H), fused so both slabs stream once per
    L-block (DESIGN.md §13).
  - ``gossip_mix_all_fwd``: ALL receivers of a gossip round at once —
    stacked (N, L) · row-normalized mixing matrix W (M, N) -> (M, L).
    Per L-block the kernel reads the (N, bl) slab ONCE and emits every
    receiver's mix, so the whole exchange moves (N+M)·L words instead of
    the Σ_j (indeg_j + 1)·L ≈ (|E|+M)·L of per-edge AXPY aggregation
    (or the (2|E|+M)·L of a gather + segment_sum).  This is the
    device-resident exchange of the stacked gossip-FL engine
    (``repro.fl.gossip``, DESIGN.md §8).

Inputs: stacked flat params (N, L), weights (N,) or (M, N).  Grid over L
chunks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, bl)
    w = w_ref[...].astype(jnp.float32)          # (N,)
    o_ref[...] = (w @ x).astype(o_ref.dtype)


def gossip_mix_fwd(
    stacked: jnp.ndarray,   # (N, L) neighbor parameter vectors (incl. self)
    weights: jnp.ndarray,   # (N,) aggregation weights (sum to 1)
    *,
    block_len: int = 65536,
    interpret: bool = False,
) -> jnp.ndarray:
    n, l = stacked.shape
    bl = min(block_len, l)
    assert l % bl == 0, (l, bl)
    return pl.pallas_call(
        _mix_kernel,
        grid=(l // bl,),
        in_specs=[
            pl.BlockSpec((n, bl), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)


def _mix_block_kernel(x_ref, h_ref, wb_ref, wh_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (m, bl) local senders
    h = h_ref[...].astype(jnp.float32)          # (H, bl) gathered halo rows
    wb = wb_ref[...].astype(jnp.float32)        # (m, m) intra-shard block
    wh = wh_ref[...].astype(jnp.float32)        # (m, H) cross-shard block
    o_ref[...] = (wb @ x + wh @ h).astype(o_ref.dtype)


def gossip_mix_block_fwd(
    local: jnp.ndarray,     # (m, L) this shard's flat sender vectors
    w_block: jnp.ndarray,   # (m, m) intra-shard mixing block
    halo: jnp.ndarray,      # (H, L) gathered boundary rows of other shards
    w_halo: jnp.ndarray,    # (m, H) cross-shard mixing block
    *,
    block_len: int = 65536,
    interpret: bool = False,
) -> jnp.ndarray:
    """Block-local mixing of the mesh-sharded exchange (one shard's view):
    ``out = w_block @ local + w_halo @ halo``.

    Per L-block the kernel streams the (m, bl) local slab AND the (H, bl)
    halo slab exactly once and emits every local receiver's mix — the
    sharded counterpart of ``gossip_mix_all_fwd``, whose (N, L) all-users
    slab no longer exists on any one device.  The weight blocks ride along
    whole (m and H are per-shard small).  With no cross-shard edges
    (H = 0) the halo term is skipped entirely.
    """
    m, l = local.shape
    h_rows = halo.shape[0]
    assert w_block.shape == (m, m), (w_block.shape, m)
    assert halo.shape[1] == l, (halo.shape, l)
    assert w_halo.shape == (m, h_rows), (w_halo.shape, (m, h_rows))
    if h_rows == 0:
        return gossip_mix_all_fwd(
            local, w_block, block_len=block_len, interpret=interpret
        )
    bl = min(block_len, l)
    assert l % bl == 0, (l, bl)
    return pl.pallas_call(
        _mix_block_kernel,
        grid=(l // bl,),
        in_specs=[
            pl.BlockSpec((m, bl), lambda i: (0, i)),
            pl.BlockSpec((h_rows, bl), lambda i: (0, i)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, h_rows), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bl), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, l), local.dtype),
        interpret=interpret,
    )(local, halo, w_block, w_halo)


def _mix_all_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, bl)
    w = w_ref[...].astype(jnp.float32)          # (M, N)
    o_ref[...] = (w @ x).astype(o_ref.dtype)


def gossip_mix_all_fwd(
    stacked: jnp.ndarray,   # (N, L) flat sender parameter vectors
    weights: jnp.ndarray,   # (M, N) mixing matrix, row m = receiver m's weights
    *,
    block_len: int = 65536,
    interpret: bool = False,
) -> jnp.ndarray:
    """All-receivers blocked mixing: out[m] = Σ_n W[m, n] · stacked[n].

    The full W block rides along to every grid step (N_T ≤ a few hundred,
    so W is KiB-scale) while the (N, bl) slab of the stacked buffer is
    streamed exactly once for all M receivers.
    """
    n, l = stacked.shape
    m = weights.shape[0]
    assert weights.shape == (m, n), (weights.shape, (m, n))
    bl = min(block_len, l)
    assert l % bl == 0, (l, bl)
    return pl.pallas_call(
        _mix_all_kernel,
        grid=(l // bl,),
        in_specs=[
            pl.BlockSpec((n, bl), lambda i: (0, i)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bl), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, l), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)
