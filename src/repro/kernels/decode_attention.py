"""Pallas TPU decode-attention kernel: one query token vs a long KV cache.

This is the memory-bound serve_step hot-spot: per step it streams the
whole cache (B·S·Hkv·D·2 bytes) through VMEM at HBM bandwidth.  Grid is
(B, nK) with kv innermost; all H query heads are processed per block so
the cache is read exactly once.  Block working set: k/v (bk, Hkv, D) +
acc (H, D) f32 — bk=512, Hkv=8, D=128 ≈ 1.3 MB.

Validated against ``ref.decode_attention_ref`` in interpret mode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, bk: int, nk: int, g: int,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale             # (H, D)
    k = k_ref[0].astype(jnp.float32)                      # (bk, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    h, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(hkv, g, d)
    # logits (Hkv, g, bk)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )
    valid_len = len_ref[0]
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (hkv, g, bk), 2)
    s = jnp.where(kpos < valid_len, s, NEG_INF)

    m_prev = m_ref[...].reshape(hkv, g)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[...].reshape(hkv, g) * corr + jnp.sum(p, axis=2)
    # pv: (Hkv, g, D)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )
    acc = acc_ref[...].reshape(hkv, g, d) * corr[..., None] + pv
    m_ref[...] = m_new.reshape(h)
    l_ref[...] = l_new.reshape(h)
    acc_ref[...] = acc.reshape(h, d)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    valid_len: jnp.ndarray,  # (B,) int32
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    bk = min(block_k, s)
    assert s % bk == 0, (s, bk)
    nk = s // bk
    scale = 1.0 / math.sqrt(d)
    valid_len = valid_len.astype(jnp.int32).reshape(b, 1)

    kernel = functools.partial(
        _decode_kernel, scale=scale, bk=bk, nk=nk, g=g
    )
    return pl.pallas_call(
        kernel,
        grid=(b, nk),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda ib, ik: (ib, 0, 0)),
            pl.BlockSpec((1, bk, hkv, d), lambda ib, ik: (ib, ik, 0, 0)),
            pl.BlockSpec((1, bk, hkv, d), lambda ib, ik: (ib, ik, 0, 0)),
            pl.BlockSpec((1, 1), lambda ib, ik: (ib, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda ib, ik: (ib, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, valid_len)
