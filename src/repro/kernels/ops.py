"""Jit'd public wrappers around the Pallas kernels.

On TPU these dispatch the compiled kernels; everywhere else they run the
kernel body in interpret mode (bit-accurate Python execution) so CPU tests
validate the exact kernel logic.  Set ``REPRO_FORCE_REF=1`` to bypass
kernels entirely (pure-jnp oracles).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bottleneck import bottleneck_eval_fwd
from repro.kernels.compress import int8_roundtrip_fwd, topk_mask_fwd
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.gossip_mix import gossip_mix_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.sdp_proj import rank_k_update_fwd, sdp_subspace_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "0") == "1"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0):
    """(B, S, H, D) x (B, S, Hkv, D) -> (B, S, H, D) (model layout)."""
    del q_offset  # kernel grid assumes aligned self-attention
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if _force_ref():
        out = ref.flash_attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention_fwd(
            qt, kt, vt, causal=causal, window=window, interpret=_interpret()
        )
    return jnp.swapaxes(out, 1, 2)


@jax.jit
def decode_attention(q, k_cache, v_cache, valid_len):
    """(B, H, D) vs (B, S, Hkv, D) cache -> (B, H, D)."""
    if _force_ref():
        return ref.decode_attention_ref(q, k_cache, v_cache, valid_len)
    return decode_attention_fwd(
        q, k_cache, v_cache, valid_len, interpret=_interpret()
    )


@jax.jit
def rmsnorm(x, scale):
    """(..., D) fused RMSNorm."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if _force_ref():
        out = ref.rmsnorm_ref(x2, scale)
    else:
        out = rmsnorm_fwd(x2, scale, interpret=_interpret())
    return out.reshape(shape)


@jax.jit
def gossip_mix(stacked, weights):
    """(N, L) neighbor params + (N,) weights -> (L,) aggregated params."""
    if _force_ref():
        return ref.gossip_mix_ref(stacked, weights)
    return gossip_mix_fwd(stacked, weights, interpret=_interpret())


@jax.jit
def sdp_subspace(Y, V):
    """(n, n) iterate + (n, k) basis -> (Y@V, VᵀYV, ΣY²) in one Y stream."""
    if _force_ref():
        return ref.sdp_subspace_ref(Y, V)
    return sdp_subspace_fwd(Y, V, interpret=_interpret())


@jax.jit
def rank_k_update(Y, A, B):
    """(n, n) − (n, k) @ (n, k)ᵀ without materializing the outer product."""
    if _force_ref():
        return ref.rank_k_update_ref(Y, A, B)
    return rank_k_update_fwd(Y, A, B, interpret=_interpret())


@jax.jit
def compress_topk(X, thresh):
    """(N, L) deltas + (N,) thresholds -> (msgs, residual) in one stream."""
    if _force_ref():
        return ref.topk_mask_ref(X, thresh)
    return topk_mask_fwd(X, thresh, interpret=_interpret())


@jax.jit
def compress_int8(X, scale):
    """(N, L) deltas + (N,) scales -> (dequantized msgs, residual)."""
    if _force_ref():
        return ref.int8_roundtrip_ref(X, scale)
    return int8_roundtrip_fwd(X, scale, interpret=_interpret())


@jax.jit
def bottleneck_eval(onehot, p, e, C, src_onehot, dst_onehot):
    """(S, T, K) one-hot samples -> (S,) Eq. 2 bottleneck times."""
    if _force_ref():
        return ref.bottleneck_eval_ref(onehot, p, e, C, src_onehot, dst_onehot)
    return bottleneck_eval_fwd(
        onehot, p, e, C, src_onehot, dst_onehot, interpret=_interpret()
    )
