"""Jit'd public wrappers around the Pallas kernels.

On TPU these dispatch the compiled kernels; everywhere else they run the
kernel body in interpret mode (bit-accurate Python execution) so CPU tests
validate the exact kernel logic.  Set ``REPRO_FORCE_REF=1`` to bypass
kernels entirely (pure-jnp oracles).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.gossip_mix import gossip_mix_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "0") == "1"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0):
    """(B, S, H, D) x (B, S, Hkv, D) -> (B, S, H, D) (model layout)."""
    del q_offset  # kernel grid assumes aligned self-attention
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if _force_ref():
        out = ref.flash_attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention_fwd(
            qt, kt, vt, causal=causal, window=window, interpret=_interpret()
        )
    return jnp.swapaxes(out, 1, 2)


@jax.jit
def decode_attention(q, k_cache, v_cache, valid_len):
    """(B, H, D) vs (B, S, Hkv, D) cache -> (B, H, D)."""
    if _force_ref():
        return ref.decode_attention_ref(q, k_cache, v_cache, valid_len)
    return decode_attention_fwd(
        q, k_cache, v_cache, valid_len, interpret=_interpret()
    )


@jax.jit
def rmsnorm(x, scale):
    """(..., D) fused RMSNorm."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if _force_ref():
        out = ref.rmsnorm_ref(x2, scale)
    else:
        out = rmsnorm_fwd(x2, scale, interpret=_interpret())
    return out.reshape(shape)


@jax.jit
def gossip_mix(stacked, weights):
    """(N, L) neighbor params + (N,) weights -> (L,) aggregated params."""
    if _force_ref():
        return ref.gossip_mix_ref(stacked, weights)
    return gossip_mix_fwd(stacked, weights, interpret=_interpret())
