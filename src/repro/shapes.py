"""Assigned input-shape grid (import-light: no jax/model dependencies)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs whose attention cost is sub-quadratic in context (SSM state, linear
# recurrence, or sliding-window cache) — the only ones long_500k runs on.
SUB_QUADRATIC = {"mamba2-1.3b", "recurrentgemma-9b", "mixtral-8x7b"}


def shape_applicable(arch_id: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_id in SUB_QUADRATIC
    return True


def smoke_shape(kind: str) -> ShapeSpec:
    if kind == "train":
        return ShapeSpec("smoke_train", "train", 128, 2)
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", "prefill", 128, 2)
    return ShapeSpec("smoke_decode", "decode", 128, 2)
