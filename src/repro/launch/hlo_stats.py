"""Loop-aware accounting over compiled (post-SPMD) HLO text.

XLA's ``cost_analysis()`` visits each computation once, so a
``jax.lax.scan`` over L layers under-counts FLOPs/bytes/collectives by
~L×.  The compiled HLO text, however, carries exact trip counts
(``backend_config={"known_trip_count":{"n":"36"}}``), so we re-account:

  cost(entry) = Σ own ops + Σ fusion/call children + Σ trip(while) · cost(body)

Per-op accounting:
  - FLOPs: ``dot`` ops (2 · |out| · Π contracting dims) and ``convolution``
    (2 · |out| · kernel reduction) — matmuls dominate every model here;
    elementwise flops are ignored (validated ≲10% vs cost_analysis on
    unrolled modules).
  - HBM bytes: Σ (output + operand bytes) of top-level (non-fused) ops,
    skipping shape-only ops (tuple/parameter/bitcast/get-tuple-element/...).
  - Collectives: same ring-model link-byte accounting as hlo_analysis, now
    multiplied by enclosing loop trip counts.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
# Operands may carry their shape inline (`dot(f32[128,256]{1,0} %a, ...)`)
# or be bare names (`dot(%a, %b)`); capture both forms per operand.
_OPERAND_SPLIT_RE = re.compile(
    r"(?:([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+)?%?([\w.\-]+)"
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done", "bitcast-convert",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(txt: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    out_txt: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list
    shapes: dict            # op name -> output shape text


def _parse_computations(text: str) -> dict[str, "_Computation"]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        stripped = line.rstrip()
        if (
            stripped.endswith("{")
            and "->" in line
            and not line.startswith(" ")
            and "=" not in line.split("->")[0].split("(")[0]
        ):
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = _Computation(hdr.group(1), [], {})
                comps[cur.name] = cur
                continue
        if line.strip() == "}" or line.strip().startswith("}"):
            # keep cur until the next header; nested braces don't occur
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, out_txt, kind = m.group(1), m.group(2), m.group(3)
        cur.ops.append(_Op(name=name, kind=kind, out_txt=out_txt, line=line))
        cur.shapes[name] = out_txt
    return comps


def _operand_shape_dims(op: _Op, shapes: dict, pos: int) -> list[int]:
    """Dims of the ``pos``-th operand of ``op`` (inline shape or name lookup)."""
    # anchor on `kind(`: a bare `.index(kind)` can land on the op *name*
    # (`%dot.1 = ... dot(...)`) or inside a tiled layout's T(8,128)
    call = re.search(re.escape(op.kind) + r"\s*\(", op.line)
    if call is None:
        return []
    tail = op.line[call.start() :]
    lparen = tail.find("(")
    # balanced scan: tiled layouts ({1,0:T(8,128)}) nest parens inside the
    # operand list, so the first ')' is not necessarily the closing one
    depth, rparen = 0, -1
    for k in range(lparen, len(tail)):
        if tail[k] == "(":
            depth += 1
        elif tail[k] == ")":
            depth -= 1
            if depth == 0:
                rparen = k
                break
    if rparen < 0:
        return []
    operands = _OPERAND_SPLIT_RE.findall(tail[lparen + 1 : rparen])
    if pos >= len(operands):
        return []
    inline_shape, name = operands[pos]
    txt = inline_shape or shapes.get(name, "")
    dims = _shape_dims(txt)
    return dims[0][1] if dims else []


def _dot_flops(op: _Op, shapes: dict) -> float:
    out_elems = 1
    dims = _shape_dims(op.out_txt)
    if dims:
        for d in dims[0][1]:
            out_elems *= d
    contract = _LHS_CONTRACT_RE.search(op.line)
    k = 1
    lshape = _operand_shape_dims(op, shapes, 0)
    if lshape and contract:
        for ci in contract.group(1).split(","):
            if ci != "" and int(ci) < len(lshape):
                k *= lshape[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, shapes: dict) -> float:
    out_elems = 1
    dims = _shape_dims(op.out_txt)
    if dims:
        for d in dims[0][1]:
            out_elems *= d
    rshape = _operand_shape_dims(op, shapes, 1)
    k = 1
    for d in rshape[:-1]:
        k *= d
    return 2.0 * out_elems * k


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def _collective_link_bytes(op: _Op) -> tuple[str, float, float]:
    """(kind, payload_bytes, link_bytes) for one collective op."""
    kind = op.kind.replace("-start", "")
    out_bytes = _shape_bytes(op.out_txt)
    g = _group_size(op.line)
    if kind == "all-gather":
        payload, factor = out_bytes, (g - 1) / g
    elif kind == "reduce-scatter":
        payload, factor = out_bytes * g, (g - 1) / g
    elif kind == "all-reduce":
        payload, factor = out_bytes, 2 * (g - 1) / g
    elif kind == "all-to-all":
        payload, factor = out_bytes, (g - 1) / g
    else:  # collective-permute
        payload, factor = out_bytes, 1.0
    return kind, payload, payload * factor


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    link_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    coll_payload: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloStats":
        return HloStats(
            flops=self.flops * k,
            bytes=self.bytes * k,
            link_bytes=self.link_bytes * k,
            coll_counts={a: v * k for a, v in self.coll_counts.items()},
            coll_payload={a: v * k for a, v in self.coll_payload.items()},
        )

    def add(self, other: "HloStats"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.link_bytes += other.link_bytes
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        for k, v in other.coll_payload.items():
            self.coll_payload[k] = self.coll_payload.get(k, 0) + v

    def to_json(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "link_bytes": self.link_bytes,
            "coll_counts": self.coll_counts,
            "coll_payload": self.coll_payload,
        }


def analyze_hlo(text: str, entry: str | None = None) -> HloStats:
    comps = _parse_computations(text)
    if not comps:
        return HloStats()
    if entry is None:
        # entry computation: the one marked ENTRY, else the largest
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        entry = m.group(1) if m else max(comps, key=lambda c: len(comps[c].ops))

    memo: dict[str, HloStats] = {}

    def cost(cname: str, stack: tuple = ()) -> HloStats:
        if cname in memo:
            return memo[cname]
        if cname not in comps or cname in stack:
            return HloStats()
        comp = comps[cname]
        total = HloStats()
        for op in comp.ops:
            if op.kind == "while":
                trip_m = _TRIP_RE.search(op.line)
                trip = int(trip_m.group(1)) if trip_m else 1
                refs = dict(
                    re.findall(r"(body|condition)=%?([\w.\-]+)", op.line)
                )
                if "body" in refs:
                    total.add(cost(refs["body"], stack + (cname,)).scaled(trip))
                if "condition" in refs:
                    total.add(cost(refs["condition"], stack + (cname,)).scaled(trip))
                total.bytes += _shape_bytes(op.out_txt)
                continue
            if op.kind in ("fusion", "call", "conditional", "async-start",
                           "custom-call", "map", "reduce", "sort", "scatter",
                           "select-and-scatter", "reduce-window"):
                for sub in _CALLS_RE.findall(op.line):
                    total.add(cost(sub, stack + (cname,)))
            if op.kind == "dot":
                total.flops += _dot_flops(op, comp.shapes)
            elif op.kind == "convolution":
                total.flops += _conv_flops(op, comp.shapes)
            if op.kind in _COLLECTIVES:
                kind, payload, link = _collective_link_bytes(op)
                total.link_bytes += link
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                total.coll_payload[kind] = total.coll_payload.get(kind, 0) + payload
            # HBM traffic: top-level materialized ops only
            if op.kind not in _SKIP_BYTES_OPS and "fused_computation" not in cname:
                total.bytes += _shape_bytes(op.out_txt)
                tail = op.line[op.line.index(op.kind) :]
                for operand in _OPERAND_RE.findall(tail)[:8]:
                    if operand in comp.shapes:
                        total.bytes += _shape_bytes(comp.shapes[operand])
        memo[cname] = total
        return total

    # fused computations are reached via their fusion op's `calls=`; their
    # internal ops contribute flops but not HBM bytes (handled above).
    return cost(entry)
