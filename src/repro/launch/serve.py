"""Serving launcher: batched greedy decode against the KV-cache path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 8 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(param_dtype=jnp.bfloat16)
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cache = api.init_cache(args.batch, args.cache)
    step = jax.jit(lambda p, c, b: api.decode_step(p, c, b), donate_argnums=1)

    tokens = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.perf_counter()
    for pos in range(args.tokens):
        batch = {"pos": jnp.full((args.batch,), pos, jnp.int32)}
        if cfg.family == "vlm":
            batch["inputs_embeds"] = jnp.ones(
                (args.batch, 1, cfg.d_model), cfg.dtype
            )
        else:
            batch["tokens"] = tokens
        logits, cache = step(params, cache, batch)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
    dt = time.perf_counter() - t0
    print(f"{args.arch}: {args.batch} seqs x {args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
