"""Production training launcher.

On real hardware this runs under the production mesh; on this CPU
container use ``--smoke`` (reduced config, no mesh) — the full configs are
exercised via ``repro.launch.dryrun``.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 50 --seq 128 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager, config_hash
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.synthetic import LMStream
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.sharding import batch_shardings, make_rules
from repro.models import build_model
from repro.train.optim import AdamW, cosine_warmup_schedule
from repro.train.trainer import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=["none", "debug", "pod", "multipod"],
                    default="none")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit(
            f"{args.arch} needs a frontend stub batch; use dryrun/smoke tests"
        )
    api = build_model(cfg)

    rules = None
    if args.mesh != "none":
        mesh = (
            make_debug_mesh() if args.mesh == "debug"
            else make_production_mesh(multi_pod=args.mesh == "multipod")
        )
        rules = make_rules(cfg, mesh)
        print(f"mesh: {mesh}")

    opt = AdamW(
        learning_rate=cosine_warmup_schedule(args.lr, 20, args.steps),
    )
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"{args.arch}{' (smoke)' if args.smoke else ''}: "
          f"{n_params/1e6:.1f}M params, {args.steps} steps")

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if args.resume and mgr.latest_step() is not None:
            state, manifest = mgr.load(state)
            start = manifest["step"]
            print(f"resumed at step {start}")

    step_fn = jax.jit(make_train_step(api, opt, rules), donate_argnums=0)
    stream = LMStream(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    )
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}", flush=True)
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state,
                     metadata={"data_step": i + 1,
                               "config": config_hash(cfg)})
    print(f"done in {time.perf_counter()-t0:.0f}s; "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
