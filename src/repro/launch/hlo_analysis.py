"""Post-SPMD HLO analysis: collective bytes, per-device roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes of the *per-device* module;
collective traffic is not included, so we parse the compiled HLO text and
sum operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, converted to per-device link bytes with
ring-algorithm factors:

    all-reduce      2·(g-1)/g · bytes
    all-gather        (g-1)/g · full (gathered) bytes
    reduce-scatter    (g-1)/g · full (input) bytes
    all-to-all        (g-1)/g · bytes
    collective-permute          bytes

v5e hardware constants are the roofline denominators.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (≈ per direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_ARR_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _array_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict            # raw payload bytes per op kind (per device)
    link_bytes: float            # ring-model per-device link bytes (total)

    def to_json(self):
        return {
            "counts": dict(self.counts),
            "bytes_by_op": {k: float(v) for k, v in self.bytes_by_op.items()},
            "link_bytes": float(self.link_bytes),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: dict = defaultdict(int)
    raw: dict = defaultdict(float)
    link = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        out_bytes = _array_bytes(m.group("out"))
        g = _group_size(line)
        counts[op] += 1
        if op == "all-gather":
            payload = out_bytes                      # gathered result
            factor = (g - 1) / g
        elif op == "reduce-scatter":
            payload = out_bytes * g                  # pre-scatter input
            factor = (g - 1) / g
        elif op == "all-reduce":
            payload = out_bytes
            factor = 2 * (g - 1) / g
        elif op == "all-to-all":
            payload = out_bytes
            factor = (g - 1) / g
        else:                                        # collective-permute
            payload = out_bytes
            factor = 1.0
        raw[op] += payload
        link += payload * factor
    return CollectiveStats(counts=dict(counts), bytes_by_op=dict(raw),
                           link_bytes=link)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [groups, group_size]
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _SRC_TGT_RE.search(line)
    if m:
        return 2
    return 2


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    link_bytes_per_device: float,
) -> dict:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = link_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms
