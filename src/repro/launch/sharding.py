"""Sharding rules: FSDP x TP x SP layouts for every assigned architecture.

Layout summary:
  - batch dims shard over the data axes (('pod', 'data') multi-pod);
  - params: "heavy" dim FSDP-sharded over 'data' (ZeRO-3 — optimizer state
    follows for free), head/ffn/vocab dims tensor-parallel over 'model';
  - residual stream between blocks is sequence-sharded over 'model'
    (Megatron-style sequence parallelism) so saved activations stay small;
  - decode KV caches shard *sequence* over 'model' (kv_heads of most archs
    are 8 < 16) and run a distributed flash-softmax inside ``shard_map``;
  - whisper (12 heads, not 16-divisible): attention params replicated over
    'model', MLP/vocab still TP-sharded (``shard_heads=False``).

``MeshRules.constrain`` is the only entry point models use, so models stay
mesh-agnostic; ``state_shardings``/``batch_shardings`` produce the jit
in/out shardings for the launcher and the dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def _divisible(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


@dataclasses.dataclass
class MeshRules:
    """Activation-sharding constraints + distributed decode attention."""

    mesh: Mesh
    cfg: ModelConfig
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    sequence_parallel: bool = True
    seq_shard_decode: bool = True

    @property
    def dp(self) -> tuple[str, ...]:
        return tuple(a for a in self.mesh.axis_names if a != self.tp_axis)

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.dp:
            out *= self.mesh.shape[a]
        return out

    @property
    def shard_heads(self) -> bool:
        return _divisible(self.cfg.num_heads, self.tp_size)

    def _ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, kind: str):
        spec = self.spec_for(kind, x.shape)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, self._ns(spec))

    def spec_for(self, kind: str, shape: tuple[int, ...]) -> P | None:
        dp, tp = self.dp, self.tp_axis
        if kind == "hidden":                      # (B, S, D)
            if self.sequence_parallel and _divisible(shape[1], self.tp_size):
                return P(dp, tp, None)
            return P(dp, None, None)
        if kind == "hidden_decode":               # (B, 1, D)
            return P(dp, None, None)
        if kind == "heads":                       # (B, S, H, hd)
            if self.shard_heads and _divisible(shape[2], self.tp_size):
                return P(dp, None, tp, None)
            return P(dp, None, None, None)
        if kind == "kv_heads":                    # (B, S, Hkv, hd)
            if self.shard_heads and _divisible(shape[2], self.tp_size):
                return P(dp, None, tp, None)
            return P(dp, None, None, None)
        if kind == "ffn":                         # (B, S, F)
            if _divisible(shape[2], self.tp_size):
                return P(dp, None, tp)
            return P(dp, None, None)
        if kind == "logits":                      # (B, S, V)
            return P(dp, None, tp)
        if kind == "logits_decode":               # (B, V)
            return P(dp, tp)
        if kind == "cache":                       # (B, S, Hkv, hd) seq-sharded
            b_spec = dp if _divisible(shape[0], self.dp_size) else None
            if self.seq_shard_decode and _divisible(shape[1], self.tp_size):
                return P(b_spec, tp, None, None)
            return P(b_spec, None, None, None)
        if kind == "moe_tokens":                  # (B, E, C, D)
            e_spec = tp if _divisible(shape[1], self.tp_size) else None
            return P(dp if _divisible(shape[0], self.dp_size) else None,
                     e_spec, None, None)
        if kind == "moe_hidden":                  # (B, E, C, F)
            b_spec = dp if _divisible(shape[0], self.dp_size) else None
            if _divisible(shape[1], self.tp_size):
                return P(b_spec, tp, None, None)
            if _divisible(shape[3], self.tp_size):
                return P(b_spec, None, None, tp)
            return P(b_spec, None, None, None)
        return None

    # -- distributed decode attention -------------------------------------
    def sharded_decode_attention(self, q, k_cache, v_cache, valid):
        """q (B,H,hd) replicated over tp; caches seq-sharded over tp."""
        from repro.compat import shard_map

        from repro.models.attention import (
            decode_attention_local,
            decode_attention_seq_sharded,
        )

        if not _divisible(k_cache.shape[1], self.tp_size):
            return decode_attention_local(
                q, k_cache, v_cache, jnp.sum(valid, axis=1)
            )
        dp, tp = self.dp, self.tp_axis
        b = dp if _divisible(q.shape[0], self.dp_size) else None
        fn = functools.partial(decode_attention_seq_sharded, axis_name=tp)
        return shard_map(
            fn,
            mesh=self.mesh,
            in_specs=(
                P(b, None, None),
                P(b, tp, None, None),
                P(b, tp, None, None),
                P(b, tp),
            ),
            out_specs=P(b, None, None),
            check_vma=False,
        )(q, k_cache, v_cache, valid)


# ---------------------------------------------------------------------------
# Parameter partition specs (pattern-matched on tree paths)
# ---------------------------------------------------------------------------


def _param_spec(path: str, shape: tuple[int, ...], rules: MeshRules) -> P:
    """PartitionSpec for one parameter leaf, by name + shape."""
    cfg, tp, fsdp = rules.cfg, rules.tp_axis, rules.fsdp_axis
    tps = rules.tp_size
    fs = rules.mesh.shape[fsdp]
    # stacked-per-layer leaves carry a leading group dim; tree paths render
    # as "['params']['groups'][0]['attn']['wq']"
    stacked = "groups" in path or "_layers" in path
    nd = len(shape)
    core = shape[1:] if stacked else shape

    def build(spec_core: tuple) -> P:
        spec_core = tuple(spec_core) + (None,) * (len(core) - len(spec_core))
        return P(*(((None,) + spec_core) if stacked else spec_core))

    def ok(axis_len, size):
        return _divisible(axis_len, size)

    heads_shardable = rules.shard_heads
    kv_shardable = heads_shardable and _divisible(cfg.num_kv_heads, tps)

    if re.search(r"\bembed\b", path):
        return build((tp if ok(core[0], tps) else None,
                      fsdp if ok(core[1], fs) else None))
    if "lm_head" in path:
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ok(core[1], tps) else None))
    if re.search(r"w[qk]|wv", path) and nd - int(stacked) == 2:
        out_ok = ok(core[1], tps)
        if re.search(r"w[kv]", path):
            out_ok = out_ok and kv_shardable
        else:
            out_ok = out_ok and heads_shardable
        return build((fsdp if ok(core[0], fs) else None, tp if out_ok else None))
    if "wo" in path:
        return build((tp if (heads_shardable and ok(core[0], tps)) else None,
                      fsdp if ok(core[1], fs) else None))
    if re.search(r"w_gate|w_up", path) and len(core) == 3:   # MoE (E, D, F)
        if ok(core[0], tps):
            return build((tp, fsdp if ok(core[1], fs) else None, None))
        return build((None, fsdp if ok(core[1], fs) else None,
                      tp if ok(core[2], tps) else None))
    if "w_down" in path and len(core) == 3:                  # MoE (E, F, D)
        if ok(core[0], tps):
            return build((tp, None, fsdp if ok(core[2], fs) else None))
        return build((None, tp if ok(core[1], tps) else None,
                      fsdp if ok(core[2], fs) else None))
    if re.search(r"w_gate|w_up", path):
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ok(core[1], tps) else None))
    if "w_down" in path:
        return build((tp if ok(core[0], tps) else None,
                      fsdp if ok(core[1], fs) else None))
    if "router" in path:
        return build((fsdp if ok(core[0], fs) else None, None))
    # SSM: keep fused in_proj replicated on the out dim (mixed segments);
    # shard the heavy input dim FSDP-style.  out_proj shards d_inner over tp.
    if "in_proj" in path and len(core) == 2:
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ("in_proj_" in path and ok(core[1], tps)) else None))
    if "out_proj" in path:
        return build((tp if ok(core[0], tps) else None,
                      fsdp if ok(core[1], fs) else None))
    if re.search(r"gate_[ax]_w", path):
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ok(core[1], tps) else None))
    # 1-D scales / biases / conv kernels: replicated
    return build(())


def param_pspecs(params: Any, rules: MeshRules):
    """Pytree of PartitionSpec matching ``params``."""

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        return _param_spec(pstr, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(visit, params)


def param_shardings(params: Any, rules: MeshRules):
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), param_pspecs(params, rules)
    )


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(batch_specs: Any, rules: MeshRules):
    """Shard every batch input over the data axes on dim 0 (positions have
    a leading 3-axis for M-RoPE; enc_frames etc. follow the same rule).
    Batches smaller than the data axes (e.g. long_500k batch=1) replicate."""
    dp = rules.dp

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.ndim >= 2 and leaf.shape[0] == 3:      # (3, B, S) positions
            b_ok = _divisible(leaf.shape[1], rules.dp_size)
            return P(None, dp if b_ok else None, *(None,) * (leaf.ndim - 2))
        b_ok = _divisible(leaf.shape[0], rules.dp_size)
        return P(dp if b_ok else None, *(None,) * (leaf.ndim - 1))

    return jax.tree.map(
        lambda l: NamedSharding(rules.mesh, spec(l)), batch_specs
    )


def cache_shardings(cache_specs: Any, rules: MeshRules):
    """KV caches: (.., B, S, Hkv, hd) -> batch over dp, seq over tp when the
    leaf is 4-D+ and divisible; SSM/LRU states: batch over dp only."""
    dp, tp = rules.dp, rules.tp_axis
    tps = rules.tp_size

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        nd = leaf.ndim
        stacked = "groups" in pstr or leaf.ndim >= 5
        off = 1 if stacked else 0
        spec = [None] * nd
        if nd > off and _divisible(leaf.shape[off], rules.dp_size):
            spec[off] = dp
        is_kv = re.search(r"\['(k|v|enc_k|enc_v)'\]", pstr)
        if (
            rules.seq_shard_decode
            and is_kv
            and nd >= off + 2
            and _divisible(leaf.shape[off + 1], tps)
        ):
            spec[off + 1] = tp
        return NamedSharding(rules.mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(visit, cache_specs)


def make_rules(cfg: ModelConfig, mesh: Mesh, **kw) -> MeshRules:
    return MeshRules(mesh=mesh, cfg=cfg, **kw)
