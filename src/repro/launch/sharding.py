"""Sharding layers: the gossip-FL user mesh and the LM-model mesh rules.

Two consumers share this module:

**Gossip-FL user mesh** (:class:`UserMesh` / :class:`FLSharding`) — the
population-scale FL engine (``repro.fl.gossip``, DESIGN.md §13) shards the
stacked ``(N_T, …)`` user-replica pytree across a 1-D ``"users"`` device
mesh: the leading user axis is split into contiguous equal blocks (one per
shard, padded with inert users when ``N_T % shards != 0``), everything
else replicated.  The round body runs under ``repro.compat.shard_map`` and
the mixing matrix becomes block-local work plus a boundary-row halo
exchange.  On a host-only platform, fake devices stand in for a real mesh:
set ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` **before the
first jax import** (the pattern of ``launch/dryrun.py`` and the
``shard_fl_smoke`` CI target).

**LM model stack** (:class:`MeshRules` + the partition-spec helpers) —
FSDP x TP x SP layouts for the assigned LM architectures:
  - batch dims shard over the data axes (('pod', 'data') multi-pod);
  - params: "heavy" dim FSDP-sharded over 'data' (ZeRO-3 — optimizer state
    follows for free), head/ffn/vocab dims tensor-parallel over 'model';
  - residual stream between blocks is sequence-sharded over 'model'
    (Megatron-style sequence parallelism) so saved activations stay small;
  - decode KV caches shard *sequence* over 'model' and run a distributed
    flash-softmax inside ``shard_map``; whisper (12 heads, not
    16-divisible) keeps attention params replicated (``shard_heads=False``).

``MeshRules.constrain`` is the only entry point models use, so models stay
mesh-agnostic; ``param_shardings``/``batch_shardings``/``cache_shardings``
produce the jit in/out shardings for the launcher and the dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def _divisible(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


# ---------------------------------------------------------------------------
# Gossip-FL user-axis mesh (population-scale stacked engine, DESIGN.md §13)
# ---------------------------------------------------------------------------

USER_AXIS = "users"


@dataclasses.dataclass(frozen=True)
class UserMesh:
    """A 1-D device mesh over the FL user axis.

    Wraps a ``jax.sharding.Mesh`` with the single axis ``"users"``; the
    stacked gossip engine splits the ``(N_T, …)`` replica pytree into
    ``num_shards`` contiguous user blocks along it.  Build one with
    :meth:`build` (first ``num_shards`` visible devices) or wrap an
    existing 1-D mesh directly.
    """

    mesh: Mesh

    def __post_init__(self):
        if self.mesh.axis_names != (USER_AXIS,):
            raise ValueError(
                f"UserMesh needs a 1-D mesh with axis ({USER_AXIS!r},), "
                f"got axes {self.mesh.axis_names}"
            )

    @classmethod
    def build(cls, num_shards: int | None = None) -> "UserMesh":
        """Mesh over the first ``num_shards`` devices (all by default).

        Raises with a fake-device hint when the host exposes fewer
        devices than requested — the count must be forced via
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
        first jax import; it cannot be raised afterwards.
        """
        devices = jax.devices()
        if num_shards is None:
            num_shards = len(devices)
        if num_shards < 1:
            raise ValueError(f"need >= 1 shard, got {num_shards}")
        if num_shards > len(devices):
            raise ValueError(
                f"requested {num_shards} user shards but only "
                f"{len(devices)} device(s) are visible; on a host-only "
                f"platform set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={num_shards} "
                f"before the first jax import"
            )
        return cls(mesh=Mesh(np.asarray(devices[:num_shards]), (USER_AXIS,)))

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[USER_AXIS])

    def spec(self, *trailing) -> P:
        """PartitionSpec sharding the leading (user) axis."""
        return P(USER_AXIS, *trailing)

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec())

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def shard_map(
        self, fn: Callable, in_specs, out_specs, **kwargs
    ) -> Callable:
        """``repro.compat.shard_map`` over this mesh (jax-version shim)."""
        from repro.compat import shard_map

        kwargs.setdefault("check_vma", False)
        return shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            **kwargs,
        )


@dataclasses.dataclass(frozen=True)
class FLSharding:
    """Placement of one FL population on a :class:`UserMesh`.

    Knows the padded user count (``N_T`` rounded up to a multiple of the
    shard count), pads host arrays with inert users, and device_puts
    stacked pytrees with the leading axis sharded over ``"users"`` —
    the one entry point the sharded gossip backend uses, mirroring how
    ``MeshRules.constrain`` is the models' single entry point.
    """

    user_mesh: UserMesh
    num_users: int

    def __post_init__(self):
        if self.num_users < 1:
            raise ValueError(f"need >= 1 user, got {self.num_users}")

    @property
    def num_shards(self) -> int:
        return self.user_mesh.num_shards

    @property
    def block_size(self) -> int:
        """Users per shard (after padding)."""
        return -(-self.num_users // self.num_shards)

    @property
    def num_padded(self) -> int:
        """``N_T`` rounded up to a multiple of the shard count."""
        return self.block_size * self.num_shards

    @property
    def num_padding(self) -> int:
        return self.num_padded - self.num_users

    def shard_of(self) -> np.ndarray:
        """(num_padded,) shard id of each (padded) user slot."""
        return np.arange(self.num_padded) // self.block_size

    def valid_mask(self) -> np.ndarray:
        """(num_padded,) bool — True for real users, False for padding."""
        return np.arange(self.num_padded) < self.num_users

    def pad_users(self, arr: np.ndarray, fill=0) -> np.ndarray:
        """Pad a host array's leading user axis to ``num_padded``."""
        arr = np.asarray(arr)
        if arr.shape[0] != self.num_users:
            raise ValueError(
                f"leading axis {arr.shape[0]} != num_users {self.num_users}"
            )
        if not self.num_padding:
            return arr
        widths = [(0, self.num_padding)] + [(0, 0)] * (arr.ndim - 1)
        return np.pad(arr, widths, constant_values=fill)

    def shard(self, tree: Any) -> Any:
        """device_put a stacked pytree: leading user axis over the mesh,
        trailing axes replicated (leaves must already be padded)."""
        ns = NamedSharding(self.user_mesh.mesh, self.user_mesh.spec())

        def put(leaf):
            leaf = jnp.asarray(leaf)
            if leaf.shape[0] != self.num_padded:
                raise ValueError(
                    f"leaf leading axis {leaf.shape[0]} != padded user "
                    f"count {self.num_padded}; pad_users() first"
                )
            return jax.device_put(leaf, ns)

        return jax.tree.map(put, tree)

    def shard_blocks(self, tree: Any) -> Any:
        """device_put per-shard constant blocks: leading axis is the SHARD
        axis (length ``num_shards``), one block per shard."""
        ns = NamedSharding(self.user_mesh.mesh, self.user_mesh.spec())

        def put(leaf):
            leaf = jnp.asarray(leaf)
            if leaf.shape[0] != self.num_shards:
                raise ValueError(
                    f"leaf leading axis {leaf.shape[0]} != shard count "
                    f"{self.num_shards}"
                )
            return jax.device_put(leaf, ns)

        return jax.tree.map(put, tree)


def pad_edge_lists(
    rows: Sequence[np.ndarray], fill: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Stack ragged per-shard index lists into a dense (S, E_max) array.

    Returns ``(stacked, lengths)``; positions past each row's length hold
    ``fill`` — callers pair them with zero weights so padded entries are
    exact no-ops in the mix.
    """
    lengths = np.asarray([len(r) for r in rows], dtype=np.int64)
    e_max = int(lengths.max()) if len(rows) else 0
    out = np.full((len(rows), e_max), fill, dtype=np.int32)
    for s, r in enumerate(rows):
        out[s, : len(r)] = r
    return out, lengths


@dataclasses.dataclass
class MeshRules:
    """Activation-sharding constraints + distributed decode attention."""

    mesh: Mesh
    cfg: ModelConfig
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    sequence_parallel: bool = True
    seq_shard_decode: bool = True

    @property
    def dp(self) -> tuple[str, ...]:
        return tuple(a for a in self.mesh.axis_names if a != self.tp_axis)

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.dp:
            out *= self.mesh.shape[a]
        return out

    @property
    def shard_heads(self) -> bool:
        return _divisible(self.cfg.num_heads, self.tp_size)

    def _ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, kind: str):
        spec = self.spec_for(kind, x.shape)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, self._ns(spec))

    def spec_for(self, kind: str, shape: tuple[int, ...]) -> P | None:
        dp, tp = self.dp, self.tp_axis
        if kind == "hidden":                      # (B, S, D)
            if self.sequence_parallel and _divisible(shape[1], self.tp_size):
                return P(dp, tp, None)
            return P(dp, None, None)
        if kind == "hidden_decode":               # (B, 1, D)
            return P(dp, None, None)
        if kind == "heads":                       # (B, S, H, hd)
            if self.shard_heads and _divisible(shape[2], self.tp_size):
                return P(dp, None, tp, None)
            return P(dp, None, None, None)
        if kind == "kv_heads":                    # (B, S, Hkv, hd)
            if self.shard_heads and _divisible(shape[2], self.tp_size):
                return P(dp, None, tp, None)
            return P(dp, None, None, None)
        if kind == "ffn":                         # (B, S, F)
            if _divisible(shape[2], self.tp_size):
                return P(dp, None, tp)
            return P(dp, None, None)
        if kind == "logits":                      # (B, S, V)
            return P(dp, None, tp)
        if kind == "logits_decode":               # (B, V)
            return P(dp, tp)
        if kind == "cache":                       # (B, S, Hkv, hd) seq-sharded
            b_spec = dp if _divisible(shape[0], self.dp_size) else None
            if self.seq_shard_decode and _divisible(shape[1], self.tp_size):
                return P(b_spec, tp, None, None)
            return P(b_spec, None, None, None)
        if kind == "moe_tokens":                  # (B, E, C, D)
            e_spec = tp if _divisible(shape[1], self.tp_size) else None
            return P(dp if _divisible(shape[0], self.dp_size) else None,
                     e_spec, None, None)
        if kind == "moe_hidden":                  # (B, E, C, F)
            b_spec = dp if _divisible(shape[0], self.dp_size) else None
            if _divisible(shape[1], self.tp_size):
                return P(b_spec, tp, None, None)
            if _divisible(shape[3], self.tp_size):
                return P(b_spec, None, None, tp)
            return P(b_spec, None, None, None)
        return None

    # -- distributed decode attention -------------------------------------
    def sharded_decode_attention(self, q, k_cache, v_cache, valid):
        """q (B,H,hd) replicated over tp; caches seq-sharded over tp."""
        from repro.compat import shard_map

        from repro.models.attention import (
            decode_attention_local,
            decode_attention_seq_sharded,
        )

        if not _divisible(k_cache.shape[1], self.tp_size):
            return decode_attention_local(
                q, k_cache, v_cache, jnp.sum(valid, axis=1)
            )
        dp, tp = self.dp, self.tp_axis
        b = dp if _divisible(q.shape[0], self.dp_size) else None
        fn = functools.partial(decode_attention_seq_sharded, axis_name=tp)
        return shard_map(
            fn,
            mesh=self.mesh,
            in_specs=(
                P(b, None, None),
                P(b, tp, None, None),
                P(b, tp, None, None),
                P(b, tp),
            ),
            out_specs=P(b, None, None),
            check_vma=False,
        )(q, k_cache, v_cache, valid)


# ---------------------------------------------------------------------------
# Parameter partition specs (pattern-matched on tree paths)
# ---------------------------------------------------------------------------


def _param_spec(path: str, shape: tuple[int, ...], rules: MeshRules) -> P:
    """PartitionSpec for one parameter leaf, by name + shape."""
    cfg, tp, fsdp = rules.cfg, rules.tp_axis, rules.fsdp_axis
    tps = rules.tp_size
    fs = rules.mesh.shape[fsdp]
    # stacked-per-layer leaves carry a leading group dim; tree paths render
    # as "['params']['groups'][0]['attn']['wq']"
    stacked = "groups" in path or "_layers" in path
    nd = len(shape)
    core = shape[1:] if stacked else shape

    def build(spec_core: tuple) -> P:
        spec_core = tuple(spec_core) + (None,) * (len(core) - len(spec_core))
        return P(*(((None,) + spec_core) if stacked else spec_core))

    def ok(axis_len, size):
        return _divisible(axis_len, size)

    heads_shardable = rules.shard_heads
    kv_shardable = heads_shardable and _divisible(cfg.num_kv_heads, tps)

    if re.search(r"\bembed\b", path):
        return build((tp if ok(core[0], tps) else None,
                      fsdp if ok(core[1], fs) else None))
    if "lm_head" in path:
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ok(core[1], tps) else None))
    if re.search(r"w[qk]|wv", path) and nd - int(stacked) == 2:
        out_ok = ok(core[1], tps)
        if re.search(r"w[kv]", path):
            out_ok = out_ok and kv_shardable
        else:
            out_ok = out_ok and heads_shardable
        return build((fsdp if ok(core[0], fs) else None, tp if out_ok else None))
    if "wo" in path:
        return build((tp if (heads_shardable and ok(core[0], tps)) else None,
                      fsdp if ok(core[1], fs) else None))
    if re.search(r"w_gate|w_up", path) and len(core) == 3:   # MoE (E, D, F)
        if ok(core[0], tps):
            return build((tp, fsdp if ok(core[1], fs) else None, None))
        return build((None, fsdp if ok(core[1], fs) else None,
                      tp if ok(core[2], tps) else None))
    if "w_down" in path and len(core) == 3:                  # MoE (E, F, D)
        if ok(core[0], tps):
            return build((tp, None, fsdp if ok(core[2], fs) else None))
        return build((None, tp if ok(core[1], tps) else None,
                      fsdp if ok(core[2], fs) else None))
    if re.search(r"w_gate|w_up", path):
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ok(core[1], tps) else None))
    if "w_down" in path:
        return build((tp if ok(core[0], tps) else None,
                      fsdp if ok(core[1], fs) else None))
    if "router" in path:
        return build((fsdp if ok(core[0], fs) else None, None))
    # SSM: keep fused in_proj replicated on the out dim (mixed segments);
    # shard the heavy input dim FSDP-style.  out_proj shards d_inner over tp.
    if "in_proj" in path and len(core) == 2:
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ("in_proj_" in path and ok(core[1], tps)) else None))
    if "out_proj" in path:
        return build((tp if ok(core[0], tps) else None,
                      fsdp if ok(core[1], fs) else None))
    if re.search(r"gate_[ax]_w", path):
        return build((fsdp if ok(core[0], fs) else None,
                      tp if ok(core[1], tps) else None))
    # 1-D scales / biases / conv kernels: replicated
    return build(())


def param_pspecs(params: Any, rules: MeshRules):
    """Pytree of PartitionSpec matching ``params``."""

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        return _param_spec(pstr, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(visit, params)


def param_shardings(params: Any, rules: MeshRules):
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), param_pspecs(params, rules)
    )


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(batch_specs: Any, rules: MeshRules):
    """Shard every batch input over the data axes on dim 0 (positions have
    a leading 3-axis for M-RoPE; enc_frames etc. follow the same rule).
    Batches smaller than the data axes (e.g. long_500k batch=1) replicate."""
    dp = rules.dp

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.ndim >= 2 and leaf.shape[0] == 3:      # (3, B, S) positions
            b_ok = _divisible(leaf.shape[1], rules.dp_size)
            return P(None, dp if b_ok else None, *(None,) * (leaf.ndim - 2))
        b_ok = _divisible(leaf.shape[0], rules.dp_size)
        return P(dp if b_ok else None, *(None,) * (leaf.ndim - 1))

    return jax.tree.map(
        lambda l: NamedSharding(rules.mesh, spec(l)), batch_specs
    )


def cache_shardings(cache_specs: Any, rules: MeshRules):
    """KV caches: (.., B, S, Hkv, hd) -> batch over dp, seq over tp when the
    leaf is 4-D+ and divisible; SSM/LRU states: batch over dp only."""
    dp, tp = rules.dp, rules.tp_axis
    tps = rules.tp_size

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        nd = leaf.ndim
        stacked = "groups" in pstr or leaf.ndim >= 5
        off = 1 if stacked else 0
        spec = [None] * nd
        if nd > off and _divisible(leaf.shape[off], rules.dp_size):
            spec[off] = dp
        is_kv = re.search(r"\['(k|v|enc_k|enc_v)'\]", pstr)
        if (
            rules.seq_shard_decode
            and is_kv
            and nd >= off + 2
            and _divisible(leaf.shape[off + 1], tps)
        ):
            spec[off + 1] = tp
        return NamedSharding(rules.mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(visit, cache_specs)


def make_rules(cfg: ModelConfig, mesh: Mesh, **kw) -> MeshRules:
    return MeshRules(mesh=mesh, cfg=cfg, **kw)
