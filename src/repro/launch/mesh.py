"""Production meshes for the multi-pod dry-run.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 topology).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes that shard the batch (everything except the tensor axis)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_summary(mesh: jax.sharding.Mesh) -> str:
    return "x".join(
        f"{name}={size}" for name, size in zip(mesh.axis_names, mesh.devices.shape)
    )
