"""Elastic runtime: fleet churn and stragglers trigger SDP re-scheduling.

The paper's scheduler runs once; at production scale machines fail, slow
down, leave, and COME BACK, so we keep (G_task, G_compute) live:

  - ``on_failure(machine)`` removes the machine and re-solves; unless the
    failure is ``permanent``, the machine's speed is stashed so
    ``on_recovery(machine)`` can later restore it under its ORIGINAL
    label — fail → rejoin → fail sequences of one machine compose, and a
    fail → rejoin round trip restores the pre-failure fleet exactly;
  - ``on_arrival(machine, speed, delays_to)`` grows the fleet with a
    genuinely new machine (explicit speed and delay rows); called
    without stats for a stashed label it delegates to ``on_recovery``;
  - ``on_delay_update(C)`` / ``on_delay_updates([C...])`` refresh the
    delay matrix (network drift, link outages) and re-schedule when the
    candidate beats the incumbent by ``reschedule_threshold``;
  - ``observe_round(times)`` EMA-updates machine speeds from measured
    per-machine round times and re-schedules on the same threshold;
  - every SDP re-solve warm-starts from the previous solver iterate.
    Beyond the structure-keyed cache in ``core.scheduler`` (which cannot
    tell two fleets of the same SIZE apart), the scheduler keeps its own
    fleet-composition-keyed cache: when a churn trace returns to a
    previously-seen set of live machines, the solve resumes from that
    exact composition's iterate.  The cache is LRU-bounded
    (``warm_cache_max``) and evicts compositions that can no longer
    recur (a machine departed permanently) — across a long churn trace
    it would otherwise grow with every fleet change.

Degraded mode: each solve runs under an optional wall-clock budget
(``solve_timeout``) and iteration budget (``solver_max_iters``) with
retry-once-then-fallback semantics — a failed attempt (solver exception,
non-finite bottleneck, overrun budget, or — with ``require_converged`` —
an unconverged SDP) is retried once from a cold start, and a second
failure degrades to the combinatorial ``fallback`` method (e.g.
``"heft"``) instead of wedging the trace.  ``fallback_count`` and
``history`` record every activation.

This is the scheduling part of fault tolerance; state recovery is
``repro.ckpt`` (checkpoint/restore around the failure).
"""

from __future__ import annotations

import bisect
import dataclasses
import time

import numpy as np

from repro.core.bqp import bottleneck_time
from repro.core.graphs import ComputeGraph, TaskGraph
from repro.core.scheduler import (
    METHODS,
    Schedule,
    clear_warm_start,
    get_warm_start,
    schedule,
    schedule_batch,
    seed_warm_start,
)
from repro.core.sdp import SDPOptions

_SDP_FAMILY = ("sdp", "sdp_naive", "sdp_ls")


@dataclasses.dataclass
class ElasticScheduler:
    task_graph: TaskGraph
    compute_graph: ComputeGraph
    method: str = "sdp"
    seed: int = 0
    reschedule_threshold: float = 0.10   # fractional bottleneck improvement
    ema_alpha: float = 0.3
    speed_clamp: float = 10.0            # max implied-speed ratio per round
    warm_start: bool = True              # reuse SDP iterates across re-solves
    # -- degraded mode ------------------------------------------------------
    # Method to degrade to when a solve fails twice (None: raise instead).
    fallback: str | None = None
    # Wall-clock budget per solve attempt; an overrun counts as a failure
    # (checked after the attempt — pair with solver_max_iters to bound the
    # attempt itself).
    solve_timeout: float | None = None
    # Iteration budget applied to every SDP solve (overrides the max_iters
    # of schedule_kwargs' sdp_options).
    solver_max_iters: int | None = None
    # Treat an unconverged SDP solve as a failure.
    require_converged: bool = False
    # -- composition warm-start cache ---------------------------------------
    warm_cache_max: int = 16
    # Extra kwargs forwarded to every ``schedule()`` call (num_samples,
    # sdp_options, ...) — the scenario engine sizes re-solves with these.
    schedule_kwargs: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.fallback is not None:
            if self.fallback not in METHODS:
                raise ValueError(
                    f"unknown fallback method {self.fallback!r}; "
                    f"choose from {METHODS}"
                )
            if self.fallback == self.method:
                raise ValueError(
                    "fallback must differ from the primary method — "
                    "retrying the same solver is not a degraded mode"
                )
        self.machine_ids = list(range(self.compute_graph.num_machines))
        # Universe-label delay matrix: rows/cols of absent machines are kept
        # current through delay updates so recoveries rejoin under the
        # delays of the moment, not of their departure.
        self._C_full = self.compute_graph.C.copy()
        self._stash: dict[int, float] = {}        # failed label -> speed
        self._comp_states: dict[frozenset, dict] = {}   # LRU, insertion-ordered
        self.fallback_count = 0
        self.history: list[dict] = []
        self.current: Schedule = self._solve_guarded()
        self.history.insert(
            0, {"event": "init", "round": None,
                "bottleneck": self.current.bottleneck,
                "machines": len(self.machine_ids)}
        )

    # -- solving -------------------------------------------------------------
    def _schedule_kwargs(self) -> dict:
        kw = dict(self.schedule_kwargs)
        if self.solver_max_iters is not None and self.method in _SDP_FAMILY:
            opts = kw.get("sdp_options") or SDPOptions()
            kw["sdp_options"] = dataclasses.replace(
                opts, max_iters=int(self.solver_max_iters)
            )
        return kw

    def _schedule(self) -> Schedule:
        return schedule(
            self.task_graph, self.compute_graph, self.method, seed=self.seed,
            warm_start=self.warm_start, **self._schedule_kwargs(),
        )

    def _remember_state(self, comp: frozenset) -> None:
        if not (self.warm_start and self.method in _SDP_FAMILY):
            return
        state = get_warm_start(self.task_graph, self.compute_graph)
        if state is None:
            return
        self._comp_states.pop(comp, None)
        self._comp_states[comp] = state                 # LRU: newest at end
        while len(self._comp_states) > self.warm_cache_max:
            self._comp_states.pop(next(iter(self._comp_states)))

    def _evict_unreachable(self) -> None:
        """Drop cached compositions that can no longer recur: a composition
        is reachable iff every machine in it is live or recoverable, so a
        permanent departure invalidates every composition containing it."""
        universe = set(self.machine_ids) | set(self._stash)
        for comp in [c for c in self._comp_states if not c <= universe]:
            del self._comp_states[comp]

    def _solve_guarded(self, round: int | None = None) -> Schedule:
        """One schedule consult under the degraded-mode contract.

        Attempt 1 warm-starts (restoring this exact fleet composition's
        cached iterate when one exists); on failure, attempt 2 retries
        once from a cold start (a poisoned warm state is a common cause);
        a second failure activates ``fallback`` — or raises when no
        fallback is configured.  Failure = solver exception, non-finite
        bottleneck, ``solve_timeout`` overrun, or (``require_converged``)
        an unconverged SDP.
        """
        comp = frozenset(self.machine_ids)
        reason = "unknown"
        for attempt in (0, 1):
            if attempt == 0 and self.warm_start:
                state = self._comp_states.get(comp)
                if state is not None:
                    seed_warm_start(self.task_graph, self.compute_graph, state)
            else:
                clear_warm_start(self.task_graph, self.compute_graph)
            t0 = time.perf_counter()
            try:
                s = self._schedule()
            except (ValueError, ArithmeticError, np.linalg.LinAlgError) as exc:
                reason = f"raise:{type(exc).__name__}"
                continue
            elapsed = time.perf_counter() - t0
            if not np.isfinite(s.bottleneck):
                reason = "non-finite bottleneck"
                continue
            if self.solve_timeout is not None and elapsed > self.solve_timeout:
                reason = f"timeout:{elapsed:.3f}s>{self.solve_timeout:.3f}s"
                continue
            if (
                self.require_converged
                and self.method in _SDP_FAMILY
                and not s.info.get("sdp_converged", True)
            ):
                reason = "unconverged"
                continue
            self._remember_state(comp)
            return s
        if self.fallback is None:
            raise RuntimeError(
                f"scheduler {self.method!r} failed twice ({reason}) and no "
                f"fallback method is configured"
            )
        self.fallback_count += 1
        s = schedule(
            self.task_graph, self.compute_graph, self.fallback, seed=self.seed
        )
        self.history.append(
            {"event": f"fallback:{self.fallback}", "round": round,
             "reason": reason, "bottleneck": s.bottleneck,
             "machines": len(self.machine_ids)}
        )
        return s

    # -- failures ------------------------------------------------------------
    def on_failure(
        self, machine_id: int, *, permanent: bool = False,
        round: int | None = None,
    ) -> Schedule:
        """Remove a machine and re-solve.

        Non-permanent failures stash the machine's current speed so
        ``on_recovery`` can restore it later; ``permanent=True`` drops the
        stash and evicts every cached warm-start composition containing
        the label (those fleets can no longer recur).  Failing a machine
        that is not in the live fleet raises — a silently-absorbed double
        failure would desynchronize the fleet from the caller's view.
        """
        if machine_id not in self.machine_ids:
            raise ValueError(
                f"machine {machine_id} is not in the live fleet "
                f"{self.machine_ids} — double failure, or a label from "
                f"another fleet?"
            )
        if len(self.machine_ids) == 1:
            raise ValueError("failing the last machine would empty the fleet")
        local = self.machine_ids.index(machine_id)
        keep = [j for j in range(len(self.machine_ids)) if j != local]
        cg = self.compute_graph
        if permanent:
            self._stash.pop(machine_id, None)
        else:
            self._stash[machine_id] = float(cg.e[local])
        self.compute_graph = ComputeGraph(
            e=cg.e[keep], C=cg.C[np.ix_(keep, keep)]
        )
        self.machine_ids.pop(local)
        if permanent:
            self._evict_unreachable()
        self.current = self._solve_guarded(round)
        self.history.append(
            {
                "event": f"fail:{machine_id}",
                "round": round,
                "bottleneck": self.current.bottleneck,
                "machines": len(self.machine_ids),
            }
        )
        return self.current

    # -- arrivals and recoveries ---------------------------------------------
    def _admit(self, machine_id: int, speed: float, event: str,
               round: int | None) -> Schedule:
        """Insert a universe label into the live fleet and re-solve.

        Delay rows come from ``_C_full`` — the CURRENT network state, so a
        recovery during delay drift rejoins under the drifted delays.
        """
        pos = bisect.bisect_left(self.machine_ids, machine_id)
        self.machine_ids.insert(pos, machine_id)
        e_new = np.insert(self.compute_graph.e, pos, speed)
        C_new = self._C_full[np.ix_(self.machine_ids, self.machine_ids)]
        self.compute_graph = ComputeGraph(e=e_new, C=C_new)
        self.current = self._solve_guarded(round)
        self.history.append(
            {
                "event": f"{event}:{machine_id}",
                "round": round,
                "bottleneck": self.current.bottleneck,
                "machines": len(self.machine_ids),
            }
        )
        return self.current

    def on_recovery(
        self, machine_id: int, *, round: int | None = None
    ) -> Schedule:
        """Re-admit a failed machine under its ORIGINAL label.

        The speed is the one stashed at failure time; the delay rows are
        taken from the current universe delay matrix (which delay updates
        keep fresh while the machine is away).  With no intervening drift
        a fail → recover round trip restores the pre-failure compute
        graph exactly.
        """
        if machine_id in self.machine_ids:
            raise ValueError(
                f"machine {machine_id} is already in the live fleet"
            )
        if machine_id not in self._stash:
            raise ValueError(
                f"machine {machine_id} has no stashed state (never failed, "
                f"or failed permanently) — use on_arrival with explicit "
                f"speed and delays"
            )
        speed = self._stash.pop(machine_id)
        return self._admit(machine_id, speed, "recover", round)

    def on_arrival(
        self,
        machine_id: int,
        speed: float | None = None,
        delays_to: np.ndarray | None = None,
        delays_from: np.ndarray | None = None,
        *,
        round: int | None = None,
    ) -> Schedule:
        """Grow the fleet with an arriving machine and re-solve.

        For a label with stashed state and no explicit ``speed`` this is
        ``on_recovery``.  Otherwise the machine is new: ``speed`` (> 0)
        and ``delays_to`` (its delay TO every existing universe machine,
        indexed by original label) are required; ``delays_from`` (the
        reverse direction) defaults to ``delays_to`` (symmetric link).
        New labels must extend the universe densely (``machine_id`` ==
        current universe size) or re-use a departed label.
        """
        if machine_id in self.machine_ids:
            raise ValueError(
                f"machine {machine_id} is already in the live fleet"
            )
        if speed is None:
            if machine_id in self._stash:
                return self.on_recovery(machine_id, round=round)
            raise ValueError(
                f"machine {machine_id} has no stashed state — arriving "
                f"machines need explicit speed and delays_to"
            )
        if speed <= 0:
            raise ValueError("arriving machine speed must be > 0")
        if delays_to is None:
            raise ValueError("arriving machines need delays_to")
        U = self._C_full.shape[0]
        if machine_id > U:
            raise ValueError(
                f"machine labels must be dense: universe has {U} labels, "
                f"got {machine_id}"
            )
        d_to = np.asarray(delays_to, dtype=np.float64)
        d_from = (
            d_to if delays_from is None
            else np.asarray(delays_from, dtype=np.float64)
        )
        width = U if machine_id == U else U - 1
        for name, d in (("delays_to", d_to), ("delays_from", d_from)):
            if d.shape != (width,):
                raise ValueError(
                    f"{name} must have one entry per other universe machine "
                    f"({width},), got {d.shape}"
                )
            if np.any(d < 0):
                raise ValueError(f"{name} must be non-negative")
        if machine_id == U:
            grown = np.zeros((U + 1, U + 1))
            grown[:U, :U] = self._C_full
            grown[U, :U] = d_to
            grown[:U, U] = d_from
            self._C_full = grown
        else:
            others = [j for j in range(U) if j != machine_id]
            self._C_full[machine_id, others] = d_to
            self._C_full[others, machine_id] = d_from
            self._C_full[machine_id, machine_id] = 0.0
            self._stash.pop(machine_id, None)   # explicit stats supersede
        return self._admit(machine_id, float(speed), "join", round)

    # -- delay drift ---------------------------------------------------------
    def _ingest_delays(self, C_new: np.ndarray) -> np.ndarray:
        """Fold a delay update into the universe matrix; return the live C.

        Accepts the full universe matrix (original labels) or the live
        fleet's subset (sorted label order) — the subset case keeps
        absent machines' rows at their last known values.
        """
        C_new = np.asarray(C_new, dtype=np.float64)
        k = len(self.machine_ids)
        if C_new.shape == self._C_full.shape:
            self._C_full = C_new.copy()
            return C_new[np.ix_(self.machine_ids, self.machine_ids)]
        if C_new.shape == (k, k):
            self._C_full[np.ix_(self.machine_ids, self.machine_ids)] = C_new
            return C_new
        raise ValueError(
            f"delay matrix shape {C_new.shape} matches neither the universe "
            f"{self._C_full.shape} nor the live fleet ({k},{k})"
        )

    def on_delay_update(
        self, C_new: np.ndarray, *, round: int | None = None
    ) -> Schedule | None:
        """Refresh the delay matrix (network drift) and maybe re-schedule.

        The scenario engine's ``drift`` delay model calls this every
        ``reschedule_every`` rounds with the current ``DelayDrift.at(r)``;
        the churn path calls it with the engine's live effective delays
        after link-outage transitions.  ``C_new`` may be indexed by the
        ORIGINAL machine labels (subset to the live fleet here, so drift
        and failure events compose) or already subset.  Without fleet
        changes the warm-start fingerprint still hits and the SDP re-solve
        resumes from the previous iterate.  The new schedule is adopted
        only when it beats the current assignment's bottleneck *under the
        new delays* by ``reschedule_threshold`` (migration is not free).
        """
        cg = self.compute_graph
        self.compute_graph = ComputeGraph(e=cg.e, C=self._ingest_delays(C_new))
        current_t = bottleneck_time(
            self.task_graph, self.compute_graph, self.current.assignment
        )
        candidate = self._solve_guarded(round)
        if candidate.bottleneck < current_t * (1 - self.reschedule_threshold):
            self.current = candidate
            self.history.append(
                {"event": "migrate", "round": round,
                 "bottleneck": candidate.bottleneck}
            )
            return candidate
        self.history.append(
            {"event": "keep", "round": round, "bottleneck": current_t}
        )
        return None

    def on_delay_updates(
        self, C_list, *, round: int | None = None
    ) -> Schedule | None:
        """Batched drift re-solve across accumulated delay updates.

        When delay telemetry arrives faster than the re-schedule cadence,
        the backlog of matrices is solved as ONE batched SDP dispatch
        (``schedule_batch``): every lane shares the task graph and machine
        speeds and differs only in C, so the stacked solve amortizes
        per-dispatch overhead and the batched warm-start cache restores
        every lane from the previous consult's iterates at once.  The LAST
        matrix is adopted as the current network state; each lane's
        candidate assignment is re-evaluated under it and the best one is
        adopted iff it beats the current assignment's bottleneck by
        ``reschedule_threshold`` — an assignment tuned for an intermediate
        delay snapshot can still win under the latest one.  (The batched
        path has no degraded mode; single-consult churn re-solves go
        through ``on_delay_update``.)
        """
        C_list = list(C_list)
        if not C_list:
            return None
        if len(C_list) == 1:
            return self.on_delay_update(C_list[0], round=round)
        cg = self.compute_graph
        k = len(self.machine_ids)
        mats = []
        for C_new in C_list[:-1]:
            C_new = np.asarray(C_new, dtype=np.float64)
            if C_new.shape != (k, k):
                C_new = C_new[np.ix_(self.machine_ids, self.machine_ids)]
            mats.append(C_new)
        mats.append(self._ingest_delays(C_list[-1]))
        self.compute_graph = ComputeGraph(e=cg.e, C=mats[-1])
        candidates = schedule_batch(
            [self.task_graph] * len(mats),
            [ComputeGraph(e=cg.e, C=C) for C in mats],
            self.method,
            seed=self.seed,
            warm_start=self.warm_start,
            **self.schedule_kwargs,
        )
        current_t = bottleneck_time(
            self.task_graph, self.compute_graph, self.current.assignment
        )
        times = [
            bottleneck_time(self.task_graph, self.compute_graph, c.assignment)
            for c in candidates
        ]
        best = int(np.argmin(times))
        if times[best] < current_t * (1 - self.reschedule_threshold):
            self.current = dataclasses.replace(
                candidates[best], bottleneck=float(times[best])
            )
            self.history.append(
                {"event": "migrate", "round": round,
                 "bottleneck": self.current.bottleneck}
            )
            return self.current
        self.history.append(
            {"event": "keep", "round": round, "bottleneck": current_t}
        )
        return None

    # -- stragglers ----------------------------------------------------------
    def observe_round(
        self,
        per_machine_time: np.ndarray,
        *,
        round: int | None = None,
        work_fraction: np.ndarray | None = None,
    ) -> Schedule | None:
        """Update speed estimates from measured times; maybe re-schedule.

        ``per_machine_time[j]`` is the measured busy time of machine j this
        round (e.g. a ``repro.sim`` ``SimResult.busy`` row subset to the
        live fleet); implied speed = assigned work / time, clamped to
        within ``speed_clamp``× of the current estimate — a loaded machine
        reporting a time of ~0 would otherwise imply a near-infinite speed
        and poison the EMA with one spike no later round can wash out.

        ``work_fraction[j]`` (optional, default 1) is the fraction of its
        assigned work machine j actually completed this round — the
        completeness dimension of ``scenarios.profiles.churn_trace``.  A
        partial-work round finishes early NOT because the machine is fast,
        so implied speed uses the completed work ``loads · work_fraction``;
        without it the shortened busy time reads as a speedup and poisons
        the EMA.
        """
        cg = self.compute_graph
        per_machine_time = np.asarray(per_machine_time, dtype=np.float64)
        loads = np.zeros(cg.num_machines)
        np.add.at(loads, self.current.assignment, self.task_graph.p)
        if work_fraction is not None:
            work_fraction = np.asarray(work_fraction, dtype=np.float64)
            if work_fraction.shape != loads.shape:
                raise ValueError(
                    f"work_fraction shape {work_fraction.shape} != "
                    f"{loads.shape} (one completed-work fraction per live "
                    f"machine)"
                )
            if np.any(work_fraction <= 0) or np.any(work_fraction > 1):
                raise ValueError("work_fraction entries must be in (0, 1]")
            loads = loads * work_fraction
        implied = np.where(
            per_machine_time > 0, loads / np.maximum(per_machine_time, 1e-12), cg.e
        )
        implied = np.where(loads > 0, implied, cg.e)   # idle machines: keep
        implied = np.clip(
            implied, cg.e / self.speed_clamp, cg.e * self.speed_clamp
        )
        new_e = (1 - self.ema_alpha) * cg.e + self.ema_alpha * implied
        self.compute_graph = ComputeGraph(e=new_e, C=cg.C)

        current_t = bottleneck_time(
            self.task_graph, self.compute_graph, self.current.assignment
        )
        candidate = self._solve_guarded(round)
        if candidate.bottleneck < current_t * (1 - self.reschedule_threshold):
            self.current = candidate
            self.history.append(
                {"event": "migrate", "round": round,
                 "bottleneck": candidate.bottleneck}
            )
            return candidate
        self.history.append(
            {"event": "keep", "round": round, "bottleneck": current_t}
        )
        return None
