"""Elastic runtime: failures and stragglers trigger SDP re-scheduling.

The paper's scheduler runs once; at production scale machines fail and
slow down, so we keep (G_task, G_compute) live:

  - ``on_failure(machine)`` removes the machine and re-solves;
  - ``observe_round(times)`` EMA-updates machine speeds from measured
    per-machine round times and re-solves when the predicted bottleneck
    improves by more than ``reschedule_threshold``;
  - every SDP re-solve warm-starts from the previous solver iterate
    (``schedule(..., warm_start=True)``): speed updates keep the problem
    structure, so the cached (Y, t, s) state is a near-optimal starting
    point and the solve converges in a fraction of the cold iterations.
    A failure changes the dimensions (new fingerprint) and cold-starts.

This is the scheduling part of fault tolerance; state recovery is
``repro.ckpt`` (checkpoint/restore around the failure).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bqp import bottleneck_time
from repro.core.graphs import ComputeGraph, TaskGraph
from repro.core.scheduler import Schedule, schedule, schedule_batch


@dataclasses.dataclass
class ElasticScheduler:
    task_graph: TaskGraph
    compute_graph: ComputeGraph
    method: str = "sdp"
    seed: int = 0
    reschedule_threshold: float = 0.10   # fractional bottleneck improvement
    ema_alpha: float = 0.3
    speed_clamp: float = 10.0            # max implied-speed ratio per round
    warm_start: bool = True              # reuse SDP iterates across re-solves
    # Extra kwargs forwarded to every ``schedule()`` call (num_samples,
    # sdp_options, ...) — the scenario engine sizes re-solves with these.
    schedule_kwargs: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.machine_ids = list(range(self.compute_graph.num_machines))
        self.current: Schedule = self._schedule()
        self.history: list[dict] = [
            {"event": "init", "bottleneck": self.current.bottleneck}
        ]

    def _schedule(self) -> Schedule:
        return schedule(
            self.task_graph, self.compute_graph, self.method, seed=self.seed,
            warm_start=self.warm_start, **self.schedule_kwargs,
        )

    # -- failures ----------------------------------------------------------
    def on_failure(self, machine_id: int) -> Schedule:
        local = self.machine_ids.index(machine_id)
        keep = [j for j in range(len(self.machine_ids)) if j != local]
        cg = self.compute_graph
        self.compute_graph = ComputeGraph(
            e=cg.e[keep], C=cg.C[np.ix_(keep, keep)]
        )
        self.machine_ids.pop(local)
        self.current = self._schedule()
        self.history.append(
            {
                "event": f"fail:{machine_id}",
                "bottleneck": self.current.bottleneck,
                "machines": len(self.machine_ids),
            }
        )
        return self.current

    # -- delay drift ---------------------------------------------------------
    def on_delay_update(self, C_new: np.ndarray) -> Schedule | None:
        """Refresh the delay matrix (network drift) and maybe re-schedule.

        The scenario engine's ``drift`` delay model calls this every
        ``reschedule_every`` rounds with the current ``DelayDrift.at(r)``.
        ``C_new`` is indexed by the ORIGINAL machine labels; after failures
        it is subset to the surviving ``machine_ids`` here, so drift and
        failure events compose.  Without failures the dimensions are
        unchanged, the warm-start fingerprint still hits, and the SDP
        re-solve resumes from the previous iterate.  The new schedule is
        adopted only when it beats the current assignment's bottleneck
        *under the new delays* by ``reschedule_threshold`` (migration is
        not free).
        """
        cg = self.compute_graph
        C_new = np.asarray(C_new, dtype=np.float64)
        if C_new.shape[0] != cg.num_machines:
            C_new = C_new[np.ix_(self.machine_ids, self.machine_ids)]
        self.compute_graph = ComputeGraph(e=cg.e, C=C_new)
        current_t = bottleneck_time(
            self.task_graph, self.compute_graph, self.current.assignment
        )
        candidate = self._schedule()
        if candidate.bottleneck < current_t * (1 - self.reschedule_threshold):
            self.current = candidate
            self.history.append(
                {"event": "migrate", "bottleneck": candidate.bottleneck}
            )
            return candidate
        self.history.append({"event": "keep", "bottleneck": current_t})
        return None

    def on_delay_updates(self, C_list) -> Schedule | None:
        """Batched drift re-solve across accumulated delay updates.

        When delay telemetry arrives faster than the re-schedule cadence,
        the backlog of matrices is solved as ONE batched SDP dispatch
        (``schedule_batch``): every lane shares the task graph and machine
        speeds and differs only in C, so the stacked solve amortizes
        per-dispatch overhead and the batched warm-start cache restores
        every lane from the previous consult's iterates at once.  The LAST
        matrix is adopted as the current network state; each lane's
        candidate assignment is re-evaluated under it and the best one is
        adopted iff it beats the current assignment's bottleneck by
        ``reschedule_threshold`` — an assignment tuned for an intermediate
        delay snapshot can still win under the latest one.
        """
        C_list = list(C_list)
        if not C_list:
            return None
        if len(C_list) == 1:
            return self.on_delay_update(C_list[0])
        cg = self.compute_graph
        mats = []
        for C_new in C_list:
            C_new = np.asarray(C_new, dtype=np.float64)
            if C_new.shape[0] != cg.num_machines:
                C_new = C_new[np.ix_(self.machine_ids, self.machine_ids)]
            mats.append(C_new)
        self.compute_graph = ComputeGraph(e=cg.e, C=mats[-1])
        candidates = schedule_batch(
            [self.task_graph] * len(mats),
            [ComputeGraph(e=cg.e, C=C) for C in mats],
            self.method,
            seed=self.seed,
            warm_start=self.warm_start,
            **self.schedule_kwargs,
        )
        current_t = bottleneck_time(
            self.task_graph, self.compute_graph, self.current.assignment
        )
        times = [
            bottleneck_time(self.task_graph, self.compute_graph, c.assignment)
            for c in candidates
        ]
        best = int(np.argmin(times))
        if times[best] < current_t * (1 - self.reschedule_threshold):
            self.current = dataclasses.replace(
                candidates[best], bottleneck=float(times[best])
            )
            self.history.append(
                {"event": "migrate", "bottleneck": self.current.bottleneck}
            )
            return self.current
        self.history.append({"event": "keep", "bottleneck": current_t})
        return None

    # -- stragglers ----------------------------------------------------------
    def observe_round(self, per_machine_time: np.ndarray) -> Schedule | None:
        """Update speed estimates from measured times; maybe re-schedule.

        ``per_machine_time[j]`` is the measured busy time of machine j this
        round (e.g. a ``repro.sim`` ``SimResult.busy`` row); implied
        speed = assigned work / time, clamped to within ``speed_clamp``×
        of the current estimate — a loaded machine reporting a time of
        ~0 would otherwise imply a near-infinite speed and poison the
        EMA with one spike no later round can wash out.
        """
        cg = self.compute_graph
        per_machine_time = np.asarray(per_machine_time, dtype=np.float64)
        loads = np.zeros(cg.num_machines)
        np.add.at(loads, self.current.assignment, self.task_graph.p)
        implied = np.where(
            per_machine_time > 0, loads / np.maximum(per_machine_time, 1e-12), cg.e
        )
        implied = np.where(loads > 0, implied, cg.e)   # idle machines: keep
        implied = np.clip(
            implied, cg.e / self.speed_clamp, cg.e * self.speed_clamp
        )
        new_e = (1 - self.ema_alpha) * cg.e + self.ema_alpha * implied
        self.compute_graph = ComputeGraph(e=new_e, C=cg.C)

        current_t = bottleneck_time(
            self.task_graph, self.compute_graph, self.current.assignment
        )
        candidate = self._schedule()
        if candidate.bottleneck < current_t * (1 - self.reschedule_threshold):
            self.current = candidate
            self.history.append(
                {"event": "migrate", "bottleneck": candidate.bottleneck}
            )
            return candidate
        self.history.append({"event": "keep", "bottleneck": current_t})
        return None
