import os
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count="
    f"{os.environ.get('REPRO_DRYRUN_DEVICES', '512')} "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: the SPMD
partitioner must accept our shardings, the compiled memory budget must fit,
and the collective schedule is extracted for the roofline report
(EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import collective_stats, roofline_terms
from repro.launch.mesh import make_production_mesh, mesh_summary
from repro.launch.sharding import (
    batch_shardings,
    cache_shardings,
    make_rules,
    param_shardings,
)
from repro.models import build_model
from repro.models.flops import model_flops, param_counts
from repro.shapes import SHAPES, shape_applicable
from repro.train.optim import AdamW, AdamWState
from repro.train.trainer import (
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from jax.sharding import NamedSharding, PartitionSpec as P


def _attach(shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes,
        shardings,
    )


def state_shardings(state_shapes, rules):
    ps = param_shardings(state_shapes["params"], rules)
    mirror = lambda tree: jax.tree.map(lambda _, s: s, tree, ps)
    opt = state_shapes["opt"]
    return {
        "params": ps,
        "opt": AdamWState(
            step=NamedSharding(rules.mesh, P()),
            m=mirror(opt.m),
            v=mirror(opt.v),
        ),
    }


def lower_cell(arch: str, shape_name: str, mesh, *, sharding_overrides=None,
               cfg_overrides=None):
    """Build and lower the step function for one cell. Returns (lowered, meta)."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    if spec.kind in ("prefill", "decode"):
        # serving checkpoints are bf16 (no fp32 master / optimizer state)
        cfg = cfg.replace(param_dtype=jnp.bfloat16)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    api = build_model(cfg)
    rules = make_rules(cfg, mesh, **(sharding_overrides or {}))

    batch_shapes = api.input_specs(spec)
    batch_sds = _attach(batch_shapes, batch_shardings(batch_shapes, rules))

    if spec.kind == "train":
        opt = AdamW(learning_rate=1e-4)
        step = make_train_step(api, opt, rules)
        state_shapes = jax.eval_shape(
            lambda: init_train_state(api, opt, jax.random.PRNGKey(0))
        )
        state_sds = _attach(state_shapes, state_shardings(state_shapes, rules))
        lowered = jax.jit(step, donate_argnums=0).lower(state_sds, batch_sds)
    elif spec.kind == "prefill":
        step = make_prefill_step(api, rules)
        p_shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
        p_sds = _attach(p_shapes, param_shardings(p_shapes, rules))
        lowered = jax.jit(step).lower(p_sds, batch_sds)
    else:  # decode
        step = make_decode_step(api, rules)
        p_shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
        p_sds = _attach(p_shapes, param_shardings(p_shapes, rules))
        cache_shapes = api.cache_specs(spec)
        cache_sds = _attach(cache_shapes, cache_shardings(cache_shapes, rules))
        lowered = jax.jit(step, donate_argnums=1).lower(p_sds, cache_sds, batch_sds)
    return lowered, {"cfg": cfg, "spec": spec}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, sharding_overrides=None, tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_summary(mesh),
        "devices": int(n_dev),
        "status": "ok",
    }
    t0 = time.perf_counter()
    try:
        lowered, meta = lower_cell(
            arch, shape_name, mesh, sharding_overrides=sharding_overrides
        )
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        rec["hlo_flops_per_device"] = flops
        rec["hlo_bytes_per_device"] = bytes_acc

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
            args = rec.get("argument_size_in_bytes", 0)
            alias = rec.get("alias_size_in_bytes", 0)
            out = rec.get("output_size_in_bytes", 0)
            tmp = rec.get("temp_size_in_bytes", 0)
            rec["hbm_peak_bytes_per_device"] = args + tmp + max(out - alias, 0)

        text = compiled.as_text()
        # loop-aware accounting (scan bodies × trip counts) — the raw
        # cost_analysis numbers above undercount scanned layers by ~L×.
        from repro.launch.hlo_stats import analyze_hlo

        stats = analyze_hlo(text)
        rec["la_flops_per_device"] = stats.flops
        rec["la_bytes_per_device"] = stats.bytes
        rec["la_link_bytes_per_device"] = stats.link_bytes
        rec["collectives"] = {
            "counts": stats.coll_counts,
            "payload_bytes": stats.coll_payload,
            "link_bytes": stats.link_bytes,
        }

        mf = model_flops(meta["cfg"], meta["spec"])
        rec["model_flops_total"] = mf["model_flops"]
        rec["params_total"] = mf["total"]
        rec["params_active"] = mf["active"]
        rec["model_flops_per_device"] = mf["model_flops"] / n_dev
        rec["useful_flops_ratio"] = (
            rec["model_flops_per_device"] / stats.flops if stats.flops else 0.0
        )
        rec.update(
            roofline_terms(stats.flops, stats.bytes, stats.link_bytes)
        )
    except Exception as e:  # noqa: BLE001 — record failures as data
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        jax.clear_caches()

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        mesh_tag = "multipod" if multi_pod else "pod"
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_tag}{suffix}.json"
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multipod]
    for a in archs:
        for s in shapes:
            if not shape_applicable(a, s):
                continue
            for mp in meshes:
                cells.append((a, s, mp))

    n_fail = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, mp, args.out)
        if rec["status"] == "ok":
            print(
                f"OK   {a:20s} {s:12s} {rec['mesh']:28s} "
                f"lower {rec['lower_s']:6.1f}s compile {rec['compile_s']:6.1f}s "
                f"flops/dev {rec['hlo_flops_per_device']:.3e} "
                f"coll {rec['collectives']['link_bytes']:.3e}B "
                f"dominant={rec['dominant']}",
                flush=True,
            )
        else:
            n_fail += 1
            print(f"FAIL {a:20s} {s:12s} multipod={mp}: {rec['error']}", flush=True)
    print(f"\n{len(cells) - n_fail}/{len(cells)} cells OK")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
