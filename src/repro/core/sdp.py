"""From-scratch SDP solver for the relaxed bottleneck-time problem (Eq. 20).

No off-the-shelf SDP solver (cvxpy/scs/mosek) exists in this environment, so
we implement Douglas-Rachford splitting on the conic form

    min  t
    s.t. <Q̃_e, Y> - 4 t + s_e = 0      for every constraint edge e   (s_e >= 0)
         <A_i, Y> = 0                   i = 1..N_T
         diag(Y) = 1
         Y ⪰ 0                          Y ∈ S^{n+1},  n = N_T · N_K

over the stacked variable  v = (vec(Y), t, s):

    f(v) = t + indicator{L v = b}       prox_f = affine projection of v - ρ·c
    g(v) = indicator{Y ⪰ 0, s >= 0}     prox_g = eigenvalue clip + relu

Two constraint-operator representations (DESIGN.md §4):

  - ``BQPData`` (dense oracle): rows assembled from the materialized Q̃
    stacks, Gram inverse precomputed — the reference path for small n.
  - ``FactoredBQP`` (matrix-free): CSR rows and the Gram matrix are
    assembled directly from the Kronecker factors via
    ``FactoredBQP.constraint_row`` — no dense L and no (|E|, n, n) stack
    ever exists.  For large row counts the Gram solve uses a Cholesky
    factorization instead of an explicit inverse.

Everything runs float64 on host (numpy / LAPACK): the scheduler is
control-plane code that runs once per topology change, off the training
critical path (see DESIGN.md §4).

The solver is generic enough to be exercised on MAXCUT-style test SDPs.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.bqp import BQPData, FactoredBQP


@dataclasses.dataclass(frozen=True)
class SDPOptions:
    max_iters: int = 6000
    tol: float = 1e-6
    rho: float = 3.0            # prox step on the linear objective
    over_relax: float = 1.7     # DR relaxation parameter λ ∈ (0, 2)
    check_every: int = 25
    verbose: bool = False
    # §Perf (beyond-paper): the constraint rows are ~97% sparse (each Q̃_e
    # touches one task's column block + one machine block + borders), so the
    # affine projection runs on a CSR representation.  False reproduces the
    # dense paper-faithful baseline (same iterates, slower matvec); ignored
    # for ``FactoredBQP`` inputs, which are always CSR.
    sparse: bool = True
    # Above this many constraint rows the Gram solve switches from a
    # precomputed inverse to a Cholesky factorization (better conditioned,
    # and the triangular solves cost the same O(m²) as the inverse matvec).
    cholesky_above: int = 768


@dataclasses.dataclass
class SDPSolution:
    """Result of the SDP relaxation.

    Y: (n+2, n+1+...)  -- actually (n+1, n+1) PSD matrix with unit diagonal
       (the Gram matrix of the homogenized ±1 variables, last index = u).
    t: epigraph value in *normalized* units; multiply by ``q_scale`` for the
       paper's units.  ``lower_bound`` is already rescaled.
    """

    Y: np.ndarray
    t: float
    lower_bound: float
    iterations: int
    residual: float
    converged: bool
    solve_seconds: float
    # representation / memory diagnostics (constraint rows m, CSR nnz,
    # bytes of the largest tensor the solver materialized)
    stats: dict = dataclasses.field(default_factory=dict)


def _flatten_sym(mat: np.ndarray) -> np.ndarray:
    return mat.reshape(-1)


class _CSR:
    """Minimal CSR matrix for the constraint operator (numpy only)."""

    def __init__(self, rows: list[np.ndarray], dim: int):
        idx_list, val_list, ptr = [], [], [0]
        for r in rows:
            nz = np.nonzero(r)[0]
            idx_list.append(nz)
            val_list.append(r[nz])
            ptr.append(ptr[-1] + nz.size)
        self.indices = np.concatenate(idx_list)
        self.values = np.concatenate(val_list)
        self.indptr = np.asarray(ptr)
        self.row_of = np.repeat(
            np.arange(len(rows)), np.diff(self.indptr)
        )
        self.shape = (len(rows), dim)

    def matvec(self, v: np.ndarray) -> np.ndarray:
        prod = self.values * v[self.indices]
        return np.bincount(self.row_of, weights=prod, minlength=self.shape[0])

    def rmatvec(self, y: np.ndarray) -> np.ndarray:
        return np.bincount(
            self.indices,
            weights=self.values * y[self.row_of],
            minlength=self.shape[1],
        )


class _AffineProjector:
    """Projection onto {v : L v = b} with L built once from the BQP data.

    Accepts either the dense ``BQPData`` oracle (rows taken from the
    materialized Q̃ stack) or the matrix-free ``FactoredBQP`` (CSR rows and
    the Gram matrix assembled straight from the Kronecker factors).
    """

    def __init__(
        self,
        bqp: BQPData | FactoredBQP,
        sparse: bool = True,
        cholesky_above: int = 768,
    ):
        n1 = bqp.n + 1                      # side of Y
        self.n1 = n1
        n_edges = len(bqp.edges)
        self.dim = n1 * n1 + 1 + n_edges    # Y_flat, t, s
        self.n_edges = n_edges
        self.m = n1 + bqp.n_tasks + n_edges
        self.stats: dict = {"constraint_rows": self.m}

        if isinstance(bqp, FactoredBQP):
            self._init_factored(bqp)
        else:
            self._init_dense(bqp, sparse)

        G = self._gram()
        G[np.diag_indices_from(G)] += 1e-10
        self._chol = self.m > cholesky_above
        if self._chol:
            # Cholesky path for large m: two O(m²) triangular solves per
            # iteration; avoids forming (and squaring the conditioning of)
            # an explicit inverse.
            import scipy.linalg as sla

            self._G_factor = sla.cho_factor(G, lower=True)
            self._cho_solve = sla.cho_solve
        else:
            # G is fixed across iterations: precompute G⁻¹ once (m ≤ a few
            # hundred) — a dense matvec per iteration instead of two LU
            # solves (§Perf: the solves were 40% of iteration time).
            self._Ginv = np.linalg.inv(G)
        self.stats["gram_bytes"] = int(G.nbytes)

    # -- construction -------------------------------------------------------
    def _init_dense(self, bqp: BQPData, sparse: bool):
        n1 = self.n1
        rows: list[np.ndarray] = []
        b: list[float] = []

        # diag(Y) = 1
        for d in range(n1):
            r = np.zeros(self.dim)
            r[d * n1 + d] = 1.0
            rows.append(r)
            b.append(1.0)

        # <A_i, Y> = 0
        for i in range(bqp.n_tasks):
            r = np.zeros(self.dim)
            r[: n1 * n1] = _flatten_sym(bqp.A[i])
            rows.append(r)
            b.append(0.0)

        # <Q̃_e, Y> - 4 t + s_e = 0   (normalized Q)
        qn = bqp.Q_tilde / bqp.q_scale
        for k in range(self.n_edges):
            r = np.zeros(self.dim)
            r[: n1 * n1] = _flatten_sym(qn[k])
            r[n1 * n1] = -4.0
            r[n1 * n1 + 1 + k] = 1.0
            rows.append(r)
            b.append(0.0)

        self.b = np.asarray(b)
        self._sparse = sparse
        L = np.stack(rows)                            # (m, dim)
        self._G = L @ L.T
        # rows list + stacked L coexist here: that transient is the dense
        # path's true build-time peak, recorded for the scaling benchmark.
        self.stats["build_peak_bytes"] = int(2 * L.nbytes)
        if sparse:
            self.L = _CSR(rows, self.dim)             # dense L is discarded
        else:
            self.L = L
        self.stats["representation"] = "dense"

    def _init_factored(self, fbqp: FactoredBQP):
        import scipy.sparse as sp

        n1, n = self.n1, fbqp.n
        n_t, n_k = fbqp.n_tasks, fbqp.n_machines
        cols: list[np.ndarray] = []
        vals: list[np.ndarray] = []
        rows: list[np.ndarray] = []
        b = np.zeros(self.m)

        # diag(Y) = 1
        diag_idx = np.arange(n1)
        rows.append(diag_idx)
        cols.append(diag_idx * n1 + diag_idx)
        vals.append(np.ones(n1))
        b[:n1] = 1.0

        # <A_i, Y> = 0: border h/2 on row & column of u, corner n_k - 2.
        # h selects (task i, machine κ) for all κ: vec indices i + κ·N_T.
        for i in range(n_t):
            h_idx = i + np.arange(n_k) * n_t
            r = n1 + i
            rows.append(np.full(2 * n_k + 1, r))
            cols.append(
                np.concatenate([h_idx * n1 + n, n * n1 + h_idx, [n * n1 + n]])
            )
            vals.append(
                np.concatenate([np.full(2 * n_k, 0.5), [n_k - 2.0]])
            )

        # <Q̃_e, Y> - 4 t + s_e = 0 with Q̃_e rows straight from the factors
        for k in range(self.n_edges):
            q_cols, q_vals = fbqp.constraint_row(k)
            r = n1 + n_t + k
            rows.append(np.full(q_cols.size + 2, r))
            cols.append(
                np.concatenate([q_cols, [n1 * n1, n1 * n1 + 1 + k]])
            )
            vals.append(
                np.concatenate([q_vals / fbqp.q_scale, [-4.0, 1.0]])
            )

        self.b = b
        self.L = sp.csr_matrix(
            (
                np.concatenate(vals),
                (np.concatenate(rows).astype(np.int64), np.concatenate(cols)),
            ),
            shape=(self.m, self.dim),
        )
        self._sparse = True
        self.stats["representation"] = "factored"
        self.stats["csr_nnz"] = int(self.L.nnz)

    def _gram(self) -> np.ndarray:
        if self.stats.get("representation") == "factored":
            return np.asarray((self.L @ self.L.T).todense())
        G = self._G
        del self._G
        return G

    # -- application --------------------------------------------------------
    def _solve_gram(self, resid: np.ndarray) -> np.ndarray:
        if self._chol:
            return self._cho_solve(self._G_factor, resid)
        return self._Ginv @ resid

    def __call__(self, v: np.ndarray) -> np.ndarray:
        if self.stats.get("representation") == "factored":
            resid = self.L @ v - self.b
            return v - self.L.T @ self._solve_gram(resid)
        if self._sparse:
            resid = self.L.matvec(v) - self.b
        else:
            resid = self.L @ v - self.b
        y = self._solve_gram(resid)
        if self._sparse:
            return v - self.L.rmatvec(y)
        return v - self.L.T @ y


def _project_cone(v: np.ndarray, n1: int, n_edges: int) -> np.ndarray:
    """Π onto {Y ⪰ 0 (symmetric), t free, s >= 0}."""
    out = v.copy()
    Y = v[: n1 * n1].reshape(n1, n1)
    Y = 0.5 * (Y + Y.T)
    w, V = np.linalg.eigh(Y)
    w = np.maximum(w, 0.0)
    out[: n1 * n1] = ((V * w) @ V.T).reshape(-1)
    if n_edges:
        s = v[n1 * n1 + 1 :]
        out[n1 * n1 + 1 :] = np.maximum(s, 0.0)
    return out


def solve_sdp(
    bqp: BQPData | FactoredBQP, options: SDPOptions | None = None
) -> SDPSolution:
    """Douglas-Rachford splitting for the relaxed problem (20)."""
    opts = options or SDPOptions()
    t0 = time.perf_counter()
    proj = _AffineProjector(
        bqp, sparse=opts.sparse, cholesky_above=opts.cholesky_above
    )
    n1, n_edges, dim = proj.n1, proj.n_edges, proj.dim

    c = np.zeros(dim)
    c[n1 * n1] = 1.0                     # objective: min t
    rho_c = opts.rho * c

    # Start from the identity Gram matrix (feasible for diag & PSD).
    w = np.zeros(dim)
    w[: n1 * n1] = np.eye(n1).reshape(-1)

    v_cone = w
    residual = np.inf
    it = 0
    lam = opts.over_relax
    for it in range(1, opts.max_iters + 1):
        v_aff = proj(w - rho_c)
        v_cone = _project_cone(2.0 * v_aff - w, n1, n_edges)
        step = v_cone - v_aff
        w = w + lam * step
        if it % opts.check_every == 0 or it == opts.max_iters:
            residual = float(np.linalg.norm(step) / np.sqrt(dim))
            if opts.verbose and it % (opts.check_every * 10) == 0:
                print(f"  sdp iter {it:5d} residual {residual:.3e}")
            if residual < opts.tol:
                break

    # Extract Y from the cone side (guaranteed PSD), renormalize diagonal to 1
    # so it is a valid Gaussian covariance for rounding.
    Y = v_cone[: n1 * n1].reshape(n1, n1)
    Y = 0.5 * (Y + Y.T)
    d = np.sqrt(np.clip(np.diag(Y), 1e-12, None))
    Y = Y / np.outer(d, d)
    np.fill_diagonal(Y, 1.0)

    t_val = float(v_cone[n1 * n1])
    # SDP bound on OPT (Eq. 24): at the optimum t* = max_e <Q̃_e, Y*>/4.
    # NOTE: a first-order iterate only *approximates* the SDP optimum, so
    # this is a certified lower bound only once ``converged`` — callers
    # (benchmarks) report it with the residual attached.
    if isinstance(bqp, FactoredBQP):
        t_from_y = float(np.max(bqp.inner(Y)) / bqp.q_scale / 4.0)
    else:
        qn = bqp.Q_tilde / bqp.q_scale
        t_from_y = float(np.max(np.einsum("eij,ij->e", qn, Y)) / 4.0)
    lower = max(t_val, 0.0) * bqp.q_scale

    stats = dict(proj.stats)
    # largest tensor the solve touched: the stacked DR variable dominates
    # for factored instances; the constraint-matrix build and the Q̃ stack
    # dominate dense ones.
    peak = max(
        3 * proj.dim * 8,
        stats.get("gram_bytes", 0),
        stats.get("build_peak_bytes", 0),
    )
    if isinstance(bqp, BQPData):
        peak = max(peak, int(bqp.Q_tilde.nbytes + bqp.Q.nbytes))
    stats["peak_tensor_bytes"] = int(peak)

    return SDPSolution(
        Y=Y,
        t=max(t_val, t_from_y),
        lower_bound=lower,
        iterations=it,
        residual=residual,
        converged=residual < opts.tol,
        solve_seconds=time.perf_counter() - t0,
        stats=stats,
    )
