"""From-scratch SDP solver for the relaxed bottleneck-time problem (Eq. 20).

No off-the-shelf SDP solver (cvxpy/scs/mosek) exists in this environment, so
we implement Douglas-Rachford splitting on the conic form

    min  t
    s.t. <Q̃_e, Y> - 4 t + s_e = 0      for every constraint edge e   (s_e >= 0)
         <A_i, Y> = 0                   i = 1..N_T
         diag(Y) = 1
         Y ⪰ 0                          Y ∈ S^{n+1},  n = N_T · N_K

over the stacked variable  v = (vec(Y), t, s):

    f(v) = t + indicator{L v = b}       prox_f = affine projection of v - ρ·c
    g(v) = indicator{Y ⪰ 0, s >= 0}     prox_g = eigenvalue clip + relu

Two constraint-operator representations (DESIGN.md §5):

  - ``BQPData`` (dense oracle): rows assembled from the materialized Q̃
    stacks, Gram inverse precomputed — the reference path for small n.
  - ``FactoredBQP`` (matrix-free): CSR rows and the Gram matrix are
    assembled directly from the Kronecker factors via
    ``FactoredBQP.constraint_row`` — no dense L and no (|E|, n, n) stack
    ever exists.  For large row counts the Gram solve uses a Cholesky
    factorization instead of an explicit inverse.

Two solver backends, selected by ``SDPOptions.backend`` (parallel to the
rounding backends in ``rounding.py``):

  - ``numpy`` — the float64 host reference: one eigendecomposition of Y per
    iteration, scipy/LAPACK affine projection.  Ground truth for tests.
  - ``jax``   — the device-resident hot loop: the whole DR iteration
    (CSR constraint matvecs via ``segment_sum``, Cholesky triangular solves
    for the affine projection, cone projection) runs inside ONE jitted
    ``lax.while_loop``; residuals are evaluated every ``check_every``
    iterations *on device*, so the loop never round-trips to host.  The
    O(n³) full eigendecomposition is replaced by a *partial-spectrum*
    projection: near convergence Y has only a handful of negative
    eigenvalues, so the solver tracks their subspace across iterations with
    a warm-started shifted subspace iteration (O(n²·k) per step) and clips
    only the negative Ritz pairs, falling back to a full ``eigh`` whenever
    the tracked subspace saturates (``num_neg == k``), its Ritz residual
    stalls above ``eig_tol``, or the periodic ``eig_refresh`` resync fires.
  - ``auto``  — ``jax`` once n+1 exceeds ``jax_above`` (where the device
    loop wins even on CPU backends) and JAX is importable, else ``numpy``.

``solve_sdp`` additionally accepts a ``warm_start`` payload — the
``SDPSolution.state`` of a previous solve.  Re-solves after incremental
topology changes (elastic re-scheduling, gossip-FL speed updates) resume
from the previous (Y, t, s) iterate instead of the identity and converge in
far fewer iterations; ``scheduler.schedule(..., warm_start=True)`` keeps a
fingerprint-keyed cache of these payloads.

The solver is generic enough to be exercised on MAXCUT-style test SDPs.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import numpy as np

from repro import compat
from repro.core.bqp import BQPData, FactoredBQP


@dataclasses.dataclass(frozen=True)
class SDPOptions:
    max_iters: int = 6000
    tol: float = 1e-6
    rho: float = 3.0            # prox step on the linear objective
    over_relax: float = 1.7     # DR relaxation parameter λ ∈ (0, 2)
    check_every: int = 25
    verbose: bool = False
    # §Perf (beyond-paper): the constraint rows are ~97% sparse (each Q̃_e
    # touches one task's column block + one machine block + borders), so the
    # affine projection runs on a CSR representation.  False reproduces the
    # dense paper-faithful baseline (same iterates, slower matvec); ignored
    # for ``FactoredBQP`` inputs, which are always CSR.
    sparse: bool = True
    # Above this many constraint rows the Gram solve switches from a
    # precomputed inverse to a Cholesky factorization (better conditioned,
    # and the triangular solves cost the same O(m²) as the inverse matvec).
    cholesky_above: int = 768
    # -- backend selection --------------------------------------------------
    # "numpy" (float64 host reference), "jax" (jitted device loop, float32),
    # or "auto": jax once n+1 > jax_above and JAX imports.
    backend: str = "auto"
    jax_above: int = 512
    # -- jax backend: partial-spectrum cone projection ----------------------
    # Size of the tracked negative-eigenspace basis (clamped to n+1); the
    # per-iteration cone projection costs O(n²·eig_k) instead of O(n³).
    eig_k: int = 16
    # Shifted subspace-iteration sweeps refining the tracked basis per DR
    # iteration (warm-started from the previous iteration's basis).
    eig_iters: int = 4
    # Ritz-residual threshold (relative to ‖Y‖_F) above which the tracked
    # subspace is declared stalled and the step falls back to a full eigh.
    eig_tol: float = 1e-3
    # Force a full-eigh resync every this many iterations (0 = only at the
    # first iteration); insurance against negative directions emerging
    # outside the tracked subspace.
    eig_refresh: int = 100
    # -- jax backend: Pallas fused-projection kernels (DESIGN.md §12) -------
    # "pallas" streams the dense iterate Y once per subspace sweep through
    # ``kernels.sdp_proj`` (fused matvec + Rayleigh-Ritz Gram + ‖Y‖², and a
    # fused rank-k clip update) — the memory-bound win recorded by
    # ``roofline.py::sdp_batch_profile``.  "jnp" keeps the plain-XLA cone
    # projection; "auto" picks pallas on TPU and jnp elsewhere (on CPU the
    # kernels run in interpret mode — exact but slow, tests only).
    kernel_backend: str = "auto"


@dataclasses.dataclass
class SDPSolution:
    """Result of the SDP relaxation.

    Y: (n+1, n+1) PSD matrix with unit diagonal — the Gram matrix of the
       homogenized ±1 variables (last row/column is the homogenization
       variable u).
    t: epigraph value in *normalized* units; multiply by ``q_scale`` for the
       paper's units.  ``lower_bound`` is already rescaled.
    bound_certified: ``lower_bound`` is the Eq. 24 certificate only when the
       solver converged; when False the recorded value is the *unconverged
       iterate's* objective and must not be reported as a bound (it can
       exceed the achieved bottleneck — see BENCH_scheduler_scaling.json
       history at n=1664).
    Y_device: jax backend only — the normalized Y resident on device
       (float32), handed to the fused rounding backend so the covariance
       never leaves device between solve and rounding.
    state: warm-start payload (raw DR iterate ``w`` over (vec(Y), t, s) and,
       for the jax backend, the tracked eigenbasis ``V``); pass it back via
       ``solve_sdp(..., warm_start=...)`` to resume after an incremental
       topology change.
    """

    Y: np.ndarray
    t: float
    lower_bound: float
    iterations: int
    residual: float
    converged: bool
    solve_seconds: float
    bound_certified: bool = False
    # representation / memory diagnostics (constraint rows m, CSR nnz,
    # bytes of the largest tensor the solver materialized, solver backend,
    # full-vs-partial eigendecomposition counts)
    stats: dict = dataclasses.field(default_factory=dict)
    Y_device: Any = None
    state: dict = dataclasses.field(default_factory=dict, repr=False)


def _flatten_sym(mat: np.ndarray) -> np.ndarray:
    return mat.reshape(-1)


class _CSR:
    """Minimal CSR matrix for the constraint operator (numpy only)."""

    def __init__(self, rows: list[np.ndarray], dim: int):
        idx_list, val_list, ptr = [], [], [0]
        for r in rows:
            nz = np.nonzero(r)[0]
            idx_list.append(nz)
            val_list.append(r[nz])
            ptr.append(ptr[-1] + nz.size)
        self.indices = np.concatenate(idx_list)
        self.values = np.concatenate(val_list)
        self.indptr = np.asarray(ptr)
        self.row_of = np.repeat(
            np.arange(len(rows)), np.diff(self.indptr)
        )
        self.shape = (len(rows), dim)

    def matvec(self, v: np.ndarray) -> np.ndarray:
        prod = self.values * v[self.indices]
        return np.bincount(self.row_of, weights=prod, minlength=self.shape[0])

    def rmatvec(self, y: np.ndarray) -> np.ndarray:
        return np.bincount(
            self.indices,
            weights=self.values * y[self.row_of],
            minlength=self.shape[1],
        )


class _AffineProjector:
    """Projection onto {v : L v = b} with L built once from the BQP data.

    Accepts either the dense ``BQPData`` oracle (rows taken from the
    materialized Q̃ stack) or the matrix-free ``FactoredBQP`` (CSR rows and
    the Gram matrix assembled straight from the Kronecker factors).

    With ``keep_gram=True`` the host-side solve machinery (inverse /
    cho_factor) is skipped and the regularized Gram matrix is retained so
    the jax backend can export a clean lower Cholesky factor plus the raw
    CSR triplets (``export_csr`` / ``cholesky_lower``) to device.
    """

    def __init__(
        self,
        bqp: BQPData | FactoredBQP,
        sparse: bool = True,
        cholesky_above: int = 768,
        keep_gram: bool = False,
    ):
        n1 = bqp.n + 1                      # side of Y
        self.n1 = n1
        n_edges = len(bqp.edges)
        self.dim = n1 * n1 + 1 + n_edges    # Y_flat, t, s
        self.n_edges = n_edges
        self.m = n1 + bqp.n_tasks + n_edges
        self.stats: dict = {"constraint_rows": self.m}

        if isinstance(bqp, FactoredBQP):
            self._init_factored(bqp)
        else:
            self._init_dense(bqp, sparse)

        G = self._gram()
        G[np.diag_indices_from(G)] += 1e-10
        self.stats["gram_bytes"] = int(G.nbytes)
        self._G_keep = G if keep_gram else None
        if keep_gram:
            self._chol = False
            return
        self._chol = self.m > cholesky_above
        if self._chol:
            # Cholesky path for large m: two O(m²) triangular solves per
            # iteration; avoids forming (and squaring the conditioning of)
            # an explicit inverse.
            import scipy.linalg as sla

            self._G_factor = sla.cho_factor(G, lower=True)
            self._cho_solve = sla.cho_solve
        else:
            # G is fixed across iterations: precompute G⁻¹ once (m ≤ a few
            # hundred) — a dense matvec per iteration instead of two LU
            # solves (§Perf: the solves were 40% of iteration time).
            self._Ginv = np.linalg.inv(G)

    # -- construction -------------------------------------------------------
    def _init_dense(self, bqp: BQPData, sparse: bool):
        n1 = self.n1
        rows: list[np.ndarray] = []
        b: list[float] = []

        # diag(Y) = 1
        for d in range(n1):
            r = np.zeros(self.dim)
            r[d * n1 + d] = 1.0
            rows.append(r)
            b.append(1.0)

        # <A_i, Y> = 0
        for i in range(bqp.n_tasks):
            r = np.zeros(self.dim)
            r[: n1 * n1] = _flatten_sym(bqp.A[i])
            rows.append(r)
            b.append(0.0)

        # <Q̃_e, Y> - 4 t + s_e = 0   (normalized Q)
        qn = bqp.Q_tilde / bqp.q_scale
        for k in range(self.n_edges):
            r = np.zeros(self.dim)
            r[: n1 * n1] = _flatten_sym(qn[k])
            r[n1 * n1] = -4.0
            r[n1 * n1 + 1 + k] = 1.0
            rows.append(r)
            b.append(0.0)

        self.b = np.asarray(b)
        self._sparse = sparse
        L = np.stack(rows)                            # (m, dim)
        self._G = L @ L.T
        # rows list + stacked L coexist here: that transient is the dense
        # path's true build-time peak, recorded for the scaling benchmark.
        self.stats["build_peak_bytes"] = int(2 * L.nbytes)
        if sparse:
            self.L = _CSR(rows, self.dim)             # dense L is discarded
        else:
            self.L = L
        self.stats["representation"] = "dense"

    def _init_factored(self, fbqp: FactoredBQP):
        import scipy.sparse as sp

        n1, n = self.n1, fbqp.n
        n_t, n_k = fbqp.n_tasks, fbqp.n_machines
        cols: list[np.ndarray] = []
        vals: list[np.ndarray] = []
        rows: list[np.ndarray] = []
        b = np.zeros(self.m)

        # diag(Y) = 1
        diag_idx = np.arange(n1)
        rows.append(diag_idx)
        cols.append(diag_idx * n1 + diag_idx)
        vals.append(np.ones(n1))
        b[:n1] = 1.0

        # <A_i, Y> = 0: border h/2 on row & column of u, corner n_k - 2.
        # h selects (task i, machine κ) for all κ: vec indices i + κ·N_T.
        for i in range(n_t):
            h_idx = i + np.arange(n_k) * n_t
            r = n1 + i
            rows.append(np.full(2 * n_k + 1, r))
            cols.append(
                np.concatenate([h_idx * n1 + n, n * n1 + h_idx, [n * n1 + n]])
            )
            vals.append(
                np.concatenate([np.full(2 * n_k, 0.5), [n_k - 2.0]])
            )

        # <Q̃_e, Y> - 4 t + s_e = 0 with Q̃_e rows straight from the factors
        for k in range(self.n_edges):
            q_cols, q_vals = fbqp.constraint_row(k)
            r = n1 + n_t + k
            rows.append(np.full(q_cols.size + 2, r))
            cols.append(
                np.concatenate([q_cols, [n1 * n1, n1 * n1 + 1 + k]])
            )
            vals.append(
                np.concatenate([q_vals / fbqp.q_scale, [-4.0, 1.0]])
            )

        self.b = b
        self.L = sp.csr_matrix(
            (
                np.concatenate(vals),
                (np.concatenate(rows).astype(np.int64), np.concatenate(cols)),
            ),
            shape=(self.m, self.dim),
        )
        self._sparse = True
        self.stats["representation"] = "factored"
        self.stats["csr_nnz"] = int(self.L.nnz)

    def _gram(self) -> np.ndarray:
        if self.stats.get("representation") == "factored":
            return np.asarray((self.L @ self.L.T).todense())
        G = self._G
        del self._G
        return G

    # -- device export ------------------------------------------------------
    def export_csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(rows, cols, vals, b) COO triplets of L for the device backend."""
        if self.stats.get("representation") == "factored":
            coo = self.L.tocoo()
            return coo.row, coo.col, coo.data, self.b
        if isinstance(self.L, _CSR):
            return self.L.row_of, self.L.indices, self.L.values, self.b
        rows, cols = np.nonzero(self.L)
        return rows, cols, self.L[rows, cols], self.b

    def cholesky_lower(self) -> np.ndarray:
        """Lower Cholesky factor of the (regularized) Gram matrix.

        Only available under ``keep_gram=True`` — computed once on host in
        float64, then shipped to device for the per-iteration triangular
        solves.
        """
        if self._G_keep is None:
            raise RuntimeError("construct _AffineProjector with keep_gram=True")
        return np.linalg.cholesky(self._G_keep)

    # -- application --------------------------------------------------------
    def _solve_gram(self, resid: np.ndarray) -> np.ndarray:
        if self._chol:
            return self._cho_solve(self._G_factor, resid)
        return self._Ginv @ resid

    def __call__(self, v: np.ndarray) -> np.ndarray:
        if self.stats.get("representation") == "factored":
            resid = self.L @ v - self.b
            return v - self.L.T @ self._solve_gram(resid)
        if self._sparse:
            resid = self.L.matvec(v) - self.b
        else:
            resid = self.L @ v - self.b
        y = self._solve_gram(resid)
        if self._sparse:
            return v - self.L.rmatvec(y)
        return v - self.L.T @ y


def _project_cone(v: np.ndarray, n1: int, n_edges: int) -> np.ndarray:
    """Π onto {Y ⪰ 0 (symmetric), t free, s >= 0}."""
    out = v.copy()
    Y = v[: n1 * n1].reshape(n1, n1)
    Y = 0.5 * (Y + Y.T)
    w, V = np.linalg.eigh(Y)
    w = np.maximum(w, 0.0)
    out[: n1 * n1] = ((V * w) @ V.T).reshape(-1)
    if n_edges:
        s = v[n1 * n1 + 1 :]
        out[n1 * n1 + 1 :] = np.maximum(s, 0.0)
    return out


def _identity_start(n1: int, dim: int) -> np.ndarray:
    """Cold-start DR state: identity Gram matrix (feasible for diag & PSD)."""
    w = np.zeros(dim)
    w[: n1 * n1] = np.eye(n1).reshape(-1)
    return w


def _warm_w(warm_start: dict | None, dim: int) -> np.ndarray | None:
    """Validated warm-start iterate; None when absent, shape-mismatched, or
    non-finite (a diverged solve must not poison subsequent re-solves)."""
    if not warm_start:
        return None
    w = warm_start.get("w")
    if w is None:
        return None
    w = np.asarray(w, dtype=np.float64)
    if w.shape != (dim,) or not np.all(np.isfinite(w)):
        return None
    return w


# ---------------------------------------------------------------------------
# numpy backend (float64 host reference)
# ---------------------------------------------------------------------------


def _solve_numpy(
    bqp, opts: SDPOptions, proj: _AffineProjector, warm_start: dict | None
):
    n1, n_edges, dim = proj.n1, proj.n_edges, proj.dim

    c = np.zeros(dim)
    c[n1 * n1] = 1.0                     # objective: min t
    rho_c = opts.rho * c

    w = _warm_w(warm_start, dim)
    warm = w is not None
    if w is None:
        w = _identity_start(n1, dim)

    v_cone = w
    residual = np.inf
    it = 0
    lam = opts.over_relax
    for it in range(1, opts.max_iters + 1):
        v_aff = proj(w - rho_c)
        v_cone = _project_cone(2.0 * v_aff - w, n1, n_edges)
        step = v_cone - v_aff
        w = w + lam * step
        if it % opts.check_every == 0 or it == opts.max_iters:
            residual = float(np.linalg.norm(step) / np.sqrt(dim))
            if opts.verbose and it % (opts.check_every * 10) == 0:
                print(f"  sdp iter {it:5d} residual {residual:.3e}")
            if residual < opts.tol:
                break

    stats = {"solver_backend": "numpy", "warm_started": warm}
    state = {"w": w.copy()}
    return v_cone, it, residual, stats, state, None


# ---------------------------------------------------------------------------
# jax backend (jitted device-resident loop, partial-spectrum projection)
# ---------------------------------------------------------------------------
#
# One jit per (shape, static-option) signature, cached below.  The whole
# Douglas-Rachford iteration lives inside a ``lax.while_loop`` whose body
# runs ``check_every`` steps through a ``lax.fori_loop`` and then evaluates
# the residual — so a full solve is a single device computation with no host
# round-trips.  Scalars (rho, λ, tolerances, max_iters) are traced array
# arguments, so retuning them does not recompile.
#
# Two constraint-operator kinds mirror the host representations:
#
#   - "csr":      generic L·v / Lᵀ·y via ``segment_sum`` over the COO
#                 triplets — works for any projector (dense oracle, duck-
#                 typed test SDPs).  XLA lowers the transpose product to a
#                 serial scatter-add, so this is the small-instance path.
#   - "factored": L·v and Lᵀ·y assembled *structurally* from the Kronecker
#                 factors (p, d, C, src, dst) — the device analogue of
#                 ``FactoredBQP.inner``/``constraint_row``.  Everything is
#                 dense einsum/outer-product passes over the (K, T, K, T)
#                 grid plus O(|E|)-sized ``segment_sum`` aggregations, so no
#                 million-element scatter ever runs.  This is what makes the
#                 n ≥ 1024 hot loop fast on CPU devices too.


def _make_device_ops(kind: str, operands, n1: int, n_tasks: int, n_machines: int):
    """Constraint-operator closures (matvec, rmatvec, b) for ONE instance.

    Shared by the single-instance jit and — per vmapped lane — the batched
    solver: the operand arrays may be traced, so one builder serves both
    paths.  ``kind`` selects the generic COO/``segment_sum`` form ("csr")
    or the structural Kronecker-factor form ("factored").
    """
    import jax.numpy as jnp

    from repro.compat import segment_sum

    idx_t = n1 * n1

    if kind == "csr":
        Lval, Lrow, Lcol, b = operands
        m = b.shape[0]

        def matvec(v):
            return segment_sum(Lval * v[Lcol], Lrow, num_segments=m)

        def rmatvec(y, dim):
            return segment_sum(Lval * y[Lrow], Lcol, num_segments=dim)

        return matvec, rmatvec, b

    # Device analogue of the host CSR built by ``_init_factored``: row
    # r of L dotted with v (matvec) and Σ_r y_r · row_r (rmatvec), both
    # in closed form from the Kronecker factors.  Row layout:
    # [diag (n1) | A (n_tasks) | Q̃/q_scale with -4t + s (|E|)].
    p, d, C, src, dst, qs = operands
    T, K = n_tasks, n_machines
    n = T * K
    n_e = src.shape[0]
    C1 = C @ jnp.ones(K, C.dtype)
    Ct1 = C.T @ jnp.ones(K, C.dtype)
    P = jnp.sum(p)
    corner = jnp.sum(d) * P + jnp.sum(C)
    dp = jnp.outer(d, p)                       # (K, T) grid of d⊗p
    eyeK = jnp.eye(K, dtype=C.dtype)
    b = jnp.concatenate(
        [jnp.ones(n1, C.dtype), jnp.zeros(T + n_e, C.dtype)]
    )

    def matvec(v):
        F = v[:idx_t].reshape(n1, n1)
        Fs = 0.5 * (F + F.T)
        r_diag = jnp.diagonal(F)
        f_row = F[:n, n].reshape(K, T)
        f_col = F[n, :n].reshape(K, T)
        r_a = 0.5 * (f_row.sum(0) + f_col.sum(0)) + (K - 2.0) * F[n, n]
        # <Q̃_e, sym(F)> — same contraction as FactoredBQP.inner
        Fxx = Fs[:n, :n].reshape(K, T, K, T)
        f = Fs[:n, n].reshape(K, T)
        comp = jnp.einsum("k,t,ktks->s", d, p, Fxx)
        blocks = Fxx.transpose(1, 3, 0, 2)[src, dst]       # (|E|, K, K)
        comm = jnp.einsum("ekl,kl->e", blocks, C)
        base = jnp.einsum("k,t,kt->", d, p, f)
        u_i = (C1 + P * d) @ f
        u_j = Ct1 @ f
        q1f = 0.5 * (base + u_i[src] + u_j[dst])
        inner = comp[src] + comm + 2.0 * q1f + corner * Fs[n, n]
        r_q = inner / qs - 4.0 * v[idx_t] + v[idx_t + 1 :]
        return jnp.concatenate([r_diag, r_a, r_q])

    def rmatvec(y, dim):
        y_d = y[:n1]
        y_a = y[n1 : n1 + T]
        y_raw = y[n1 + T :]
        y_q = y_raw / qs
        S = jnp.sum(y_q)
        c_i = segment_sum(y_q, src, num_segments=T)
        c_j = segment_sum(y_q, dst, num_segments=T)
        W2 = segment_sum(y_q, src * T + dst, num_segments=T * T)
        W2 = W2.reshape(T, T)
        # X-X block: Σ_e y_e · sym(D ⊗ (p δ_iᵀ) + C ⊗ (δ_i δ_jᵀ))
        M = 0.5 * (jnp.outer(p, c_i) + jnp.outer(c_i, p))
        Z = jnp.einsum("kl,k,ts->ktls", eyeK, d, M)
        T1 = jnp.einsum("kl,ts->ktls", C, W2)
        Z = Z + 0.5 * (T1 + T1.transpose(2, 3, 0, 1))
        # borders: Σ_e y_e q1_e + the A-row borders (0.5 per machine)
        g = 0.5 * (
            S * dp
            + jnp.outer(C1 + P * d, c_i)
            + jnp.outer(Ct1, c_j)
            + jnp.broadcast_to(y_a[None, :], (K, T))
        )
        g = g.reshape(-1)
        corner_y = S * corner + (K - 2.0) * jnp.sum(y_a)
        Y1 = jnp.zeros((n1, n1), y.dtype)
        Y1 = Y1.at[:n, :n].set(Z.reshape(n, n))
        Y1 = Y1.at[:n, n].add(g)
        Y1 = Y1.at[n, :n].add(g)
        Y1 = Y1.at[n, n].add(corner_y)
        di = jnp.arange(n1)
        Y1 = Y1.at[di, di].add(y_d)
        return jnp.concatenate(
            [Y1.reshape(-1), -4.0 * jnp.sum(y_raw)[None], y_raw]
        )

    return matvec, rmatvec, b




@functools.lru_cache(maxsize=16)
def _cone_fns(k: int, eig_iters: int, kernel_backend: str = "jnp"):
    """PSD-cone projection pair shared by the single and batched loops.

    ``cone_full`` is the O(n³) reference ``eigh`` and reseeds the tracked
    basis with the k most-negative eigenvectors; ``cone_partial`` refines a
    warm basis with ``eig_iters`` shifted subspace-iteration sweeps and
    clips only the negative Ritz pairs, reporting ``ok=False`` when the
    tracked subspace saturates or its Ritz residual exceeds eig_tol·σ.

    ``kernel_backend="pallas"`` runs the same sweep/Rayleigh-Ritz/clip
    sequence through the fused projection kernels (``kernels.sdp_proj``,
    DESIGN.md §12): each sweep's matvec also yields the small Gram and the
    shift norm from ONE stream of Y, and the rank-k clip never materializes
    its outer product — eig_iters+2 streams of Y per call instead of
    eig_iters+3 (plus the update temp).  The small solves (qr/eigh) are
    identical, so iterates agree with the jnp path to f32 roundoff.
    """
    import jax.numpy as jnp
    from jax import lax

    def cone_full(Y):
        ew, EV = jnp.linalg.eigh(Y)
        Yp = (EV * jnp.maximum(ew, 0.0)) @ EV.T
        return Yp, EV[:, :k]          # basis <- k most-negative eigvecs

    def _epilogue(Y, V, YV, G, sigma, eig_tol, clip_update):
        theta, U = jnp.linalg.eigh(G)            # Ritz values, ascending
        W = V @ U
        neg = theta < 0.0
        # Ritz residual of the negative pairs: ‖Y w - θ w‖ certifies the
        # clip; saturation (num_neg == k) means negatives may extend
        # beyond the tracked subspace — both force the full-eigh path.
        R = YV @ U - W * theta
        res = jnp.sqrt(jnp.sum(jnp.where(neg, jnp.sum(R * R, axis=0), 0.0)))
        ok = (jnp.sum(neg) < k) & (res <= eig_tol * jnp.maximum(sigma, 1.0))
        Yp = clip_update(Y, W, jnp.where(neg, theta, 0.0))
        return ok, Yp, W

    def cone_partial(Y, V, eig_tol):
        # Shifted subspace iteration on (σI - Y): its top-k invariant
        # subspace is Y's bottom-k.  σ = ‖Y‖_F ≥ λ_max keeps the shift
        # positive; the basis is warm (last iteration's), so a few
        # sweeps suffice near convergence.
        sigma = jnp.linalg.norm(Y)

        def sweep(_, Vc):
            Q, _ = jnp.linalg.qr(sigma * Vc - Y @ Vc)
            return Q

        V = lax.fori_loop(0, eig_iters, sweep, V)
        YV = Y @ V
        return _epilogue(
            Y, V, YV, V.T @ YV, sigma, eig_tol,
            lambda Y, W, th: Y - (W * th) @ W.T,
        )

    def cone_partial_pallas(Y, V, eig_tol):
        import jax

        from repro.kernels.sdp_proj import rank_k_update_fwd, sdp_subspace_fwd

        interp = jax.default_backend() != "tpu"
        YV, G, ss = sdp_subspace_fwd(Y, V, interpret=interp)
        sigma = jnp.sqrt(ss)

        def sweep(_, carry):
            Vc, YVc, _ = carry
            Q, _ = jnp.linalg.qr(sigma * Vc - YVc)
            return (Q,) + sdp_subspace_fwd(Y, Q, interpret=interp)[:2]

        V, YV, G = lax.fori_loop(0, eig_iters, sweep, (V, YV, G))
        return _epilogue(
            Y, V, YV, G, sigma, eig_tol,
            lambda Y, W, th: rank_k_update_fwd(Y, W * th, W, interpret=interp),
        )

    if kernel_backend == "pallas":
        return cone_full, cone_partial_pallas
    return cone_full, cone_partial


@functools.lru_cache(maxsize=32)
def _dr_jax_fn(
    n1: int,
    check_every: int,
    k: int,
    eig_iters: int,
    eig_refresh: int,
    kind: str,
    n_tasks: int,
    n_machines: int,
    kernel_backend: str = "jnp",
):
    """Build + jit the whole single-instance DR loop for one problem shape.

    Everything that changes the traced graph is in the cache key; scalars
    (rho, lam, tol, eig_tol, max_iters) stay traced arguments so retuning
    them never recompiles.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.scipy.linalg import solve_triangular

    idx_t = n1 * n1
    cone_full, cone_partial = _cone_fns(k, eig_iters, kernel_backend)

    def run(w0, V0, operands, CL, rho, lam, tol, eig_tol, max_iters):
        dim = w0.shape[0]
        matvec, rmatvec, b = _make_device_ops(
            kind, operands, n1, n_tasks, n_machines
        )

        def affine(v):
            resid = matvec(v) - b
            z = solve_triangular(CL, resid, lower=True)
            y = solve_triangular(CL.T, z, lower=False)
            return v - rmatvec(y, dim)

        def chunk(state):
            w, V, vc, it, res, nf, npart = state
            nsteps = jnp.minimum(check_every, max_iters - it)

            def body(j, carry):
                w, V, vc, nf, npart, _ = carry
                git = it + j
                if eig_refresh > 0:
                    force = git % eig_refresh == 0
                else:
                    force = git == 0
                v_aff = affine(w.at[idx_t].add(-rho))
                y = 2.0 * v_aff - w
                Y = y[:idx_t].reshape(n1, n1)
                Y = 0.5 * (Y + Y.T)
                ok, Yp_p, V_p = cone_partial(Y, V, eig_tol)
                use_full = force | ~ok
                Yp, Vn = lax.cond(
                    use_full,
                    lambda _: cone_full(Y),
                    lambda _: (Yp_p, V_p),
                    operand=None,
                )
                v_cone = jnp.concatenate(
                    [
                        Yp.reshape(-1),
                        y[idx_t : idx_t + 1],
                        jnp.maximum(y[idx_t + 1 :], 0.0),
                    ]
                )
                step = v_cone - v_aff
                w = w + lam * step
                nf = nf + use_full.astype(jnp.int32)
                npart = npart + (~use_full).astype(jnp.int32)
                return w, Vn, v_cone, nf, npart, jnp.sum(step * step)

            w, V, vc, nf, npart, sn = lax.fori_loop(
                0, nsteps, body, (w, V, vc, nf, npart, jnp.zeros((), w.dtype))
            )
            it = it + nsteps
            res = jnp.sqrt(sn / dim)
            return w, V, vc, it, res, nf, npart

        def cond(state):
            it, res = state[3], state[4]
            return (it < max_iters) & (res >= tol)

        zero = jnp.zeros((), jnp.int32)
        state = (w0, V0, w0, zero, jnp.asarray(jnp.inf, w0.dtype), zero, zero)
        return lax.while_loop(cond, chunk, state)

    return jax.jit(run)


@functools.lru_cache(maxsize=16)
def _dr_jax_batch_fn(
    n1: int,
    check_every: int,
    k: int,
    eig_iters: int,
    eig_refresh: int,
    kind: str,
    n_tasks: int,
    n_machines: int,
    kernel_backend: str = "jnp",
):
    """Build + jit the BATCHED DR loop: B same-shape instances, one dispatch.

    The per-instance math (constraint matvecs, affine projection, partial
    cone projection) is vmapped, but the loop itself is written manually
    rather than vmapping the single-instance body: under ``vmap`` a
    ``lax.cond`` lowers to a select that executes BOTH branches, which
    would run the O(n³) full eigh for the whole batch on every iteration.
    Instead the full-eigh fallback is a ``lax.scan`` over lanes with a
    per-lane ``lax.cond`` — under scan (unlike vmap) ``cond`` stays real
    control flow, so each step runs the full ``eigh`` for exactly the
    lanes that need it and no others (see the comment at the scan).  The
    ``eig_refresh`` schedule is batch-uniform, so each instance's
    full/partial decisions (and hence its iterates) match its own
    sequential solve.

    Per-instance convergence masking: every ``check_every`` steps the
    chunk's end state is merged with ``jnp.where(done, old, new)`` so
    converged instances freeze, ``it_conv`` records the iteration count at
    which each instance's residual first crossed ``tol`` (the sequential
    path's reported ``iterations``), and the while_loop exits once all
    instances are done or ``max_iters`` hits.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.scipy.linalg import solve_triangular

    idx_t = n1 * n1
    cone_full, cone_partial = _cone_fns(k, eig_iters, kernel_backend)

    def run(w0, V0, operands, CL, rho, lam, tol, eig_tol, max_iters):
        B, dim = w0.shape

        def one_affine(w_i, ops_i, CL_i):
            matvec, rmatvec, b = _make_device_ops(
                kind, ops_i, n1, n_tasks, n_machines
            )
            resid = matvec(w_i) - b
            z = solve_triangular(CL_i, resid, lower=True)
            y = solve_triangular(CL_i.T, z, lower=False)
            return w_i - rmatvec(y, dim)

        affine_b = jax.vmap(one_affine, in_axes=(0, 0, 0))
        cone_partial_b = jax.vmap(cone_partial, in_axes=(0, 0, None))

        def chunk(state):
            w, V, vc, it, res, done, it_conv, nf, npart = state
            nsteps = jnp.minimum(check_every, max_iters - it)

            def body(j, carry):
                w, V, vc, nf, npart, _ = carry
                git = it + j
                if eig_refresh > 0:
                    force = git % eig_refresh == 0
                else:
                    force = git == 0
                v_aff = affine_b(w.at[:, idx_t].add(-rho), operands, CL)
                y = 2.0 * v_aff - w
                Y = y[:, :idx_t].reshape(B, n1, n1)
                Y = 0.5 * (Y + jnp.transpose(Y, (0, 2, 1)))
                ok, Yp_p, V_p = cone_partial_b(Y, V, eig_tol)
                use_full = force | ~ok                        # (B,)

                # Per-lane full-eigh fallback WITHOUT batch amplification.
                # Under vmap a cond lowers to a select that evaluates both
                # branches, and a batch-level cond(any(use_full)) charges
                # the O(n1³) batched eigh to every lane whenever ONE lane
                # fails — with B lanes failing independently at rate p the
                # trigger fires at rate 1-(1-p)^B ≈ 1, so the "fallback"
                # becomes the steady state.  A lax.scan over lanes keeps
                # cond as real control flow (scan bodies run sequentially),
                # so each step pays the full projection for exactly the
                # lanes that need it — the same cost profile as B
                # sequential solves.  The scan itself still re-stacks
                # (Yp, V) for all B lanes, so an outer batch-level cond
                # skips it entirely on the common no-failure iteration
                # (identity: the scan with use_full all-False returns
                # exactly (Yp_p, V_p)).
                def lane(_, xs):
                    Y_i, Yp_i, V_i, uf = xs
                    Yp_i, V_i = lax.cond(
                        uf, lambda: cone_full(Y_i), lambda: (Yp_i, V_i)
                    )
                    return None, (Yp_i, V_i)

                def scan_lanes():
                    _, out = lax.scan(
                        lane, None, (Y, Yp_p, V_p, use_full)
                    )
                    return out

                Yp, Vn = lax.cond(
                    jnp.any(use_full), scan_lanes, lambda: (Yp_p, V_p)
                )
                v_cone = jnp.concatenate(
                    [
                        Yp.reshape(B, -1),
                        y[:, idx_t : idx_t + 1],
                        jnp.maximum(y[:, idx_t + 1 :], 0.0),
                    ],
                    axis=1,
                )
                step = v_cone - v_aff
                w = w + lam * step
                nf = nf + use_full.astype(jnp.int32)
                npart = npart + (~use_full).astype(jnp.int32)
                return w, Vn, v_cone, nf, npart, jnp.sum(step * step, axis=1)

            w2, V2, vc2, nf2, npart2, sn = lax.fori_loop(
                0,
                nsteps,
                body,
                (w, V, vc, nf, npart, jnp.zeros((B,), w.dtype)),
            )
            it2 = it + nsteps
            res_b = jnp.sqrt(sn / dim)
            # Freeze converged instances: their iterate, basis, residual,
            # and eig counters keep the values they had at first crossing.
            keep = done[:, None]
            w = jnp.where(keep, w, w2)
            V = jnp.where(done[:, None, None], V, V2)
            vc = jnp.where(keep, vc, vc2)
            nf = jnp.where(done, nf, nf2)
            npart = jnp.where(done, npart, npart2)
            res = jnp.where(done, res, res_b)
            newly = (~done) & (res_b < tol)
            it_conv = jnp.where(newly, it2, it_conv)
            done = done | newly
            return w, V, vc, it2, res, done, it_conv, nf, npart

        def cond(state):
            it, done = state[3], state[5]
            return (it < max_iters) & ~jnp.all(done)

        zero_b = jnp.zeros((B,), jnp.int32)
        state = (
            w0,
            V0,
            w0,
            jnp.zeros((), jnp.int32),
            jnp.full((B,), jnp.inf, w0.dtype),
            jnp.zeros((B,), bool),
            zero_b,
            zero_b,
            zero_b,
        )
        return lax.while_loop(cond, chunk, state)

    return jax.jit(run)


@functools.lru_cache(maxsize=8)
def _normalize_y_fn(n1: int):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def normalize(vc):
        Y = vc[: n1 * n1].reshape(n1, n1)
        Y = 0.5 * (Y + Y.T)
        d = jnp.sqrt(jnp.clip(jnp.diag(Y), 1e-12, None))
        Y = Y / jnp.outer(d, d)
        eye = jnp.eye(n1, dtype=bool)
        return jnp.where(eye, 1.0, Y)

    return normalize


@functools.lru_cache(maxsize=8)
def _normalize_y_batch_fn(n1: int):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def normalize(vc):                                    # vc: (B, dim)
        Y = vc[:, : n1 * n1].reshape(-1, n1, n1)
        Y = 0.5 * (Y + jnp.transpose(Y, (0, 2, 1)))
        d = jnp.sqrt(jnp.clip(jnp.diagonal(Y, axis1=1, axis2=2), 1e-12, None))
        Y = Y / (d[:, :, None] * d[:, None, :])
        eye = jnp.eye(n1, dtype=bool)
        return jnp.where(eye[None], 1.0, Y)

    return normalize


def _host_operands(bqp, proj: _AffineProjector):
    """Host-side operand arrays for ``_make_device_ops``.

    Returns ``(kind, n_tasks, n_machines, arrays)`` with float32/int32
    numpy leaves so a single solve can push them straight to device and a
    batched solve can ``np.stack`` the per-instance leaves first.
    """
    if isinstance(bqp, FactoredBQP):
        arrays = (
            np.asarray(bqp.p, np.float32),
            np.asarray(bqp.d, np.float32),
            np.asarray(bqp.C, np.float32),
            np.asarray(bqp.src, np.int32),
            np.asarray(bqp.dst, np.int32),
            np.asarray(bqp.q_scale, np.float32),
        )
        return "factored", bqp.n_tasks, bqp.n_machines, arrays
    rows, cols, vals, b = proj.export_csr()
    arrays = (
        np.asarray(vals, np.float32),
        np.asarray(rows, np.int32),
        np.asarray(cols, np.int32),
        np.asarray(b, np.float32),
    )
    return "csr", 0, 0, arrays


def _solve_jax(bqp, opts: SDPOptions, proj: _AffineProjector, warm_start: dict | None):
    import jax.numpy as jnp

    n1, dim = proj.n1, proj.dim
    CL = proj.cholesky_lower()
    k = min(opts.eig_k, n1)
    dtype = jnp.float32

    kind, n_t, n_k, host_ops = _host_operands(bqp, proj)
    operands = tuple(jnp.asarray(a) for a in host_ops)

    w_np = _warm_w(warm_start, dim)
    warm = w_np is not None
    if w_np is None:
        w_np = _identity_start(n1, dim)
    V_np = warm_start.get("V") if warm_start else None
    if V_np is None or np.asarray(V_np).shape != (n1, k):
        V_np = np.eye(n1, k)   # placeholder; iteration 0 full-eigh reseeds it

    run = _dr_jax_fn(
        n1, opts.check_every, k, opts.eig_iters, opts.eig_refresh, kind, n_t,
        n_k, _resolve_kernel_backend(opts)
    )
    w, V, v_cone, it, residual, n_full, n_partial = run(
        jnp.asarray(w_np, dtype),
        jnp.asarray(V_np, dtype),
        operands,
        jnp.asarray(CL, dtype),
        jnp.asarray(opts.rho, dtype),
        jnp.asarray(opts.over_relax, dtype),
        jnp.asarray(opts.tol, dtype),
        jnp.asarray(opts.eig_tol, dtype),
        jnp.asarray(opts.max_iters, jnp.int32),
    )
    Y_device = _normalize_y_fn(n1)(v_cone)

    stats = {
        "solver_backend": "jax",
        "solver_dtype": "float32",
        "constraint_kind": kind,
        "warm_started": warm,
        "eig_full": int(n_full),
        "eig_partial": int(n_partial),
        "eig_k": k,
    }
    state = {"w": np.asarray(w, np.float64), "V": np.asarray(V, np.float64)}
    v_cone_host = np.asarray(v_cone, np.float64)
    return v_cone_host, int(it), float(residual), stats, state, Y_device


# Count of batched jit dispatches — smoke tests assert a B-instance solve
# increments this by exactly one (i.e. the batch really was ONE dispatch).
_BATCH_RUN_CALLS = 0


class _BatchShapeError(ValueError):
    """Same-shape instances whose device operands still disagree in shape

    (e.g. CSR exports with different sparsity counts) — the caller falls
    back to sequential solves instead of crashing.
    """


def _solve_jax_batch(bqps, opts: SDPOptions, projs, warm_starts):
    """Stack B same-shape instances and run the batched DR jit ONCE."""
    import jax.numpy as jnp

    global _BATCH_RUN_CALLS
    B = len(bqps)
    n1, dim = projs[0].n1, projs[0].dim
    k = min(opts.eig_k, n1)
    dtype = jnp.float32

    host = [_host_operands(bqp, proj) for bqp, proj in zip(bqps, projs)]
    kind, n_t, n_k, _ = host[0]
    for kk, tt, mm, arrays in host[1:]:
        if (kk, tt, mm) != (kind, n_t, n_k) or any(
            a.shape != a0.shape for a, a0 in zip(arrays, host[0][3])
        ):
            raise _BatchShapeError(
                "instance device operands disagree in kind or shape"
            )
    operands = tuple(
        jnp.asarray(np.stack([h[3][i] for h in host]))
        for i in range(len(host[0][3]))
    )
    CL = jnp.asarray(np.stack([p.cholesky_lower() for p in projs]), dtype)

    w_stack, V_stack, warm_flags = [], [], []
    for ws in warm_starts:
        w_np = _warm_w(ws, dim)
        warm_flags.append(w_np is not None)
        if w_np is None:
            w_np = _identity_start(n1, dim)
        V_np = ws.get("V") if ws else None
        if V_np is None or np.asarray(V_np).shape != (n1, k):
            V_np = np.eye(n1, k)   # placeholder; iteration 0 full-eigh reseeds
        w_stack.append(np.asarray(w_np, np.float32))
        V_stack.append(np.asarray(V_np, np.float32))

    run = _dr_jax_batch_fn(
        n1, opts.check_every, k, opts.eig_iters, opts.eig_refresh, kind, n_t,
        n_k, _resolve_kernel_backend(opts)
    )
    _BATCH_RUN_CALLS += 1
    w, V, v_cone, it, res, done, it_conv, n_full, n_partial = run(
        jnp.asarray(np.stack(w_stack)),
        jnp.asarray(np.stack(V_stack)),
        operands,
        CL,
        jnp.asarray(opts.rho, dtype),
        jnp.asarray(opts.over_relax, dtype),
        jnp.asarray(opts.tol, dtype),
        jnp.asarray(opts.eig_tol, dtype),
        jnp.asarray(opts.max_iters, jnp.int32),
    )
    Y_device = _normalize_y_batch_fn(n1)(v_cone)

    it_total = int(it)
    out = []
    for i in range(B):
        stats = {
            "solver_backend": "jax",
            "solver_dtype": "float32",
            "constraint_kind": kind,
            "warm_started": warm_flags[i],
            "eig_full": int(n_full[i]),
            "eig_partial": int(n_partial[i]),
            "eig_k": k,
            "batch": B,
            "batch_index": i,
            "batch_dispatches": 1,
        }
        state = {
            "w": np.asarray(w[i], np.float64),
            "V": np.asarray(V[i], np.float64),
        }
        # A converged instance reports the iteration at which its residual
        # first crossed tol (it froze there), NOT the global loop count.
        it_i = int(it_conv[i]) if bool(done[i]) else it_total
        out.append(
            (
                np.asarray(v_cone[i], np.float64),
                it_i,
                float(res[i]),
                stats,
                state,
                Y_device[i],
            )
        )
    return out


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def _resolve_backend(opts: SDPOptions, n1: int) -> str:
    if opts.backend == "auto":
        if n1 > opts.jax_above and compat.jax_available():
            return "jax"
        return "numpy"
    if opts.backend not in ("numpy", "jax"):
        raise ValueError(
            f"unknown SDP backend {opts.backend!r}; "
            "choose from ('auto', 'numpy', 'jax')"
        )
    return opts.backend


def _resolve_kernel_backend(opts: SDPOptions) -> str:
    """Pick the cone-projection kernel lane for the jax backend.

    "auto" = the fused Pallas kernels on TPU, plain XLA elsewhere (in
    interpret mode the kernels are exact but orders of magnitude slower, so
    CPU only runs them when asked explicitly — tests and the differential
    harness do).
    """
    kb = opts.kernel_backend
    if kb not in ("auto", "jnp", "pallas"):
        raise ValueError(
            f"unknown kernel backend {kb!r}; "
            "choose from ('auto', 'jnp', 'pallas')"
        )
    if kb == "auto":
        import jax

        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return kb


def solve_sdp(
    bqp: BQPData | FactoredBQP,
    options: SDPOptions | None = None,
    warm_start: dict | None = None,
) -> SDPSolution:
    """Douglas-Rachford splitting for the relaxed problem (20).

    ``warm_start`` takes the ``state`` payload of a previous ``SDPSolution``
    (same problem dimensions); mismatched payloads are silently ignored and
    the solve cold-starts from the identity.
    """
    opts = options or SDPOptions()
    t0 = time.perf_counter()
    backend = _resolve_backend(opts, bqp.n + 1)
    if backend == "jax" and not compat.jax_available():
        # "auto" already degraded to numpy in _resolve_backend; an *explicit*
        # jax request must fail loudly rather than silently run the host
        # loop at a fraction of the speed.
        raise ImportError(
            "SDPOptions(backend='jax') requested but jax is not importable; "
            "use backend='auto' (or 'numpy') for a host fallback"
        )

    proj = _AffineProjector(
        bqp,
        sparse=opts.sparse,
        cholesky_above=opts.cholesky_above,
        keep_gram=backend == "jax",
    )
    if backend == "jax":
        v_cone, it, residual, bstats, state, Y_device = _solve_jax(
            bqp, opts, proj, warm_start
        )
    else:
        v_cone, it, residual, bstats, state, Y_device = _solve_numpy(
            bqp, opts, proj, warm_start
        )
    return _finish_solution(
        bqp, opts, proj, v_cone, it, residual, bstats, state, Y_device,
        time.perf_counter() - t0,
    )


def _finish_solution(
    bqp,
    opts: SDPOptions,
    proj: _AffineProjector,
    v_cone: np.ndarray,
    it: int,
    residual: float,
    bstats: dict,
    state: dict,
    Y_device,
    seconds: float,
) -> SDPSolution:
    """Host post-processing shared by single and batched solves."""
    n1 = proj.n1

    # Extract Y from the cone side (guaranteed PSD up to the projection
    # tolerance), renormalize diagonal to 1 so it is a valid Gaussian
    # covariance for rounding.
    Y = v_cone[: n1 * n1].reshape(n1, n1)
    Y = 0.5 * (Y + Y.T)
    d = np.sqrt(np.clip(np.diag(Y), 1e-12, None))
    Y = Y / np.outer(d, d)
    np.fill_diagonal(Y, 1.0)

    t_val = float(v_cone[n1 * n1])
    # SDP bound on OPT (Eq. 24): at the optimum t* = max_e <Q̃_e, Y*>/4.
    # NOTE: a first-order iterate only *approximates* the SDP optimum, so
    # this is a certified lower bound only once ``converged`` — the
    # ``bound_certified`` flag records exactly that, and callers
    # (Schedule.info, benchmarks) must not report uncertified values.
    if isinstance(bqp, FactoredBQP):
        t_from_y = float(np.max(bqp.inner(Y)) / bqp.q_scale / 4.0)
    else:
        qn = bqp.Q_tilde / bqp.q_scale
        t_from_y = float(np.max(np.einsum("eij,ij->e", qn, Y)) / 4.0)
    lower = max(t_val, 0.0) * bqp.q_scale

    stats = dict(proj.stats)
    stats.update(bstats)
    # largest tensor the solve touched: the stacked DR variable dominates
    # for factored instances; the constraint-matrix build and the Q̃ stack
    # dominate dense ones.
    itemsize = 4 if stats.get("solver_backend") == "jax" else 8
    peak = max(
        3 * proj.dim * itemsize,
        stats.get("gram_bytes", 0),
        stats.get("build_peak_bytes", 0),
    )
    if isinstance(bqp, BQPData):
        peak = max(peak, int(bqp.Q_tilde.nbytes + bqp.Q.nbytes))
    stats["peak_tensor_bytes"] = int(peak)

    converged = residual < opts.tol
    return SDPSolution(
        Y=Y,
        t=max(t_val, t_from_y),
        lower_bound=lower,
        iterations=it,
        residual=residual,
        converged=converged,
        bound_certified=converged,
        solve_seconds=seconds,
        stats=stats,
        Y_device=Y_device,
        state=state,
    )


def solve_sdp_batch(
    bqps,
    options: SDPOptions | None = None,
    warm_starts=None,
) -> list[SDPSolution]:
    """Solve B same-shape instances in ONE jitted batched DR dispatch.

    All instances must share representation type, ``n``, ``n_tasks``,
    ``n_machines``, and constraint-edge count; their weights (p, d, C,
    q_scale / CSR values) are free to differ — they become the vmapped
    batch axis.  Per-instance convergence masking freezes instances the
    moment their residual crosses ``tol`` while stragglers keep iterating,
    so each returned ``SDPSolution`` matches its own sequential
    ``solve_sdp`` call (iterate, residual, iteration count) to float32
    tolerance.

    ``warm_starts`` is an optional list of per-instance ``state`` payloads
    (``None`` entries cold-start that lane).  Backend resolution differs
    from ``solve_sdp``: "auto" takes the batched jax path whenever JAX is
    importable regardless of ``jax_above`` — amortizing dispatch overhead
    across the batch is the whole point — while "numpy" (or a missing JAX
    under "auto") degrades to B sequential host solves.

    Per-instance ``solve_seconds`` is the batch wall time divided by B;
    the full wall time is in ``stats["batch_seconds"]``.
    """
    opts = options or SDPOptions()
    bqps = list(bqps)
    if not bqps:
        return []
    if warm_starts is None:
        warm_starts = [None] * len(bqps)
    warm_starts = list(warm_starts)
    if len(warm_starts) != len(bqps):
        raise ValueError("warm_starts must have one entry per instance")

    first = bqps[0]
    for b in bqps[1:]:
        if (
            type(b) is not type(first)
            or b.n != first.n
            or b.n_tasks != first.n_tasks
            or b.n_machines != first.n_machines
            or len(b.edges) != len(first.edges)
        ):
            raise ValueError(
                "solve_sdp_batch requires same-shape instances "
                "(same type, n, n_tasks, n_machines, and edge count)"
            )

    if opts.backend == "jax" and not compat.jax_available():
        raise ImportError(
            "SDPOptions(backend='jax') requested but jax is not importable; "
            "use backend='auto' (or 'numpy') for a host fallback"
        )
    if opts.backend == "numpy" or not compat.jax_available():
        return [solve_sdp(b, opts, ws) for b, ws in zip(bqps, warm_starts)]

    t0 = time.perf_counter()
    projs = [
        _AffineProjector(
            b,
            sparse=opts.sparse,
            cholesky_above=opts.cholesky_above,
            keep_gram=True,
        )
        for b in bqps
    ]
    try:
        raw = _solve_jax_batch(bqps, opts, projs, warm_starts)
    except _BatchShapeError:
        return [solve_sdp(b, opts, ws) for b, ws in zip(bqps, warm_starts)]
    total = time.perf_counter() - t0

    sols = []
    for bqp, proj, (v_cone, it, residual, bstats, state, Y_dev) in zip(
        bqps, projs, raw
    ):
        bstats = dict(bstats)
        bstats["batch_seconds"] = total
        sols.append(
            _finish_solution(
                bqp, opts, proj, v_cone, it, residual, bstats, state, Y_dev,
                total / len(bqps),
            )
        )
    return sols
