"""Randomized rounding of the SDP solution + the paper's bounds.

Implements:
  - ``randomized_rounding``: sample z ~ N(0, Y*), take sign(z), fold the
    homogenization variable u, repair/filter to feasible assignments, pick
    the best (paper §3, Aspremont-Boyd style).  Two backends: a clear
    numpy reference and a JAX ``vmap``/``jit`` implementation that evaluates
    tens of thousands of samples in one fused call (§Perf item).
  - ``naive_rounding``: per-task argmax of the relaxed solution (the paper's
    "SDP with naive rounding" baseline).
  - ``expected_bottleneck``: Eq. (22)-(23) arcsin formula.
  - ``sdp_lower_bound`` / ``optimal_upper_bound``: Eq. (24) and (27).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bqp import BQPData, bottleneck_time_batch
from repro.core.graphs import ComputeGraph, TaskGraph


@dataclasses.dataclass
class RoundingResult:
    assignment: np.ndarray          # (N_T,) machine indices, best sample
    bottleneck: float               # exact bottleneck time of ``assignment``
    num_feasible: int               # samples surviving the feasibility filter
    num_samples: int
    expected_bottleneck: float      # Eq. (22)-(23)
    lower_bound: float              # Eq. (24)  (<= OPT)
    upper_bound: float              # Eq. (27)  (>= OPT, see note in DESIGN.md)


def _sample_signs(
    Y: np.ndarray, num_samples: int, rng: np.random.Generator
) -> np.ndarray:
    """Draw sign(z), z ~ N(0, Y), as ±1 matrix (num_samples, n+1)."""
    # Eigen square root is robust to the slightly indefinite Y that a
    # first-order solver returns.
    w, V = np.linalg.eigh(0.5 * (Y + Y.T))
    root = V * np.sqrt(np.clip(w, 0.0, None))
    g = rng.standard_normal((num_samples, Y.shape[0]))
    z = g @ root.T
    s = np.sign(z)
    s[s == 0] = 1.0
    return s, z


def signs_to_assignments(
    signs: np.ndarray, z: np.ndarray, n_tasks: int, n_machines: int
) -> tuple[np.ndarray, np.ndarray]:
    """±1 samples -> (assignments (B, N_T), strict_feasible (B,) bool).

    Folds u (last coordinate), reshapes column-major, and repairs:
      - multiple machines selected for a task: keep the one with the largest
        continuous score z (paper footnote 9 allows dropping duplicates);
      - zero machines selected: strictly infeasible (flagged), repaired to
        the argmax-z machine so every sample yields *some* assignment.
    """
    u = signs[:, -1:]
    x = signs[:, :-1] * u                          # fold homogenization
    zx = z[:, :-1] * u
    B = x.shape[0]
    # column-major vec: index κ·N_T + τ  ->  (machine κ, task τ)
    sel = (x.reshape(B, n_machines, n_tasks) > 0)  # (B, K, T)
    score = zx.reshape(B, n_machines, n_tasks)     # continuous scores
    masked = np.where(sel, score, -np.inf)
    any_sel = sel.any(axis=1)                      # (B, T)
    strict = any_sel.all(axis=1)
    # repair: fall back to raw score where nothing was selected
    choice = np.where(any_sel[:, None, :], masked, score)
    assignments = np.argmax(choice, axis=1)        # (B, T)
    return assignments, strict


def randomized_rounding(
    bqp: BQPData,
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    Y: np.ndarray,
    *,
    num_samples: int = 2000,
    rng: np.random.Generator | None = None,
    strict: bool = False,
    backend: str = "numpy",
) -> RoundingResult:
    rng = rng or np.random.default_rng(0)
    signs, z = _sample_signs(Y, num_samples, rng)
    assignments, strict_mask = signs_to_assignments(
        signs, z, bqp.n_tasks, bqp.n_machines
    )
    if strict:
        if not strict_mask.any():
            # Paper discards infeasible samples; if none survive, fall back
            # to repaired samples (never fail).
            candidate = assignments
        else:
            candidate = assignments[strict_mask]
    else:
        candidate = assignments

    if backend == "jax":
        times = np.asarray(
            _bottleneck_batch_jax(task_graph, compute_graph, candidate)
        )
    else:
        times = bottleneck_time_batch(task_graph, compute_graph, candidate)
    best = int(np.argmin(times))

    return RoundingResult(
        assignment=candidate[best],
        bottleneck=float(times[best]),
        num_feasible=int(strict_mask.sum()),
        num_samples=num_samples,
        expected_bottleneck=expected_bottleneck(bqp, Y),
        lower_bound=sdp_lower_bound(bqp, Y),
        upper_bound=optimal_upper_bound(bqp, Y),
    )


def naive_rounding(bqp: BQPData, Y: np.ndarray) -> np.ndarray:
    """Paper's 'SDP with naive rounding': round the relaxed solution.

    The relaxed x is read off the u-column of the Gram matrix
    (Y[:n, -1] ≈ E[x·u]); per task we pick the machine with the largest
    relaxed indicator (equivalent to rounding to the closest feasible
    integer point).
    """
    x_relaxed = Y[:-1, -1]
    m_relaxed = (x_relaxed + 1.0) / 2.0
    M = m_relaxed.reshape(bqp.n_machines, bqp.n_tasks)  # column-major
    return np.argmax(M, axis=0)


# ---------------------------------------------------------------------------
# Paper analysis: expectation and bounds
# ---------------------------------------------------------------------------


def expected_bottleneck(bqp: BQPData, Y: np.ndarray) -> float:
    """Eq. (22)-(23): max_e (1/4) E[ẑᵀ Q̃_e ẑ] via the arcsin identity."""
    asin = np.arcsin(np.clip(Y, -1.0, 1.0))
    vals = np.einsum("eij,ij->e", bqp.Q_tilde, asin) * (2.0 / np.pi)
    return float(np.max(vals) / 4.0)


def sdp_lower_bound(bqp: BQPData, Y: np.ndarray) -> float:
    """Eq. (24): the SDP objective max_e <Q̃_e, Y*>/4 lower-bounds OPT."""
    vals = np.einsum("eij,ij->e", bqp.Q_tilde, Y)
    return float(np.max(vals) / 4.0)


def optimal_upper_bound(bqp: BQPData, Y: np.ndarray) -> float:
    """Eq. (26)-(27): OPT <= max_e (1/4) Σ Q̃_e ∘ (0.112 + 0.878 Y).

    (The paper's Eq. 27 omits the 1/4 of Eq. 25; we keep it so the bound is
    in bottleneck-time units and comparable with Fig. 4/5.)
    """
    lin = 0.112 + 0.878 * np.clip(Y, -1.0, 1.0)
    vals = np.einsum("eij,ij->e", bqp.Q_tilde, lin)
    return float(np.max(vals) / 4.0)


# ---------------------------------------------------------------------------
# JAX-vectorized bottleneck evaluation (beyond-paper §Perf optimization)
# ---------------------------------------------------------------------------

_JAX_CACHE: dict = {}


def _bottleneck_batch_jax(
    task_graph: TaskGraph, compute_graph: ComputeGraph, assignments: np.ndarray
):
    """Batched bottleneck evaluation on device via one jitted call."""
    import jax
    import jax.numpy as jnp

    key = (id(task_graph), id(compute_graph))
    fn = _JAX_CACHE.get(key)
    if fn is None:
        p = jnp.asarray(task_graph.p, dtype=jnp.float32)
        e = jnp.asarray(compute_graph.e, dtype=jnp.float32)
        C = jnp.asarray(compute_graph.C, dtype=jnp.float32)
        n_k = compute_graph.num_machines
        if task_graph.edges:
            src = jnp.asarray([i for (i, _) in task_graph.edges])
            dst = jnp.asarray([j for (_, j) in task_graph.edges])
        else:
            src = dst = jnp.zeros((0,), dtype=jnp.int32)

        def one(a):
            onehot = jax.nn.one_hot(a, n_k, dtype=jnp.float32)   # (T, K)
            loads = onehot.T @ p                                  # (K,)
            t_comp = (loads / e)[a]                               # (T,)
            delays = C[a[src], a[dst]]                            # (|E|,)
            comm = jnp.zeros_like(t_comp).at[src].max(delays)
            return jnp.max(t_comp + comm)

        fn = jax.jit(jax.vmap(one))
        _JAX_CACHE[key] = fn
    return fn(jnp.asarray(assignments))
