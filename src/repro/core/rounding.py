"""Randomized rounding of the SDP solution + the paper's bounds.

Implements:
  - ``randomized_rounding``: sample z ~ N(0, Y*), take sign(z), fold the
    homogenization variable u, repair/filter to feasible assignments, pick
    the best (paper §3, Aspremont-Boyd style).  Two backends:
      * ``numpy`` — the clear float64 reference implementation;
      * ``jax``   — the whole pipeline (sampling, sign folding, repair,
        batched bottleneck evaluation, arg-best selection) fused into ONE
        jitted call, so tens of thousands of samples never leave device
        (§Perf item; DESIGN.md §6).  When the SDP solve also ran on device
        (``SDPSolution.Y_device``), pass it via ``Y_device=`` and the
        covariance square root is taken on device as well — the Gram matrix
        never round-trips to host between solve and rounding.
  - ``naive_rounding``: per-task argmax of the relaxed solution (the paper's
    "SDP with naive rounding" baseline).
  - ``expected_bottleneck``: Eq. (22)-(23) arcsin formula.
  - ``sdp_lower_bound`` / ``optimal_upper_bound``: Eq. (24) and (27).
  - ``analysis_bounds``: all three transforms at once; with a
    device-resident Gram matrix and the matrix-free representation they run
    in one jitted call on device instead of three host O(n²) passes.

All analysis functions accept either the dense ``BQPData`` oracle or the
matrix-free ``FactoredBQP`` (DESIGN.md §2); with the factored form the
arcsin/linear transforms touch only the dense (n+1)² Gram matrix Y — never
an (|E|, n, n) stack.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.bqp import BQPData, FactoredBQP, bottleneck_time_batch
from repro.core.graphs import ComputeGraph, TaskGraph

AnyBQP = BQPData | FactoredBQP


@dataclasses.dataclass
class RoundingResult:
    assignment: np.ndarray          # (N_T,) machine indices, best sample
    bottleneck: float               # exact bottleneck time of ``assignment``
    num_feasible: int               # samples surviving the feasibility filter
    num_samples: int
    expected_bottleneck: float      # Eq. (22)-(23)
    lower_bound: float              # Eq. (24)  (<= OPT)
    upper_bound: float              # Eq. (27)  (>= OPT, see note in DESIGN.md)


def _covariance_root(Y: np.ndarray) -> np.ndarray:
    """Eigen square root, robust to the slightly indefinite Y that a
    first-order solver returns."""
    w, V = np.linalg.eigh(0.5 * (Y + Y.T))
    return V * np.sqrt(np.clip(w, 0.0, None))


def _sample_signs(
    Y: np.ndarray, num_samples: int, rng: np.random.Generator
) -> np.ndarray:
    """Draw sign(z), z ~ N(0, Y), as ±1 matrix (num_samples, n+1)."""
    root = _covariance_root(Y)
    g = rng.standard_normal((num_samples, Y.shape[0]))
    z = g @ root.T
    s = np.sign(z)
    s[s == 0] = 1.0
    return s, z


def signs_to_assignments(
    signs: np.ndarray, z: np.ndarray, n_tasks: int, n_machines: int
) -> tuple[np.ndarray, np.ndarray]:
    """±1 samples -> (assignments (B, N_T), strict_feasible (B,) bool).

    Folds u (last coordinate), reshapes column-major, and repairs:
      - multiple machines selected for a task: keep the one with the largest
        continuous score z (paper footnote 9 allows dropping duplicates);
      - zero machines selected: strictly infeasible (flagged), repaired to
        the argmax-z machine so every sample yields *some* assignment.
    """
    u = signs[:, -1:]
    x = signs[:, :-1] * u                          # fold homogenization
    zx = z[:, :-1] * u
    B = x.shape[0]
    # column-major vec: index κ·N_T + τ  ->  (machine κ, task τ)
    sel = (x.reshape(B, n_machines, n_tasks) > 0)  # (B, K, T)
    score = zx.reshape(B, n_machines, n_tasks)     # continuous scores
    masked = np.where(sel, score, -np.inf)
    any_sel = sel.any(axis=1)                      # (B, T)
    strict = any_sel.all(axis=1)
    # repair: fall back to raw score where nothing was selected
    choice = np.where(any_sel[:, None, :], masked, score)
    assignments = np.argmax(choice, axis=1)        # (B, T)
    return assignments, strict


def randomized_rounding(
    bqp: AnyBQP,
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    Y: np.ndarray,
    *,
    num_samples: int = 2000,
    rng: np.random.Generator | None = None,
    strict: bool = False,
    backend: str = "numpy",
    Y_device: object | None = None,
    kernel_backend: str = "auto",
) -> RoundingResult:
    rng = rng or np.random.default_rng(0)

    if backend == "jax":
        assignment, bottleneck, num_feasible = _rounding_fused_jax(
            task_graph,
            compute_graph,
            bqp.n_tasks,
            bqp.n_machines,
            Y,
            num_samples,
            rng,
            strict,
            Y_device=Y_device,
            kernel_backend=kernel_backend,
        )
    else:
        signs, z = _sample_signs(Y, num_samples, rng)
        assignments, strict_mask = signs_to_assignments(
            signs, z, bqp.n_tasks, bqp.n_machines
        )
        if strict and strict_mask.any():
            # Paper discards infeasible samples; if none survive, fall back
            # to repaired samples (never fail).
            candidate = assignments[strict_mask]
        else:
            candidate = assignments
        times = bottleneck_time_batch(task_graph, compute_graph, candidate)
        best = int(np.argmin(times))
        assignment = candidate[best]
        bottleneck = float(times[best])
        num_feasible = int(strict_mask.sum())

    # The numpy backend is the float64 reference oracle end to end — only
    # the jax backend hands the analysis transforms a device (f32) Y.
    exp_b, lb, ub = analysis_bounds(
        bqp, Y, Y_device=Y_device if backend == "jax" else None
    )
    return RoundingResult(
        assignment=assignment,
        bottleneck=bottleneck,
        num_feasible=num_feasible,
        num_samples=num_samples,
        expected_bottleneck=exp_b,
        lower_bound=lb,
        upper_bound=ub,
    )


def naive_rounding(bqp: AnyBQP, Y: np.ndarray) -> np.ndarray:
    """Paper's 'SDP with naive rounding': round the relaxed solution.

    The relaxed x is read off the u-column of the Gram matrix
    (Y[:n, -1] ≈ E[x·u]); per task we pick the machine with the largest
    relaxed indicator (equivalent to rounding to the closest feasible
    integer point).
    """
    x_relaxed = Y[:-1, -1]
    m_relaxed = (x_relaxed + 1.0) / 2.0
    M = m_relaxed.reshape(bqp.n_machines, bqp.n_tasks)  # column-major
    return np.argmax(M, axis=0)


# ---------------------------------------------------------------------------
# Paper analysis: expectation and bounds
# ---------------------------------------------------------------------------


def _edge_inner(bqp: AnyBQP, F: np.ndarray) -> np.ndarray:
    """<Q̃_e, F> for all constraint edges, dense oracle or matrix-free."""
    if isinstance(bqp, FactoredBQP):
        return bqp.inner(F)
    return np.einsum("eij,ij->e", bqp.Q_tilde, F)


def expected_bottleneck(bqp: AnyBQP, Y: np.ndarray) -> float:
    """Eq. (22)-(23): max_e (1/4) E[ẑᵀ Q̃_e ẑ] via the arcsin identity."""
    asin = np.arcsin(np.clip(Y, -1.0, 1.0))
    vals = _edge_inner(bqp, asin) * (2.0 / np.pi)
    return float(np.max(vals) / 4.0)


def sdp_lower_bound(bqp: AnyBQP, Y: np.ndarray) -> float:
    """Eq. (24): the SDP objective max_e <Q̃_e, Y*>/4 lower-bounds OPT."""
    vals = _edge_inner(bqp, Y)
    return float(np.max(vals) / 4.0)


def optimal_upper_bound(bqp: AnyBQP, Y: np.ndarray) -> float:
    """Eq. (26)-(27): OPT <= max_e (1/4) Σ Q̃_e ∘ (0.112 + 0.878 Y).

    (The paper's Eq. 27 omits the 1/4 of Eq. 25; we keep it so the bound is
    in bottleneck-time units and comparable with Fig. 4/5.)
    """
    lin = 0.112 + 0.878 * np.clip(Y, -1.0, 1.0)
    vals = _edge_inner(bqp, lin)
    return float(np.max(vals) / 4.0)


def analysis_bounds(
    bqp: AnyBQP, Y: np.ndarray, *, Y_device=None
) -> tuple[float, float, float]:
    """(expected_bottleneck, sdp_lower_bound, optimal_upper_bound) in one go.

    With a device-resident Gram matrix (``SDPSolution.Y_device``) and the
    matrix-free representation, all three Eq. (22)-(24)/(27) transforms run
    in ONE jitted call on device — the host otherwise pays three O(n²)
    arcsin/linear passes plus the factored inner products per ``schedule()``
    even after a device-resident solve.  Dense instances (small by
    construction, DESIGN.md §2) keep the float64 host path.
    """
    if Y_device is not None and isinstance(bqp, FactoredBQP):
        fn = _device_analysis_fn(bqp)
        exp_b, lb, ub = fn(Y_device)
        return float(exp_b), float(lb), float(ub)
    return (
        expected_bottleneck(bqp, Y),
        sdp_lower_bound(bqp, Y),
        optimal_upper_bound(bqp, Y),
    )


_ANALYSIS_CACHE: collections.OrderedDict = collections.OrderedDict()
_ANALYSIS_CACHE_MAX = 8


def _device_analysis_fn(bqp: FactoredBQP):
    """Jitted (expected, lower, upper) from a device Y, keyed on content."""
    import jax
    import jax.numpy as jnp

    key = (
        bqp.p.tobytes(),
        bqp.d.tobytes(),
        bqp.C.tobytes(),
        bqp.src.tobytes(),
        bqp.dst.tobytes(),
    )
    fn = _cache_lookup(_ANALYSIS_CACHE, key)
    if fn is not None:
        return fn

    K, T, n = bqp.n_machines, bqp.n_tasks, bqp.n
    p = jnp.asarray(bqp.p, jnp.float32)
    d = jnp.asarray(bqp.d, jnp.float32)
    C = jnp.asarray(bqp.C, jnp.float32)
    src = jnp.asarray(bqp.src, jnp.int32)
    dst = jnp.asarray(bqp.dst, jnp.int32)
    C1 = jnp.asarray(bqp._C1, jnp.float32)
    Ct1 = jnp.asarray(bqp._Ct1, jnp.float32)
    P = jnp.float32(bqp._P)
    corner = jnp.float32(bqp.corner)

    def inner(F):
        """Device twin of ``FactoredBQP.inner`` (same closed forms)."""
        F = 0.5 * (F + F.T)
        Fxx = F[:n, :n].reshape(K, T, K, T)
        f = F[:n, -1].reshape(K, T)
        comp = jnp.einsum("k,t,ktks->s", d, p, Fxx)
        blocks = Fxx.transpose(1, 3, 0, 2)[src, dst]      # (|E|, K, K)
        comm = jnp.einsum("ekl,kl->e", blocks, C)
        base = jnp.einsum("k,t,kt->", d, p, f)
        u_i = (C1 + P * d) @ f
        u_j = Ct1 @ f
        q1f = 0.5 * (base + u_i[src] + u_j[dst])
        return comp[src] + comm + 2.0 * q1f + corner * F[-1, -1]

    @jax.jit
    def analysis(Y):
        Yc = jnp.clip(Y, -1.0, 1.0)
        exp_b = jnp.max(inner(jnp.arcsin(Yc)) * (2.0 / jnp.pi)) / 4.0
        lb = jnp.max(inner(Y)) / 4.0
        ub = jnp.max(inner(0.112 + 0.878 * Yc)) / 4.0
        return exp_b, lb, ub

    _cache_insert(_ANALYSIS_CACHE, key, analysis, _ANALYSIS_CACHE_MAX)
    return analysis


# ---------------------------------------------------------------------------
# Fused JAX rounding (beyond-paper §Perf optimization)
# ---------------------------------------------------------------------------
#
# One jitted call per (instance, strict) pair: z = g·rootᵀ, sign fold,
# duplicate/empty repair, batched bottleneck evaluation, and best-sample
# selection all stay on device.  Gaussians g come from the caller's numpy
# rng so the two backends draw identical samples.

_JAX_CACHE: collections.OrderedDict = collections.OrderedDict()
_JAX_CACHE_MAX = 32


def _cache_lookup(cache: collections.OrderedDict, key):
    """LRU read: refresh recency so hot closures survive eviction."""
    val = cache.get(key)
    if val is not None:
        cache.move_to_end(key)
    return val


def _cache_insert(cache: collections.OrderedDict, key, val, max_size: int):
    """LRU insert with SINGLE-entry eviction: a cache-capacity+1-th instance
    evicts only the least-recently-used closure instead of wiping the whole
    cache (which would recompile every cached instance on its next use)."""
    while len(cache) >= max_size:
        cache.popitem(last=False)
    cache[key] = val


def _rounding_kernel_backend(kernel_backend: str) -> str:
    """"auto" = the Pallas batched bottleneck evaluator on TPU, the vmapped
    gather evaluator elsewhere (interpret mode is exact but slow on CPU)."""
    if kernel_backend not in ("auto", "jnp", "pallas"):
        raise ValueError(
            f"unknown kernel backend {kernel_backend!r}; "
            "choose from ('auto', 'jnp', 'pallas')"
        )
    if kernel_backend == "auto":
        import jax

        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return kernel_backend


def _fused_rounding_fn(
    task_graph: TaskGraph, compute_graph: ComputeGraph, n_tasks: int,
    n_machines: int, strict: bool, kernel_backend: str = "jnp",
):
    import jax
    import jax.numpy as jnp

    # Key on instance *content*, not object identity: ids get reused after
    # GC and would silently hand back a closure baked with another
    # instance's workloads/speeds/edges.
    key = (
        task_graph.p.tobytes(),
        task_graph.edges,
        compute_graph.e.tobytes(),
        compute_graph.C.tobytes(),
        n_tasks,
        n_machines,
        strict,
        kernel_backend,
    )
    fn = _cache_lookup(_JAX_CACHE, key)
    if fn is not None:
        return fn

    p = jnp.asarray(task_graph.p, dtype=jnp.float32)
    e = jnp.asarray(compute_graph.e, dtype=jnp.float32)
    C = jnp.asarray(compute_graph.C, dtype=jnp.float32)
    if task_graph.edges:
        src = jnp.asarray([i for (i, _) in task_graph.edges])
        dst = jnp.asarray([j for (_, j) in task_graph.edges])
    else:
        src = dst = jnp.zeros((0,), dtype=jnp.int32)

    def bottleneck_one(a):
        onehot = jax.nn.one_hot(a, n_machines, dtype=jnp.float32)  # (T, K)
        loads = onehot.T @ p                                        # (K,)
        t_comp = (loads / e)[a]                                     # (T,)
        delays = C[a[src], a[dst]]                                  # (|E|,)
        comm = jnp.zeros_like(t_comp).at[src].max(delays)
        return jnp.max(t_comp + comm)

    if kernel_backend == "pallas":
        from repro.kernels.bottleneck import bottleneck_eval_fwd

        interp = jax.default_backend() != "tpu"
        src_oh = jax.nn.one_hot(src, n_tasks, dtype=jnp.float32)   # (|E|, T)
        dst_oh = jax.nn.one_hot(dst, n_tasks, dtype=jnp.float32)

        def eval_times(assignments):
            oh = jax.nn.one_hot(assignments, n_machines, dtype=jnp.float32)
            return bottleneck_eval_fwd(
                oh, p, e, C, src_oh, dst_oh, interpret=interp
            )
    else:
        def eval_times(assignments):
            return jax.vmap(bottleneck_one)(assignments)

    @jax.jit
    def rounding(root, g):
        B = g.shape[0]
        z = g @ root.T                                  # (B, n+1)
        s = jnp.where(z >= 0, 1.0, -1.0)                # sign with 0 -> +1
        u = s[:, -1:]
        zx = (z[:, :-1] * u).reshape(B, n_machines, n_tasks)
        sel = (s[:, :-1] * u).reshape(B, n_machines, n_tasks) > 0
        masked = jnp.where(sel, zx, -jnp.inf)
        any_sel = sel.any(axis=1)                       # (B, T)
        strict_mask = any_sel.all(axis=1)               # (B,)
        choice = jnp.where(any_sel[:, None, :], masked, zx)
        assignments = jnp.argmax(choice, axis=1)        # (B, T)
        times = eval_times(assignments)                 # (B,)
        if strict:
            times = jnp.where(
                strict_mask.any(),
                jnp.where(strict_mask, times, jnp.inf),
                times,
            )
        best = jnp.argmin(times)
        return assignments[best], times[best], strict_mask.sum()

    _cache_insert(_JAX_CACHE, key, rounding, _JAX_CACHE_MAX)
    return rounding


def _fused_rounding_batch_fn(
    B: int, n_tasks: int, n_machines: int, n_edges: int, strict: bool,
    kernel_backend: str = "jnp",
):
    """Batched twin of ``_fused_rounding_fn``: B instances, one dispatch.

    Keyed on *shape* only — the per-instance weights (p, e, C, src, dst)
    are traced arguments, so one closure serves every same-shape batch.
    The leading ``"batch"`` tag plus the batch dimension ``B`` keep batched
    and single-instance closures of the same instance shape from evicting
    each other out of the shared ``_JAX_CACHE`` LRU.
    """
    import jax
    import jax.numpy as jnp

    key = ("batch", B, n_tasks, n_machines, n_edges, strict, kernel_backend)
    fn = _cache_lookup(_JAX_CACHE, key)
    if fn is not None:
        return fn

    if kernel_backend == "pallas":
        from repro.kernels.bottleneck import bottleneck_eval_fwd

        interp = jax.default_backend() != "tpu"

    def round_one(p, e, C, src, dst, root, g):
        def bottleneck_one(a):
            onehot = jax.nn.one_hot(a, n_machines, dtype=jnp.float32)
            loads = onehot.T @ p
            t_comp = (loads / e)[a]
            delays = C[a[src], a[dst]]
            comm = jnp.zeros_like(t_comp).at[src].max(delays)
            return jnp.max(t_comp + comm)

        if kernel_backend == "pallas":
            src_oh = jax.nn.one_hot(src, n_tasks, dtype=jnp.float32)
            dst_oh = jax.nn.one_hot(dst, n_tasks, dtype=jnp.float32)

            def eval_times(assignments):
                oh = jax.nn.one_hot(
                    assignments, n_machines, dtype=jnp.float32
                )
                return bottleneck_eval_fwd(
                    oh, p, e, C, src_oh, dst_oh, interpret=interp
                )
        else:
            def eval_times(assignments):
                return jax.vmap(bottleneck_one)(assignments)

        S = g.shape[0]
        z = g @ root.T                                  # (S, n+1)
        s = jnp.where(z >= 0, 1.0, -1.0)                # sign with 0 -> +1
        u = s[:, -1:]
        zx = (z[:, :-1] * u).reshape(S, n_machines, n_tasks)
        sel = (s[:, :-1] * u).reshape(S, n_machines, n_tasks) > 0
        masked = jnp.where(sel, zx, -jnp.inf)
        any_sel = sel.any(axis=1)                       # (S, T)
        strict_mask = any_sel.all(axis=1)               # (S,)
        choice = jnp.where(any_sel[:, None, :], masked, zx)
        assignments = jnp.argmax(choice, axis=1)        # (S, T)
        times = eval_times(assignments)                 # (S,)
        if strict:
            times = jnp.where(
                strict_mask.any(),
                jnp.where(strict_mask, times, jnp.inf),
                times,
            )
        best = jnp.argmin(times)
        return assignments[best], times[best], strict_mask.sum()

    rounding = jax.jit(jax.vmap(round_one))
    _cache_insert(_JAX_CACHE, key, rounding, _JAX_CACHE_MAX)
    return rounding


_DEVICE_ROOT_FN = None


def _device_covariance_root(Y_device):
    """Eigen square root of a device-resident Y — the solve→rounding hand-off
    path: the covariance stays on device end to end."""
    global _DEVICE_ROOT_FN
    if _DEVICE_ROOT_FN is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def _root(Y):
            Y = 0.5 * (Y + Y.T)
            w, V = jnp.linalg.eigh(Y)
            return V * jnp.sqrt(jnp.clip(w, 0.0, None))

        _DEVICE_ROOT_FN = _root
    return _DEVICE_ROOT_FN(Y_device)


def _rounding_fused_jax(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    n_tasks: int,
    n_machines: int,
    Y: np.ndarray,
    num_samples: int,
    rng: np.random.Generator,
    strict: bool,
    Y_device=None,
    kernel_backend: str = "auto",
) -> tuple[np.ndarray, float, int]:
    fn = _fused_rounding_fn(
        task_graph, compute_graph, n_tasks, n_machines, strict,
        _rounding_kernel_backend(kernel_backend),
    )
    if Y_device is not None:
        root = _device_covariance_root(Y_device)
    else:
        root = _covariance_root(Y).astype(np.float32)
    g = rng.standard_normal((num_samples, Y.shape[0])).astype(np.float32)
    assignment, t_best, n_feasible = fn(root, g)
    return (
        np.asarray(assignment, dtype=np.int64),
        float(t_best),
        int(n_feasible),
    )


_DEVICE_ROOT_BATCH_FN = None


def _device_covariance_root_batch(Y_stack):
    """Batched eigen square roots of B stacked device covariances."""
    global _DEVICE_ROOT_BATCH_FN
    if _DEVICE_ROOT_BATCH_FN is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def _root(Ys):
            Ys = 0.5 * (Ys + jnp.transpose(Ys, (0, 2, 1)))
            w, V = jnp.linalg.eigh(Ys)
            return V * jnp.sqrt(jnp.clip(w, 0.0, None))[:, None, :]

        _DEVICE_ROOT_BATCH_FN = _root
    return _DEVICE_ROOT_BATCH_FN(Y_stack)


def randomized_rounding_batch(
    bqps,
    task_graphs,
    compute_graphs,
    Ys,
    *,
    num_samples: int = 2000,
    rngs=None,
    strict: bool = False,
    backend: str = "jax",
    Y_devices=None,
    kernel_backend: str = "auto",
) -> list[RoundingResult]:
    """Round B same-shape SDP solutions in ONE fused jitted dispatch.

    The per-instance pipeline is identical to ``randomized_rounding``'s jax
    backend (same gaussians from each instance's rng, same repair and
    selection), vmapped over the batch: sampling, sign folding, repair,
    bottleneck evaluation, and arg-best selection for all B instances run
    on device together.  When every instance carries a device-resident
    covariance (``Y_devices``), the B square roots are also taken in one
    batched ``eigh``.

    The Eq. (22)-(24)/(27) analysis bounds are computed per instance on the
    float64 host path — it is exact and avoids compiling B content-keyed
    device-analysis closures for instances that are typically seen once.

    Falls back to B sequential numpy-backend calls when jax is unavailable
    or ``backend`` is not "jax".
    """
    from repro import compat

    B = len(bqps)
    if not (len(task_graphs) == len(compute_graphs) == len(Ys) == B):
        raise ValueError("bqps, task_graphs, compute_graphs, Ys must align")
    if B == 0:
        return []
    if rngs is None:
        rngs = [None] * B
    if Y_devices is None:
        Y_devices = [None] * B

    T, K = bqps[0].n_tasks, bqps[0].n_machines
    n_e = len(task_graphs[0].edges)
    for bqp, tg in zip(bqps, task_graphs):
        if (bqp.n_tasks, bqp.n_machines, len(tg.edges)) != (T, K, n_e):
            raise ValueError(
                "randomized_rounding_batch requires same-shape instances "
                "(same n_tasks, n_machines, and task-graph edge count)"
            )

    if backend != "jax" or not compat.jax_available():
        return [
            randomized_rounding(
                bqp,
                tg,
                cg,
                Y,
                num_samples=num_samples,
                rng=rng,
                strict=strict,
                backend="numpy",
            )
            for bqp, tg, cg, Y, rng in zip(
                bqps, task_graphs, compute_graphs, Ys, rngs
            )
        ]

    p_s = np.stack([np.asarray(tg.p, np.float32) for tg in task_graphs])
    e_s = np.stack([np.asarray(cg.e, np.float32) for cg in compute_graphs])
    C_s = np.stack([np.asarray(cg.C, np.float32) for cg in compute_graphs])
    if n_e:
        src_s = np.stack(
            [np.asarray([i for (i, _) in tg.edges], np.int32) for tg in task_graphs]
        )
        dst_s = np.stack(
            [np.asarray([j for (_, j) in tg.edges], np.int32) for tg in task_graphs]
        )
    else:
        src_s = dst_s = np.zeros((B, 0), np.int32)

    if all(yd is not None for yd in Y_devices):
        import jax.numpy as jnp

        roots = _device_covariance_root_batch(jnp.stack(Y_devices))
    else:
        roots = np.stack(
            [_covariance_root(Y).astype(np.float32) for Y in Ys]
        )
    g = np.stack(
        [
            (rng or np.random.default_rng(0))
            .standard_normal((num_samples, Y.shape[0]))
            .astype(np.float32)
            for rng, Y in zip(rngs, Ys)
        ]
    )

    fn = _fused_rounding_batch_fn(
        B, T, K, n_e, strict, _rounding_kernel_backend(kernel_backend)
    )
    assignments, times, feas = fn(p_s, e_s, C_s, src_s, dst_s, roots, g)

    out = []
    for i in range(B):
        exp_b, lb, ub = analysis_bounds(bqps[i], Ys[i])
        out.append(
            RoundingResult(
                assignment=np.asarray(assignments[i], dtype=np.int64),
                bottleneck=float(times[i]),
                num_feasible=int(feas[i]),
                num_samples=num_samples,
                expected_bottleneck=exp_b,
                lower_bound=lb,
                upper_bound=ub,
            )
        )
    return out
