"""Unified scheduling API — the paper's technique as a first-class feature.

``schedule(task_graph, compute_graph, method=...)`` returns a ``Schedule``
with the assignment, its exact bottleneck time, and method-specific
diagnostics (SDP bounds, sample statistics, solver residuals).

Methods:
  - ``sdp``         : the paper — SDP relaxation + randomized rounding
  - ``sdp_naive``   : SDP relaxation + naive (argmax) rounding
  - ``sdp_ls``      : beyond-paper — ``sdp`` refined by 1-move local search
  - ``heft``        : HEFT on the §4.1.1 DAG rewrite
  - ``tp_heft``     : throughput-HEFT greedy period minimization
  - ``greedy`` / ``random`` / ``round_robin`` / ``sorted`` : simple baselines

SDP methods pick the problem representation automatically: the dense
``BQPData`` oracle for small instances, the matrix-free ``FactoredBQP``
once the dense (|E|, n, n) stacks would cross ``_DENSE_BYTES_LIMIT``
(DESIGN.md §2).  Override with ``representation=`` and observe the choice
in ``Schedule.info["representation"]``.

The SDP solver backend is selected the same way the rounding backend is:
``solver_backend=`` ("auto" | "numpy" | "jax", DESIGN.md §5) — "auto"
moves the Douglas-Rachford hot loop onto the JAX device once the Gram
side crosses ``SDPOptions.jax_above``.  ``warm_start=True`` keeps a
module-level cache of solver states keyed by the (task-graph,
compute-graph) *structural fingerprint*, so repeated ``schedule()`` calls
after incremental topology changes (speed EMA updates, elastic
re-scheduling) resume from the previous (Y, t, s) iterate instead of the
identity.

``Schedule.info`` reports the solver's Eq. 24 value as ``lower_bound``
only when the solve converged (``bound_certified``); an unconverged
iterate's value appears as ``lower_bound_uncertified`` instead — it is
*not* a bound and has historically exceeded the achieved bottleneck at
large n.  The rounding pass's own Eq. 24 re-evaluation is reported
separately as ``rounding_lower_bound``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core import bqp as bqp_mod
from repro.core.graphs import ComputeGraph, TaskGraph
from repro.core.rounding import (
    naive_rounding,
    randomized_rounding,
    randomized_rounding_batch,
)
from repro.core.sdp import SDPOptions, solve_sdp, solve_sdp_batch

METHODS = (
    "sdp",
    "sdp_naive",
    "sdp_ls",
    "heft",
    "tp_heft",
    "greedy",
    "random",
    "round_robin",
    "sorted",
)

REPRESENTATIONS = ("auto", "dense", "factored")

# Auto mode switches to the matrix-free representation once the dense
# Q/Q̃ stacks would exceed this many bytes (~100 MB ≈ N_T·N_K past ~300).
_DENSE_BYTES_LIMIT = 100_000_000

# Warm-start cache: structural fingerprint -> last SDPSolution.state.  The
# fingerprint deliberately excludes weights (p, e, C): an incremental
# topology change keeps the structure, so the previous iterate is a valid —
# and very close — starting point.  Dimension changes (machine failure)
# change the fingerprint and cold-start naturally.  True LRU: hits move
# the entry to the end of the (insertion-ordered) dict, and eviction pops
# the front — a hot fingerprint re-used on every re-solve survives while
# stale ones age out.
_WARM_STARTS: dict[tuple, dict] = {}
_WARM_STARTS_MAX = 8

# Batched warm starts: a tuple of per-instance fingerprints -> the list of
# per-lane solver states from the last ``schedule_batch`` of that exact
# batch composition.  Falls back lane-by-lane to ``_WARM_STARTS`` when the
# composition is new, and writes each lane's state back there after the
# solve so single-instance and batched re-solves stay interoperable.
_WARM_STARTS_BATCH: dict[tuple, list] = {}
_WARM_STARTS_BATCH_MAX = 4


def _warm_fingerprint(task_graph: TaskGraph, compute_graph: ComputeGraph) -> tuple:
    return (
        task_graph.num_tasks,
        compute_graph.num_machines,
        tuple(task_graph.edges),
    )


def clear_warm_start(
    task_graph: TaskGraph | None = None,
    compute_graph: ComputeGraph | None = None,
) -> bool:
    """Drop cached solver state for this problem structure (or all of it).

    The fingerprint deliberately ignores weights, so a later solve of a
    *different* instance with the same structure (e.g. the same ring
    topology under another seed) would otherwise resume from this one's
    iterate.  Callers that need runs reproducible from their own inputs
    alone (the scenario engine's drift simulation) clear the entry first.
    Called with no arguments it wipes BOTH caches wholesale — the churn
    simulation path uses this, since a churn trace re-solves at every
    fleet size and clearing one structure would leave the others warm.
    Returns True if anything was dropped.
    """
    if task_graph is None and compute_graph is None:
        hit = bool(_WARM_STARTS) or bool(_WARM_STARTS_BATCH)
        _WARM_STARTS.clear()
        _WARM_STARTS_BATCH.clear()
        return hit
    fp = _warm_fingerprint(task_graph, compute_graph)
    hit = _WARM_STARTS.pop(fp, None) is not None
    stale = [k for k in _WARM_STARTS_BATCH if fp in k]
    for k in stale:
        del _WARM_STARTS_BATCH[k]
    return hit or bool(stale)


def get_warm_start(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> dict | None:
    """Peek the cached solver state for this problem structure (or None).

    With ``seed_warm_start`` this is the control layer's handle on the
    warm-start cache: ``ElasticScheduler`` snapshots the state after each
    re-solve into its own fleet-composition-keyed cache and restores it
    when a composition recurs (fail → rejoin round trips), which the
    structure-only fingerprint cannot distinguish.  Reading does not
    touch LRU recency.
    """
    return _WARM_STARTS.get(_warm_fingerprint(task_graph, compute_graph))


def seed_warm_start(
    task_graph: TaskGraph, compute_graph: ComputeGraph, state: dict
) -> None:
    """Install ``state`` as the warm start for this problem structure.

    The next ``schedule(..., warm_start=True)`` of the same (N_T, N_K,
    edges) structure resumes from it.  Evicts LRU entries as needed, like
    a solve-produced insertion.
    """
    fp = _warm_fingerprint(task_graph, compute_graph)
    _WARM_STARTS.pop(fp, None)
    while len(_WARM_STARTS) >= _WARM_STARTS_MAX:
        _WARM_STARTS.pop(next(iter(_WARM_STARTS)))
    _WARM_STARTS[fp] = state


def _pick_representation(
    task_graph: TaskGraph, compute_graph: ComputeGraph, representation: str
) -> str:
    if representation not in REPRESENTATIONS:
        raise ValueError(
            f"unknown representation {representation!r}; "
            f"choose from {REPRESENTATIONS}"
        )
    if representation != "auto":
        return representation
    dense_bytes = bqp_mod.dense_bytes_estimate(task_graph, compute_graph)
    return "factored" if dense_bytes > _DENSE_BYTES_LIMIT else "dense"


@dataclasses.dataclass
class Schedule:
    """A task→machine assignment with its exact Eq. 2 bottleneck time.

    ``info`` carries method-specific diagnostics; for the sdp family:

      - ``representation`` — "dense" | "factored" (auto-picked, §2 of
        DESIGN.md) and ``solver_backend`` — "numpy" | "jax" (auto-picked
        once the Gram side crosses ``SDPOptions.jax_above``);
      - ``sdp_iterations`` / ``sdp_residual`` / ``sdp_converged`` /
        ``sdp_seconds`` / ``solver_stats`` — solver observability;
      - ``bound_certified`` and exactly ONE of ``lower_bound`` (Eq. 24 at
        a converged solve — a true bound) or ``lower_bound_uncertified``
        (the same value off an unconverged iterate — NOT a bound; it has
        exceeded the achieved bottleneck at large n).  Both always carry
        the SOLVER's value; the rounding pass's re-evaluation of Eq. 24
        on the Y it consumed (device fp32 on the jax backend) is kept
        separately as ``rounding_lower_bound`` and never overwrites it;
      - ``expected_bottleneck`` (Eqs. 22–23), ``upper_bound`` (Eq. 27),
        ``rounding_lower_bound`` (Eq. 24 re-evaluated at rounding),
        ``num_feasible``, ``warm_started`` — rounding diagnostics.
    """

    assignment: np.ndarray
    bottleneck: float
    method: str
    info: dict[str, Any] = dataclasses.field(default_factory=dict)

    def machine_of(self, task: int) -> int:
        return int(self.assignment[task])


def schedule(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    method: str = "sdp",
    *,
    seed: int = 0,
    num_samples: int = 4000,
    sdp_options: SDPOptions | None = None,
    rounding_backend: str = "jax",
    solver_backend: str | None = None,
    representation: str = "auto",
    warm_start: bool = False,
    _sdp_cache: dict | None = None,
) -> Schedule:
    """Compute a task->machine assignment minimizing bottleneck time.

    The sdp family auto-selects its machinery unless overridden:
    ``representation`` ("auto" picks dense vs. matrix-free by instance
    size), ``solver_backend`` (None defers to ``sdp_options.backend``,
    "auto" moves the solve on device past ``SDPOptions.jax_above``), and
    ``rounding_backend`` ("jax" fuses sampling→repair→evaluation into one
    jitted call).  ``warm_start=True`` resumes the solver from a cached
    iterate when the (N_T, N_K, edges) structure was seen before —
    re-schedules after weight-only changes (speed EMA updates, delay
    drift) converge in a fraction of the cold iteration count.  See
    ``Schedule`` for the ``info`` keys, including the certified
    ``lower_bound`` vs ``lower_bound_uncertified`` distinction.
    """
    rng = np.random.default_rng(seed)
    info: dict[str, Any] = {}

    if method in ("sdp", "sdp_naive", "sdp_ls"):
        cache = _sdp_cache if _sdp_cache is not None else {}
        if "sol" not in cache:
            rep = _pick_representation(task_graph, compute_graph, representation)
            if rep == "factored":
                cache["bqp"] = bqp_mod.build_factored_bqp(
                    task_graph, compute_graph
                )
            else:
                cache["bqp"] = bqp_mod.build_bqp(task_graph, compute_graph)
            cache["representation"] = rep
            opts = sdp_options or SDPOptions()
            if solver_backend is not None:
                opts = dataclasses.replace(opts, backend=solver_backend)
            fp = _warm_fingerprint(task_graph, compute_graph)
            ws = _WARM_STARTS.get(fp) if warm_start else None
            if ws is not None:
                # LRU hit: move to end now, so even if the new iterate is
                # rejected below the hot entry keeps its recency
                _WARM_STARTS[fp] = _WARM_STARTS.pop(fp)
            cache["sol"] = solve_sdp(cache["bqp"], opts, warm_start=ws)
            # never cache a diverged iterate — a poisoned state would make
            # every later warm re-solve NaN where a cold start recovers
            state = cache["sol"].state
            if warm_start and np.all(np.isfinite(state.get("w", np.inf))):
                if fp not in _WARM_STARTS:
                    while len(_WARM_STARTS) >= _WARM_STARTS_MAX:
                        _WARM_STARTS.pop(next(iter(_WARM_STARTS)))
                _WARM_STARTS[fp] = state
        data, sol = cache["bqp"], cache["sol"]
        info.update(
            representation=cache["representation"],
            sdp_iterations=sol.iterations,
            sdp_residual=sol.residual,
            sdp_converged=sol.converged,
            sdp_seconds=sol.solve_seconds,
            bound_certified=sol.bound_certified,
            solver_backend=sol.stats.get("solver_backend"),
            warm_started=sol.stats.get("warm_started", False),
            solver_stats=sol.stats,
        )
        # Eq. 24 is a certificate only at the SDP optimum: report the value
        # of an unconverged iterate under a name that can't be mistaken for
        # a bound (it has exceeded the achieved bottleneck at large n).
        bound_key = "lower_bound" if sol.bound_certified else "lower_bound_uncertified"
        info[bound_key] = sol.lower_bound
        if method == "sdp_naive":
            assignment = naive_rounding(data, sol.Y)
        else:
            # ``schedule_batch`` pre-rounds all lanes in one fused dispatch
            # and hands the result down here; sharing it across the sdp /
            # sdp_ls methods matches the sequential path, which redraws the
            # same gaussians from ``default_rng(seed)`` on every call.
            res = cache.get("rounding")
            if res is None:
                res = randomized_rounding(
                    data,
                    task_graph,
                    compute_graph,
                    sol.Y,
                    num_samples=num_samples,
                    rng=rng,
                    backend=rounding_backend,
                    Y_device=sol.Y_device,
                )
            # the rounding pass re-evaluates Eq. 24 on the Y it consumed
            # (possibly on device, in fp32); keep it under its own key —
            # it must not overwrite the solver's certified value
            info.update(
                num_feasible=res.num_feasible,
                expected_bottleneck=res.expected_bottleneck,
                upper_bound=res.upper_bound,
                rounding_lower_bound=res.lower_bound,
            )
            assignment = res.assignment
            if method == "sdp_ls":
                from repro.sched.baselines import local_search_refine

                assignment = local_search_refine(
                    task_graph, compute_graph, assignment
                )
    elif method == "heft":
        from repro.sched.heft import heft_assignment

        assignment = heft_assignment(task_graph, compute_graph)
    elif method == "tp_heft":
        from repro.sched.tp_heft import tp_heft_assignment

        assignment = tp_heft_assignment(task_graph, compute_graph)
    elif method == "greedy":
        from repro.sched.baselines import greedy_bottleneck_assignment

        assignment = greedy_bottleneck_assignment(task_graph, compute_graph)
    elif method == "random":
        from repro.sched.baselines import random_assignment

        assignment = random_assignment(task_graph, compute_graph, rng)
    elif method == "round_robin":
        from repro.sched.baselines import round_robin_assignment

        assignment = round_robin_assignment(task_graph, compute_graph)
    elif method == "sorted":
        from repro.sched.baselines import sorted_assignment

        assignment = sorted_assignment(task_graph, compute_graph)
    else:
        raise ValueError(f"unknown method {method!r}; choose from {METHODS}")

    t = bqp_mod.bottleneck_time(task_graph, compute_graph, assignment)
    return Schedule(
        assignment=np.asarray(assignment, dtype=np.int64),
        bottleneck=t,
        method=method,
        info=info,
    )


def schedule_batch(
    task_graphs,
    compute_graphs,
    method: str = "sdp",
    *,
    seed: int = 0,
    num_samples: int = 4000,
    sdp_options: SDPOptions | None = None,
    rounding_backend: str = "jax",
    solver_backend: str | None = None,
    representation: str = "auto",
    warm_start: bool = False,
) -> list[Schedule]:
    """Schedule B same-shape instances with ONE batched SDP solve.

    The scheduler-as-a-service entry point: all B Douglas-Rachford solves
    run as a single jitted dispatch with per-instance convergence masking
    (``solve_sdp_batch``), and the Gaussian roundings run as one fused
    batched dispatch (``randomized_rounding_batch``).  Each returned
    ``Schedule`` matches what B independent ``schedule()`` calls with the
    same ``seed`` would produce (same gaussians per lane, same ``info``
    keys) up to float32 batching noise.

    ``warm_start=True`` keys the B stacked solver states by the tuple of
    per-instance structural fingerprints: re-scheduling the same batch
    composition after weight-only changes (delay drift across a fleet)
    restores all lanes at once, a new composition falls back lane-by-lane
    to the single-instance cache, and the per-lane states are written back
    to it so batched and single re-solves interoperate.

    Instances must share (n_tasks, n_machines, edge count); non-sdp
    methods and empty batches degrade to sequential ``schedule()`` calls.
    """
    B = len(task_graphs)
    if len(compute_graphs) != B:
        raise ValueError("task_graphs and compute_graphs must align")
    if B == 0:
        return []
    if method not in ("sdp", "sdp_naive", "sdp_ls"):
        return [
            schedule(
                tg, cg, method,
                seed=seed,
                num_samples=num_samples,
                sdp_options=sdp_options,
                rounding_backend=rounding_backend,
                solver_backend=solver_backend,
                representation=representation,
                warm_start=warm_start,
            )
            for tg, cg in zip(task_graphs, compute_graphs)
        ]

    reps = {
        _pick_representation(tg, cg, representation)
        for tg, cg in zip(task_graphs, compute_graphs)
    }
    if len(reps) != 1:
        raise ValueError("schedule_batch requires a uniform representation")
    rep = reps.pop()
    build = (
        bqp_mod.build_factored_bqp if rep == "factored" else bqp_mod.build_bqp
    )
    bqps = [build(tg, cg) for tg, cg in zip(task_graphs, compute_graphs)]

    opts = sdp_options or SDPOptions()
    if solver_backend is not None:
        opts = dataclasses.replace(opts, backend=solver_backend)

    fps = [
        _warm_fingerprint(tg, cg)
        for tg, cg in zip(task_graphs, compute_graphs)
    ]
    batch_key = tuple(fps)
    warm_states: list = [None] * B
    if warm_start:
        cached = _WARM_STARTS_BATCH.get(batch_key)
        if cached is not None:
            _WARM_STARTS_BATCH[batch_key] = _WARM_STARTS_BATCH.pop(batch_key)
            warm_states = list(cached)
        else:
            warm_states = [_WARM_STARTS.get(fp) for fp in fps]

    sols = solve_sdp_batch(bqps, opts, warm_starts=warm_states)

    if warm_start:
        states = [s.state for s in sols]
        finite = [
            bool(np.all(np.isfinite(st.get("w", np.inf)))) for st in states
        ]
        if all(finite):
            if batch_key not in _WARM_STARTS_BATCH:
                while len(_WARM_STARTS_BATCH) >= _WARM_STARTS_BATCH_MAX:
                    _WARM_STARTS_BATCH.pop(next(iter(_WARM_STARTS_BATCH)))
            _WARM_STARTS_BATCH[batch_key] = states
        for fp, st, ok in zip(fps, states, finite):
            if not ok:
                continue
            if fp in _WARM_STARTS:
                _WARM_STARTS.pop(fp)
            else:
                while len(_WARM_STARTS) >= _WARM_STARTS_MAX:
                    _WARM_STARTS.pop(next(iter(_WARM_STARTS)))
            _WARM_STARTS[fp] = st

    rounding_results: list = [None] * B
    if method in ("sdp", "sdp_ls"):
        rounding_results = randomized_rounding_batch(
            bqps,
            task_graphs,
            compute_graphs,
            [s.Y for s in sols],
            num_samples=num_samples,
            rngs=[np.random.default_rng(seed) for _ in range(B)],
            backend=rounding_backend,
            Y_devices=[s.Y_device for s in sols],
        )

    out = []
    for tg, cg, bqp, sol, res in zip(
        task_graphs, compute_graphs, bqps, sols, rounding_results
    ):
        cache = {"bqp": bqp, "sol": sol, "representation": rep}
        if res is not None:
            cache["rounding"] = res
        out.append(
            schedule(
                tg, cg, method,
                seed=seed,
                num_samples=num_samples,
                sdp_options=sdp_options,
                rounding_backend=rounding_backend,
                representation=representation,
                _sdp_cache=cache,
            )
        )
    return out


def compare_methods(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    methods: tuple[str, ...] = ("heft", "tp_heft", "sdp_naive", "sdp"),
    _sdp_cache: dict | None = None,
    **kw,
) -> dict[str, Schedule]:
    """Run several schedulers on one instance, sharing one SDP solve."""
    cache: dict = _sdp_cache if _sdp_cache is not None else {}
    out = {}
    for m in methods:
        out[m] = schedule(task_graph, compute_graph, m, _sdp_cache=cache, **kw)
    return out
