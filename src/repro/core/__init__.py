"""Core of the paper: bottleneck-time-minimizing task scheduling via SDP."""

from repro.core.bqp import (
    BQPData,
    FactoredBQP,
    bottleneck_time,
    bottleneck_time_batch,
    brute_force_optimum,
    build_bqp,
    build_factored_bqp,
    dense_bytes_estimate,
)
from repro.core.graphs import (
    ComputeGraph,
    TaskGraph,
    gossip_task_graph,
    random_compute_graph,
    random_task_graph,
)
from repro.core.rounding import (
    RoundingResult,
    expected_bottleneck,
    naive_rounding,
    optimal_upper_bound,
    randomized_rounding,
    sdp_lower_bound,
)
from repro.core.scheduler import (
    METHODS,
    REPRESENTATIONS,
    Schedule,
    compare_methods,
    schedule,
)
from repro.core.sdp import SDPOptions, SDPSolution, solve_sdp

__all__ = [
    "BQPData",
    "ComputeGraph",
    "FactoredBQP",
    "METHODS",
    "REPRESENTATIONS",
    "RoundingResult",
    "SDPOptions",
    "SDPSolution",
    "Schedule",
    "TaskGraph",
    "bottleneck_time",
    "bottleneck_time_batch",
    "brute_force_optimum",
    "build_bqp",
    "build_factored_bqp",
    "compare_methods",
    "dense_bytes_estimate",
    "expected_bottleneck",
    "gossip_task_graph",
    "naive_rounding",
    "optimal_upper_bound",
    "randomized_rounding",
    "random_compute_graph",
    "random_task_graph",
    "schedule",
    "sdp_lower_bound",
    "solve_sdp",
]
