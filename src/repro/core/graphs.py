"""Task and compute graphs for distributed iterative processes.

The paper models an iterative process as a *general directed graph* (cycles
allowed) of tasks, executed on a complete graph of networked machines.

  - ``TaskGraph``: tasks with per-task work ``p`` and directed data
    dependencies (task i's output is consumed by its successors each
    iteration).
  - ``ComputeGraph``: machines with execution speeds ``e`` and a pairwise
    communication-delay matrix ``C`` (seconds to ship one task's output
    from machine j to machine j'); ``C[j, j] == 0``.

Both are plain, immutable, numpy-backed containers so they can be consumed
from host-side schedulers and from JAX code alike.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

Edge = tuple[int, int]


@dataclasses.dataclass(frozen=True)
class TaskGraph:
    """Directed (possibly cyclic) graph of tasks.

    Attributes:
      p: (N_T,) required computation of each task (work units).
      edges: list of (i, i') pairs — task i produces input for task i'.
    """

    p: np.ndarray
    edges: tuple[Edge, ...]

    def __post_init__(self):
        object.__setattr__(self, "p", np.asarray(self.p, dtype=np.float64))
        if self.p.ndim != 1:
            raise ValueError(f"p must be 1-D, got shape {self.p.shape}")
        n = self.num_tasks
        for (i, j) in self.edges:
            if not (0 <= i < n and 0 <= j < n):
                raise ValueError(f"edge ({i},{j}) out of range for {n} tasks")
        if np.any(self.p < 0):
            raise ValueError("task work p must be non-negative")

    @property
    def num_tasks(self) -> int:
        return int(self.p.shape[0])

    @property
    def adjacency(self) -> np.ndarray:
        """(N_T, N_T) boolean adjacency: A[i, i'] = 1 iff edge (i -> i')."""
        a = np.zeros((self.num_tasks, self.num_tasks), dtype=bool)
        for (i, j) in self.edges:
            a[i, j] = True
        return a

    def successors(self, i: int) -> list[int]:
        return [j for (a, j) in self.edges if a == i]

    def predecessors(self, i: int) -> list[int]:
        return [a for (a, j) in self.edges if j == i]

    def constraint_edges(self) -> tuple[Edge, ...]:
        """Edges that generate BQP constraints.

        The paper constrains ``t_comp(i) + C[m(i), m(i')] <= t`` for every
        task-graph edge (i, i').  A task with no successors still has a
        compute time, so we add a self-loop (i, i) for it — ``C[j, j] = 0``
        makes that constraint exactly ``t_comp(i) <= t``.
        """
        has_succ = set(i for (i, _) in self.edges)
        extra = tuple((i, i) for i in range(self.num_tasks) if i not in has_succ)
        return tuple(self.edges) + extra

    def validate_is_dag(self) -> bool:
        """True iff the task graph is acyclic (HEFT needs the DAG rewrite otherwise)."""
        n = self.num_tasks
        adj = {i: [] for i in range(n)}
        indeg = [0] * n
        for (i, j) in self.edges:
            adj[i].append(j)
            indeg[j] += 1
        stack = [i for i in range(n) if indeg[i] == 0]
        seen = 0
        while stack:
            u = stack.pop()
            seen += 1
            for v in adj[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        return seen == n


@dataclasses.dataclass(frozen=True)
class ComputeGraph:
    """Complete graph of networked machines.

    Attributes:
      e: (N_K,) execution speeds (work units / second); > 0.
      C: (N_K, N_K) communication delay matrix, C[j, j'] = delay of shipping
         one task's output from machine j to j'; diagonal is zero.
    """

    e: np.ndarray
    C: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "e", np.asarray(self.e, dtype=np.float64))
        object.__setattr__(self, "C", np.asarray(self.C, dtype=np.float64))
        if self.e.ndim != 1:
            raise ValueError("e must be 1-D")
        k = self.num_machines
        if self.C.shape != (k, k):
            raise ValueError(f"C must be ({k},{k}), got {self.C.shape}")
        if np.any(self.e <= 0):
            raise ValueError("machine speeds must be positive")
        if np.any(self.C < 0):
            raise ValueError("communication delays must be non-negative")
        if np.any(np.abs(np.diag(self.C)) > 0):
            raise ValueError("C diagonal (self-communication) must be zero")

    @property
    def num_machines(self) -> int:
        return int(self.e.shape[0])

    @classmethod
    def from_bandwidths(
        cls, e: Sequence[float], bandwidth: np.ndarray, message_bytes: float
    ) -> "ComputeGraph":
        """Build the delay matrix from link bandwidths and a message size.

        ``bandwidth[j, j']`` in bytes/s; zero bandwidth => effectively
        infinite delay (paper: unconnected machines).
        """
        bw = np.asarray(bandwidth, dtype=np.float64)
        with np.errstate(divide="ignore"):
            C = np.where(bw > 0, message_bytes / np.maximum(bw, 1e-300), np.inf)
        np.fill_diagonal(C, 0.0)
        # Replace inf with a large-but-finite sentinel so the BQP stays numeric.
        finite = C[np.isfinite(C)]
        cap = (finite.max() * 1e3 + 1.0) if finite.size else 1.0
        C = np.where(np.isfinite(C), C, cap)
        return cls(e=np.asarray(e, dtype=np.float64), C=C)


# ---------------------------------------------------------------------------
# Random instance generators (paper §4 settings)
# ---------------------------------------------------------------------------


def random_task_graph(
    rng: np.random.Generator,
    num_tasks: int,
    *,
    degree_low: int = 2,
    degree_high: int = 4,
    p_sigma: float = 1.0,
) -> TaskGraph:
    """Random directed task graph with per-vertex out-degree ~ U{degree_low, degree_high}.

    Work p ~ |N(0, p_sigma)| (folded normal — the paper samples N(0, sigma);
    negative work is non-physical, see DESIGN.md §3).
    """
    if num_tasks < 2:
        raise ValueError("need >= 2 tasks")
    p = np.abs(rng.normal(0.0, p_sigma, size=num_tasks)) + 1e-3
    edges: list[Edge] = []
    hi = min(degree_high, num_tasks - 1)
    lo = min(degree_low, hi)
    for i in range(num_tasks):
        deg = int(rng.integers(lo, hi + 1))
        others = [j for j in range(num_tasks) if j != i]
        targets = rng.choice(others, size=deg, replace=False)
        edges.extend((i, int(t)) for t in targets)
    return TaskGraph(p=p, edges=tuple(sorted(set(edges))))


def random_compute_graph(
    rng: np.random.Generator,
    num_machines: int,
    *,
    e_sigma: float = np.sqrt(15.0),
    c_sigma: float = np.sqrt(10.0),
    c_uniform: bool = False,
) -> ComputeGraph:
    """Paper §4.1.2 settings: C ~ |N(0, sqrt(10))| i.i.d., e ~ |N(0, sqrt(15))|.

    With ``c_uniform=True`` uses the §4.2 FL setting C ~ Unif(0, 1).
    """
    e = np.abs(rng.normal(0.0, e_sigma, size=num_machines)) + 1e-2
    if c_uniform:
        C = rng.uniform(0.0, 1.0, size=(num_machines, num_machines))
    else:
        C = np.abs(rng.normal(0.0, c_sigma, size=(num_machines, num_machines)))
    np.fill_diagonal(C, 0.0)
    return ComputeGraph(e=e, C=C)


def gossip_task_graph(
    rng: np.random.Generator,
    num_users: int,
    *,
    degree_low: int = 6,
    degree_high: int = 7,
    p: np.ndarray | None = None,
) -> TaskGraph:
    """Paper §4.2: gossip topology, out-degree ~ Unif{degree_low, degree_high}.

    All users hold equal data shards => equal work by default.
    """
    if p is None:
        p = np.ones(num_users)
    g = random_task_graph(
        rng, num_users, degree_low=degree_low, degree_high=degree_high
    )
    return TaskGraph(p=np.asarray(p, dtype=np.float64), edges=g.edges)


# ---------------------------------------------------------------------------
# Topology families (scenario engine, DESIGN.md §4)
# ---------------------------------------------------------------------------
#
# Each generator returns a ``TaskGraph`` over ``num_tasks`` vertices with
# unit work by default (pass ``p=`` for heterogeneous work).  Directed-edge
# semantics are the paper's: edge (i, j) means task i's output feeds task j
# every iteration, so undirected families (ring, torus, small-world,
# scale-free) emit both directions of every link — the gossip exchange is
# bidirectional on those topologies.


def _with_work(edges: Iterable[Edge], num_tasks: int, p) -> TaskGraph:
    if p is None:
        p = np.ones(num_tasks)
    return TaskGraph(p=np.asarray(p, dtype=np.float64), edges=tuple(sorted(set(edges))))


def ring_task_graph(
    num_tasks: int, *, bidirectional: bool = True, p: np.ndarray | None = None
) -> TaskGraph:
    """Ring of ``num_tasks`` vertices: i -> (i+1) mod n (and back if bidirectional)."""
    if num_tasks < 2:
        raise ValueError("need >= 2 tasks")
    edges = [(i, (i + 1) % num_tasks) for i in range(num_tasks)]
    if bidirectional:
        edges += [(j, i) for (i, j) in edges]
    return _with_work(edges, num_tasks, p)


def torus_task_graph(
    rows: int, cols: int, *, p: np.ndarray | None = None
) -> TaskGraph:
    """2-D wraparound grid (rows x cols): every vertex exchanges with its
    4 lattice neighbors (both directions), ``num_tasks = rows * cols``."""
    if rows < 2 or cols < 2:
        raise ValueError("torus needs rows, cols >= 2")
    n = rows * cols
    edges: list[Edge] = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for (dr, dc) in ((0, 1), (1, 0)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if i != j:                      # 2-wide axes collapse to self
                    edges += [(i, j), (j, i)]
    return _with_work(edges, n, p)


def erdos_renyi_task_graph(
    rng: np.random.Generator,
    num_tasks: int,
    *,
    edge_prob: float = 0.2,
    p: np.ndarray | None = None,
) -> TaskGraph:
    """Directed G(n, q): each ordered pair (i, j), i != j, independently
    becomes an edge with probability ``edge_prob``."""
    if num_tasks < 2:
        raise ValueError("need >= 2 tasks")
    mask = rng.random((num_tasks, num_tasks)) < edge_prob
    np.fill_diagonal(mask, False)
    edges = [(int(i), int(j)) for i, j in zip(*np.nonzero(mask))]
    return _with_work(edges, num_tasks, p)


def scale_free_task_graph(
    rng: np.random.Generator,
    num_tasks: int,
    *,
    attach: int = 2,
    p: np.ndarray | None = None,
) -> TaskGraph:
    """Barabási–Albert preferential attachment (undirected, both directions).

    Starts from a clique of ``attach + 1`` seed vertices; every later vertex
    links to ``attach`` distinct existing vertices sampled proportionally to
    their current degree — a few high-degree hubs emerge, the classic
    "parameter-server-ish" extreme for gossip averaging.
    """
    seed_n = attach + 1
    if num_tasks < seed_n + 1:
        raise ValueError(f"need > {seed_n} tasks for attach={attach}")
    und: set[tuple[int, int]] = {
        (a, b) for a in range(seed_n) for b in range(a + 1, seed_n)
    }
    degree = np.zeros(num_tasks)
    degree[:seed_n] = seed_n - 1
    for v in range(seed_n, num_tasks):
        targets: set[int] = set()
        while len(targets) < attach:
            w = degree[:v] / degree[:v].sum()
            t = int(rng.choice(v, p=w))
            targets.add(t)
        for t in targets:
            und.add((min(v, t), max(v, t)))
            degree[v] += 1
            degree[t] += 1
    edges = [(a, b) for (a, b) in und] + [(b, a) for (a, b) in und]
    return _with_work(edges, num_tasks, p)


def small_world_task_graph(
    rng: np.random.Generator,
    num_tasks: int,
    *,
    k: int = 4,
    rewire_prob: float = 0.1,
    p: np.ndarray | None = None,
) -> TaskGraph:
    """Watts–Strogatz small world (undirected, both directions emitted).

    Ring lattice where every vertex links to its ``k // 2`` nearest
    neighbors on each side; each lattice edge is rewired to a uniform
    random endpoint with probability ``rewire_prob``.
    """
    half = k // 2
    if half < 1 or num_tasks <= k:
        raise ValueError(f"need num_tasks > k >= 2, got n={num_tasks}, k={k}")
    und: set[tuple[int, int]] = set()
    for i in range(num_tasks):
        for d in range(1, half + 1):
            j = (i + d) % num_tasks
            if rng.random() < rewire_prob:
                choices = [
                    c for c in range(num_tasks)
                    if c != i and (min(i, c), max(i, c)) not in und
                ]
                if choices:
                    j = int(rng.choice(choices))
            und.add((min(i, j), max(i, j)))
    edges = [(a, b) for (a, b) in und] + [(b, a) for (a, b) in und]
    return _with_work(edges, num_tasks, p)


def layered_dag_task_graph(
    rng: np.random.Generator,
    layers: int,
    width: int,
    *,
    edge_prob: float = 0.5,
    p: np.ndarray | None = None,
) -> TaskGraph:
    """Layered feed-forward DAG (``layers`` x ``width`` vertices).

    Each vertex links to each vertex of the next layer with probability
    ``edge_prob``; every non-final vertex is guaranteed an outgoing edge and
    every non-first vertex an incoming one, so the pipeline is connected.
    The result always passes ``TaskGraph.validate_is_dag``.
    """
    if layers < 2 or width < 1:
        raise ValueError("need layers >= 2, width >= 1")
    edges: list[Edge] = []
    for l in range(layers - 1):
        lo, nxt = l * width, (l + 1) * width
        covered_in = set()
        for a in range(lo, lo + width):
            targets = [nxt + b for b in range(width) if rng.random() < edge_prob]
            if not targets:                      # guarantee an outgoing edge
                targets = [nxt + int(rng.integers(width))]
            edges += [(a, t) for t in targets]
            covered_in.update(targets)
        for b in range(nxt, nxt + width):        # guarantee an incoming edge
            if b not in covered_in:
                edges.append((lo + int(rng.integers(width)), b))
    return _with_work(edges, layers * width, p)


# ---------------------------------------------------------------------------
# Hierarchical / clustered topologies (population-scale gossip, DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# "Graph-based Gossiping for Communication Efficiency in Decentralized
# Federated Learning" (PAPERS.md): organize users as edge clusters whose
# members gossip densely with each other while only designated CLUSTER
# HEADS gossip on a sparse global graph — communication grows with the
# head graph, not with the population.  The cluster structure is also what
# the sharded FL engine partitions across its user mesh: clusters map onto
# shards, so the only cross-shard (halo) edges are head-to-head links.

CLUSTER_INNER_TOPOLOGIES = ("dense", "ring", "gossip")
CLUSTER_HEAD_TOPOLOGIES = ("ring", "dense")


def cluster_assignment(num_tasks: int, clusters: int) -> np.ndarray:
    """(num_tasks,) cluster id per vertex — the contiguous balanced split
    ``cluster_task_graph`` uses (cluster sizes differ by at most one)."""
    if not (1 <= clusters <= num_tasks):
        raise ValueError(
            f"need 1 <= clusters <= num_tasks, got clusters={clusters}, "
            f"num_tasks={num_tasks}"
        )
    out = np.empty(num_tasks, dtype=np.int64)
    for c, block in enumerate(np.array_split(np.arange(num_tasks), clusters)):
        out[block] = c
    return out


def cluster_task_graph(
    rng: np.random.Generator,
    num_tasks: int,
    *,
    clusters: int = 4,
    inner_topology: str = "dense",
    head_topology: str = "ring",
    heads_per_cluster: int = 1,
    inner_degree: int = 3,
    p: np.ndarray | None = None,
) -> TaskGraph:
    """Hierarchical gossip: dense intra-cluster exchange, sparse head graph.

    Vertices are split into ``clusters`` contiguous groups
    (``cluster_assignment``).  Within each cluster the ``inner_topology``
    family wires the members (``dense`` = complete digraph, ``ring``, or
    ``gossip`` = ``inner_degree`` random undirected neighbors per member);
    the first ``heads_per_cluster`` vertices of each cluster are its heads,
    and corresponding heads of neighboring clusters exchange on the
    ``head_topology`` graph over clusters (``ring`` or ``dense``).  Every
    link is undirected — both edge directions are emitted, like the other
    undirected families.
    """
    if inner_topology not in CLUSTER_INNER_TOPOLOGIES:
        raise ValueError(
            f"unknown inner topology {inner_topology!r}; "
            f"choose from {CLUSTER_INNER_TOPOLOGIES}"
        )
    if head_topology not in CLUSTER_HEAD_TOPOLOGIES:
        raise ValueError(
            f"unknown head topology {head_topology!r}; "
            f"choose from {CLUSTER_HEAD_TOPOLOGIES}"
        )
    if clusters < 2:
        raise ValueError(f"need >= 2 clusters, got {clusters}")
    if num_tasks < 2 * clusters:
        raise ValueError(
            f"need >= 2 members per cluster: num_tasks={num_tasks} < "
            f"2 * clusters={2 * clusters}"
        )
    cluster_of = cluster_assignment(num_tasks, clusters)
    members = [np.nonzero(cluster_of == c)[0] for c in range(clusters)]
    min_size = min(len(m) for m in members)
    if not (1 <= heads_per_cluster <= min_size):
        raise ValueError(
            f"heads_per_cluster={heads_per_cluster} must be in "
            f"[1, {min_size}] (the smallest cluster size)"
        )
    if inner_topology == "gossip" and inner_degree < 1:
        raise ValueError(f"inner_degree must be >= 1, got {inner_degree}")

    und: set[tuple[int, int]] = set()

    def link(a: int, b: int) -> None:
        if a != b:
            und.add((min(a, b), max(a, b)))

    for mem in members:
        k = len(mem)
        if inner_topology == "dense":
            for x in range(k):
                for y in range(x + 1, k):
                    link(int(mem[x]), int(mem[y]))
        elif inner_topology == "ring":
            for x in range(k):
                link(int(mem[x]), int(mem[(x + 1) % k]))
        else:  # gossip: inner_degree random undirected neighbors per member
            deg = min(inner_degree, k - 1)
            for x in range(k):
                others = np.concatenate([mem[:x], mem[x + 1 :]])
                for t in rng.choice(others, size=deg, replace=False):
                    link(int(mem[x]), int(t))

    # Head graph over clusters: head h of cluster c links to head h of each
    # neighboring cluster (ring) or of every other cluster (dense).
    for c in range(clusters):
        peers = (
            [(c + 1) % clusters] if head_topology == "ring"
            else [d for d in range(clusters) if d != c]
        )
        for d in peers:
            for h in range(heads_per_cluster):
                link(int(members[c][h]), int(members[d][h]))

    edges = [(a, b) for (a, b) in und] + [(b, a) for (a, b) in und]
    return _with_work(edges, num_tasks, p)


# ---------------------------------------------------------------------------
# Graph-partition utilities (user-mesh sharding, DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The sharded FL engine splits users into ``num_shards`` CONTIGUOUS blocks
# of equal (padded) size; every task-graph edge crossing a block boundary
# becomes halo traffic.  These helpers relabel users so that clusters land
# whole on shards, minimizing those boundary edges.


def contiguous_shard_of(num_tasks: int, num_shards: int) -> np.ndarray:
    """(num_tasks,) shard id under the engine's contiguous block layout:
    user ``u`` lives on shard ``u // ceil(num_tasks / num_shards)``."""
    if num_shards < 1:
        raise ValueError(f"need >= 1 shard, got {num_shards}")
    block = -(-num_tasks // num_shards)
    return np.arange(num_tasks) // block


def halo_edge_count(task_graph: TaskGraph, shard_of: np.ndarray) -> int:
    """Number of task-graph edges whose endpoints live on different shards
    (each such edge ships one boundary row per round)."""
    shard_of = np.asarray(shard_of)
    if shard_of.shape != (task_graph.num_tasks,):
        raise ValueError(
            f"shard_of shape {shard_of.shape} != ({task_graph.num_tasks},)"
        )
    return int(
        sum(1 for (i, j) in task_graph.edges if shard_of[i] != shard_of[j])
    )


def cluster_shard_permutation(
    cluster_of: np.ndarray, num_shards: int
) -> np.ndarray:
    """User permutation packing whole clusters onto contiguous shard blocks.

    Lists users cluster by cluster IN CLUSTER-INDEX ORDER, so relabeling
    with ``permute_task_graph(tg, perm)`` makes the engine's contiguous
    ``ceil(n / num_shards)`` blocks respect cluster boundaries wherever
    cluster sizes allow — only head-to-head (inter-cluster) links can then
    cross shards.  Order preservation matters: the ``cluster`` family's
    head graph connects ring-ADJACENT cluster indices, so keeping
    neighboring clusters next to each other also keeps most head links
    intra-shard (a balanced-load bin-packing that scatters adjacent
    clusters measurably worsens the halo).  ``perm[new] = old``: new user
    ``k`` is old user ``perm[k]``.
    """
    cluster_of = np.asarray(cluster_of)
    if num_shards < 1:
        raise ValueError(f"need >= 1 shard, got {num_shards}")
    # stable sort by cluster id: groups clusters, preserves user order
    # within each cluster and cluster-index adjacency across them
    return np.argsort(cluster_of, kind="stable").astype(np.int64)


def permute_task_graph(
    task_graph: TaskGraph, perm: np.ndarray
) -> TaskGraph:
    """Relabel tasks by ``perm`` (``perm[new] = old``): work and edges move
    with their task, so the relabeled graph is isomorphic to the input."""
    perm = np.asarray(perm)
    n = task_graph.num_tasks
    if sorted(perm.tolist()) != list(range(n)):
        raise ValueError(f"perm must be a permutation of range({n})")
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n)
    return TaskGraph(
        p=task_graph.p[perm],
        edges=tuple(
            sorted((int(inv[i]), int(inv[j])) for (i, j) in task_graph.edges)
        ),
    )


TOPOLOGY_FAMILIES = (
    "ring",
    "torus",
    "erdos_renyi",
    "scale_free",
    "small_world",
    "layered_dag",
    "cluster",
    "gossip",
    "random",
)
