"""Binary Quadratic Program formulation of bottleneck-time minimization.

Implements Eqs. (7)-(21) of the paper:

  - per-edge quadratic forms ``Q_{i,i'} = D ⊗ (p δ_iᵀ) + C ⊗ (δ_i δ_{i'}ᵀ)``
    over ``m = vec(M)`` (column-major, ``m[κ·N_T + τ] = M[τ, κ]``),
  - the ±1 homogenized forms ``Q̃_{i,i'}`` and assignment matrices ``A_i``,
  - exact bottleneck-time evaluation of any assignment (numpy and JAX,
    batched) — used both by the schedulers and as the test oracle.

Note: the paper writes the communication Kronecker term as
``Cᵀ ⊗ I_iᵀ I_{i'}``; with column-major ``vec`` the form that reproduces
``C[m(i), m(i')]`` is ``C ⊗ (δ_i δ_{i'}ᵀ)``.  We use the latter and verify
against the direct evaluator in tests (the evaluator is the ground truth).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graphs import ComputeGraph, Edge, TaskGraph


# ---------------------------------------------------------------------------
# Direct evaluation (ground truth)
# ---------------------------------------------------------------------------


def assignment_to_matrix(assignment: np.ndarray, num_machines: int) -> np.ndarray:
    """(N_T,) machine indices -> one-hot (N_T, N_K)."""
    a = np.asarray(assignment, dtype=np.int64)
    M = np.zeros((a.shape[0], num_machines), dtype=np.float64)
    M[np.arange(a.shape[0]), a] = 1.0
    return M


def task_times(
    task_graph: TaskGraph, compute_graph: ComputeGraph, assignment: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per-task (t_comp, t_comm) for an assignment vector (N_T,) of machine ids.

    t_comp(i) = sum of work co-located with i / speed of m(i)     (Eq. 7)
    t_comm(i) = max over successors i' of C[m(i), m(i')]          (Eq. 10)
    """
    a = np.asarray(assignment, dtype=np.int64)
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    loads = np.zeros(compute_graph.num_machines)
    np.add.at(loads, a, p)
    t_comp = loads[a] / e[a]
    t_comm = np.zeros(task_graph.num_tasks)
    for (i, j) in task_graph.edges:
        t_comm[i] = max(t_comm[i], C[a[i], a[j]])
    return t_comp, t_comm


def bottleneck_time(
    task_graph: TaskGraph, compute_graph: ComputeGraph, assignment: np.ndarray
) -> float:
    """Eq. (2): max over tasks of compute + communicate time."""
    t_comp, t_comm = task_times(task_graph, compute_graph, assignment)
    return float(np.max(t_comp + t_comm))


def bottleneck_time_batch(
    task_graph: TaskGraph, compute_graph: ComputeGraph, assignments: np.ndarray
) -> np.ndarray:
    """Vectorized bottleneck over a batch (B, N_T) of assignment vectors."""
    a = np.asarray(assignments, dtype=np.int64)
    if a.ndim == 1:
        a = a[None]
    B, n_t = a.shape
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    n_k = compute_graph.num_machines
    onehot = np.zeros((B, n_t, n_k))
    onehot[np.arange(B)[:, None], np.arange(n_t)[None, :], a] = 1.0
    loads = np.einsum("bti,t->bi", onehot, p)          # (B, N_K)
    t_comp = np.take_along_axis(loads / e[None], a, axis=1)  # (B, N_T)
    t = t_comp.copy()
    if task_graph.edges:
        src = np.array([i for (i, _) in task_graph.edges])
        dst = np.array([j for (_, j) in task_graph.edges])
        delays = C[a[:, src], a[:, dst]]               # (B, |E|)
        comm = np.zeros_like(t_comp)
        np.maximum.at(comm, (np.arange(B)[:, None], src[None, :].repeat(B, 0)), delays)
        t = t_comp + comm
    return np.max(t, axis=1)


def brute_force_optimum(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> tuple[np.ndarray, float]:
    """Exact optimum by enumeration (tests only; N_K ** N_T assignments)."""
    n_t, n_k = task_graph.num_tasks, compute_graph.num_machines
    total = n_k**n_t
    if total > 2_000_000:
        raise ValueError(f"brute force too large: {n_k}^{n_t}")
    idx = np.arange(total)
    assignments = np.empty((total, n_t), dtype=np.int64)
    for t in range(n_t):
        assignments[:, t] = idx % n_k
        idx = idx // n_k
    times = bottleneck_time_batch(task_graph, compute_graph, assignments)
    best = int(np.argmin(times))
    return assignments[best], float(times[best])


# ---------------------------------------------------------------------------
# BQP / SDP matrices
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BQPData:
    """All matrices of the homogenized ±1 formulation (Eqs. 20-21).

    Attributes:
      n: N_T * N_K (dimension of m / x).
      edges: constraint edge list (task-graph edges + self-loops for sinks).
      Q: (|edges|, n, n) symmetrized 0/1-domain quadratic forms Q_{i,i'}.
      Q_tilde: (|edges|, n+1, n+1) homogenized ±1-domain forms (Eq. 21).
      A: (N_T, n+1, n+1) homogenized assignment constraint matrices (Eq. 21).
      q_scale: normalization factor applied to Q_tilde for the SDP solver
        (``Q_tilde_scaled = Q_tilde / q_scale``); bottleneck values in the
        original units are ``t * q_scale``.
    """

    n_tasks: int
    n_machines: int
    edges: tuple[Edge, ...]
    Q: np.ndarray
    Q_tilde: np.ndarray
    A: np.ndarray
    q_scale: float

    @property
    def n(self) -> int:
        return self.n_tasks * self.n_machines


def build_bqp(task_graph: TaskGraph, compute_graph: ComputeGraph) -> BQPData:
    n_t, n_k = task_graph.num_tasks, compute_graph.num_machines
    n = n_t * n_k
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    D = np.diag(1.0 / e)
    edges = task_graph.constraint_edges()

    eye = np.eye(n_t)
    Q = np.empty((len(edges), n, n))
    for k, (i, j) in enumerate(edges):
        comp = np.kron(D, np.outer(p, eye[i]))           # D ⊗ (p δ_iᵀ)
        comm = np.kron(C, np.outer(eye[i], eye[j]))      # C ⊗ (δ_i δ_jᵀ)
        q = comp + comm
        Q[k] = 0.5 * (q + q.T)                           # symmetrize (Remark 1)

    # Homogenization (Eq. 19/21): with symmetric Q the bordered form must
    # contribute 2u·(1ᵀQx), so the border is Q1 — the paper's printed Q1/2
    # only yields u·(1ᵀQx) and fails the x̃ᵀQ̃x̃ == 4·mᵀQm identity (verified
    # against the direct evaluator in tests).
    ones = np.ones(n)
    Q_tilde = np.empty((len(edges), n + 1, n + 1))
    for k in range(len(edges)):
        q1 = Q[k] @ ones
        Q_tilde[k, :n, :n] = Q[k]
        Q_tilde[k, :n, n] = q1
        Q_tilde[k, n, :n] = q1
        Q_tilde[k, n, n] = ones @ q1

    # H row i selects variable (task i, machine κ) for all κ (column-major vec).
    A = np.zeros((n_t, n + 1, n + 1))
    for i in range(n_t):
        h = np.zeros(n)
        h[i::n_t] = 1.0
        A[i, :n, n] = h / 2.0
        A[i, n, :n] = h / 2.0
        A[i, n, n] = n_k - 2.0

    q_scale = float(np.max(np.abs(Q_tilde))) or 1.0
    return BQPData(
        n_tasks=n_t,
        n_machines=n_k,
        edges=edges,
        Q=Q,
        Q_tilde=Q_tilde,
        A=A,
        q_scale=q_scale,
    )


def quadratic_bottleneck(bqp: BQPData, m_vec: np.ndarray) -> float:
    """Evaluate max_e mᵀ Q_e m for a 0/1 vectorized assignment (test oracle)."""
    vals = np.einsum("i,eij,j->e", m_vec, bqp.Q, m_vec)
    return float(np.max(vals))


def assignment_to_vec(assignment: np.ndarray, n_machines: int) -> np.ndarray:
    """Machine-index vector -> column-major vec(M) in {0,1}^n."""
    M = assignment_to_matrix(assignment, n_machines)
    return M.flatten(order="F")


def vec_to_assignment(m_vec: np.ndarray, n_tasks: int, n_machines: int) -> np.ndarray:
    """vec(M) -> machine-index vector (argmax per task row)."""
    M = m_vec.reshape((n_machines, n_tasks)).T
    return np.argmax(M, axis=1)
