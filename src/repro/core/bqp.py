"""Binary Quadratic Program formulation of bottleneck-time minimization.

Implements Eqs. (7)-(21) of the paper:

  - per-edge quadratic forms ``Q_{i,i'} = D ⊗ (p δ_iᵀ) + C ⊗ (δ_i δ_{i'}ᵀ)``
    over ``m = vec(M)`` (column-major, ``m[κ·N_T + τ] = M[τ, κ]``),
  - the ±1 homogenized forms ``Q̃_{i,i'}`` and assignment matrices ``A_i``,
  - exact bottleneck-time evaluation of any assignment (numpy and JAX,
    batched) — used both by the schedulers and as the test oracle.

Note: the paper writes the communication Kronecker term as
``Cᵀ ⊗ I_iᵀ I_{i'}``; with column-major ``vec`` the form that reproduces
``C[m(i), m(i')]`` is ``C ⊗ (δ_i δ_{i'}ᵀ)``.  We use the latter and verify
against the direct evaluator in tests (the evaluator is the ground truth).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.graphs import ComputeGraph, Edge, TaskGraph


# ---------------------------------------------------------------------------
# Direct evaluation (ground truth)
# ---------------------------------------------------------------------------


def assignment_to_matrix(assignment: np.ndarray, num_machines: int) -> np.ndarray:
    """(N_T,) machine indices -> one-hot (N_T, N_K)."""
    a = np.asarray(assignment, dtype=np.int64)
    M = np.zeros((a.shape[0], num_machines), dtype=np.float64)
    M[np.arange(a.shape[0]), a] = 1.0
    return M


def task_times(
    task_graph: TaskGraph, compute_graph: ComputeGraph, assignment: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per-task (t_comp, t_comm) for an assignment vector (N_T,) of machine ids.

    t_comp(i) = sum of work co-located with i / speed of m(i)     (Eq. 7)
    t_comm(i) = max over successors i' of C[m(i), m(i')]          (Eq. 10)
    """
    a = np.asarray(assignment, dtype=np.int64)
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    loads = np.zeros(compute_graph.num_machines)
    np.add.at(loads, a, p)
    t_comp = loads[a] / e[a]
    t_comm = np.zeros(task_graph.num_tasks)
    for (i, j) in task_graph.edges:
        t_comm[i] = max(t_comm[i], C[a[i], a[j]])
    return t_comp, t_comm


def bottleneck_time(
    task_graph: TaskGraph, compute_graph: ComputeGraph, assignment: np.ndarray
) -> float:
    """Eq. (2): max over tasks of compute + communicate time."""
    t_comp, t_comm = task_times(task_graph, compute_graph, assignment)
    return float(np.max(t_comp + t_comm))


def bottleneck_time_batch(
    task_graph: TaskGraph, compute_graph: ComputeGraph, assignments: np.ndarray
) -> np.ndarray:
    """Vectorized bottleneck over a batch (B, N_T) of assignment vectors."""
    a = np.asarray(assignments, dtype=np.int64)
    if a.ndim == 1:
        a = a[None]
    B, n_t = a.shape
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    n_k = compute_graph.num_machines
    onehot = np.zeros((B, n_t, n_k))
    onehot[np.arange(B)[:, None], np.arange(n_t)[None, :], a] = 1.0
    loads = np.einsum("bti,t->bi", onehot, p)          # (B, N_K)
    t_comp = np.take_along_axis(loads / e[None], a, axis=1)  # (B, N_T)
    t = t_comp.copy()
    if task_graph.edges:
        src = np.array([i for (i, _) in task_graph.edges])
        dst = np.array([j for (_, j) in task_graph.edges])
        delays = C[a[:, src], a[:, dst]]               # (B, |E|)
        comm = np.zeros_like(t_comp)
        np.maximum.at(comm, (np.arange(B)[:, None], src[None, :].repeat(B, 0)), delays)
        t = t_comp + comm
    return np.max(t, axis=1)


def brute_force_optimum(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> tuple[np.ndarray, float]:
    """Exact optimum by enumeration (tests only; N_K ** N_T assignments)."""
    n_t, n_k = task_graph.num_tasks, compute_graph.num_machines
    total = n_k**n_t
    if total > 2_000_000:
        raise ValueError(f"brute force too large: {n_k}^{n_t}")
    idx = np.arange(total)
    assignments = np.empty((total, n_t), dtype=np.int64)
    for t in range(n_t):
        assignments[:, t] = idx % n_k
        idx = idx // n_k
    times = bottleneck_time_batch(task_graph, compute_graph, assignments)
    best = int(np.argmin(times))
    return assignments[best], float(times[best])


# ---------------------------------------------------------------------------
# BQP / SDP matrices
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BQPData:
    """All matrices of the homogenized ±1 formulation (Eqs. 20-21).

    Attributes:
      n: N_T * N_K (dimension of m / x).
      edges: constraint edge list (task-graph edges + self-loops for sinks).
      Q: (|edges|, n, n) symmetrized 0/1-domain quadratic forms Q_{i,i'}.
      Q_tilde: (|edges|, n+1, n+1) homogenized ±1-domain forms (Eq. 21).
      A: (N_T, n+1, n+1) homogenized assignment constraint matrices (Eq. 21).
      q_scale: normalization factor applied to Q_tilde for the SDP solver
        (``Q_tilde_scaled = Q_tilde / q_scale``); bottleneck values in the
        original units are ``t * q_scale``.
    """

    n_tasks: int
    n_machines: int
    edges: tuple[Edge, ...]
    Q: np.ndarray
    Q_tilde: np.ndarray
    A: np.ndarray
    q_scale: float

    @property
    def n(self) -> int:
        return self.n_tasks * self.n_machines


def build_bqp(task_graph: TaskGraph, compute_graph: ComputeGraph) -> BQPData:
    n_t, n_k = task_graph.num_tasks, compute_graph.num_machines
    n = n_t * n_k
    p, e, C = task_graph.p, compute_graph.e, compute_graph.C
    D = np.diag(1.0 / e)
    edges = task_graph.constraint_edges()

    eye = np.eye(n_t)
    Q = np.empty((len(edges), n, n))
    for k, (i, j) in enumerate(edges):
        comp = np.kron(D, np.outer(p, eye[i]))           # D ⊗ (p δ_iᵀ)
        comm = np.kron(C, np.outer(eye[i], eye[j]))      # C ⊗ (δ_i δ_jᵀ)
        q = comp + comm
        Q[k] = 0.5 * (q + q.T)                           # symmetrize (Remark 1)

    # Homogenization (Eq. 19/21): with symmetric Q the bordered form must
    # contribute 2u·(1ᵀQx), so the border is Q1 — the paper's printed Q1/2
    # only yields u·(1ᵀQx) and fails the x̃ᵀQ̃x̃ == 4·mᵀQm identity (verified
    # against the direct evaluator in tests).
    ones = np.ones(n)
    Q_tilde = np.empty((len(edges), n + 1, n + 1))
    for k in range(len(edges)):
        q1 = Q[k] @ ones
        Q_tilde[k, :n, :n] = Q[k]
        Q_tilde[k, :n, n] = q1
        Q_tilde[k, n, :n] = q1
        Q_tilde[k, n, n] = ones @ q1

    # H row i selects variable (task i, machine κ) for all κ (column-major vec).
    A = np.zeros((n_t, n + 1, n + 1))
    for i in range(n_t):
        h = np.zeros(n)
        h[i::n_t] = 1.0
        A[i, :n, n] = h / 2.0
        A[i, n, :n] = h / 2.0
        A[i, n, n] = n_k - 2.0

    q_scale = float(np.max(np.abs(Q_tilde))) or 1.0
    return BQPData(
        n_tasks=n_t,
        n_machines=n_k,
        edges=edges,
        Q=Q,
        Q_tilde=Q_tilde,
        A=A,
        q_scale=q_scale,
    )


def quadratic_bottleneck(bqp: BQPData, m_vec: np.ndarray) -> float:
    """Evaluate max_e mᵀ Q_e m for a 0/1 vectorized assignment (test oracle)."""
    vals = np.einsum("i,eij,j->e", m_vec, bqp.Q, m_vec)
    return float(np.max(vals))


def assignment_to_vec(assignment: np.ndarray, n_machines: int) -> np.ndarray:
    """Machine-index vector -> column-major vec(M) in {0,1}^n."""
    M = assignment_to_matrix(assignment, n_machines)
    return M.flatten(order="F")


def vec_to_assignment(m_vec: np.ndarray, n_tasks: int, n_machines: int) -> np.ndarray:
    """vec(M) -> machine-index vector (argmax per task row)."""
    M = m_vec.reshape((n_machines, n_tasks)).T
    return np.argmax(M, axis=1)


# ---------------------------------------------------------------------------
# Factored (matrix-free) representation
# ---------------------------------------------------------------------------
#
# Every Q_e is a sum of two Kronecker products of rank-structured pieces,
#
#   Q_e = D ⊗ (p δ_iᵀ) + C ⊗ (δ_i δ_jᵀ),        D = diag(d),  d = 1/e,
#
# so all operator actions needed by the SDP pipeline — <Q̃_e, Y>, Q̃_e·x,
# the homogenization border Q̃_e·1, and the sparse constraint rows — follow
# from (p, d, C, i, j) in closed form without materializing any n×n matrix.
# With the (K, T) grid view of vec (entry (κ, τ) ↔ index κ·N_T + τ):
#
#   Q·1   = d⊗p + (C1)⊗δ_i              Qᵀ·1  = P·(d⊗δ_i) + (Cᵀ1)⊗δ_j
#   1ᵀQ·1 = (Σd)(Σp) + ΣC               q1    = (Q·1 + Qᵀ·1) / 2
#
# Peak memory is O(n + |E|·N_K²) per instance versus the dense
# O(|E|·n²) stacks of ``BQPData`` — the dense form is kept as the
# small-instance oracle (see DESIGN.md §2).


@dataclasses.dataclass(frozen=True)
class FactoredBQP:
    """Matrix-free homogenized BQP: operators instead of (|E|, n, n) stacks.

    Attributes:
      p: (N_T,) task work.
      d: (N_K,) reciprocal machine speeds 1/e.
      C: (N_K, N_K) communication delays.
      src/dst: (|E|,) int arrays — constraint edge endpoints (i, j).
      q_scale: same normalization as ``BQPData.q_scale`` (max |Q̃_e| entry).
    """

    n_tasks: int
    n_machines: int
    edges: tuple[Edge, ...]
    p: np.ndarray
    d: np.ndarray
    C: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    q_scale: float

    @property
    def n(self) -> int:
        return self.n_tasks * self.n_machines

    @property
    def n1(self) -> int:
        return self.n + 1

    # -- cached scalar/vector summaries of the Kronecker factors ----------
    @functools.cached_property
    def _C1(self) -> np.ndarray:
        return self.C @ np.ones(self.n_machines)

    @functools.cached_property
    def _Ct1(self) -> np.ndarray:
        return self.C.T @ np.ones(self.n_machines)

    @functools.cached_property
    def _P(self) -> float:
        return float(np.sum(self.p))

    @functools.cached_property
    def corner(self) -> float:
        """1ᵀ Q_e 1 — identical for every edge."""
        return float(np.sum(self.d) * self._P + np.sum(self.C))

    # -- operator interface ------------------------------------------------
    def border(self, k: int) -> np.ndarray:
        """Homogenization border q1 = (Q_e·1 + Q_eᵀ·1)/2 for edge k, (n,)."""
        i, j = int(self.src[k]), int(self.dst[k])
        q1 = 0.5 * np.outer(self.d, self.p)                  # (K, T) grid
        q1[:, i] += 0.5 * (self._C1 + self._P * self.d)
        q1[:, j] += 0.5 * self._Ct1
        return q1.reshape(-1)

    def apply(self, k: int, x: np.ndarray) -> np.ndarray:
        """Q̃_k @ x for homogenized x (n+1,), never building Q̃_k."""
        K, T = self.n_machines, self.n_tasks
        i, j = int(self.src[k]), int(self.dst[k])
        v = np.asarray(x[: self.n], dtype=np.float64).reshape(K, T)
        u = float(x[self.n])
        Qv = np.outer(self.d * v[:, i], self.p)              # D ⊗ (p δ_iᵀ)
        Qv[:, i] += self.C @ v[:, j]                         # C ⊗ (δ_i δ_jᵀ)
        Qtv = np.zeros((K, T))
        Qtv[:, i] = self.d * (v @ self.p)
        Qtv[:, j] += self.C.T @ v[:, i]
        q1 = self.border(k)
        out = np.empty(self.n1)
        out[: self.n] = 0.5 * (Qv + Qtv).reshape(-1) + q1 * u
        out[self.n] = q1 @ x[: self.n] + self.corner * u
        return out

    def inner(self, F: np.ndarray) -> np.ndarray:
        """All-edge inner products <Q̃_e, F> for symmetric F (n+1, n+1).

        O(n·N_T + |E|·N_K²) work and O(|E|·N_K²) scratch — this is the
        matrix-free replacement for ``einsum("eij,ij->e", Q_tilde, F)``.
        """
        K, T = self.n_machines, self.n_tasks
        F = 0.5 * (F + F.T)
        Fxx = F[: self.n, : self.n].reshape(K, T, K, T)
        f = F[: self.n, -1].reshape(K, T)
        # <D ⊗ (p δ_iᵀ), Fxx> = Σ_κ d_κ Σ_τ p_τ Fxx[κ,τ,κ,i]
        comp = np.einsum("k,t,ktks->s", self.d, self.p, Fxx, optimize=True)
        # <C ⊗ (δ_i δ_jᵀ), Fxx> = Σ_{κκ'} C[κ,κ'] Fxx[κ,i,κ',j]
        blocks = Fxx.transpose(1, 3, 0, 2)[self.src, self.dst]  # (|E|, K, K)
        comm = np.einsum("ekl,kl->e", blocks, self.C)
        # 2·q1_eᵀ f with q1 = [d⊗p + (C1+P·d)⊗δ_i + (Cᵀ1)⊗δ_j] / 2
        base = float(np.einsum("k,t,kt->", self.d, self.p, f))
        u_i = (self._C1 + self._P * self.d) @ f              # (T,)
        u_j = self._Ct1 @ f
        q1f = 0.5 * (base + u_i[self.src] + u_j[self.dst])
        return comp[self.src] + comm + 2.0 * q1f + self.corner * F[-1, -1]

    def constraint_row(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Sparse (indices, values) of Q̃_k flattened over (n+1)².

        nnz is O(n + N_K²) per edge versus the (n+1)² dense row.  Rows are
        memoized on the instance: the q_scale pass at build time and the
        affine projector consume the same arrays.
        """
        cache = self.__dict__.setdefault("_row_cache", {})
        if k in cache:
            return cache[k]
        K, T, n, n1 = self.n_machines, self.n_tasks, self.n, self.n1
        i, j = int(self.src[k]), int(self.dst[k])
        kk = np.repeat(np.arange(K), T)
        tt = np.tile(np.arange(T), K)
        # compute block: entries ((κ,τ), (κ,i)) = d_κ p_τ, halved + transposed
        a_comp = kk * T + tt
        b_comp = kk * T + i
        v_comp = 0.5 * np.outer(self.d, self.p).reshape(-1)
        # communicate block: ((κ,i), (κ',j)) = C[κ,κ'], halved + transposed
        ka = np.repeat(np.arange(K), K)
        kb = np.tile(np.arange(K), K)
        a_comm = ka * T + i
        b_comm = kb * T + j
        v_comm = 0.5 * self.C.reshape(-1)
        # border + corner
        q1 = self.border(k)
        a_all = np.concatenate(
            [a_comp, b_comp, a_comm, b_comm, np.arange(n), np.full(n, n1 - 1), [n1 - 1]]
        )
        b_all = np.concatenate(
            [b_comp, a_comp, b_comm, a_comm, np.full(n, n1 - 1), np.arange(n), [n1 - 1]]
        )
        v_all = np.concatenate([v_comp, v_comp, v_comm, v_comm, q1, q1, [self.corner]])
        lin = a_all.astype(np.int64) * n1 + b_all
        uniq, inv = np.unique(lin, return_inverse=True)
        vals = np.bincount(inv, weights=v_all, minlength=uniq.size)
        keep = vals != 0.0
        cache[k] = (uniq[keep], vals[keep])
        return cache[k]


def build_factored_bqp(
    task_graph: TaskGraph, compute_graph: ComputeGraph
) -> FactoredBQP:
    """Factored analogue of ``build_bqp``; identical ``q_scale`` and edges."""
    n_t, n_k = task_graph.num_tasks, compute_graph.num_machines
    edges = task_graph.constraint_edges()
    src = np.asarray([i for (i, _) in edges], dtype=np.int64)
    dst = np.asarray([j for (_, j) in edges], dtype=np.int64)
    fbqp = FactoredBQP(
        n_tasks=n_t,
        n_machines=n_k,
        edges=edges,
        p=task_graph.p,
        d=1.0 / compute_graph.e,
        C=compute_graph.C,
        src=src,
        dst=dst,
        q_scale=1.0,
    )
    # q_scale = max |Q̃_e| entry, computed from the merged sparse rows so it
    # matches the dense ``np.max(np.abs(Q_tilde))`` exactly.
    scale = 0.0
    for k in range(len(edges)):
        _, vals = fbqp.constraint_row(k)
        if vals.size:
            scale = max(scale, float(np.max(np.abs(vals))))
    object.__setattr__(fbqp, "q_scale", scale or 1.0)
    return fbqp


def dense_bytes_estimate(task_graph: TaskGraph, compute_graph: ComputeGraph) -> int:
    """Bytes the dense ``BQPData`` stacks (Q + Q̃) would occupy."""
    n = task_graph.num_tasks * compute_graph.num_machines
    n_e = len(task_graph.constraint_edges())
    return 8 * n_e * (n * n + (n + 1) * (n + 1))
