"""Mixture-of-Experts FFN (Mixtral 8x7B top-2, OLMoE 64-expert top-8).

GShard-style *grouped* capacity dispatch: each sequence (= group) routes
its own tokens with per-group capacity C = ceil(S·k·cf / E), so the
dispatch cumsum stays local to a data shard (no cross-device sequential
dependency) and GSPMD can shard the expert matmuls:

    xe  (B, E, C, D)  — B over data axes, E over 'model' (EP) when E is
                        divisible (OLMoE 64/16), else F over 'model'
                        (Mixtral 8 experts -> expert-internal TP)
    h   (B, E, C, F)
    out scatter-adds back into (B, S, D) weighted by router probs.

No sorts and no O(N·E·C) one-hot einsums: positions-in-expert come from a
per-group cumsum, gather/scatter move the tokens.  Tokens beyond capacity
are dropped (Switch/GShard semantics; capacity_factor controls the rate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, swiglu


def init_moe_params(key, cfg: ModelConfig) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = cfg.param_dtype
    return {
        "router": dense_init(kr, (d, e), dtype=pd),
        "w_gate": dense_init(kg, (e, d, f), in_axis=1, dtype=pd),
        "w_up": dense_init(ku, (e, d, f), in_axis=1, dtype=pd),
        "w_down": dense_init(kd, (e, f, d), in_axis=1, dtype=pd),
    }


def _shard(rules, x, kind):
    return rules.constrain(x, kind) if rules is not None else x


def moe_ffn(
    params: dict, x: jnp.ndarray, cfg: ModelConfig, rules=None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux load-balancing loss (scalar)).

    With a mesh and E % tp == 0, uses the explicit shard_map EP path
    (``moe_ffn_sharded``) — GSPMD's sharding propagation hits "last-resort
    replication" on the data-dependent dispatch gather/scatter and moves
    E·C-sized buffers (§Perf olmoe iteration: 834 -> ~60 GB link bytes).
    """
    if (
        rules is not None
        and getattr(rules, "mesh", None) is not None
        and getattr(rules, "shard_moe", True)
        and x.shape[1] % rules.tp_size == 0
        and (
            cfg.num_experts % rules.tp_size == 0     # expert-parallel
            or cfg.d_ff % rules.tp_size == 0         # expert-internal TP
        )
    ):
        return moe_ffn_sharded(params, x, cfg, rules)
    return _moe_ffn_gspmd(params, x, cfg, rules)


def _moe_ffn_gspmd(
    params: dict, x: jnp.ndarray, cfg: ModelConfig, rules=None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    capacity = int(max(1, -(-s * k * cfg.capacity_factor // e)))

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)            # (B, S, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (B, S, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- per-group dispatch ------------------------------------------------
    expert_of = gate_idx.reshape(b, s * k)                     # (B, S·k)
    onehot = jax.nn.one_hot(expert_of, e, dtype=jnp.int32)     # (B, S·k, E)
    pos = jnp.cumsum(onehot, axis=1) * onehot                  # 1-based
    pos_in_expert = jnp.max(pos, axis=-1) - 1                  # (B, S·k)
    keep = (pos_in_expert >= 0) & (pos_in_expert < capacity)

    slot = expert_of * capacity + jnp.where(keep, pos_in_expert, 0)
    token_of_choice = jnp.repeat(jnp.arange(s), k)[None].repeat(b, axis=0)
    grp = jnp.arange(b)[:, None]

    # dropped choices scatter into a trash slot (index e·C) so they can
    # never clobber a real slot (slot 0 belongs to expert 0, position 0!)
    slot_or_trash = jnp.where(keep, slot, e * capacity)
    dispatch = jnp.zeros((b, e * capacity + 1), dtype=jnp.int32)
    dispatch = dispatch.at[grp, slot_or_trash].set(
        token_of_choice, mode="drop"
    )[:, :-1]
    slot_used = jnp.zeros((b, e * capacity + 1), dtype=jnp.bool_)
    slot_used = slot_used.at[grp, slot_or_trash].set(keep, mode="drop")[:, :-1]
    slot_gate = jnp.zeros((b, e * capacity + 1), dtype=jnp.float32)
    slot_gate = slot_gate.at[grp, slot_or_trash].set(
        gate_vals.reshape(b, s * k), mode="drop"
    )[:, :-1]

    # --- expert compute ------------------------------------------------------
    xe = jnp.take_along_axis(x, dispatch[..., None], axis=1)   # (B, E·C, D)
    xe = xe * slot_used[..., None].astype(x.dtype)
    xe = _shard(rules, xe.reshape(b, e, capacity, d), "moe_tokens")

    h = swiglu(
        jnp.einsum("becd,edf->becf", xe, params["w_gate"].astype(xe.dtype)),
        jnp.einsum("becd,edf->becf", xe, params["w_up"].astype(xe.dtype)),
    )
    h = _shard(rules, h, "moe_hidden")
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(h.dtype))
    ye = _shard(rules, ye, "moe_tokens")

    # --- combine -------------------------------------------------------------
    yw = ye.reshape(b, e * capacity, d) * slot_gate[..., None].astype(ye.dtype)
    out = jnp.zeros((b, s, d), dtype=jnp.float32)
    out = out.at[grp, dispatch].add(
        jnp.where(slot_used[..., None], yw, 0).astype(jnp.float32)
    )

    # Switch-style load-balancing auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    frac = jnp.mean(
        jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=(0, 1, 2)
    )
    aux = e * jnp.sum(frac * me)

    return out.astype(x.dtype), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Explicit expert-parallel path (shard_map)
# ---------------------------------------------------------------------------
#
# Pattern (per tensor-parallel shard): all-gather the sequence-sharded
# hidden over 'model' (cheap: B·S·D), route ALL tokens (router weights are
# replicated so every shard computes identical assignments), dispatch only
# the tokens destined for the shard's OWN experts, run the local expert
# FFNs, scatter-add a partial (B, S, D), and reduce-scatter it straight
# back into the sequence-sharded layout.  Per-layer link bytes ≈
# 2·B·S·D — independent of top-k and capacity factor, which is what makes
# high-k MoE (OLMoE top-8) schedulable.


def _moe_core_local(
    params_local: dict, xf: jnp.ndarray, cfg: ModelConfig, lo: int, e_local: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch/compute/combine for experts [lo, lo + e_local) only.

    xf: (B, S, D) full-sequence tokens (identical on every shard).
    Returns (partial out (B, S, D), aux loss).
    """
    b, s, d = xf.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    capacity = int(max(1, -(-s * k * cfg.capacity_factor // e)))

    router_logits = jnp.einsum(
        "bsd,de->bse", xf.astype(jnp.float32),
        params_local["router"].astype(jnp.float32),
    )
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    expert_of = gate_idx.reshape(b, s * k)
    local_of = expert_of - lo
    in_range = (local_of >= 0) & (local_of < e_local)
    local_of = jnp.where(in_range, local_of, 0)

    onehot = jax.nn.one_hot(local_of, e_local, dtype=jnp.int32)
    onehot = onehot * in_range[..., None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) * onehot
    pos_in_expert = jnp.max(pos, axis=-1) - 1
    keep = in_range & (pos_in_expert >= 0) & (pos_in_expert < capacity)

    slot = local_of * capacity + jnp.where(keep, pos_in_expert, 0)
    token_of_choice = jnp.repeat(jnp.arange(s), k)[None].repeat(b, axis=0)
    grp = jnp.arange(b)[:, None]

    # see _moe_ffn_gspmd: dropped choices go to a trash slot
    slot_or_trash = jnp.where(keep, slot, e_local * capacity)
    dispatch = jnp.zeros((b, e_local * capacity + 1), dtype=jnp.int32)
    dispatch = dispatch.at[grp, slot_or_trash].set(
        token_of_choice, mode="drop"
    )[:, :-1]
    slot_used = jnp.zeros((b, e_local * capacity + 1), dtype=jnp.bool_)
    slot_used = slot_used.at[grp, slot_or_trash].set(keep, mode="drop")[:, :-1]
    slot_gate = jnp.zeros((b, e_local * capacity + 1), dtype=jnp.float32)
    slot_gate = slot_gate.at[grp, slot_or_trash].set(
        gate_vals.reshape(b, s * k), mode="drop"
    )[:, :-1]

    xe = jnp.take_along_axis(xf, dispatch[..., None], axis=1)
    xe = (xe * slot_used[..., None].astype(xf.dtype)).reshape(
        b, e_local, capacity, d
    )
    h = swiglu(
        jnp.einsum("becd,edf->becf", xe, params_local["w_gate"].astype(xe.dtype)),
        jnp.einsum("becd,edf->becf", xe, params_local["w_up"].astype(xe.dtype)),
    )
    ye = jnp.einsum("becf,efd->becd", h, params_local["w_down"].astype(h.dtype))

    yw = ye.reshape(b, e_local * capacity, d) * slot_gate[..., None].astype(ye.dtype)
    out = jnp.zeros((b, s, d), dtype=jnp.float32)
    out = out.at[grp, dispatch].add(
        jnp.where(slot_used[..., None], yw, 0).astype(jnp.float32)
    )

    me = jnp.mean(probs, axis=(0, 1))
    frac = jnp.mean(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=(0, 1, 2))
    aux = e * jnp.sum(frac * me)
    return out.astype(xf.dtype), aux.astype(jnp.float32)


def moe_ffn_sharded(
    params: dict, x: jnp.ndarray, cfg: ModelConfig, rules
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sharded MoE via shard_map (see block comment above).

    Two modes, same communication structure (all-gather seq in,
    psum_scatter partial outputs back to sequence-sharded):
      - EP   (E % tp == 0): each shard owns E/tp whole experts;
      - F-TP (otherwise, F % tp == 0 — Mixtral's 8 experts on tp=16):
        every shard owns all experts but only F/tp of each FFN; swiglu is
        elementwise over F and w_down contracts F, so per-shard outputs
        are exact partial sums.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    dp, tp = rules.dp, rules.tp_axis
    tp_size = rules.tp_size
    ep_mode = cfg.num_experts % tp_size == 0
    e_local = cfg.num_experts // tp_size if ep_mode else cfg.num_experts
    b_spec = dp if x.shape[0] % rules.dp_size == 0 else None

    def inner(x_shard, router, wg, wu, wd):
        # x_shard (B_l, S/tp, D): recover the full sequence locally
        xf = jax.lax.all_gather(x_shard, tp, axis=1, tiled=True)
        lo = jax.lax.axis_index(tp) * e_local if ep_mode else 0
        plocal = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        out_partial, aux = _moe_core_local(plocal, xf, cfg, lo, e_local)
        out = jax.lax.psum_scatter(
            out_partial, tp, scatter_dimension=1, tiled=True
        )
        return out, aux

    if ep_mode:
        w_specs = (P(tp, None, None),) * 3
    else:
        w_specs = (P(None, None, tp), P(None, None, tp), P(None, tp, None))
    out, aux = shard_map(
        inner,
        mesh=rules.mesh,
        in_specs=(P(b_spec, tp, None), P(None, None)) + w_specs,
        out_specs=(P(b_spec, tp, None), P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out, aux
