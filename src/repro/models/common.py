"""Shared model machinery: config, initializers, norms, RoPE, embeddings.

All models are pure-functional JAX: ``params`` are pytrees of ``jnp``
arrays, built by ``init(rng, cfg)`` and consumed by ``apply(params, ...)``.
Layer stacks use ``jax.lax.scan`` over stacked parameters so the lowered
HLO size is independent of depth (critical for 88-layer dry-runs).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers every assigned architecture family.

    ``block_pattern`` selects the per-layer block type cycle, e.g.
    ``("attn",)`` for dense transformers, ``("ssm",)`` for mamba2,
    ``("rglru", "rglru", "local_attn")`` for recurrentgemma.
    """

    name: str = "model"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 => d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 1000
    vocab_pad_multiple: int = 256
    tied_embeddings: bool = False   # lm_head = embedᵀ (mamba2 ties them)
    max_seq_len: int = 131072
    # attention
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False              # qwen2-vl 3-axis M-RoPE
    window: int = 0                  # 0 => full causal; >0 sliding window
    block_pattern: tuple[str, ...] = ("attn",)
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU (recurrentgemma)
    lru_width: int = 0               # 0 => d_model
    local_window: int = 2048
    # encoder-decoder (whisper)
    num_encoder_layers: int = 0
    encoder_seq_ratio: int = 1       # encoder frames per decoder token slot
    # training
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    scan_layers: bool = True
    train_microbatches: int = 1   # gradient-accumulation steps per batch
    # Cast the f32 master params to ``dtype`` ONCE per step (outside the
    # layer scan) so FSDP all-gathers move bf16, not f32.  §Perf iteration:
    # False reproduces the recorded baseline artifacts.
    cast_params_once: bool = True
    attn_chunk: int = 1024           # kv-chunk for flash-style jnp attention
    # frontend stubs
    frontend: str = "none"           # none | audio | vision

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape: Sequence[int], in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def swiglu(x_gate: jnp.ndarray, x_up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(x_gate) * x_up


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float, sections=None
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: 3 position axes (t, h, w) across frequency
    sections.  positions: (3, ..., seq).  Default sections follow the
    published 2:3:3 split ((16, 24, 24) at head_dim 128), scaled to the
    actual head_dim so reduced smoke configs work."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    if sections is None:
        a = half * 2 // 8
        b = half * 3 // 8
        sections = (a, b, half - a - b)
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    # choose which position axis drives each frequency band
    axis_for_freq = np.concatenate(
        [np.full(s, i) for i, s in enumerate(sections)]
    )  # (half,)
    pos = positions.astype(jnp.float32)  # (3, ..., seq)
    sel = jnp.take(pos, jnp.asarray(axis_for_freq), axis=0)  # (half, ..., seq)
    sel = jnp.moveaxis(sel, 0, -1)  # (..., seq, half)
    angles = sel * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          ignore_id: int = -1) -> jnp.ndarray:
    """Mean next-token CE over valid positions. logits (..., V) f32/bf16."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
