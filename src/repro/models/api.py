"""Uniform model API: build any assigned architecture behind one interface.

``build_model(cfg)`` returns a ``ModelAPI`` whose members are pure
functions suitable for ``jax.jit``:

  - ``init_params(rng)``
  - ``loss_fn(params, batch)``            (training)
  - ``forward(params, batch)``            (prefill: logits, no loss/opt)
  - ``init_cache(batch, seq_len)``        (decode state)
  - ``decode_step(params, cache, batch)`` (one serve step)
  - ``input_specs(shape)``                (ShapeDtypeStruct stand-ins,
                                           no device allocation — dry-run)

Batch layouts per family are documented in ``input_specs``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.shapes import ShapeSpec
from repro.models import transformer as tf
from repro.models import whisper as wh
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable            # (params, batch, rules=None) -> scalar
    forward: Callable            # (params, batch, rules=None) -> logits
    init_cache: Callable         # (batch, seq_len) -> cache pytree
    decode_step: Callable        # (params, cache, batch, rules=None) -> (logits, cache)
    input_specs: Callable        # (ShapeSpec) -> batch pytree of SDS
    cache_specs: Callable        # (ShapeSpec) -> cache pytree of SDS


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _whisper_seqs(spec: ShapeSpec) -> tuple[int, int]:
    """Encoder frames get the full seq_len; decoder gets seq_len // 4
    (whisper's audio:text ratio is ≈3-4:1; see DESIGN.md)."""
    return spec.seq_len, max(spec.seq_len // 4, 64)


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return _build_whisper(cfg)
    return _build_lm(cfg)


# ---------------------------------------------------------------------------
# Decoder-only LM families (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------


def _build_lm(cfg: ModelConfig) -> ModelAPI:
    uses_embeds = cfg.family in ("vlm",)

    def init_params(rng):
        return tf.init_lm_params(rng, cfg)

    def loss_fn(params, batch, rules=None):
        return tf.lm_loss(params, batch, cfg, rules)

    def forward(params, batch, rules=None):
        return tf.lm_forward(
            params,
            batch.get("tokens"),
            cfg,
            rules,
            positions=batch.get("positions"),
            inputs_embeds=batch.get("inputs_embeds"),
        )

    def init_cache(batch, seq_len):
        return tf.init_decode_cache(cfg, batch, seq_len)

    def decode_step(params, cache, batch, rules=None):
        return tf.lm_decode_step(
            params,
            cache,
            batch.get("tokens"),
            batch["pos"],
            cfg,
            rules,
            inputs_embeds=batch.get("inputs_embeds"),
        )

    def input_specs(spec: ShapeSpec):
        b, s = spec.global_batch, spec.seq_len
        if spec.kind in ("train", "prefill"):
            out: dict[str, Any] = {}
            if uses_embeds:
                out["inputs_embeds"] = _sds((b, s, cfg.d_model), cfg.dtype)
                out["positions"] = _sds((3, b, s), jnp.int32)
            else:
                out["tokens"] = _sds((b, s), jnp.int32)
            if spec.kind == "train":
                out["labels"] = _sds((b, s), jnp.int32)
            return out
        # decode: one new token, cache of seq_len
        out = {"pos": _sds((b,), jnp.int32)}
        if uses_embeds:
            out["inputs_embeds"] = _sds((b, 1, cfg.d_model), cfg.dtype)
        else:
            out["tokens"] = _sds((b,), jnp.int32)
        return out

    def cache_specs(spec: ShapeSpec):
        return jax.eval_shape(
            lambda: init_cache(spec.global_batch, spec.seq_len)
        )

    return ModelAPI(
        cfg=cfg,
        init_params=init_params,
        loss_fn=loss_fn,
        forward=forward,
        init_cache=init_cache,
        decode_step=decode_step,
        input_specs=input_specs,
        cache_specs=cache_specs,
    )


# ---------------------------------------------------------------------------
# Whisper (enc-dec)
# ---------------------------------------------------------------------------


def _build_whisper(cfg: ModelConfig) -> ModelAPI:
    def init_params(rng):
        return wh.init_whisper_params(rng, cfg)

    def loss_fn(params, batch, rules=None):
        return wh.whisper_loss(params, batch, cfg, rules)

    def forward(params, batch, rules=None):
        return wh.whisper_forward(
            params, batch["enc_frames"], batch["dec_tokens"], cfg, rules
        )

    def init_cache(batch, seq_len, enc_len=None):
        return wh.init_whisper_cache(
            cfg, batch, seq_len, enc_len or max(seq_len // 4, 64)
        )

    def decode_step(params, cache, batch, rules=None):
        return wh.whisper_decode_step(
            params, cache, batch["tokens"], batch["pos"], cfg, rules
        )

    def input_specs(spec: ShapeSpec):
        b = spec.global_batch
        s_enc, s_dec = _whisper_seqs(spec)
        if spec.kind in ("train", "prefill"):
            out = {
                "enc_frames": _sds((b, s_enc, cfg.d_model), cfg.dtype),
                "dec_tokens": _sds((b, s_dec), jnp.int32),
            }
            if spec.kind == "train":
                out["labels"] = _sds((b, s_dec), jnp.int32)
            return out
        return {"tokens": _sds((b,), jnp.int32), "pos": _sds((b,), jnp.int32)}

    def cache_specs(spec: ShapeSpec):
        # decode cache: self-attn cache of seq_len + cross KV of seq_len//16
        enc_len = max(spec.seq_len // 16, 64)
        return jax.eval_shape(
            lambda: init_cache(spec.global_batch, spec.seq_len, enc_len)
        )

    return ModelAPI(
        cfg=cfg,
        init_params=init_params,
        loss_fn=loss_fn,
        forward=forward,
        init_cache=init_cache,
        decode_step=decode_step,
        input_specs=input_specs,
        cache_specs=cache_specs,
    )
