"""Attention: GQA training/prefill attention + distributed decode attention.

Three implementations with identical semantics (tested against each other):

  - ``dense``   : full (S, S) logits — reference / small shapes.
  - ``chunked`` : flash-style online softmax in pure jnp — python loop over
    query blocks, ``lax.scan`` over kv chunks, *triangular block skipping*
    for causal masks so HLO FLOPs ≈ S²/2 instead of S².  Memory is
    O(q_block × kv_chunk) — this is what the 32k prefill dry-runs lower.
  - ``pallas``  : the Pallas flash kernel (repro.kernels) on TPU.

Decode attention supports a sequence-sharded KV cache via ``shard_map``
(kv_heads of the assigned archs are mostly 8 < model-axis 16, so the cache
shards over *sequence*; softmax runs distributed with psum-max/psum-sum).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp


def _split_heads(q, k, v):
    """(B,S,H,D),(B,S,Hkv,D) -> grouped views; returns group size g."""
    h, hkv = q.shape[2], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    return h // hkv


def dense_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Reference attention. q (B,Sq,H,D), k/v (B,Sk,Hkv,D) -> (B,Sq,H,D)."""
    g = _split_heads(q, k, v)
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal or window:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = jnp.ones((sq, sk), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 1024,
    kv_chunk: int = 1024,
    q_offset: int = 0,
    return_lse: bool = False,
):
    """Flash-style attention in pure jnp (see module docstring).

    Causal triangular skipping: query block t only scans kv chunks that can
    contain unmasked keys, so compiled FLOPs follow the true mask area.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = _split_heads(q, k, v)
    scale = 1.0 / math.sqrt(d)
    q_block = min(q_block, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_block == 0 and sk % kv_chunk == 0, (sq, q_block, sk, kv_chunk)

    kc = k.reshape(b, sk // kv_chunk, kv_chunk, hkv, d)
    vc = v.reshape(b, sk // kv_chunk, kv_chunk, hkv, d)

    outs = []
    lses = []
    for qb in range(sq // q_block):
        qi = q[:, qb * q_block : (qb + 1) * q_block]
        qi = qi.reshape(b, q_block, hkv, g, d).astype(jnp.float32) * scale
        q_lo = q_offset + qb * q_block
        q_hi = q_lo + q_block
        # kv chunk range that intersects the mask for this q block
        hi_chunk = min(sk, q_hi) if causal else sk
        lo_chunk = max(0, q_lo - window + 1) if window else 0
        c0 = lo_chunk // kv_chunk
        c1 = (hi_chunk + kv_chunk - 1) // kv_chunk
        c1 = max(c1, c0 + 1)

        def step(carry, ck):
            m_prev, l_prev, acc = carry
            kj, vj, cidx = ck
            logits = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi, kj.astype(jnp.float32)
            )  # (B, Hkv, g, qb, kc)
            qpos = q_lo + jnp.arange(q_block)
            kpos = cidx * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_block, kv_chunk), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_block), -jnp.inf, dtype=jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), dtype=jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, d), dtype=jnp.float32)
        ks = jnp.moveaxis(kc[:, c0:c1], 1, 0)   # (nc, B, kc, Hkv, d)
        vs = jnp.moveaxis(vc[:, c0:c1], 1, 0)
        cidxs = jnp.arange(c0, c1)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, cidxs))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1).reshape(b, q_block, h, d)
        outs.append(out.astype(q.dtype))
        if return_lse:
            lses.append(m + jnp.log(jnp.maximum(l, 1e-30)))  # (B,Hkv,g,qb)
    result = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    if return_lse:
        lse = jnp.concatenate(lses, axis=-1) if len(lses) > 1 else lses[0]
        return result, lse
    return result


# ---------------------------------------------------------------------------
# Flash attention with custom VJP: the backward recomputes per-block
# probabilities from (q, k, v, out, lse) instead of letting jax AD save the
# per-chunk S²-sized intermediates of the forward scan.  This is the
# memory-correct training/prefill path (the Pallas kernel mirrors it on TPU).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_jnp(q, k, v, causal=True, window=0, q_block=1024,
                        kv_chunk=1024, q_offset=0):
    out, _ = _flash_fwd(q, k, v, causal, window, q_block, kv_chunk, q_offset)
    return out


def _flash_fwd(q, k, v, causal, window, q_block, kv_chunk, q_offset):
    out, lse = chunked_attention(
        q, k, v, causal=causal, window=window, q_block=q_block,
        kv_chunk=kv_chunk, q_offset=q_offset, return_lse=True,
    )
    return out, (q, k, v, out, lse)


def _fwd_rule(q, k, v, causal, window, q_block, kv_chunk, q_offset):
    out, res = _flash_fwd(q, k, v, causal, window, q_block, kv_chunk, q_offset)
    return out, res


def _bwd_rule(causal, window, q_block, kv_chunk, q_offset, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    q_block = min(q_block, sq)
    kv_chunk = min(kv_chunk, sk)

    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) * scale
    og = out.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    dog = dout.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    # D_i = rowsum(dout ∘ out)  (B, S, hkv, g)
    delta = jnp.sum(og * dog, axis=-1)

    dq = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    dk = jnp.zeros((b, sk, hkv, d), jnp.float32)
    dv = jnp.zeros((b, sk, hkv, d), jnp.float32)

    nq = sq // q_block
    for cj in range(sk // kv_chunk):
        k_lo = cj * kv_chunk
        kj = k[:, k_lo : k_lo + kv_chunk].astype(jnp.float32)  # (B,kc,hkv,d)
        vj = v[:, k_lo : k_lo + kv_chunk].astype(jnp.float32)
        # q blocks that can see this chunk
        qb0 = (k_lo // q_block) if causal else 0
        qb1 = nq
        if window:
            # q < k_lo + kv_chunk + window
            qb1 = min(
                nq, (k_lo + kv_chunk + window - q_offset + q_block - 1) // q_block
            )
            qb1 = max(qb1, qb0 + 1)
        idxs = jnp.arange(qb0, qb1)

        def step(carry, qi):
            dkj, dvj = carry
            sl = qi * q_block
            qi_blk = jax.lax.dynamic_slice_in_dim(qg, sl, q_block, axis=1)
            do_blk = jax.lax.dynamic_slice_in_dim(dog, sl, q_block, axis=1)
            lse_blk = jax.lax.dynamic_slice_in_dim(lse, sl, q_block, axis=-1)
            dl_blk = jax.lax.dynamic_slice_in_dim(delta, sl, q_block, axis=1)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi_blk, kj)
            p = jnp.exp(logits - lse_blk[..., None])
            qpos = q_offset + sl + jnp.arange(q_block)
            kpos = k_lo + jnp.arange(kv_chunk)
            mask = jnp.ones((q_block, kv_chunk), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            p = jnp.where(mask[None, None, None], p, 0.0)
            dvj = dvj + jnp.einsum("bhgqk,bqhgd->bkhd", p, do_blk)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_blk, vj)
            ds = p * (dp - jnp.moveaxis(dl_blk, 1, -1)[..., None])
            dq_blk = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kj)
            dkj = dkj + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qi_blk)
            return (dkj, dvj), dq_blk

        dk0 = jnp.zeros((b, kv_chunk, hkv, d), jnp.float32)
        dv0 = jnp.zeros((b, kv_chunk, hkv, d), jnp.float32)
        (dkj, dvj), dq_blocks = jax.lax.scan(step, (dk0, dv0), idxs)
        # dq_blocks: (nqj, B, q_block, hkv, g, d) -> add into dq
        nqj = qb1 - qb0
        dq_add = jnp.moveaxis(dq_blocks, 0, 1).reshape(
            b, nqj * q_block, hkv, g, d
        )
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq,
            jax.lax.dynamic_slice_in_dim(dq, qb0 * q_block, nqj * q_block, 1)
            + dq_add,
            qb0 * q_block,
            axis=1,
        )
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk,
            jax.lax.dynamic_slice_in_dim(dk, k_lo, kv_chunk, 1) + dkj,
            k_lo,
            axis=1,
        )
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv,
            jax.lax.dynamic_slice_in_dim(dv, k_lo, kv_chunk, 1) + dvj,
            k_lo,
            axis=1,
        )

    dq = (dq * scale).reshape(b, sq, h, d).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_jnp.defvjp(_fwd_rule, _bwd_rule)


def attention(
    q, k, v, *, causal=True, window=0, impl="chunked", q_block=1024,
    kv_chunk=1024, q_offset=0,
):
    if impl == "dense" or q.shape[1] * k.shape[1] <= 512 * 512:
        return dense_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    if impl == "pallas":
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    return flash_attention_jnp(
        q, k, v, causal, window, q_block, kv_chunk, q_offset
    )


# ---------------------------------------------------------------------------
# Decode attention (single new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention_local(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    valid_len,             # scalar or (B,) number of valid cache slots
) -> jnp.ndarray:
    """Reference single-token attention over a (local) cache."""
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(s)
    mask = pos[None] < jnp.reshape(valid_len, (-1, 1))
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attention_seq_sharded(
    q: jnp.ndarray,        # (B, H, D) replicated over the model axis
    k_cache: jnp.ndarray,  # (B, S_local, Hkv, D) — seq shard of the cache
    v_cache: jnp.ndarray,
    valid_mask: jnp.ndarray,  # (B, S_local) bool — local validity
    axis_name: str,
) -> jnp.ndarray:
    """Distributed flash-softmax decode: runs *inside* shard_map.

    Each model shard holds S/tp cache slots; we compute local partial
    (max, exp-sum, weighted V) and combine with three psums.  This is the
    sequence-parallel decode path used when kv_heads < model-axis size.
    """
    b, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    logits = jnp.where(valid_mask[:, None, None, :], logits, -1e30)
    m_local = jnp.max(logits, axis=-1)
    m = jax.lax.pmax(m_local, axis_name)
    p = jnp.exp(logits - m[..., None])
    # zero out invalid slots exactly (exp(-1e30 - m) may underflow anyway)
    p = jnp.where(valid_mask[:, None, None, :], p, 0.0)
    l = jax.lax.psum(jnp.sum(p, axis=-1), axis_name)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    acc = jax.lax.psum(acc, axis_name)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)
