"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: intra-chunk "attention-like"
quadratic term + inter-chunk linear state recurrence (``lax.scan`` over
chunks).  Decode is the O(1) recurrent update on the (B, H, P, N) state —
this is what makes ``long_500k`` tractable for this arch.

Shapes: d_inner = expand·d_model, heads H = d_inner/headdim P, state N.
Single B/C group (n_groups=1), scalar-per-head A, per-step softplus dt.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig, dense_init, rms_norm


def init_ssm_params(key, cfg: ModelConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    kin, kout, kconv, kdt = jax.random.split(key, 4)
    pd = cfg.param_dtype
    d_in_proj = 2 * di + 2 * n + h          # z, x, B, C, dt
    conv_ch = di + 2 * n                     # conv over (x, B, C)
    return {
        "in_proj": dense_init(kin, (d, d_in_proj), dtype=pd),
        "conv_w": dense_init(kconv, (cfg.conv_width, conv_ch), dtype=pd),
        "conv_b": jnp.zeros((conv_ch,), dtype=pd),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ).astype(pd),
        "D": jnp.ones((h,), dtype=pd),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 1e-1, h))), dtype=pd
        ),
        "norm_scale": jnp.zeros((di,), dtype=pd),
        "out_proj": dense_init(kout, (di, d), dtype=pd),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum': out[..., i, j] = sum_{j < t <= i} x[..., t].

    Returns -inf above the diagonal (causal decay mask in log space).
    """
    l = x.shape[-1]
    x_cum = jnp.cumsum(x, axis=-1)
    diff = x_cum[..., :, None] - x_cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,      # (B, S, H, P) discretization-ready inputs
    dt: jnp.ndarray,     # (B, S, H) positive step sizes
    A: jnp.ndarray,      # (H,) negative decay rates
    Bm: jnp.ndarray,     # (B, S, N)
    Cm: jnp.ndarray,     # (B, S, N)
    chunk: int,
    h0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    c = s // chunk

    xd = (x * dt[..., None]).astype(jnp.float32)             # discretized input
    dA = (dt * A[None, None, :]).astype(jnp.float32)          # (B, S, H) log decay
    # chunked views
    xc = xd.reshape(b, c, chunk, h, p)
    dAc = dA.reshape(b, c, chunk, h).transpose(0, 1, 3, 2)    # (B, C, H, L)
    Bc = Bm.reshape(b, c, chunk, n).astype(jnp.float32)
    Cc = Cm.reshape(b, c, chunk, n).astype(jnp.float32)

    dA_cum = jnp.cumsum(dAc, axis=-1)                         # (B, C, H, L)

    # 1. intra-chunk (quadratic, "attention-like"):
    Lmask = jnp.exp(_segsum(dAc))                             # (B, C, H, L, L)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)            # (B, C, L, L)
    y_diag = jnp.einsum("bchlm,bclm,bcmhp->bclhp", Lmask, scores, xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)         # (B, C, H, L)
    states = jnp.einsum("bclh,bcln,bclhp->bchpn",
                        decay_states.transpose(0, 1, 3, 2), Bc, xc)

    # 3. inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(dA_cum[..., -1])                    # (B, C, H)

    def step(hprev, inp):
        st, dec = inp                                          # (B,H,P,N), (B,H)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    hfin, hprevs = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    hprevs = jnp.moveaxis(hprevs, 0, 1)                       # (B, C, H, P, N)

    # 4. contribution of carried-in state to each position
    state_decay = jnp.exp(dA_cum)                             # (B, C, H, L)
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", Cc, hprevs, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, hfin


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv. x (B,S,C), w (W,C). Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), dtype=x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return jax.nn.silu(y + b[None, None, :]), new_state


def mamba2_block(
    params: dict, x: jnp.ndarray, cfg: ModelConfig,
    conv_state=None, ssm_state=None, decode: bool = False,
):
    """Full Mamba-2 mixer. x (B,S,D) -> (y (B,S,D), (conv_state, ssm_state))."""
    b, s, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ params["in_proj"].astype(x.dtype)            # (B,S,·)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, new_conv_state = _causal_conv(
        conv_in, params["conv_w"].astype(x.dtype),
        params["conv_b"].astype(x.dtype), conv_state,
    )
    xs, Bm, Cm = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                          # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (H,)
    xh = xs.reshape(b, s, h, p)

    if decode:
        # single-step recurrence; s == 1
        dA = jnp.exp(dt[:, 0] * A[None])                       # (B,H)
        upd = jnp.einsum(
            "bhp,bn->bhpn", (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
            Bm[:, 0].astype(jnp.float32),
        )
        new_ssm = ssm_state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cm[:, 0].astype(jnp.float32))
        y = y[:, None]                                         # (B,1,H,P)
    else:
        y, new_ssm = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, ssm_state)

    y = y + xh.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_scale"])
    out = y @ params["out_proj"].astype(y.dtype)
    return out, (new_conv_state, new_ssm)
