"""Pure-JAX model zoo for the assigned architectures."""

from repro.models.api import ModelAPI, build_model
from repro.models.common import ModelConfig
from repro.models.flops import model_flops, param_counts

__all__ = ["ModelAPI", "ModelConfig", "build_model", "model_flops", "param_counts"]
