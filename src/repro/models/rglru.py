"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = σ(W_a x_t + b_a)          recurrence gate
    i_t = σ(W_x x_t + b_x)          input gate
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill evaluates the linear recurrence with
``jax.lax.associative_scan`` (log-depth); decode is the O(1) step.  The
block wraps the LRU with a conv1d branch and a GeLU gate branch (Griffin's
recurrent block).  Sub-quadratic by construction -> used for long_500k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init

_C = 8.0  # Griffin's fixed temperature on the recurrence gate


def init_rglru_params(key, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.resolved_lru_width
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    pd = cfg.param_dtype
    return {
        "in_proj_x": dense_init(k1, (d, w), dtype=pd),
        "in_proj_gate": dense_init(k2, (d, w), dtype=pd),
        "conv_w": dense_init(k3, (cfg.conv_width, w), dtype=pd),
        "conv_b": jnp.zeros((w,), dtype=pd),
        "gate_a_w": dense_init(k4, (w, w), dtype=pd),
        "gate_a_b": jnp.zeros((w,), dtype=pd),
        "gate_x_w": dense_init(k5, (w, w), dtype=pd),
        "gate_x_b": jnp.zeros((w,), dtype=pd),
        "lambda_p": jnp.full((w,), 0.65, dtype=pd),
        "out_proj": dense_init(k6, (w, d), dtype=pd),
    }


def _rg_lru(params, x, h0=None, decode: bool = False):
    """x (B,S,W) -> (out (B,S,W), h_final (B,W))."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(
        xf @ params["gate_a_w"].astype(jnp.float32) + params["gate_a_b"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        xf @ params["gate_x_w"].astype(jnp.float32) + params["gate_x_b"].astype(jnp.float32)
    )
    log_a = -_C * jax.nn.softplus(params["lambda_p"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                                        # (B,S,W)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * xf)

    if decode:
        h_prev = jnp.zeros_like(gated[:, 0]) if h0 is None else h0
        h = a[:, 0] * h_prev + gated[:, 0]
        return h[:, None].astype(x.dtype), h

    if h0 is not None:
        # fold the carried-in state into the first step
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _a_sc, h_sc = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h_sc.astype(x.dtype), h_sc[:, -1]


def rglru_block(
    params: dict, x: jnp.ndarray, cfg: ModelConfig,
    conv_state=None, lru_state=None, decode: bool = False,
):
    """Griffin recurrent block. x (B,S,D) -> (y, (conv_state, lru_state))."""
    from repro.models.ssm import _causal_conv

    branch = x @ params["in_proj_x"].astype(x.dtype)           # (B,S,W)
    gate = jax.nn.gelu(x @ params["in_proj_gate"].astype(x.dtype))
    branch, new_conv = _causal_conv(
        branch, params["conv_w"].astype(x.dtype),
        params["conv_b"].astype(x.dtype), conv_state,
    )
    lru_out, new_lru = _rg_lru(params, branch, lru_state, decode=decode)
    y = (lru_out * gate) @ params["out_proj"].astype(x.dtype)
    return y, (new_conv, new_lru)
