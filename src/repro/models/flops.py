"""Analytic parameter and FLOP counts per architecture x shape.

Used by (a) the scheduler — task work ``p_i`` is the FLOPs of a local
training round, (b) the roofline report — MODEL_FLOPS = 6·N·D for training
(dense) / 6·N_active·D (MoE), 2·N·D for inference, plus exact attention
terms, compared against compiled HLO FLOPs to expose remat/redundancy
waste.
"""

from __future__ import annotations

import dataclasses

from repro.shapes import ShapeSpec
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParamCounts:
    total: int                  # all params (incl. embeddings)
    active: int                 # per-token active params (MoE: top-k share)
    embedding: int


def _attn_params(cfg: ModelConfig) -> int:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return d * h * hd * 2 + d * hkv * hd * 2


def _mlp_params(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff


def _moe_params(cfg: ModelConfig) -> tuple[int, int]:
    total = cfg.d_model * cfg.num_experts + cfg.num_experts * _mlp_params(cfg)
    active = cfg.d_model * cfg.num_experts + cfg.num_experts_per_tok * _mlp_params(cfg)
    return total, active


def _ssm_params(cfg: ModelConfig) -> int:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return d * (2 * di + 2 * n + h) + di * d + cfg.conv_width * (di + 2 * n)


def _rglru_params(cfg: ModelConfig) -> int:
    d, w = cfg.d_model, cfg.resolved_lru_width
    return 2 * d * w + 2 * w * w + w * d + cfg.conv_width * w


def _block_params(cfg: ModelConfig, kind: str) -> tuple[int, int]:
    """(total, active) params of one block of ``kind``."""
    if kind in ("attn", "local_attn"):
        a = _attn_params(cfg)
        if cfg.num_experts:
            mt, ma = _moe_params(cfg)
            return a + mt, a + ma
        m = _mlp_params(cfg)
        return a + m, a + m
    if kind == "ssm":
        s = _ssm_params(cfg)
        return s, s
    if kind == "rglru":
        r = _rglru_params(cfg) + _mlp_params(cfg)
        return r, r
    raise ValueError(kind)


def param_counts(cfg: ModelConfig) -> ParamCounts:
    pat = cfg.block_pattern
    total = active = 0
    for i in range(cfg.num_layers):
        t, a = _block_params(cfg, pat[i % len(pat)])
        total += t
        active += a
    if cfg.family == "encdec":
        n_enc = cfg.num_encoder_layers or cfg.num_layers
        enc = n_enc * (_attn_params(cfg) + _mlp_params(cfg))
        dec_x = cfg.num_layers * _attn_params(cfg)   # cross-attention
        total += enc + dec_x
        active += enc + dec_x
    emb = cfg.padded_vocab * cfg.d_model * (1 if cfg.tied_embeddings else 2)
    return ParamCounts(total=total + emb, active=active + emb, embedding=emb)


def _attn_matmul_flops(cfg: ModelConfig, seq: int, causal: bool = True) -> int:
    """Per-token score+value FLOPs for one attention layer at context ``seq``."""
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    eff = seq / 2 if causal else seq
    return int(2 * 2 * h * hd * eff)


def _encdec_flops(cfg: ModelConfig, spec: ShapeSpec) -> float:
    """Whisper: encoder runs on s_enc frames, decoder on s_dec tokens;
    decode runs the decoder only against cached encoder KV."""
    b, s = spec.global_batch, spec.seq_len
    s_enc, s_dec = s, max(s // 4, 64)
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    enc_params = n_enc * (_attn_params(cfg) + _mlp_params(cfg))
    dec_params = cfg.num_layers * (2 * _attn_params(cfg) + _mlp_params(cfg))
    emb = cfg.padded_vocab * cfg.d_model
    mult = 3 if spec.kind == "train" else 1
    if spec.kind in ("train", "prefill"):
        f = 2 * enc_params * b * s_enc + 2 * (dec_params + emb) * b * s_dec
        f += b * s_enc * n_enc * _attn_matmul_flops(cfg, s_enc, causal=False)
        f += b * s_dec * cfg.num_layers * (
            _attn_matmul_flops(cfg, s_dec) + _attn_matmul_flops(cfg, s_enc, causal=False)
        )
        return mult * f
    # decode: decoder-only, self cache of s + cross cache of s//16
    f = 2 * (dec_params + emb) * b
    f += b * cfg.num_layers * (
        _attn_matmul_flops(cfg, s, causal=False)
        + _attn_matmul_flops(cfg, max(s // 16, 64), causal=False)
    )
    return f


def model_flops(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    """MODEL_FLOPS for the roofline table (whole-step, all devices)."""
    counts = param_counts(cfg)
    if cfg.family == "encdec":
        return {
            "model_flops": float(_encdec_flops(cfg, spec)),
            **dataclasses.asdict(counts),
        }
    b, s = spec.global_batch, spec.seq_len
    n_attn = sum(
        1
        for i in range(cfg.num_layers)
        if cfg.block_pattern[i % len(cfg.block_pattern)] in ("attn", "local_attn")
    )
    # effective attention context per layer kind
    win = cfg.local_window if "local_attn" in cfg.block_pattern else cfg.window

    if spec.kind == "train":
        tokens = b * s
        mf = 6 * counts.active * tokens
        ctx = min(s, win) if win else s
        mf += 3 * tokens * n_attn * _attn_matmul_flops(cfg, ctx)
        return {"model_flops": float(mf), **dataclasses.asdict(counts)}
    if spec.kind == "prefill":
        tokens = b * s
        mf = 2 * counts.active * tokens
        ctx = min(s, win) if win else s
        mf += tokens * n_attn * _attn_matmul_flops(cfg, ctx)
        return {"model_flops": float(mf), **dataclasses.asdict(counts)}
    # decode: one token per sequence
    tokens = b
    mf = 2 * counts.active * tokens
    ctx = min(s, win) if win else s
    mf += tokens * n_attn * _attn_matmul_flops(cfg, ctx, causal=False)
    return {"model_flops": float(mf), **dataclasses.asdict(counts)}
