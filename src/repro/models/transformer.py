"""Decoder LM assembly for all decoder-style assigned architectures.

One parameterized stack covers: dense GQA transformers (qwen3, nemo,
granite, mistral-large), MoE (mixtral, olmoe), Mamba-2 (ssm), RecurrentGemma
(rglru/local_attn hybrid) and the VLM backbone (qwen2-vl, M-RoPE +
precomputed patch embeddings).

Structure: ``cfg.block_pattern`` defines a repeating *group* of sub-blocks
(e.g. ("rglru", "rglru", "local_attn")).  ``num_layers`` is split into
``num_layers // len(pattern)`` scanned groups (stacked params,
``jax.lax.scan``) plus an unscanned remainder — HLO size is depth-
independent, which keeps 88-layer dry-run compiles fast.  Each group is
rematerialized (``jax.checkpoint``) when ``cfg.remat``.

Everything is mesh-agnostic: sharding enters only through the optional
``ShardingRules`` (launch/sharding.py) via ``with_sharding_constraint``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.common import (
    ModelConfig,
    apply_mrope,
    apply_rope,
    dense_init,
    embed_init,
    rms_norm,
    softmax_cross_entropy,
    swiglu,
)
from repro.models.moe import init_moe_params, moe_ffn
from repro.models.rglru import init_rglru_params, rglru_block
from repro.models.ssm import init_ssm_params, mamba2_block


# ---------------------------------------------------------------------------
# Sharding hooks (no-ops unless launch/sharding.py provides rules)
# ---------------------------------------------------------------------------


class NullRules:
    """Default: no sharding constraints (single-device smoke tests)."""

    mesh = None
    shard_heads = True
    seq_shard_decode = False

    def constrain(self, x, kind: str):
        return x


def _shard(rules, x, kind):
    return rules.constrain(x, kind) if rules is not None else x


# ---------------------------------------------------------------------------
# Sub-block parameter init
# ---------------------------------------------------------------------------


def init_attn_params(key, cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    pd = cfg.param_dtype
    p = {
        "wq": dense_init(kq, (d, h * hd), dtype=pd),
        "wk": dense_init(kk, (d, hkv * hd), dtype=pd),
        "wv": dense_init(kv, (d, hkv * hd), dtype=pd),
        "wo": dense_init(ko, (h * hd, d), dtype=pd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype=pd)
        p["k_norm"] = jnp.zeros((hd,), dtype=pd)
    return p


def init_mlp_params(key, cfg: ModelConfig) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    return {
        "w_gate": dense_init(kg, (d, f), dtype=pd),
        "w_up": dense_init(ku, (d, f), dtype=pd),
        "w_down": dense_init(kd, (f, d), dtype=pd),
    }


def init_block_params(key, cfg: ModelConfig, kind: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    pd = cfg.param_dtype
    d = cfg.d_model
    if kind in ("attn", "local_attn"):
        ffn_kind = "moe" if cfg.num_experts else "mlp"
        ffn = (
            init_moe_params(k2, cfg) if cfg.num_experts else init_mlp_params(k2, cfg)
        )
        return {
            "ln1": jnp.zeros((d,), dtype=pd),
            "attn": init_attn_params(k1, cfg),
            "ln2": jnp.zeros((d,), dtype=pd),
            ffn_kind: ffn,
        }
    if kind == "ssm":
        return {"ln1": jnp.zeros((d,), dtype=pd), "mixer": init_ssm_params(k1, cfg)}
    if kind == "rglru":
        return {
            "ln1": jnp.zeros((d,), dtype=pd),
            "rec": init_rglru_params(k1, cfg),
            "ln2": jnp.zeros((d,), dtype=pd),
            "mlp": init_mlp_params(k2, cfg),
        }
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# Sub-block application
# ---------------------------------------------------------------------------


def mlp_apply(p: dict, x: jnp.ndarray, rules) -> jnp.ndarray:
    h = swiglu(x @ p["w_gate"].astype(x.dtype), x @ p["w_up"].astype(x.dtype))
    h = _shard(rules, h, "ffn")
    out = h @ p["w_down"].astype(h.dtype)
    # partial sums over the tp-sharded F dim land directly in the
    # sequence-sharded layout -> GSPMD emits reduce-scatter, not
    # all-reduce (halves link bytes; §Perf P9)
    return _shard(rules, out, "hidden")


def _qkv(p, x, cfg: ModelConfig, rules, positions):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = _shard(rules, q, "heads")
    k = _shard(rules, k, "kv_heads")
    v = _shard(rules, v, "kv_heads")
    return q, k, v


def attn_apply_train(
    p, x, cfg: ModelConfig, rules, *, window: int, positions, causal: bool = True
):
    """Training / prefill self-attention (no cache interaction)."""
    q, k, v = _qkv(p, x, cfg, rules, positions)
    out = attn_mod.attention(
        q, k, v, causal=causal, window=window,
        q_block=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
    )
    out = _shard(rules, out, "heads")
    b, s, _, _ = out.shape
    out = out.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim)
    proj = out @ p["wo"].astype(out.dtype)
    return _shard(rules, proj, "hidden")    # reduce-scatter (see mlp_apply)


def attn_apply_decode(
    p, x, cfg: ModelConfig, rules, *, window: int, cache: dict,
    pos: jnp.ndarray, positions: jnp.ndarray | None = None,
):
    """Single-token decode with cache update.

    ``cache`` holds k/v of shape (B, S_cache, Hkv, hd); ``pos`` (B,) is the
    absolute position of the incoming token (``positions`` carries the
    RoPE/M-RoPE view of it).  Sliding-window archs use a ring buffer of
    size min(window, S_cache).
    """
    b = x.shape[0]
    s_cache = cache["k"].shape[1]
    if positions is None:
        positions = pos[:, None]                               # (B, 1)
    q, k, v = _qkv(p, x, cfg, rules, positions)
    slot = pos % s_cache if window else jnp.minimum(pos, s_cache - 1)

    def upd(buf, new):
        return jax.vmap(
            lambda bf, nw, sl: jax.lax.dynamic_update_slice(bf, nw, (sl, 0, 0))
        )(buf, new, slot)

    k_cache = upd(cache["k"], k)
    v_cache = upd(cache["v"], v)
    k_cache = _shard(rules, k_cache, "cache")
    v_cache = _shard(rules, v_cache, "cache")

    # validity: slots holding tokens within the attention span of ``pos``
    idx = jnp.arange(s_cache)[None, :]
    if window:
        valid = idx < jnp.minimum(pos[:, None] + 1, s_cache)
    else:
        valid = idx <= pos[:, None]
    q1 = q[:, 0]                                               # (B, H, hd)

    if rules is not None and getattr(rules, "seq_shard_decode", False) and rules.mesh is not None:
        out = rules.sharded_decode_attention(q1, k_cache, v_cache, valid)
    else:
        out = attn_mod.decode_attention_local(
            q1, k_cache, v_cache, jnp.sum(valid, axis=1)
        )
    out = out.reshape(b, 1, cfg.num_heads * cfg.resolved_head_dim)
    out = out @ p["wo"].astype(out.dtype)
    return out, {"k": k_cache, "v": v_cache}


def block_apply(
    kind: str,
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rules,
    *,
    positions,
    cache=None,
    pos=None,
    decode: bool = False,
):
    """One sub-block with pre-norm residual wiring.

    Returns (x, new_cache, aux_loss).
    """
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" else cfg.window
        h = rms_norm(x, p["ln1"])
        if decode:
            a, new_attn_cache = attn_apply_decode(
                p["attn"], h, cfg, rules, window=window, cache=cache, pos=pos,
                positions=positions,
            )
        else:
            a = attn_apply_train(
                p["attn"], h, cfg, rules, window=window, positions=positions
            )
            new_attn_cache = cache
        x = _shard(rules, x + a, "hidden")
        h2 = rms_norm(x, p["ln2"])
        if cfg.num_experts:
            f, aux = moe_ffn(p["moe"], h2, cfg, rules)
        else:
            f = mlp_apply(p["mlp"], h2, rules)
        x = _shard(rules, x + f, "hidden")
        return x, new_attn_cache, aux
    if kind == "ssm":
        h = rms_norm(x, p["ln1"])
        conv_state = cache["conv"] if cache else None
        ssm_state = cache["ssm"] if cache else None
        y, (new_conv, new_ssm) = mamba2_block(
            p["mixer"], h, cfg, conv_state, ssm_state, decode=decode
        )
        x = _shard(rules, x + y, "hidden")
        new_cache = {"conv": new_conv, "ssm": new_ssm} if cache else None
        return x, new_cache, aux
    if kind == "rglru":
        h = rms_norm(x, p["ln1"])
        conv_state = cache["conv"] if cache else None
        lru_state = cache["lru"] if cache else None
        y, (new_conv, new_lru) = rglru_block(
            p["rec"], h, cfg, conv_state, lru_state, decode=decode
        )
        x = _shard(rules, x + y, "hidden")
        h2 = rms_norm(x, p["ln2"])
        x = _shard(rules, x + mlp_apply(p["mlp"], h2, rules), "hidden")
        new_cache = {"conv": new_conv, "lru": new_lru} if cache else None
        return x, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------


def _layer_plan(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    """(num scanned groups, remainder kinds)."""
    pat = cfg.block_pattern
    groups = cfg.num_layers // len(pat)
    rem = cfg.num_layers - groups * len(pat)
    return groups, tuple(pat[:rem])


def init_lm_params(key, cfg: ModelConfig) -> dict:
    ke, kh, kb, kr = jax.random.split(key, 4)
    groups, rem = _layer_plan(cfg)
    pat = cfg.block_pattern
    pd = cfg.param_dtype

    def one_group(k):
        ks = jax.random.split(k, len(pat))
        return tuple(
            init_block_params(ks[i], cfg, kind) for i, kind in enumerate(pat)
        )

    group_keys = jax.random.split(kb, max(groups, 1))
    stacked = jax.vmap(one_group)(group_keys[:groups]) if groups else None
    rem_keys = jax.random.split(kr, max(len(rem), 1))
    remainder = tuple(
        init_block_params(rem_keys[i], cfg, kind) for i, kind in enumerate(rem)
    )
    params = {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype=pd),
        "final_norm": jnp.zeros((cfg.d_model,), dtype=pd),
        "groups": stacked,
        "remainder": remainder,
    }
    if not cfg.tied_embeddings:
        params["lm_head"] = dense_init(
            kh, (cfg.d_model, cfg.padded_vocab), dtype=pd
        )
    return params


def _lm_head(params, x, cfg: ModelConfig):
    if cfg.tied_embeddings:
        return x @ params["embed"].astype(x.dtype).T
    return x @ params["lm_head"].astype(x.dtype)


def _embed(params, tokens, cfg, inputs_embeds=None):
    if inputs_embeds is not None:
        return inputs_embeds.astype(cfg.dtype)
    return jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)


def lm_forward(
    params: dict,
    tokens: jnp.ndarray | None,
    cfg: ModelConfig,
    rules=None,
    *,
    positions: jnp.ndarray | None = None,
    inputs_embeds: jnp.ndarray | None = None,
    return_aux: bool = False,
):
    """Training forward: (B, S) tokens -> (B, S, V) logits
    (+ MoE aux loss when ``return_aux``)."""
    x = _embed(params, tokens, cfg, inputs_embeds)
    b, s, _ = x.shape
    if positions is None:
        base = jnp.arange(s, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(
            base[None] if cfg.mrope else base, (3, b, s) if cfg.mrope else (b, s)
        )
    x = _shard(rules, x, "hidden")
    pat = cfg.block_pattern
    groups, rem = _layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def group_fn(x, gp):
        aux_sum = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pat):
            x, _, aux = block_apply(
                kind, gp[i], x, cfg, rules, positions=positions
            )
            aux_sum = aux_sum + aux
        return x, aux_sum

    if groups:
        body = jax.checkpoint(group_fn) if cfg.remat else group_fn

        def scan_body(carry, gp):
            x, aux_acc = carry
            x, aux_sum = body(x, gp)
            return (x, aux_acc + aux_sum), None

        if cfg.scan_layers:
            (x, aux_total), _ = jax.lax.scan(
                scan_body, (x, aux_total), params["groups"]
            )
        else:
            for g in range(groups):
                gp = jax.tree.map(lambda a: a[g], params["groups"])
                x, aux_sum = body(x, gp)
                aux_total = aux_total + aux_sum
    for i, kind in enumerate(rem):
        x, _, aux = block_apply(
            kind, params["remainder"][i], x, cfg, rules, positions=positions
        )
        aux_total = aux_total + aux
    x = rms_norm(x, params["final_norm"])
    logits = _lm_head(params, x, cfg)
    logits = _shard(rules, logits, "logits")
    return (logits, aux_total) if return_aux else logits


AUX_LOSS_COEF = 0.01


def lm_loss(params, batch: dict, cfg: ModelConfig, rules=None):
    logits, aux = lm_forward(
        params,
        batch.get("tokens"),
        cfg,
        rules,
        positions=batch.get("positions"),
        inputs_embeds=batch.get("inputs_embeds"),
        return_aux=True,
    )
    ce = softmax_cross_entropy(logits, batch["labels"])
    if cfg.num_experts:
        return ce + AUX_LOSS_COEF * aux
    return ce


# ---------------------------------------------------------------------------
# Decode (serve) path
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Allocate the per-layer decode state, stacked per scanned group."""
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    groups, rem = _layer_plan(cfg)
    pat = cfg.block_pattern

    def block_cache(kind):
        if kind == "attn":
            s = seq_len if not cfg.window else min(seq_len, cfg.window)
            return {
                "k": jnp.zeros((batch, s, hkv, hd), cfg.dtype),
                "v": jnp.zeros((batch, s, hkv, hd), cfg.dtype),
            }
        if kind == "local_attn":
            s = min(seq_len, cfg.local_window)
            return {
                "k": jnp.zeros((batch, s, hkv, hd), cfg.dtype),
                "v": jnp.zeros((batch, s, hkv, hd), cfg.dtype),
            }
        if kind == "ssm":
            return {
                "conv": jnp.zeros(
                    (batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state),
                    cfg.dtype,
                ),
                "ssm": jnp.zeros(
                    (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32,
                ),
            }
        if kind == "rglru":
            w = cfg.resolved_lru_width
            return {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, w), cfg.dtype),
                "lru": jnp.zeros((batch, w), jnp.float32),
            }
        raise ValueError(kind)

    def group_cache(_):
        return tuple(block_cache(k) for k in pat)

    stacked = (
        jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[group_cache(g) for g in range(groups)],
        )
        if groups
        else None
    )
    remainder = tuple(block_cache(k) for k in rem)
    return {"groups": stacked, "remainder": remainder}


def lm_decode_step(
    params: dict,
    cache: dict,
    tokens: jnp.ndarray,        # (B,) int32 — the newest token
    pos: jnp.ndarray,           # (B,) int32 — its absolute position
    cfg: ModelConfig,
    rules=None,
    inputs_embeds: jnp.ndarray | None = None,   # (B, 1, D) for stub frontends
):
    """One decode step: returns ((B, V) logits, new cache)."""
    x = _embed(params, tokens[:, None] if tokens is not None else None, cfg,
               inputs_embeds)
    x = _shard(rules, x, "hidden_decode")
    pat = cfg.block_pattern
    groups, rem = _layer_plan(cfg)
    if cfg.mrope:
        positions = jnp.broadcast_to(pos[None, :, None], (3,) + pos.shape + (1,))
    else:
        positions = pos[:, None]

    def group_fn(x, gp_and_cache):
        gp, gc = gp_and_cache
        new_caches = []
        for i, kind in enumerate(pat):
            x, nc, _ = block_apply(
                kind, gp[i], x, cfg, rules,
                positions=positions, cache=gc[i], pos=pos, decode=True,
            )
            new_caches.append(nc)
        return x, tuple(new_caches)

    new_cache = {"groups": None, "remainder": ()}
    if groups:
        def scan_body(x, gp_gc):
            x, nc = group_fn(x, gp_gc)
            return x, nc

        x, new_group_cache = jax.lax.scan(
            scan_body, x, (params["groups"], cache["groups"])
        )
        new_cache["groups"] = new_group_cache
    new_rem = []
    for i, kind in enumerate(rem):
        x, nc, _ = block_apply(
            kind, params["remainder"][i], x, cfg, rules,
            positions=positions, cache=cache["remainder"][i], pos=pos,
            decode=True,
        )
        new_rem.append(nc)
    new_cache["remainder"] = tuple(new_rem)

    x = rms_norm(x, params["final_norm"])
    logits = _lm_head(params, x, cfg)[:, 0]
    logits = _shard(rules, logits, "logits_decode")
    return logits, new_cache
