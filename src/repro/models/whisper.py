"""Whisper-small backbone: encoder-decoder transformer (arXiv:2212.04356).

The audio frontend (log-mel + conv subsampling) is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(B, S_enc, d_model).  The backbone is faithful: 12-layer bidirectional
encoder, 12-layer decoder with causal self-attention + cross-attention,
MHA (kv == heads), learned-free sinusoidal positions (the published model
uses learned absolute embeddings for the decoder; sinusoidal avoids a
32k-position table for the prefill_32k shape exercise — noted deviation).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.common import (
    ModelConfig,
    dense_init,
    embed_init,
    rms_norm,
    softmax_cross_entropy,
)
from repro.models.transformer import (
    NullRules,
    _shard,
    init_attn_params,
    init_mlp_params,
    mlp_apply,
)


def _sinusoid(seq: int, d: int, dtype) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / np.power(10000.0, dim / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return jnp.asarray(out, dtype=dtype)


def _init_xattn_params(key, cfg: ModelConfig) -> dict:
    return init_attn_params(key, cfg)


def init_whisper_params(key, cfg: ModelConfig) -> dict:
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    ke, kd, kh, kem = jax.random.split(key, 4)
    pd = cfg.param_dtype

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.zeros((cfg.d_model,), pd),
            "attn": init_attn_params(k1, cfg),
            "ln2": jnp.zeros((cfg.d_model,), pd),
            "mlp": init_mlp_params(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": jnp.zeros((cfg.d_model,), pd),
            "attn": init_attn_params(k1, cfg),
            "ln_x": jnp.zeros((cfg.d_model,), pd),
            "xattn": _init_xattn_params(k2, cfg),
            "ln2": jnp.zeros((cfg.d_model,), pd),
            "mlp": init_mlp_params(k3, cfg),
        }

    enc_keys = jax.random.split(ke, n_enc)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": embed_init(kem, (cfg.padded_vocab, cfg.d_model), dtype=pd),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), pd),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "dec_norm": jnp.zeros((cfg.d_model,), pd),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.padded_vocab), dtype=pd),
    }


def _self_attn(p, x, cfg, rules, *, causal, positions=None):
    from repro.models.transformer import attn_apply_train

    return attn_apply_train(
        p, x, cfg, rules, window=0,
        positions=positions if positions is not None
        else jnp.arange(x.shape[1], dtype=jnp.int32)[None],
        causal=causal,
    )


def _cross_attn(p, x, enc_kv, cfg: ModelConfig, rules):
    """x (B,Sd,D) queries against precomputed encoder (k, v)."""
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    q = _shard(rules, q, "heads")
    k, v = enc_kv
    out = attn_mod.attention(q, k, v, causal=False, q_block=cfg.attn_chunk,
                             kv_chunk=cfg.attn_chunk)
    out = out.reshape(b, s, h * hd)
    return out @ p["wo"].astype(out.dtype)


def _enc_kv(p, enc_out, cfg, rules):
    b, s, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, s, hkv, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, s, hkv, hd)
    return _shard(rules, k, "kv_heads"), _shard(rules, v, "kv_heads")


def whisper_encode(params, enc_frames: jnp.ndarray, cfg: ModelConfig, rules=None):
    """enc_frames: precomputed (B, S_enc, D) frame embeddings (frontend stub)."""
    x = enc_frames.astype(cfg.dtype) + _sinusoid(
        enc_frames.shape[1], cfg.d_model, cfg.dtype
    )
    x = _shard(rules, x, "hidden")

    def body(x, lp):
        h = rms_norm(x, lp["ln1"])
        x = _shard(rules, x + _self_attn(lp["attn"], h, cfg, rules, causal=False),
                   "hidden")
        h = rms_norm(x, lp["ln2"])
        x = _shard(rules, x + mlp_apply(lp["mlp"], h, rules), "hidden")
        return x, None

    fn = jax.checkpoint(lambda x, lp: body(x, lp)) if cfg.remat else body
    x, _ = jax.lax.scan(lambda c, lp: fn(c, lp), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"])


def whisper_forward(
    params, enc_frames: jnp.ndarray, dec_tokens: jnp.ndarray,
    cfg: ModelConfig, rules=None,
) -> jnp.ndarray:
    """Teacher-forced training forward -> (B, S_dec, V) logits."""
    enc_out = whisper_encode(params, enc_frames, cfg, rules)
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(cfg.dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model, cfg.dtype)
    x = _shard(rules, x, "hidden")

    def body(x, lp):
        h = rms_norm(x, lp["ln1"])
        x = _shard(rules, x + _self_attn(lp["attn"], h, cfg, rules, causal=True),
                   "hidden")
        h = rms_norm(x, lp["ln_x"])
        kv = _enc_kv(lp["xattn"], enc_out, cfg, rules)
        x = _shard(rules, x + _cross_attn(lp["xattn"], h, kv, cfg, rules), "hidden")
        h = rms_norm(x, lp["ln2"])
        x = _shard(rules, x + mlp_apply(lp["mlp"], h, rules), "hidden")
        return x, None

    fn = jax.checkpoint(lambda x, lp: body(x, lp)) if cfg.remat else body
    x, _ = jax.lax.scan(lambda c, lp: fn(c, lp), x, params["dec_layers"])
    x = rms_norm(x, params["dec_norm"])
    logits = x @ params["lm_head"].astype(x.dtype)
    return _shard(rules, logits, "logits")


def whisper_loss(params, batch, cfg: ModelConfig, rules=None):
    logits = whisper_forward(
        params, batch["enc_frames"], batch["dec_tokens"], cfg, rules
    )
    return softmax_cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_whisper_cache(cfg: ModelConfig, batch: int, seq_len: int, enc_len: int):
    """Self-attn KV cache + precomputed cross-attn encoder KV per layer."""
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, seq_len, hkv, hd), cfg.dtype),
        "v": jnp.zeros((L, batch, seq_len, hkv, hd), cfg.dtype),
        "enc_k": jnp.zeros((L, batch, enc_len, hkv, hd), cfg.dtype),
        "enc_v": jnp.zeros((L, batch, enc_len, hkv, hd), cfg.dtype),
    }


def whisper_decode_step(
    params, cache, tokens: jnp.ndarray, pos: jnp.ndarray,
    cfg: ModelConfig, rules=None,
):
    """One decoder token against cached self-attn KV + encoder KV."""
    from repro.models.transformer import attn_apply_decode

    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)
    x = x + _sinusoid(1, cfg.d_model, cfg.dtype)  # position stub for 1 token
    x = _shard(rules, x, "hidden_decode")

    def body(x, lp_and_cache):
        lp, kc, vc, ek, ev = lp_and_cache
        h = rms_norm(x, lp["ln1"])
        a, nc = attn_apply_decode(
            lp["attn"], h, cfg, rules, window=0,
            cache={"k": kc, "v": vc}, pos=pos,
        )
        x = x + a
        h = rms_norm(x, lp["ln_x"])
        x = x + _cross_attn(lp["xattn"], h, (ek, ev), cfg, rules)
        h = rms_norm(x, lp["ln2"])
        x = x + mlp_apply(lp["mlp"], h, rules)
        return x, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"],
         cache["enc_k"], cache["enc_v"]),
    )
    x = rms_norm(x, params["dec_norm"])
    logits = (x @ params["lm_head"].astype(x.dtype))[:, 0]
    new_cache = dict(cache, k=nk, v=nv)
    return logits, new_cache
