"""mistral-large-123b [dense]: 88L, d=12288, 96H (GQA kv=8, head_dim=128),
ff=28672, vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407;
unverified]"""

from repro.models.common import ModelConfig

ARCH_ID = "mistral-large-123b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=88,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32768,
        rope_theta=1_000_000.0,
        train_microbatches=8,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, remat=False,
    )
