"""whisper-small [audio]: enc-dec, 12+12L, d=768, 12H (kv=12), ff=3072,
vocab=51865.  Conv/log-mel frontend is a stub (precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""

from repro.models.common import ModelConfig

ARCH_ID = "whisper-small"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="encdec",
        num_layers=12,
        num_encoder_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        frontend="audio",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=512, remat=False,
    )
