"""mixtral-8x7b [moe]: 32L, d=4096, 32H (GQA kv=8), ff=14336, vocab=32000,
8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""

from repro.models.common import ModelConfig

ARCH_ID = "mixtral-8x7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        num_experts_per_tok=2,
        window=4096,
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, num_experts=4, num_experts_per_tok=2, window=64,
        remat=False,
    )
