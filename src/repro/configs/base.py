"""Shape specs and helpers shared by the per-architecture config files.

Every assigned architecture gets its own ``src/repro/configs/<id>.py`` with
``config()`` (the exact published configuration) and ``smoke_config()``
(a reduced same-family variant for CPU smoke tests).  The canonical shape
definitions live in ``repro.shapes`` (import-light); this module re-exports
them for config-file convenience.
"""

from __future__ import annotations

from repro.shapes import (  # noqa: F401  (re-export)
    SHAPES,
    SUB_QUADRATIC,
    ShapeSpec,
    shape_applicable,
    smoke_shape,
)
