"""Architecture config registry: ``get_config("qwen3-8b")`` etc.

Every assigned architecture has its own module with ``config()`` (exact
published numbers) and ``smoke_config()`` (reduced same-family variant).
"""

from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    SUB_QUADRATIC,
    ShapeSpec,
    shape_applicable,
    smoke_shape,
)
from repro.models.common import ModelConfig

_MODULES = {
    "whisper-small": "repro.configs.whisper_small",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
}

ARCH_IDS = tuple(_MODULES)


def _normalize(arch_id: str) -> str:
    a = arch_id.lower().replace("_", "-")
    if a not in _MODULES:
        # allow python-module style ids like "mamba2_1_3b"
        for k in _MODULES:
            if k.replace("-", "").replace(".", "") == a.replace("-", "").replace(".", ""):
                return k
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return a


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[_normalize(arch_id)])
    return mod.config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[_normalize(arch_id)])
    return mod.smoke_config()


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "SUB_QUADRATIC",
    "ShapeSpec",
    "get_config",
    "get_smoke_config",
    "shape_applicable",
    "smoke_shape",
]
