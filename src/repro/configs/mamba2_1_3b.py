"""mamba2-1.3b [ssm]: 48L, d=2048, attention-free SSD blocks,
ssm_state=128, headdim=64, expand=2, vocab=50280.
[arXiv:2405.21060; unverified]"""

from repro.models.common import ModelConfig

ARCH_ID = "mamba2-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=1,            # unused (attention-free)
        num_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        block_pattern=("ssm",),
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        tied_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, vocab_size=512, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=32, remat=False,
    )
