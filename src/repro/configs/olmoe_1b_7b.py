"""olmoe-1b-7b [moe]: 16L, d=2048, 16H (GQA kv=16), ff=1024 per expert,
vocab=50304, 64 experts top-8, qk_norm.  [arXiv:2409.02060; hf]"""

from repro.models.common import ModelConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        num_experts=64,
        num_experts_per_tok=8,
        qk_norm=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=64,
        vocab_size=512, num_experts=8, num_experts_per_tok=2, remat=False,
    )
