"""recurrentgemma-9b [hybrid]: 38L, d=4096, 16H (MQA kv=1), ff=12288,
vocab=256000.  RG-LRU + local attention in a 1:2 attention:recurrence
pattern — block groups of (rglru, rglru, local_attn); 38 = 12×3 + 2, the
two remainder layers are rglru.  [arXiv:2402.19427; unverified]"""

from repro.models.common import ModelConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=2048,
        lru_width=4096,
        train_microbatches=2,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, local_window=32, lru_width=64, remat=False,
    )
