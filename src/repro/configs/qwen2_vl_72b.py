"""qwen2-vl-72b [vlm]: 80L, d=8192, 64H (GQA kv=8), ff=29568,
vocab=152064, M-RoPE + dynamic resolution.  The vision frontend is a stub:
``input_specs()`` provides precomputed patch/text embeddings and 3-axis
(t, h, w) M-RoPE position ids.  [arXiv:2409.12191; hf]"""

from repro.models.common import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        mrope=True,
        rope_theta=1_000_000.0,
        frontend="vision",
        train_microbatches=4,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, remat=False,
    )
