"""mistral-nemo-12b [dense]: 40L, d=5120, 32H (GQA kv=8, head_dim=128),
ff=14336, vocab=131072, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""

from repro.models.common import ModelConfig

ARCH_ID = "mistral-nemo-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1_000_000.0,
        max_seq_len=131072,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, remat=False,
    )
