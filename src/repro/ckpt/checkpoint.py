"""Checkpointing: atomic npz snapshots with a JSON manifest + resume.

Fault-tolerance contract: a checkpoint is (a) written
atomically (tmp file + rename), (b) self-describing (manifest carries the
step, config hash, data-pipeline cursor, and schedule), (c) discoverable
(``latest_step``), so a re-launched job — possibly with a different
machine set after a failure — resumes exactly.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def _unflatten(template: Any, arrays: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int, name: str) -> str:
        return os.path.join(self.directory, f"step_{step:010d}_{name}")

    def save(self, step: int, state: Any, metadata: dict | None = None) -> str:
        arrays = _flatten(state)
        tmp_fd, tmp_path = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        os.close(tmp_fd)
        np.savez(tmp_path, **{k: v for k, v in arrays.items()})
        # np.savez appends .npz to a name without it; normalize
        if not tmp_path.endswith(".npz") and os.path.exists(tmp_path + ".npz"):
            os.replace(tmp_path + ".npz", tmp_path)
        data_path = self._path(step, "state.npz")
        os.replace(tmp_path, data_path)

        manifest = {
            "step": step,
            "time": time.time(),
            "arrays": sorted(arrays),
            "bytes": int(sum(a.nbytes for a in arrays.values())),
            **(metadata or {}),
        }
        mpath = self._path(step, "manifest.json")
        tmp = mpath + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, mpath)
        self._gc()
        return data_path

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.directory):
            if fn.endswith("_manifest.json"):
                out.append(int(fn.split("_")[1]))
        return sorted(out)

    def load(self, template: Any, step: int | None = None) -> tuple[Any, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(self._path(step, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(self._path(step, "state.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        return _unflatten(template, arrays), manifest

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            for name in ("state.npz", "manifest.json"):
                try:
                    os.remove(self._path(s, name))
                except FileNotFoundError:
                    pass
