"""Discrete-event execution engine for scheduled iterative processes.

``simulate(task_graph, compute_graph, assignment, num_rounds, spec)``
replays the per-task compute/send/receive events of an assignment on the
machines, under one of three execution semantics (``repro.sim.events``):
a full round barrier (``sync`` — the paper's Eq. 2 model, pinned to
``bqp.bottleneck_time`` in tests), send/compute pipelining without
staleness (``overlap``), and barrier-free execution on the latest
delivered neighbor outputs (``async`` — staleness + steady-state
throughput instead of a bottleneck time).

The data plane is a single priority queue of timestamped events:

  - ``compute``: machine j finished its round-r compute (all co-located
    tasks — Eq. 7 charges a task the whole machine load, so outputs ship
    when the machine's queue drains);
  - ``arrive``: one task-graph edge's output was delivered to the
    consumer's machine (``C[m(i), m(i')]`` after the sender's compute);
    zero-delay deliveries short-circuit the queue.

Under ``sync`` the control plane shares the round structure:
:class:`~repro.sim.events.ControlEvent` entries (machine failure /
arrival / recovery, slowdown, delay drift, link outages, elastic
re-schedule) fire at their round's barrier — the engine keeps the fleet
state in ORIGINAL machine labels (speeds ``e_full``, delay base
``C_base``, a boolean ``up`` mask, and a multiplicative link-outage
mask) and subsets to the live machines each round, so fail → rejoin →
fail sequences of one label compose and absent machines report NaN busy
times.  ``schedule_fn`` is consulted exactly where
``fl.simulator.timeline`` used to run its bespoke loop.
``on_round_end(r, busy)`` exposes the engine-measured per-machine busy
times after each barrier (the feed for
``ElasticScheduler.observe_round``); returning an assignment adopts it.
"""

from __future__ import annotations

import heapq
from collections import defaultdict

import numpy as np

from repro.core.graphs import ComputeGraph, TaskGraph
from repro.sim.events import (
    ControlEvent,
    ExecutionSpec,
    SimResult,
    steady_period,
)

_COMPUTE, _ARRIVE = 0, 1


class _Jitter:
    """Per-(machine, round) compute-time multipliers.

    Inactive specs (all-zero sigma and straggler probability) draw
    nothing and return exact 1.0 factors, keeping the no-perturbation
    path bit-identical to the analytic Eq. 2 value.
    """

    def __init__(self, spec: ExecutionSpec, num_machines: int):
        sigma = np.asarray(spec.jitter_sigma, np.float64)
        prob = np.asarray(spec.straggler_prob, np.float64)
        for name, arr in (("jitter_sigma", sigma), ("straggler_prob", prob)):
            if arr.ndim > 1 or (arr.ndim == 1 and arr.size != num_machines):
                raise ValueError(
                    f"per-machine {name} needs {num_machines} entries, "
                    f"got shape {arr.shape}"
                )
        self.sigma = np.broadcast_to(sigma, (num_machines,)).copy()
        self.prob = np.broadcast_to(prob, (num_machines,)).copy()
        self.factor = float(spec.straggler_factor)
        self.active = bool(np.any(self.sigma > 0) or np.any(self.prob > 0))
        self.rng = np.random.default_rng(spec.seed)

    def draw(self, machine_ids) -> np.ndarray:
        k = len(machine_ids)
        if not self.active:
            return np.ones(k)
        ids = np.asarray(machine_ids, dtype=np.int64)
        f = self.rng.lognormal(0.0, self.sigma[ids])
        straggle = self.rng.random(k) < self.prob[ids]
        return np.where(straggle, f * self.factor, f)


def _machine_loads(task_graph: TaskGraph, a: np.ndarray, k: int) -> np.ndarray:
    loads = np.zeros(k)
    np.add.at(loads, a, task_graph.p)
    return loads


def simulate(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    assignment: np.ndarray,
    num_rounds: int,
    execution: ExecutionSpec | None = None,
    *,
    control_events: tuple[ControlEvent, ...] = (),
    schedule_fn=None,
    on_round_end=None,
) -> SimResult:
    """Simulate ``num_rounds`` of the assignment under ``execution``.

    ``schedule_fn(task_graph, compute_graph, round_idx) -> assignment``
    is consulted by ``fail`` / ``join`` / ``recover`` / ``slowdown`` /
    ``reschedule`` control events (the compute graph it receives is the
    live fleet in sorted original-label order, link-outage penalties
    applied); ``on_round_end(round_idx, busy) -> assignment | None`` fires
    after every sync barrier with the live machines' measured busy times.
    Control events and round-end feedback require ``sync`` semantics —
    the barrier is the only globally quiescent point at which changing
    the fleet or the assignment is well defined.
    """
    spec = execution if execution is not None else ExecutionSpec()
    if num_rounds < 1:
        raise ValueError("num_rounds must be >= 1")
    a = np.asarray(assignment, dtype=np.int64)
    if a.shape != (task_graph.num_tasks,):
        raise ValueError(
            f"assignment shape {a.shape} != ({task_graph.num_tasks},)"
        )
    if np.any(a < 0) or np.any(a >= compute_graph.num_machines):
        raise ValueError("assignment references unknown machines")
    if spec.semantics == "sync":
        return _simulate_sync(
            task_graph, compute_graph, a, num_rounds, spec,
            control_events, schedule_fn, on_round_end,
        )
    if control_events:
        raise ValueError(
            "control events (fail/join/recover/slowdown/delay_update/"
            "link_down/link_up/reschedule) require sync semantics — the "
            "round barrier is the only quiescent point"
        )
    if on_round_end is not None:
        raise ValueError("on_round_end feedback requires sync semantics")
    return _simulate_free(task_graph, compute_graph, a, num_rounds, spec)


# ---------------------------------------------------------------------------
# sync: round barrier + control plane
# ---------------------------------------------------------------------------


def _check_label(machine: int, k0: int, kind: str, r: int) -> None:
    if not 0 <= machine < k0:
        raise ValueError(
            f"round {r}: {kind} event references machine {machine} outside "
            f"the compute graph's universe of {k0} machines (grow the fleet "
            f"at the control layer — ElasticScheduler.on_arrival — before "
            f"simulating)"
        )


def _simulate_sync(
    task_graph, compute_graph, a, num_rounds, spec,
    control_events, schedule_fn, on_round_end,
) -> SimResult:
    # Fleet state in ORIGINAL machine labels: ``up`` marks the live
    # machines, ``e_full``/``C_base`` carry every machine's current speed
    # and nominal delay rows (so a machine that fails and later rejoins
    # gets its own state back), and ``link_mask`` holds the multiplicative
    # outage penalties of intermittently-down links.  The live compute
    # graph each round is (e_full, C_base * link_mask) subset to the
    # sorted live labels.
    k0 = compute_graph.num_machines
    up = np.ones(k0, dtype=bool)
    e_full = compute_graph.e.copy()
    C_base = compute_graph.C.copy()
    link_mask = np.ones((k0, k0))
    a = a.copy()
    jitter = _Jitter(spec, k0)
    edges = task_graph.edges

    by_round: dict[int, list[ControlEvent]] = {}
    for ev in control_events:
        by_round.setdefault(ev.round, []).append(ev)

    round_times = np.zeros(num_rounds)
    busy = np.full((num_rounds, k0), np.nan)
    fleet_size = np.zeros(num_rounds, dtype=np.int64)
    reschedule_rounds: list[int] = []
    events_processed = 0

    for r in range(num_rounds):
        # -- control plane: fires at the barrier opening round r --------
        resched = False
        for ev in by_round.get(r, ()):
            m = ev.machine
            if ev.kind == "delay_update":
                C_new = np.asarray(ev.C, dtype=np.float64)
                if C_new.shape == (k0, k0):
                    C_base = C_new.copy()
                else:
                    live = np.flatnonzero(up)
                    if C_new.shape != (live.size, live.size):
                        raise ValueError(
                            f"round {r}: delay_update matrix has shape "
                            f"{C_new.shape}; expected the full universe "
                            f"({k0},{k0}) or the live fleet "
                            f"({live.size},{live.size})"
                        )
                    C_base[np.ix_(live, live)] = C_new
            elif ev.kind == "fail":
                _check_label(m, k0, ev.kind, r)
                if not up[m]:
                    raise ValueError(
                        f"round {r}: fail of machine {m}, which is already "
                        f"down — double failures desynchronize the fleet"
                    )
                if up.sum() == 1:
                    raise ValueError(
                        f"round {r}: fail of machine {m} would empty the fleet"
                    )
                up[m] = False
                resched = True
            elif ev.kind in ("join", "recover"):
                _check_label(m, k0, ev.kind, r)
                if up[m]:
                    raise ValueError(
                        f"round {r}: {ev.kind} of machine {m}, which is "
                        f"already up"
                    )
                up[m] = True
                resched = True
            elif ev.kind == "slowdown":
                _check_label(m, k0, ev.kind, r)
                if not up[m]:
                    raise ValueError(
                        f"round {r}: slowdown of machine {m}, which is down"
                    )
                e_full[m] *= ev.factor
                resched = True
            elif ev.kind == "link_down":
                _check_label(m, k0, ev.kind, r)
                _check_label(ev.peer, k0, ev.kind, r)
                if link_mask[m, ev.peer] != 1.0:
                    raise ValueError(
                        f"round {r}: link_down of ({m},{ev.peer}), which is "
                        f"already in an outage window"
                    )
                link_mask[m, ev.peer] = link_mask[ev.peer, m] = ev.factor
            elif ev.kind == "link_up":
                _check_label(m, k0, ev.kind, r)
                _check_label(ev.peer, k0, ev.kind, r)
                if link_mask[m, ev.peer] == 1.0:
                    raise ValueError(
                        f"round {r}: link_up of ({m},{ev.peer}), which is "
                        f"not in an outage window"
                    )
                link_mask[m, ev.peer] = link_mask[ev.peer, m] = 1.0
            else:  # "reschedule" — validated by ControlEvent
                resched = True

        machine_ids = [int(j) for j in np.flatnonzero(up)]
        k = len(machine_ids)
        e = e_full[machine_ids]
        C = (C_base * link_mask)[np.ix_(machine_ids, machine_ids)]
        if resched:
            if schedule_fn is None:
                raise ValueError(
                    "fail/join/recover/slowdown/reschedule control events "
                    "need schedule_fn"
                )
            a = np.asarray(
                schedule_fn(task_graph, ComputeGraph(e=e, C=C), r),
                dtype=np.int64,
            )
            reschedule_rounds.append(r)
        if np.any(a < 0) or np.any(a >= k):
            raise ValueError(
                f"round {r}: assignment references machines outside the "
                f"live fleet of {k}"
            )

        # -- data plane: one queue per round, round-local clock ---------
        loads = _machine_loads(task_graph, a, k)
        factors = jitter.draw(machine_ids)
        busy_r = loads / e * factors
        out_by_machine: list[list[int]] = [[] for _ in range(k)]
        for (i, i2) in edges:
            out_by_machine[a[i]].append(a[i2])
        heap: list[tuple[float, int, int, int]] = []
        seq = 0
        for j in range(k):
            heapq.heappush(heap, (busy_r[j], seq, _COMPUTE, j))
            seq += 1
        barrier = 0.0
        while heap:
            t, _, kind, j = heapq.heappop(heap)
            events_processed += 1
            if t > barrier:
                barrier = t
            if kind == _COMPUTE:
                for dst in out_by_machine[j]:
                    heapq.heappush(heap, (t + C[j, dst], seq, _ARRIVE, dst))
                    seq += 1
        round_times[r] = barrier
        busy[r, machine_ids] = busy_r
        fleet_size[r] = k

        if on_round_end is not None:
            adopted = on_round_end(r, busy_r.copy())
            if adopted is not None:
                a = np.asarray(adopted, dtype=np.int64)

    completion = np.cumsum(round_times)
    n_t = task_graph.num_tasks
    period = steady_period(completion)
    return SimResult(
        semantics="sync",
        num_rounds=num_rounds,
        round_completion=completion,
        round_times=round_times,
        busy=busy,
        fleet_size=fleet_size,
        total_time=float(completion[-1]),
        period=period,
        throughput=1.0 / period if period > 0 else float("inf"),
        staleness_mean=0.0,
        staleness_max=0,
        staleness_per_task=np.zeros(n_t),
        reschedule_rounds=reschedule_rounds,
        machine_ids=machine_ids,
        assignment=a,
        events_processed=events_processed,
    )


# ---------------------------------------------------------------------------
# overlap / async: free-running machines, one global queue
# ---------------------------------------------------------------------------


def _simulate_free(task_graph, compute_graph, a, num_rounds, spec) -> SimResult:
    semantics = spec.semantics
    k = compute_graph.num_machines
    n_t = task_graph.num_tasks
    e, C = compute_graph.e, compute_graph.C
    jitter = _Jitter(spec, k)
    loads = _machine_loads(task_graph, a, k)
    base = loads / e

    edges = list(task_graph.edges)
    n_e = len(edges)
    src_m = np.array([a[i] for (i, _) in edges], dtype=np.int64)
    dst_m = np.array([a[j] for (_, j) in edges], dtype=np.int64)
    dst_task = np.array([j for (_, j) in edges], dtype=np.int64)
    out_by_machine: list[list[int]] = [[] for _ in range(k)]
    in_by_machine: list[list[int]] = [[] for _ in range(k)]
    for idx in range(n_e):
        out_by_machine[src_m[idx]].append(idx)
        in_by_machine[dst_m[idx]].append(idx)
    in_count = np.bincount(dst_m, minlength=k) if n_e else np.zeros(k, np.int64)

    heap: list[tuple[float, int, int, int, int]] = []
    seq = 0
    mailbox = np.full(n_e, -1, dtype=np.int64)  # freshest delivered src round
    arrived = [defaultdict(int) for _ in range(k)]  # round -> deliveries
    done_round = np.full(k, -1, dtype=np.int64)
    waiting = np.full(k, -1, dtype=np.int64)  # overlap: round gated on inputs

    # round completion: computes for async; computes + deliveries for overlap
    need = k + (n_e if semantics == "overlap" else 0)
    remaining = np.full(num_rounds, need, dtype=np.int64)
    completion = np.zeros(num_rounds)
    busy = np.zeros((num_rounds, k))
    stale_sum = np.zeros(n_t)
    stale_cnt = np.zeros(n_t)
    stale_max = 0
    events_processed = 0

    def finish_one(r: int, t: float) -> None:
        if r < num_rounds:
            remaining[r] -= 1
            if remaining[r] == 0:
                completion[r] = t

    def start(j: int, r: int, t: float) -> None:
        nonlocal seq, stale_max
        if semantics == "async" and r > 0:
            # staleness vs the synchronous reference: sync round r consumes
            # round r-1 outputs; fresher-than-sync inputs count as 0
            for idx in in_by_machine[j]:
                lag = (r - 1) - int(mailbox[idx])
                if lag > 0:
                    stale_sum[dst_task[idx]] += lag
                    if lag > stale_max:
                        stale_max = lag
                stale_cnt[dst_task[idx]] += 1
        b = base[j] * jitter.draw([j])[0] if jitter.active else base[j]
        busy[r, j] = b
        heapq.heappush(heap, (t + b, seq, _COMPUTE, j, r))
        seq += 1

    def deliver(idx: int, r_src: int, t: float) -> None:
        if r_src > mailbox[idx]:
            mailbox[idx] = r_src
        j = int(dst_m[idx])
        arrived[j][r_src] += 1
        if semantics == "overlap":
            finish_one(r_src, t)
            nr = r_src + 1
            if (
                waiting[j] == nr
                and done_round[j] == r_src
                and arrived[j][r_src] == in_count[j]
                and nr < num_rounds
            ):
                waiting[j] = -1
                start(j, nr, t)

    for j in range(k):
        start(j, 0, 0.0)

    while heap:
        t, _, kind, x, r = heapq.heappop(heap)
        events_processed += 1
        if kind == _COMPUTE:
            j = x
            done_round[j] = r
            for idx in out_by_machine[j]:
                c = C[j, dst_m[idx]]
                if c == 0.0:  # zero-delay links short-circuit the queue
                    events_processed += 1
                    deliver(idx, r, t)
                else:
                    heapq.heappush(heap, (t + c, seq, _ARRIVE, idx, r))
                    seq += 1
            finish_one(r, t)
            nr = r + 1
            if nr < num_rounds:
                if semantics == "async":
                    start(j, nr, t)
                elif arrived[j][r] == in_count[j]:
                    start(j, nr, t)
                else:
                    waiting[j] = nr
        else:
            deliver(x, r, t)

    round_times = np.diff(completion, prepend=0.0)
    period = steady_period(completion)
    samples = stale_cnt.sum()
    return SimResult(
        semantics=semantics,
        num_rounds=num_rounds,
        round_completion=completion,
        round_times=round_times,
        busy=busy,
        fleet_size=np.full(num_rounds, k, dtype=np.int64),
        total_time=float(completion[-1]),
        period=period,
        throughput=1.0 / period if period > 0 else float("inf"),
        staleness_mean=float(stale_sum.sum() / samples) if samples else 0.0,
        staleness_max=int(stale_max),
        staleness_per_task=stale_sum / np.maximum(stale_cnt, 1),
        reschedule_rounds=[],
        machine_ids=list(range(k)),
        assignment=a,
        events_processed=events_processed,
    )
