"""Discrete-event execution engine for scheduled iterative processes.

``simulate(task_graph, compute_graph, assignment, num_rounds, spec)``
replays the per-task compute/send/receive events of an assignment on the
machines, under one of three execution semantics (``repro.sim.events``):
a full round barrier (``sync`` — the paper's Eq. 2 model, pinned to
``bqp.bottleneck_time`` in tests), send/compute pipelining without
staleness (``overlap``), and barrier-free execution on the latest
delivered neighbor outputs (``async`` — staleness + steady-state
throughput instead of a bottleneck time).

The data plane is a single priority queue of timestamped events with a
DOCUMENTED total order: keys are ``(t, kind, index, round)`` and at equal
``t`` the kinds process as

  ``arrive`` (0)   one task-graph edge's output delivered to the
                   consumer's machine — all same-instant deliveries
                   settle first, in edge-index order;
  ``compute`` (1)  machine j finished its round-r compute (all co-located
                   tasks — Eq. 7 charges a task the whole machine load,
                   so outputs ship when the machine's queue drains), in
                   machine-index order;
  ``boundary`` (2) machine j's round-r boundary: its mailbox snapshot is
                   read (the mix schedule), staleness is accounted, churn
                   windows apply, and the next local round starts — after
                   every same-instant arrival and compute, in
                   machine-index order (which also fixes the jitter-draw
                   order).

No insertion sequence number participates in the ordering, so permuting
the order events are pushed leaves ``SimResult`` bit-identical
(regression-tested in ``tests/test_sim.py``).

Under ``sync`` the control plane shares the round structure:
:class:`~repro.sim.events.ControlEvent` entries fire at their round's
barrier — the engine keeps the fleet state in ORIGINAL machine labels and
subsets to the live machines each round.  ``schedule_fn`` is consulted
exactly where ``fl.simulator.timeline`` used to run its bespoke loop;
``on_round_end(r, busy)`` exposes the engine-measured per-machine busy
times after each barrier.

Under ``async`` the machine-LOCAL control kinds
(``fail``/``join``/``recover``/``slowdown``) compose without a barrier: a
fail freezes the machine when it would start that local round, a recover
fires once the live fleet's frontier (minimum round any up machine is
computing) reaches the recover round — rejoin triggers push/pull
anti-entropy so the returning machine's mailbox catches up and its frozen
snapshot reaches its neighbors — and per-machine token accounts
(``repro.sim.flow``) bound in-flight sends.  The per-(round, edge)
mailbox snapshots are recorded as ``SimResult.mix_versions``, the mix
schedule ``repro.fl.async_gossip.AsyncGossipTrainer`` replays so model
updates actually flow barrier-free (DESIGN.md §11).
"""

from __future__ import annotations

import heapq
from collections import defaultdict

import numpy as np

from repro.core.graphs import ComputeGraph, TaskGraph
from repro.sim.events import (
    ASYNC_CONTROL_KINDS,
    ControlEvent,
    ExecutionSpec,
    SimResult,
    steady_period,
)
from repro.sim.flow import TokenAccount

# Queue-key kind priorities — the documented total order at equal time.
_EV_ARRIVE, _EV_COMPUTE, _EV_BOUNDARY = 0, 1, 2


class _Jitter:
    """Per-(machine, round) compute-time multipliers.

    Inactive specs (all-zero sigma and straggler probability) draw
    nothing and return exact 1.0 factors, keeping the no-perturbation
    path bit-identical to the analytic Eq. 2 value.
    """

    def __init__(self, spec: ExecutionSpec, num_machines: int):
        sigma = np.asarray(spec.jitter_sigma, np.float64)
        prob = np.asarray(spec.straggler_prob, np.float64)
        for name, arr in (("jitter_sigma", sigma), ("straggler_prob", prob)):
            if arr.ndim > 1 or (arr.ndim == 1 and arr.size != num_machines):
                raise ValueError(
                    f"per-machine {name} needs {num_machines} entries, "
                    f"got shape {arr.shape}"
                )
        self.sigma = np.broadcast_to(sigma, (num_machines,)).copy()
        self.prob = np.broadcast_to(prob, (num_machines,)).copy()
        self.factor = float(spec.straggler_factor)
        self.active = bool(np.any(self.sigma > 0) or np.any(self.prob > 0))
        self.rng = np.random.default_rng(spec.seed)

    def draw(self, machine_ids) -> np.ndarray:
        k = len(machine_ids)
        if not self.active:
            return np.ones(k)
        ids = np.asarray(machine_ids, dtype=np.int64)
        f = self.rng.lognormal(0.0, self.sigma[ids])
        straggle = self.rng.random(k) < self.prob[ids]
        return np.where(straggle, f * self.factor, f)


def _machine_loads(task_graph: TaskGraph, a: np.ndarray, k: int) -> np.ndarray:
    loads = np.zeros(k)
    np.add.at(loads, a, task_graph.p)
    return loads


def _check_busy_factors(busy_factors, num_rounds: int, k: int):
    if busy_factors is None:
        return None
    bf = np.asarray(busy_factors, dtype=np.float64)
    if bf.shape != (num_rounds, k):
        raise ValueError(
            f"busy_factors shape {bf.shape} != ({num_rounds}, {k}) — one "
            f"multiplicative factor per (round, original machine label)"
        )
    if np.any(bf <= 0):
        raise ValueError("busy_factors must be > 0")
    return bf


def simulate(
    task_graph: TaskGraph,
    compute_graph: ComputeGraph,
    assignment: np.ndarray,
    num_rounds: int,
    execution: ExecutionSpec | None = None,
    *,
    control_events: tuple[ControlEvent, ...] = (),
    schedule_fn=None,
    on_round_end=None,
    busy_factors=None,
) -> SimResult:
    """Simulate ``num_rounds`` of the assignment under ``execution``.

    ``schedule_fn(task_graph, compute_graph, round_idx) -> assignment``
    is consulted by ``fail`` / ``join`` / ``recover`` / ``slowdown`` /
    ``reschedule`` control events under ``sync`` semantics (the compute
    graph it receives is the live fleet in sorted original-label order,
    link-outage penalties applied); ``on_round_end(round_idx, busy) ->
    assignment | None`` fires after every sync barrier with the live
    machines' measured busy times.  ``busy_factors`` is an optional
    ``(num_rounds, N_K)`` matrix of multiplicative per-(round, machine)
    compute-time factors (responsiveness/completeness device states —
    ``scenarios.profiles.churn_trace``), applied on top of jitter.

    Global control events (``delay_update``, ``link_down``/``link_up``,
    ``reschedule``) require ``sync`` — the barrier is the only globally
    quiescent point at which changing the delay matrix or the assignment
    is well defined.  The machine-LOCAL kinds (``fail``/``join``/
    ``recover``/``slowdown``) additionally compose with ``async``
    semantics, where the assignment is fixed and a churned-out machine
    simply freezes at its local round until the fleet frontier reaches
    its recovery round.  ``overlap`` admits no control plane.
    """
    spec = execution if execution is not None else ExecutionSpec()
    if num_rounds < 1:
        raise ValueError("num_rounds must be >= 1")
    a = np.asarray(assignment, dtype=np.int64)
    if a.shape != (task_graph.num_tasks,):
        raise ValueError(
            f"assignment shape {a.shape} != ({task_graph.num_tasks},)"
        )
    if np.any(a < 0) or np.any(a >= compute_graph.num_machines):
        raise ValueError("assignment references unknown machines")
    if spec.semantics != "async" and spec.token_capacity is not None:
        raise ValueError(
            f"token-account flow control requires async semantics (got "
            f"{spec.semantics!r}): under sync/overlap every send is a "
            f"dependency, so a skipped send would deadlock its consumer"
        )
    if spec.semantics == "sync":
        return _simulate_sync(
            task_graph, compute_graph, a, num_rounds, spec,
            control_events, schedule_fn, on_round_end, busy_factors,
        )
    if on_round_end is not None:
        raise ValueError("on_round_end feedback requires sync semantics")
    if spec.semantics == "overlap" and control_events:
        raise ValueError(
            "control events require sync semantics under overlap — use "
            "sync for the full control plane or async for the "
            "machine-local fail/join/recover/slowdown subset"
        )
    for ev in control_events:
        if ev.kind not in ASYNC_CONTROL_KINDS:
            raise ValueError(
                f"{ev.kind} control events require sync semantics — the "
                f"round barrier is the only quiescent point for global "
                f"delay/link/assignment changes; async admits the "
                f"machine-local kinds {ASYNC_CONTROL_KINDS}"
            )
    return _simulate_free(
        task_graph, compute_graph, a, num_rounds, spec,
        control_events=control_events, busy_factors=busy_factors,
    )


# ---------------------------------------------------------------------------
# sync: round barrier + control plane
# ---------------------------------------------------------------------------


def _check_label(machine: int, k0: int, kind: str, r: int) -> None:
    if not 0 <= machine < k0:
        raise ValueError(
            f"round {r}: {kind} event references machine {machine} outside "
            f"the compute graph's universe of {k0} machines (grow the fleet "
            f"at the control layer — ElasticScheduler.on_arrival — before "
            f"simulating)"
        )


def _simulate_sync(
    task_graph, compute_graph, a, num_rounds, spec,
    control_events, schedule_fn, on_round_end, busy_factors,
) -> SimResult:
    # Fleet state in ORIGINAL machine labels: ``up`` marks the live
    # machines, ``e_full``/``C_base`` carry every machine's current speed
    # and nominal delay rows (so a machine that fails and later rejoins
    # gets its own state back), and ``link_mask`` holds the multiplicative
    # outage penalties of intermittently-down links.  The live compute
    # graph each round is (e_full, C_base * link_mask) subset to the
    # sorted live labels.
    k0 = compute_graph.num_machines
    up = np.ones(k0, dtype=bool)
    e_full = compute_graph.e.copy()
    C_base = compute_graph.C.copy()
    link_mask = np.ones((k0, k0))
    a = a.copy()
    jitter = _Jitter(spec, k0)
    bf = _check_busy_factors(busy_factors, num_rounds, k0)
    edges = task_graph.edges

    by_round: dict[int, list[ControlEvent]] = {}
    for ev in control_events:
        by_round.setdefault(ev.round, []).append(ev)

    round_times = np.zeros(num_rounds)
    busy = np.full((num_rounds, k0), np.nan)
    fleet_size = np.zeros(num_rounds, dtype=np.int64)
    reschedule_rounds: list[int] = []
    events_processed = 0
    barrier_stalls = 0

    for r in range(num_rounds):
        # -- control plane: fires at the barrier opening round r --------
        resched = False
        for ev in by_round.get(r, ()):
            m = ev.machine
            if ev.kind == "delay_update":
                C_new = np.asarray(ev.C, dtype=np.float64)
                if C_new.shape == (k0, k0):
                    C_base = C_new.copy()
                else:
                    live = np.flatnonzero(up)
                    if C_new.shape != (live.size, live.size):
                        raise ValueError(
                            f"round {r}: delay_update matrix has shape "
                            f"{C_new.shape}; expected the full universe "
                            f"({k0},{k0}) or the live fleet "
                            f"({live.size},{live.size})"
                        )
                    C_base[np.ix_(live, live)] = C_new
            elif ev.kind == "fail":
                _check_label(m, k0, ev.kind, r)
                if not up[m]:
                    raise ValueError(
                        f"round {r}: fail of machine {m}, which is already "
                        f"down — double failures desynchronize the fleet"
                    )
                if up.sum() == 1:
                    raise ValueError(
                        f"round {r}: fail of machine {m} would empty the fleet"
                    )
                up[m] = False
                resched = True
            elif ev.kind in ("join", "recover"):
                _check_label(m, k0, ev.kind, r)
                if up[m]:
                    raise ValueError(
                        f"round {r}: {ev.kind} of machine {m}, which is "
                        f"already up"
                    )
                up[m] = True
                resched = True
            elif ev.kind == "slowdown":
                _check_label(m, k0, ev.kind, r)
                if not up[m]:
                    raise ValueError(
                        f"round {r}: slowdown of machine {m}, which is down"
                    )
                e_full[m] *= ev.factor
                resched = True
            elif ev.kind == "link_down":
                _check_label(m, k0, ev.kind, r)
                _check_label(ev.peer, k0, ev.kind, r)
                if link_mask[m, ev.peer] != 1.0:
                    raise ValueError(
                        f"round {r}: link_down of ({m},{ev.peer}), which is "
                        f"already in an outage window"
                    )
                link_mask[m, ev.peer] = link_mask[ev.peer, m] = ev.factor
            elif ev.kind == "link_up":
                _check_label(m, k0, ev.kind, r)
                _check_label(ev.peer, k0, ev.kind, r)
                if link_mask[m, ev.peer] == 1.0:
                    raise ValueError(
                        f"round {r}: link_up of ({m},{ev.peer}), which is "
                        f"not in an outage window"
                    )
                link_mask[m, ev.peer] = link_mask[ev.peer, m] = 1.0
            else:  # "reschedule" — validated by ControlEvent
                resched = True

        machine_ids = [int(j) for j in np.flatnonzero(up)]
        k = len(machine_ids)
        e = e_full[machine_ids]
        C = (C_base * link_mask)[np.ix_(machine_ids, machine_ids)]
        if resched:
            if schedule_fn is None:
                raise ValueError(
                    "fail/join/recover/slowdown/reschedule control events "
                    "need schedule_fn"
                )
            a = np.asarray(
                schedule_fn(task_graph, ComputeGraph(e=e, C=C), r),
                dtype=np.int64,
            )
            reschedule_rounds.append(r)
        if np.any(a < 0) or np.any(a >= k):
            raise ValueError(
                f"round {r}: assignment references machines outside the "
                f"live fleet of {k}"
            )

        # -- data plane: one queue per round, round-local clock ---------
        loads = _machine_loads(task_graph, a, k)
        factors = jitter.draw(machine_ids)
        busy_r = loads / e * factors
        if bf is not None:
            busy_r = busy_r * bf[r, machine_ids]
        out_by_machine: list[list[int]] = [[] for _ in range(k)]
        for (i, i2) in edges:
            out_by_machine[a[i]].append(a[i2])
        heap: list[tuple[float, int, int]] = []
        for j in range(k):
            heapq.heappush(heap, (busy_r[j], _EV_COMPUTE, j))
        barrier = 0.0
        while heap:
            t, kind, j = heapq.heappop(heap)
            events_processed += 1
            if t > barrier:
                barrier = t
            if kind == _EV_COMPUTE:
                for dst in out_by_machine[j]:
                    heapq.heappush(heap, (t + C[j, dst], _EV_ARRIVE, dst))
        round_times[r] = barrier
        busy[r, machine_ids] = busy_r
        fleet_size[r] = k
        # a machine whose compute drained strictly before the barrier sat
        # idle waiting for the fleet — the stall async execution removes
        barrier_stalls += int(np.sum(busy_r < barrier))

        if on_round_end is not None:
            adopted = on_round_end(r, busy_r.copy())
            if adopted is not None:
                a = np.asarray(adopted, dtype=np.int64)

    completion = np.cumsum(round_times)
    n_t = task_graph.num_tasks
    period = steady_period(completion)
    return SimResult(
        semantics="sync",
        num_rounds=num_rounds,
        round_completion=completion,
        round_times=round_times,
        busy=busy,
        fleet_size=fleet_size,
        total_time=float(completion[-1]),
        period=period,
        throughput=1.0 / period if period > 0 else float("inf"),
        staleness_mean=0.0,
        staleness_max=0,
        staleness_per_task=np.zeros(n_t),
        reschedule_rounds=reschedule_rounds,
        machine_ids=machine_ids,
        assignment=a,
        events_processed=events_processed,
        barrier_stalls=barrier_stalls,
    )


# ---------------------------------------------------------------------------
# overlap / async: free-running machines, one global queue
# ---------------------------------------------------------------------------


def _async_control_plan(control_events, k0: int, num_rounds: int):
    """Per-machine down windows + slowdown schedule from async control
    events.

    Returns ``(windows, slowdowns)``: ``windows[m]`` is a sorted list of
    ``[fail_round, recover_round)`` half-open intervals (an unpaired fail
    yields ``recover_round = num_rounds + 1`` — the machine never
    returns); ``slowdowns[m]`` is a sorted list of ``(round, factor)``
    applied when the machine's local round reaches ``round`` (or at its
    recovery, if it is down then).
    """
    per: list[list[ControlEvent]] = [[] for _ in range(k0)]
    for ev in control_events:
        _check_label(ev.machine, k0, ev.kind, ev.round)
        per[ev.machine].append(ev)
    windows: list[list[tuple[int, int]]] = [[] for _ in range(k0)]
    slowdowns: list[list[tuple[int, float]]] = [[] for _ in range(k0)]
    arrive_first = {"join": 0, "recover": 0, "slowdown": 1, "fail": 2}
    for m in range(k0):
        open_round = None
        for ev in sorted(per[m], key=lambda ev: (ev.round, arrive_first[ev.kind])):
            if ev.kind == "slowdown":
                slowdowns[m].append((ev.round, float(ev.factor)))
            elif ev.kind == "fail":
                if open_round is not None:
                    raise ValueError(
                        f"round {ev.round}: fail of machine {m}, which is "
                        f"already down — double failures desynchronize the "
                        f"fleet"
                    )
                open_round = ev.round
            else:  # join / recover
                if open_round is None:
                    raise ValueError(
                        f"round {ev.round}: {ev.kind} of machine {m}, which "
                        f"is already up"
                    )
                if ev.round <= open_round:
                    raise ValueError(
                        f"round {ev.round}: {ev.kind} of machine {m} does "
                        f"not follow its fail at round {open_round}"
                    )
                windows[m].append((open_round, ev.round))
                open_round = None
        if open_round is not None:
            windows[m].append((open_round, num_rounds + 1))
    return windows, slowdowns


def _simulate_free(
    task_graph, compute_graph, a, num_rounds, spec,
    control_events=(), busy_factors=None,
) -> SimResult:
    semantics = spec.semantics
    k = compute_graph.num_machines
    n_t = task_graph.num_tasks
    e_eff = compute_graph.e.astype(np.float64).copy()
    C = compute_graph.C
    jitter = _Jitter(spec, k)
    bf = _check_busy_factors(busy_factors, num_rounds, k)
    loads = _machine_loads(task_graph, a, k)

    edges = list(task_graph.edges)
    n_e = len(edges)
    src_m = np.array([a[i] for (i, _) in edges], dtype=np.int64)
    dst_m = np.array([a[j] for (_, j) in edges], dtype=np.int64)
    dst_task = np.array([j for (_, j) in edges], dtype=np.int64)
    out_by_machine: list[list[int]] = [[] for _ in range(k)]
    in_by_machine: list[list[int]] = [[] for _ in range(k)]
    for idx in range(n_e):
        out_by_machine[src_m[idx]].append(idx)
        in_by_machine[dst_m[idx]].append(idx)
    in_count = np.bincount(dst_m, minlength=k) if n_e else np.zeros(k, np.int64)

    windows, slowdowns = _async_control_plan(control_events, k, num_rounds)
    tokens = (
        [TokenAccount(spec.token_capacity, spec.token_refill) for _ in range(k)]
        if spec.token_capacity is not None else None
    )

    # Queue keys (t, kind, idx, round): value-determined total order — see
    # the module docstring.  Duplicate keys (e.g. an anti-entropy push of
    # a version the regular send already shipped) are harmless: delivery
    # keeps the freshest version either way.
    heap: list[tuple[float, int, int, int]] = []
    mailbox = np.full(n_e, -1, dtype=np.int64)  # freshest delivered src round
    arrived = [defaultdict(int) for _ in range(k)]  # round -> deliveries
    done_round = np.full(k, -1, dtype=np.int64)
    waiting = np.full(k, -1, dtype=np.int64)  # overlap: round gated on inputs

    # overlap round completion: computes + deliveries countdown
    remaining = np.full(num_rounds, k + n_e, dtype=np.int64)
    overlap_completion = np.zeros(num_rounds)
    machine_end = np.full((num_rounds, k), np.nan)
    busy = np.full((num_rounds, k), np.nan)
    down_rounds = np.zeros((num_rounds, k), dtype=bool)
    mix_versions = (
        np.full((num_rounds, n_e), -1, dtype=np.int64)
        if semantics == "async" else None
    )
    stale_sum = np.zeros(n_t)
    stale_cnt = np.zeros(n_t)
    stale_max = 0
    barrier_stalls = 0
    send_skips = 0
    antientropy = 0
    events_processed = 0

    # churn state: next_round[j] is the local round an UP machine is
    # computing (or num_rounds once finished); the fleet frontier is its
    # minimum over up machines.
    up = np.ones(k, dtype=bool)
    win_idx = np.zeros(k, dtype=np.int64)
    next_round = np.zeros(k, dtype=np.int64)
    resume_round = np.full(k, -1, dtype=np.int64)
    down_from = np.full(k, -1, dtype=np.int64)
    any_windows = any(windows[m] for m in range(k))

    def push(t: float, kind: int, idx: int, r: int) -> None:
        heapq.heappush(heap, (t, kind, idx, r))

    def apply_slowdowns(j: int, upto: int) -> None:
        while slowdowns[j] and slowdowns[j][0][0] <= upto:
            _, f = slowdowns[j].pop(0)
            e_eff[j] *= f

    def start(j: int, r: int, t: float) -> None:
        next_round[j] = r
        b = loads[j] / e_eff[j]
        if jitter.active:
            b *= jitter.draw([j])[0]
        if bf is not None:
            b *= bf[r, j]
        busy[r, j] = b
        push(t + b, _EV_COMPUTE, j, r)

    def send_outputs(j: int, r: int, t: float) -> None:
        nonlocal send_skips
        out = out_by_machine[j]
        if not out:
            return
        if tokens is not None:
            acct = tokens[j]
            acct.replenish()
            rot = r % len(out)
            for idx in out[rot:] + out[:rot]:
                if acct.try_send():
                    push(t + C[j, dst_m[idx]], _EV_ARRIVE, idx, r)
                else:
                    send_skips += 1
        else:
            for idx in out:
                push(t + C[j, dst_m[idx]], _EV_ARRIVE, idx, r)

    def check_frontier(t: float) -> None:
        """Recover down machines whose resume round the frontier reached.

        Each recovery lowers the live frontier (the rejoiner restarts at
        its resume round), so the frontier is recomputed after every one;
        ties recover in (resume_round, machine index) order.
        """
        while True:
            pending = [
                j for j in range(k) if not up[j] and resume_round[j] >= 0
            ]
            if not pending:
                return
            live = next_round[up]
            frontier = int(live.min()) if live.size else num_rounds
            ready = [j for j in pending if resume_round[j] <= frontier]
            if not ready:
                return
            recover(min(ready, key=lambda j: (resume_round[j], j)), t)

    def recover(j: int, t: float) -> None:
        nonlocal antientropy
        rr = int(resume_round[j])
        down_rounds[down_from[j]:min(rr, num_rounds), j] = True
        up[j] = True
        resume_round[j] = -1
        apply_slowdowns(j, rr)
        # push/pull anti-entropy: pull each in-neighbor's latest completed
        # snapshot (the mailbox may have missed token-skipped sends), push
        # the frozen local snapshot back out — both delay-charged.
        for idx in in_by_machine[j]:
            v = int(done_round[src_m[idx]])
            if v >= 0:
                push(t + C[src_m[idx], j], _EV_ARRIVE, idx, v)
                antientropy += 1
        v = int(done_round[j])
        if v >= 0:
            for idx in out_by_machine[j]:
                push(t + C[j, dst_m[idx]], _EV_ARRIVE, idx, v)
                antientropy += 1
        if rr < num_rounds:
            start(j, rr, t)
        else:  # pragma: no cover — windows are clipped to the trace length
            next_round[j] = num_rounds

    def boundary(j: int, r: int, t: float) -> None:
        """End of machine j's local round r: every same-instant delivery
        has already settled (arrive < boundary at equal t)."""
        nonlocal stale_max, barrier_stalls
        machine_end[r, j] = t
        if mix_versions is not None:
            for idx in in_by_machine[j]:
                mix_versions[r, idx] = mailbox[idx]
        if semantics == "async" and r < num_rounds - 1:
            # staleness vs the synchronous reference: sync round r+1
            # consumes round-r outputs; fresher-than-sync counts as 0
            for idx in in_by_machine[j]:
                lag = r - int(mailbox[idx])
                if lag > 0:
                    stale_sum[dst_task[idx]] += lag
                    if lag > stale_max:
                        stale_max = lag
                stale_cnt[dst_task[idx]] += 1
        nr = r + 1
        w = windows[j]
        while win_idx[j] < len(w) and w[win_idx[j]][1] <= nr:
            win_idx[j] += 1  # the whole window passed while the machine lagged
        if win_idx[j] < len(w) and w[win_idx[j]][0] <= nr:
            _, hi = w[win_idx[j]]
            win_idx[j] += 1
            up[j] = False
            down_from[j] = nr
            resume_round[j] = hi if hi <= num_rounds else -1
            if hi > num_rounds:  # never returns
                down_rounds[nr:, j] = True
            check_frontier(t)
            return
        if nr < num_rounds:
            apply_slowdowns(j, nr)
            if semantics == "async" or arrived[j][r] == in_count[j]:
                start(j, nr, t)
            else:
                waiting[j] = nr
                barrier_stalls += 1  # blocked on a neighbor's round-r output
        else:
            next_round[j] = num_rounds
        if any_windows:
            check_frontier(t)

    def deliver(idx: int, r_src: int, t: float) -> None:
        if r_src > mailbox[idx]:
            mailbox[idx] = r_src
        j = int(dst_m[idx])
        arrived[j][r_src] += 1
        if semantics == "overlap":
            if r_src < num_rounds:
                remaining[r_src] -= 1
                if remaining[r_src] == 0:
                    overlap_completion[r_src] = t
            nr = r_src + 1
            if (
                waiting[j] == nr
                and done_round[j] == r_src
                and arrived[j][r_src] == in_count[j]
                and nr < num_rounds
            ):
                waiting[j] = -1
                start(j, nr, t)

    for j in range(k):
        if windows[j] and windows[j][0][0] <= 0:
            _, hi = windows[j][0]
            win_idx[j] = 1
            up[j] = False
            down_from[j] = 0
            resume_round[j] = hi if hi <= num_rounds else -1
            if hi > num_rounds:
                down_rounds[:, j] = True
        else:
            start(j, 0, 0.0)
    check_frontier(0.0)

    while heap:
        t, kind, x, r = heapq.heappop(heap)
        events_processed += 1
        if kind == _EV_COMPUTE:
            done_round[x] = r
            send_outputs(x, r, t)
            if semantics == "overlap" and r < num_rounds:
                remaining[r] -= 1
                if remaining[r] == 0:
                    overlap_completion[r] = t
            push(t, _EV_BOUNDARY, x, r)
        elif kind == _EV_BOUNDARY:
            boundary(x, r, t)
        else:
            deliver(x, r_src=r, t=t)

    if semantics == "overlap":
        completion = overlap_completion
    else:
        # async round r completes when the last machine that RAN it
        # finished; all-down rounds inherit the previous completion, and a
        # recovered laggard finishing round r after the fleet passed r+1
        # is monotonized away (completion is a wall-clock cumulative).
        completion = np.zeros(num_rounds)
        prev = 0.0
        for r in range(num_rounds):
            row = machine_end[r]
            if not np.all(np.isnan(row)):
                prev = max(prev, float(np.nanmax(row)))
            completion[r] = prev

    round_times = np.diff(completion, prepend=0.0)
    period = steady_period(completion)
    samples = stale_cnt.sum()
    live_per_round = (~down_rounds).sum(axis=1)
    return SimResult(
        semantics=semantics,
        num_rounds=num_rounds,
        round_completion=completion,
        round_times=round_times,
        busy=busy,
        fleet_size=live_per_round.astype(np.int64),
        total_time=float(completion[-1]),
        period=period,
        throughput=1.0 / period if period > 0 else float("inf"),
        staleness_mean=float(stale_sum.sum() / samples) if samples else 0.0,
        staleness_max=int(stale_max),
        staleness_per_task=stale_sum / np.maximum(stale_cnt, 1),
        reschedule_rounds=[],
        machine_ids=list(range(k)),
        assignment=a,
        events_processed=events_processed,
        barrier_stalls=barrier_stalls,
        send_skips=send_skips,
        antientropy_msgs=antientropy,
        mix_versions=mix_versions,
        machine_round_end=machine_end if semantics == "async" else None,
        machine_down=down_rounds if semantics == "async" else None,
    )
