"""Discrete-event execution engine: sync / overlap / async semantics.

``simulate`` replays a schedule's per-task compute/send/receive events on
the machines (DESIGN.md §9); ``ExecutionSpec`` picks the semantics and
the per-machine jitter/straggler model, ``ControlEvent`` injects
failures, slowdowns, delay drift, and elastic re-schedules into the same
queue (the machine-local subset — ``ASYNC_CONTROL_KINDS`` — also
composes with barrier-free execution, DESIGN.md §11), ``TokenAccount``
bounds in-flight async sends, and ``SimResult`` carries round timings,
per-machine busy times, staleness metrics, per-(round, edge) delivered
versions, and steady-state throughput.
"""

from repro.sim.engine import simulate
from repro.sim.events import (
    ASYNC_CONTROL_KINDS,
    CONTROL_KINDS,
    SEMANTICS,
    ControlEvent,
    ExecutionSpec,
    SimResult,
    steady_period,
)
from repro.sim.flow import TokenAccount

__all__ = [
    "ASYNC_CONTROL_KINDS",
    "CONTROL_KINDS",
    "ControlEvent",
    "ExecutionSpec",
    "SEMANTICS",
    "SimResult",
    "TokenAccount",
    "simulate",
    "steady_period",
]
