"""Discrete-event execution engine: sync / overlap / async semantics.

``simulate`` replays a schedule's per-task compute/send/receive events on
the machines (DESIGN.md §9); ``ExecutionSpec`` picks the semantics and
the per-machine jitter/straggler model, ``ControlEvent`` injects
failures, slowdowns, delay drift, and elastic re-schedules into the same
queue, and ``SimResult`` carries round timings, per-machine busy times,
staleness metrics, and steady-state throughput.
"""

from repro.sim.engine import simulate
from repro.sim.events import (
    CONTROL_KINDS,
    SEMANTICS,
    ControlEvent,
    ExecutionSpec,
    SimResult,
    steady_period,
)

__all__ = [
    "CONTROL_KINDS",
    "ControlEvent",
    "ExecutionSpec",
    "SEMANTICS",
    "SimResult",
    "simulate",
    "steady_period",
]
