"""Event-model vocabulary of the discrete-event execution engine.

The engine (``repro.sim.engine``) simulates per-task compute/send/receive
events on the scheduled machines.  This module holds the declarative
pieces shared by the engine and its callers:

  - :class:`ExecutionSpec` — which execution semantics to simulate
    (``sync`` | ``overlap`` | ``async``) and the per-machine perturbation
    model (compute-time jitter and stragglers);
  - :class:`ControlEvent` — round-indexed control-plane events (machine
    failure/arrival/recovery, slowdown, delay drift, link outages,
    elastic re-schedule) that enter the same queue as the data-plane
    events;
  - :class:`SimResult` — round timings, per-machine busy times, staleness
    metrics, and steady-state throughput.

Semantics (DESIGN.md §9):

  ``sync``
      Full round barrier — the paper's Eq. 2 model.  Every machine starts
      round r+1 only once every round-r compute has finished AND every
      round-r output has been delivered.  With no jitter the per-round
      time equals ``bqp.bottleneck_time`` / ``fl.simulator.round_time``
      exactly (pinned in tests).
  ``overlap``
      Per-machine pipelining without staleness: machine j starts round
      r+1 as soon as (a) its own round-r compute is done and (b) all
      round-r inputs destined to its tasks have arrived.  The gossip send
      of round r overlaps the compute of round r+1 on the sender — this
      subsumes the old ``round_time(..., overlap=True)`` flag with a real
      dependency-graph model (cyclic topologies are throttled by their
      max cycle mean, which the crude ``max(comp, comm)`` formula missed).
  ``async``
      Machines never block on neighbors: round r+1 compute starts right
      after round r's, consuming the *latest delivered* neighbor outputs.
      Communication moves off the critical path entirely; its cost
      resurfaces as per-task staleness (rounds behind the synchronous
      reference), and the barrier time is replaced by steady-state round
      throughput.  ``async`` additionally admits a machine-local control
      plane (``fail``/``join``/``recover``/``slowdown`` — DESIGN.md §11)
      and token-account flow control (``token_capacity``/``token_refill``,
      ``repro.sim.flow``), and records the per-(round, edge) consumed
      versions (``SimResult.mix_versions``) that couple the engine to the
      barrier-free gossip trainer (``repro.fl.async_gossip``).

Event ordering is a documented total order: queue keys are
``(time, kind, index, round)`` with ``arrive < compute < boundary`` at
equal time — all same-instant deliveries settle before any machine's
round boundary reads its mailbox, and boundaries process in machine-index
order (which also fixes the jitter-draw order).  No insertion sequence
number participates, so permuting event insertion order leaves results
bit-identical (regression-tested).
"""

from __future__ import annotations

import dataclasses

import numpy as np

SEMANTICS = ("sync", "overlap", "async")

CONTROL_KINDS = (
    "fail",
    "slowdown",
    "delay_update",
    "reschedule",
    "join",
    "recover",
    "link_down",
    "link_up",
)

# The machine-local subset that also composes with ``async`` semantics
# (no global quiescent point needed — see ControlEvent's docstring).
ASYNC_CONTROL_KINDS = ("fail", "join", "recover", "slowdown")


@dataclasses.dataclass(frozen=True)
class ExecutionSpec:
    """Execution semantics + per-machine perturbation model.

    Attributes:
      semantics: ``sync`` | ``overlap`` | ``async`` (see module docstring).
      jitter_sigma: log-normal sigma of the per-round multiplicative
        compute-time jitter; scalar or per-machine array (original machine
        labels).  0 disables jitter (and keeps timings bit-exact).
      straggler_prob: per-round probability that a machine straggles,
        multiplying its compute time by ``straggler_factor``; scalar or
        per-machine array.
      straggler_factor: compute-time multiplier of a straggling round.
      seed: rng stream for the jitter/straggler draws (anything
        ``np.random.default_rng`` accepts) — simulation results are a
        pure function of (instance, assignment, spec).  Use a stream
        distinct from the one that generated the instance, or the
        "noise" replays the instance's own variates.
      token_capacity: per-machine send-token budget (``repro.sim.flow``;
        async only).  None disables flow control; a value >= 1 bounds
        each machine's in-flight gossip sends per round to the capacity.
      token_refill: tokens deposited per completed round (>= 0), saturating
        at the capacity.
    """

    semantics: str = "sync"
    jitter_sigma: float | tuple = 0.0
    straggler_prob: float | tuple = 0.0
    straggler_factor: float = 4.0
    seed: int | tuple = 0
    token_capacity: float | None = None
    token_refill: float = 1.0

    def __post_init__(self):
        if self.semantics not in SEMANTICS:
            raise ValueError(
                f"unknown semantics {self.semantics!r}; choose from {SEMANTICS}"
            )
        if np.any(np.asarray(self.jitter_sigma) < 0):
            raise ValueError("jitter_sigma must be >= 0")
        prob = np.asarray(self.straggler_prob)
        if np.any(prob < 0) or np.any(prob > 1):
            raise ValueError("straggler_prob must be in [0, 1]")
        if self.straggler_factor <= 0:
            raise ValueError("straggler_factor must be > 0")
        if self.token_capacity is not None and not self.token_capacity >= 1.0:
            raise ValueError(
                f"token_capacity must be >= 1 or None (got "
                f"{self.token_capacity})"
            )
        if not self.token_refill >= 0.0:
            raise ValueError(f"token_refill must be >= 0 (got {self.token_refill})")

    @property
    def perturbed(self) -> bool:
        """True when any machine can deviate from its nominal speed."""
        return bool(
            np.any(np.asarray(self.jitter_sigma) > 0)
            or np.any(np.asarray(self.straggler_prob) > 0)
        )


@dataclasses.dataclass(frozen=True)
class ControlEvent:
    """A control-plane event entering the simulation queue at a round start.

    ``machine`` is the ORIGINAL machine label (stable across failures,
    like ``fl.simulator.SimEvent``).  Kinds:

      - ``fail``: machine leaves the fleet; triggers ``schedule_fn``.
        Failing a machine that is already down raises at simulation
        time (a silently-ignored double failure would desynchronize the
        engine's fleet from the control layer's).
      - ``join`` / ``recover``: machine (re-)enters the fleet with its
        original speed and delay rows; triggers ``schedule_fn``.  The
        two kinds carry trace semantics — ``join`` is the first arrival
        of a machine that began the trace down (a ``fail`` at round 0),
        ``recover`` a return after a mid-trace failure — the engine
        treats them identically.  Labels must lie inside the original
        compute graph (the machine *universe*); genuinely new machines
        are grown at the control layer (``ElasticScheduler.on_arrival``)
        before the simulation starts.
      - ``slowdown``: machine speed is multiplied by ``factor`` (> 0;
        the change persists across fail/recover round trips); triggers
        ``schedule_fn``.
      - ``delay_update``: the delay matrix becomes ``C`` (indexed by
        original labels; subset to survivors automatically).  Does NOT
        re-schedule by itself — pair with a ``reschedule`` event.
      - ``link_down`` / ``link_up``: the (undirected) link between
        ``machine`` and ``peer`` enters/leaves an outage window — while
        down, its delay is multiplied by ``factor`` (> 1; models the
        retry/reroute cost of an intermittent link).  Like
        ``delay_update`` these do not re-schedule by themselves.
      - ``reschedule``: call ``schedule_fn`` (e.g. an
        ``ElasticScheduler`` consult) and adopt its assignment.

    ``delay_update``, ``link_down``/``link_up``, and ``reschedule``
    require ``sync`` semantics: they change global state (the delay
    matrix or the assignment), and the round barrier is the only globally
    quiescent point for that.  ``fail``/``join``/``recover``/``slowdown``
    are machine-LOCAL and additionally compose with ``async`` semantics:
    a fail takes effect when the machine would start local round
    ``round`` (freezing it there), a recover at round r2 fires once the
    live fleet's frontier — the minimum round any up machine is computing
    — reaches r2 (the barrier-free analog of "everyone reached the
    barrier"), and a slowdown applies from the machine's local round
    onward.  See DESIGN.md §11.
    """

    round: int
    kind: str
    machine: int = -1
    factor: float = 1.0
    C: np.ndarray | None = None
    peer: int = -1

    def __post_init__(self):
        if self.kind not in CONTROL_KINDS:
            raise ValueError(
                f"unknown control kind {self.kind!r}; choose from {CONTROL_KINDS}"
            )
        if self.round < 0:
            raise ValueError("control events fire at round starts (round >= 0)")
        if self.kind == "delay_update" and self.C is None:
            raise ValueError("delay_update events need the new C matrix")
        if self.kind in ("fail", "slowdown", "join", "recover") and self.machine < 0:
            raise ValueError(f"{self.kind} events need a machine label >= 0")
        if self.kind == "slowdown" and self.factor <= 0:
            raise ValueError(
                "slowdown factor must be > 0 — a non-positive factor would "
                "corrupt the machine's speed instead of scaling it"
            )
        if self.kind in ("link_down", "link_up"):
            if self.machine < 0 or self.peer < 0:
                raise ValueError(
                    f"{self.kind} events need machine and peer labels >= 0"
                )
            if self.machine == self.peer:
                raise ValueError(
                    f"{self.kind} events need two distinct endpoints "
                    f"(self-links carry no delay)"
                )
        if self.kind == "link_down" and self.factor <= 1.0:
            raise ValueError(
                "link_down factor is an outage delay penalty and must be > 1"
            )


@dataclasses.dataclass
class SimResult:
    """Output of one simulated execution.

    Attributes:
      semantics: the simulated execution semantics.
      round_completion: (R,) wall-clock time at which round r fully
        completed (sync: the barrier; overlap: all round-r computes done
        and outputs delivered; async: the last machine finished round r's
        compute).
      round_times: (R,) completion increments — under ``sync`` with no
        jitter each entry equals Eq. 2 exactly.
      busy: (R, N_K) per-round busy time per machine, indexed by ORIGINAL
        machine label; NaN while a machine is absent (failed, or not yet
        joined).  Feed rows to ``ElasticScheduler.observe_round`` (live
        machines only).
      fleet_size: (R,) number of live machines during each round (after
        that round's control events) — constant under overlap/async,
        which admit no control plane.
      total_time: completion of the final round.
      period: steady-state time per round (second-half average of the
        completion increments); ``throughput`` is its reciprocal.
      staleness_mean / staleness_max: async only — average/worst number
        of rounds a consumed neighbor output lagged the synchronous
        reference (0 under sync/overlap by construction).
      staleness_per_task: (N_T,) mean staleness of each task's inputs.
      reschedule_rounds: rounds whose control events re-ran the scheduler.
      machine_ids: surviving original machine labels.
      assignment: final task→machine assignment (local indices).
      events_processed: total data-plane events popped from the queue.
      barrier_stalls: executions blocked on a neighbor — under ``sync``
        the machines that finished a round strictly before its barrier,
        under ``overlap`` the starts gated on missing inputs.  0 under
        ``async`` by construction (machines never wait).
      send_skips: gossip sends dropped by token-account flow control.
      antientropy_msgs: push/pull catch-up messages exchanged when a
        churned-out machine recovered (async churn only).
      mix_versions: async only — (R, |E|) freshest delivered source round
        in each edge's mailbox when its destination machine finished
        local round r (-1: nothing delivered yet).  This is the mix
        schedule ``repro.fl.async_gossip.AsyncGossipTrainer`` replays.
      machine_round_end: async only — (R, N_K) wall-clock time machine j
        finished local round r (NaN: skipped while churned out).
      machine_down: async only — (R, N_K) bool, True where machine j
        skipped round r between a fail and its recovery.
    """

    semantics: str
    num_rounds: int
    round_completion: np.ndarray
    round_times: np.ndarray
    busy: np.ndarray
    fleet_size: np.ndarray
    total_time: float
    period: float
    throughput: float
    staleness_mean: float
    staleness_max: int
    staleness_per_task: np.ndarray
    reschedule_rounds: list[int]
    machine_ids: list[int]
    assignment: np.ndarray
    events_processed: int
    barrier_stalls: int = 0
    send_skips: int = 0
    antientropy_msgs: int = 0
    mix_versions: np.ndarray | None = None
    machine_round_end: np.ndarray | None = None
    machine_down: np.ndarray | None = None


def steady_period(round_completion: np.ndarray) -> float:
    """Steady-state time per round: average completion increment over the
    second half of the run (the first half absorbs the pipeline-fill /
    staleness-warmup transient)."""
    comp = np.asarray(round_completion, dtype=np.float64)
    R = comp.shape[0]
    if R == 0:
        return float("nan")
    if R == 1:
        return float(comp[0])
    w = max(1, R // 2)
    return float((comp[-1] - comp[w - 1]) / (R - w))
