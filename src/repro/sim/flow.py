"""Token-account flow control for barrier-free gossip (gossipy-style).

Under ``async`` semantics machines never block on neighbors, so a fast
sender can flood a slow receiver's network path with arbitrarily many
in-flight messages.  A :class:`TokenAccount` bounds that: each machine
holds at most ``capacity`` send tokens, every completed round deposits
``refill`` tokens (saturating at ``capacity``), and every gossip send
spends one whole token — when the account is empty the send is *skipped*
(the neighbor keeps mixing with the last delivered snapshot; the version
counters in the trainer absorb the gap as extra staleness).

Invariants (property-tested in ``tests/test_property.py``):

  - ``0 <= tokens <= capacity`` after every operation — the balance is
    never negative and never exceeds the cap;
  - at most ``floor(capacity)`` sends can succeed between two
    ``replenish`` calls, so in-flight messages per machine per round are
    bounded by the capacity.

The engine (``repro.sim.engine``) instantiates one account per machine
when ``ExecutionSpec.token_capacity`` is set, replenishes it at each
compute completion, and walks the machine's out-edges round-robin
(rotated by the round index so no fixed edge monopolizes a scarce
budget).  Flow control composes only with ``async`` semantics: under
``sync``/``overlap`` a skipped send would deadlock a consumer waiting on
that input, so ``simulate`` rejects the combination.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class TokenAccount:
    """A saturating send-token bucket (one per machine).

    ``capacity`` is the maximum balance (>= 1 — a capacity below one
    token could never send); ``refill`` the deposit per completed round
    (>= 0).  The account starts full so round 0 behaves like unlimited
    gossip on any out-degree <= capacity.
    """

    capacity: float
    refill: float = 1.0
    tokens: float = dataclasses.field(init=False)
    sent: int = dataclasses.field(default=0, init=False)
    skipped: int = dataclasses.field(default=0, init=False)

    def __post_init__(self):
        if not self.capacity >= 1.0:
            raise ValueError(
                f"token capacity must be >= 1 (got {self.capacity}); a "
                f"budget below one token could never send"
            )
        if not self.refill >= 0.0:
            raise ValueError(f"token refill must be >= 0 (got {self.refill})")
        self.tokens = float(self.capacity)

    def replenish(self) -> None:
        """Deposit one round's refill, saturating at the capacity."""
        self.tokens = min(float(self.capacity), self.tokens + float(self.refill))

    def try_send(self) -> bool:
        """Spend one token if available; False means the send is skipped."""
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            self.sent += 1
            return True
        self.skipped += 1
        return False
