"""Scenario-sweep benchmark suite: the new grid combinations end to end.

Runs every scenario in ``repro.scenarios.presets.NEW_COMBINATIONS``
(schedule → simulate → bottleneck report; two of them train gossip FL)
and records the sweep into ``BENCH_scenarios.json`` — the same file
``scripts/sweep.py`` writes, so an interrupted CLI sweep and this suite
share resume state.  Records that already existed in the file are NOT
re-measured; their rows are labeled ``cached=yes`` so stale numbers can't
pass for fresh ones.  ``resume=False`` (``make bench-scenarios``)
re-measures THIS suite's grid points while leaving records other sweeps
wrote (e.g. the fig6 FL record) intact.  Quick mode uses CI-sized
sampling budgets.
"""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import Timer, emit


def main(
    quick: bool = True, out_path: str = "BENCH_scenarios.json",
    resume: bool = True,
) -> dict:
    from repro.scenarios import run_sweep
    from repro.scenarios.engine import _write_atomic, record_key, scenario_key
    from repro.scenarios.presets import NEW_COMBINATIONS

    mine = {scenario_key(sc, quick) for sc in NEW_COMBINATIONS}
    pre: set = set()
    path = pathlib.Path(out_path)
    if path.exists():
        existing = json.loads(path.read_text()).get("records", [])
        if resume:
            pre = {record_key(r) for r in existing}
        else:
            # Re-measure this suite's own grid points; records other
            # sweeps wrote (fig6, CLI presets) are not this target's to
            # destroy.
            keep = [r for r in existing if record_key(r) not in mine]
            _write_atomic(path, {"bench": "scenario_sweep", "records": keep})
    with Timer() as t:
        payload = run_sweep(
            NEW_COMBINATIONS, out_path=out_path, quick=quick, resume=True
        )
    # The resumed file may hold records from other sweeps (CLI presets,
    # other budgets); report only this suite's own grid points.
    records = [r for r in payload["records"] if record_key(r) in mine]
    fresh = 0
    for rec in records:
        methods = rec["methods"]
        best = min(methods, key=lambda m: methods[m]["predicted_bottleneck"])
        sdp = methods.get("sdp", {})
        cached = record_key(rec) in pre
        fresh += not cached
        emit(
            f"scenario_{rec['scenario']}",
            rec["elapsed_seconds"] * 1e6,
            f"best={best};sdp={sdp.get('predicted_bottleneck', float('nan')):.3f};"
            f"migrations={sdp.get('num_migrations', 0)};"
            f"fl={'yes' if rec.get('fl') else 'no'};"
            f"cached={'yes' if cached else 'no'}",
        )
    emit(
        "scenario_sweep_total",
        t.seconds * 1e6 / max(fresh, 1),
        f"scenarios={len(records)};fresh={fresh};out={out_path}",
    )
    return payload


if __name__ == "__main__":
    main(quick=False)
