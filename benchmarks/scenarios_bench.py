"""Scenario-sweep benchmark suite: the new grid combinations end to end.

Runs every scenario in ``repro.scenarios.presets.NEW_COMBINATIONS``
(schedule → simulate → bottleneck report; two of them train gossip FL)
and records the sweep into ``BENCH_scenarios.json`` — the same file
``scripts/sweep.py`` writes, so an interrupted CLI sweep and this suite
share resume state.  Resume semantics are
``benchmarks.common.sweep_suite``'s (shared with ``async_bench``):
records that already existed in the file are NOT re-measured; their rows
are labeled ``cached=yes`` so stale numbers can't pass for fresh ones.
``resume=False`` (``make bench-scenarios``) re-measures THIS suite's
grid points while leaving records other sweeps wrote (e.g. the fig6 FL
record) intact.  Quick mode uses CI-sized sampling budgets.
"""

from __future__ import annotations

from benchmarks.common import emit, sweep_suite


def main(
    quick: bool = True, out_path: str = "BENCH_scenarios.json",
    resume: bool = True,
) -> dict:
    from repro.scenarios.presets import NEW_COMBINATIONS

    def emit_row(rec, cached):
        methods = rec["methods"]
        best = min(methods, key=lambda m: methods[m]["predicted_bottleneck"])
        sdp = methods.get("sdp", {})
        emit(
            f"scenario_{rec['scenario']}",
            rec["elapsed_seconds"] * 1e6,
            f"best={best};sdp={sdp.get('predicted_bottleneck', float('nan')):.3f};"
            f"migrations={sdp.get('num_migrations', 0)};"
            f"fl={'yes' if rec.get('fl') else 'no'};"
            f"cached={'yes' if cached else 'no'}",
        )

    return sweep_suite(
        NEW_COMBINATIONS, emit_row, "scenario_sweep_total",
        quick=quick, out_path=out_path, resume=resume,
    )


if __name__ == "__main__":
    main(quick=False)
