"""Barrier-free gossip-FL benchmark: loss vs SIMULATED wall-clock.

For each preset in ``repro.scenarios.presets.ASYNC_FL_COMBINATIONS`` with
a straggler profile, runs the SAME instance (task graph, machine fleet,
schedules, straggler draws) twice:

  - ``sync``:  the barriered stacked trainer; the time axis is the sync
    event engine's round completions, so every round pays the
    max-over-machines straggler penalty at the barrier.
  - ``async``: ``run_fl_async`` — the async event engine replays the
    assignment barrier-free and the ``AsyncGossipTrainer`` mixes with the
    snapshots the simulated network actually delivered, staleness-weighted.

Both curves land in ``BENCH_gossip_fl.json`` under the ``async_fl`` key
(read-modify-write: the stacked-engine throughput sweep in the same file
is preserved), plus the comparison the record exists for: the sync loss
reached by the time async finished, next to async's final loss.  The
churn preset contributes the robustness evidence — finite losses,
frozen-then-recovered replicas, zero barrier stalls.  Schema:
``docs/benchmarks.md`` (async-FL records).

``async_fl_smoke()`` (``make async_fl_smoke``) is the CI guard: the
degenerate anchor (all-active + fresh versions + ``s === 1`` reproduces
the stacked per-round losses to fp32) plus a straggler replay that must
mix at least one stale snapshot with zero barrier stalls.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from benchmarks.common import Timer, emit

# Losses are fp32 accumulations over a few thousand samples; the stacked
# and async engines may order reductions differently only through the
# mixing path, which the degenerate anchor pins to this tolerance.
DEGENERATE_ATOL = 1e-5


def _fl_experiment(sc, quick: bool):
    """The preset's FL workload as an FLExperiment (quick mode shrinks it)."""
    from repro.fl.gossip import GossipConfig
    from repro.fl.runner import FLExperiment

    fl = sc.fl
    rounds = min(fl.rounds, 4) if quick else fl.rounds
    samples = min(fl.num_samples, 512) if quick else fl.num_samples
    return FLExperiment(
        dataset=fl.dataset,
        num_users=sc.num_tasks,
        num_machines=sc.num_machines,
        rounds=rounds,
        num_samples=samples,
        seed=sc.seed,
        gossip=GossipConfig(local_steps=fl.local_steps, batch_size=fl.batch_size),
    )


def _sync_loss_at(t: float, losses: list, times: list) -> float:
    """Step-interpolate the sync curve: loss of the last round done by t."""
    done = [loss for loss, tr in zip(losses, times) if tr <= t]
    return float(done[-1]) if done else float("inf")


def _compare_preset(name: str, quick: bool) -> dict:
    """Sync-vs-async loss curves of one straggler preset, shared instance."""
    from repro.fl.runner import run_fl, run_fl_async
    from repro.scenarios import get_scenario
    from repro.scenarios.engine import build_compute_graph, build_task_graph
    from repro.sim import simulate

    sc = get_scenario(name)
    rng = np.random.default_rng(sc.seed)
    tg = build_task_graph(sc, rng)
    cg, _ = build_compute_graph(sc, rng)
    exp = _fl_experiment(sc, quick)
    spec = sc.execution_spec()
    sw = sc.staleness_weights()

    # Barriered twin: same instance + straggler draws, sync semantics.
    sync = run_fl(exp, methods=sc.schedulers, compute_graph=cg, task_graph=tg)
    sync_losses = [float(h["mean_loss"]) for h in sync["history"]]
    sync_spec = dataclasses.replace(spec, semantics="sync")
    sync_times = {}
    for m, sched in sync["schedules"].items():
        res = simulate(
            tg, cg, np.asarray(sched.assignment, dtype=np.int64),
            exp.rounds, sync_spec,
        )
        sync_times[m] = [float(t) for t in res.round_completion]

    ares = run_fl_async(
        exp, methods=sc.schedulers, compute_graph=cg, task_graph=tg,
        schedules=sync["schedules"], execution=spec, staleness=sw,
        archive_depth=sc.fl.archive_depth,
    )

    methods = {}
    for m, rows in ares["history"].items():
        a_losses = [float(h["mean_loss"]) for h in rows]
        a_times = [float(h["sim_time"]) for h in rows]
        t_final = a_times[-1]
        sync_at_t = _sync_loss_at(t_final, sync_losses, sync_times[m])
        methods[m] = {
            "sync": {"losses": sync_losses, "sim_time": sync_times[m]},
            "async": {
                "losses": a_losses,
                "sim_time": a_times,
                "stale_mixes": int(ares["stale_mixes"][m]),
                "barrier_stalls": int(ares["barrier_stalls"][m]),
            },
            "async_final_time": t_final,
            "async_final_loss": a_losses[-1],
            "sync_loss_at_async_time": sync_at_t,
            # async made >= as much progress by its own finish time
            "async_progress_ge_sync": bool(a_losses[-1] <= sync_at_t + 1e-6),
        }
        emit(
            f"async_fl_{name}_{m}",
            0.0,
            f"async_loss={a_losses[-1]:.4f};sync_loss_at_t={sync_at_t:.4f};"
            f"stale={ares['stale_mixes'][m]};"
            f"stalls={ares['barrier_stalls'][m]}",
        )
    return {
        "preset": name,
        "rounds": exp.rounds,
        "staleness": {"kind": sw.kind, "a": float(sw.a), "b": int(sw.b)},
        "methods": methods,
    }


def _churn_point(name: str, quick: bool) -> dict:
    """Churn-trace evidence: the scenario engine's async-FL record."""
    from repro.scenarios import get_scenario, run_scenario

    rec = run_scenario(get_scenario(name), quick=quick)
    fl = rec["fl"]
    point = {
        "preset": name,
        "churn": rec["churn"],
        "staleness": fl["staleness"],
        "per_method": fl["per_method"],
    }
    for m, d in fl["per_method"].items():
        active = d["active_users"]
        n = max(active)
        dipped = min(active) < n
        recovered = dipped and any(
            active[i] > min(active[: i + 1]) for i in range(1, len(active))
        )
        point["per_method"][m]["frozen_then_recovered"] = bool(
            dipped and recovered
        )
        emit(
            f"async_fl_churn_{m}",
            0.0,
            f"finite={all(np.isfinite(d['losses']))};"
            f"stalls={d['barrier_stalls']};froze_recovered={dipped and recovered};"
            f"active={'/'.join(str(a) for a in active)}",
        )
    return point


def main(
    quick: bool = True, out_path: str = "BENCH_gossip_fl.json",
) -> dict:
    from repro.scenarios.presets import ASYNC_FL_COMBINATIONS

    straggler = [sc.name for sc in ASYNC_FL_COMBINATIONS if sc.churn is None]
    churn = [sc.name for sc in ASYNC_FL_COMBINATIONS if sc.churn is not None]
    with Timer() as t:
        payload = {
            "bench": "async_fl",
            "quick": quick,
            "points": [_compare_preset(n, quick) for n in straggler],
            "churn_points": [_churn_point(n, quick) for n in churn],
        }
    payload["elapsed_seconds"] = t.seconds

    path = pathlib.Path(out_path)
    data = json.loads(path.read_text()) if path.exists() else {}
    data["async_fl"] = payload
    path.write_text(json.dumps(data, indent=2) + "\n")
    emit("async_fl_sweep_total", t.seconds * 1e6, f"out={out_path}")
    return payload


def async_fl_smoke() -> None:
    """CI smoke for the barrier-free FL path.

    Asserts (1) the degenerate anchor: an ``AsyncGossipTrainer`` stepped
    with defaults (all users active, fresh versions, constant ``s === 1``)
    reproduces the stacked ``GossipTrainer``'s per-round losses to fp32;
    (2) a straggler replay mixes at least one stale snapshot, stalls at no
    barrier, and keeps losses finite.
    """
    from benchmarks.fig6_gossip_fl import _mlp_init, _mlp_loss
    from repro.core.graphs import gossip_task_graph
    from repro.data.synthetic import image_dataset
    from repro.fl.async_gossip import AsyncGossipTrainer
    from repro.fl.gossip import GossipConfig, GossipTrainer
    from repro.fl.runner import FLExperiment, run_fl_async
    from repro.sim import ExecutionSpec

    # (1) degenerate anchor, MLP-sized so the smoke stays fast
    rng = np.random.default_rng(0)
    tg = gossip_task_graph(rng, 8, degree_low=6, degree_high=7)
    train, _ = image_dataset("mnist", 256, seed=0)
    shards = train.split(8, rng)
    cfg = GossipConfig(local_steps=2, batch_size=8, backend="stacked")
    sync_tr = GossipTrainer(tg, _mlp_init, _mlp_loss, shards, cfg, seed=0)
    async_tr = AsyncGossipTrainer(tg, _mlp_init, _mlp_loss, shards, cfg, seed=0)
    for r in range(3):
        ls = sync_tr.step_round()["mean_loss"]
        la = async_tr.step_round()["mean_loss"]
        assert abs(ls - la) <= DEGENERATE_ATOL, (
            f"round {r}: degenerate async loss {la} != stacked {ls}"
        )
    assert async_tr.total_stale_mixes == 0, async_tr.total_stale_mixes

    # (2) straggler replay: stale snapshots flow, no barrier stalls
    exp = FLExperiment(
        num_users=8, num_machines=3, rounds=3, num_samples=256, seed=0,
        gossip=GossipConfig(local_steps=2, batch_size=8),
    )
    spec = ExecutionSpec(
        semantics="async", jitter_sigma=0.1,
        straggler_prob=0.4, straggler_factor=3.0,
    )
    res = run_fl_async(exp, methods=("heft",), execution=spec)
    rows = res["history"]["heft"]
    losses = [h["mean_loss"] for h in rows]
    assert all(np.isfinite(losses)), losses
    assert res["stale_mixes"]["heft"] >= 1, res["stale_mixes"]
    assert res["barrier_stalls"]["heft"] == 0, res["barrier_stalls"]
    emit(
        "smoke_async_fl", 0.0,
        f"degenerate_atol={DEGENERATE_ATOL};stale={res['stale_mixes']['heft']};"
        f"stalls=0;loss_final={losses[-1]:.3f}",
    )


if __name__ == "__main__":
    main(quick=False)
