"""Fig. 6 reproduction: gossip-based FL bottleneck time (MNIST / CIFAR-10).

Paper §4.2 setting: N_T = 10 users (degree ~ Unif{6,7}), N_K = 4
homogeneous machines, C ~ Unif(0, 1); CNN = 2 conv + 3 fc.  We report the
per-round bottleneck of HEFT / TP-HEFT / SDP-naive / SDP-randomized plus
the learning curve (accuracy rises while SDP executes rounds fastest).

The FL engine itself runs on the stacked device-resident backend
(DESIGN.md §8); ``sweep()`` records rounds/sec of the stacked engine vs
the per-user reference loop at N_T ∈ {10, 32, 64, 128} into
``BENCH_gossip_fl.json``, and ``stacked_smoke()`` is the CI check that the
single-jit round path took effect.  ``sharded_sweep()`` scales the same
round math to N_T ∈ {128, 1k, 10k} on the mesh-sharded engine
(DESIGN.md §13) and records shard-count invariance, stacked-equivalence,
and halo-exchange volume under the ``sharded`` key; ``sharded_smoke()``
is its CI check (the ``shard_fl_smoke`` target).
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core.graphs import cluster_task_graph, gossip_task_graph
from repro.data.synthetic import ImageDataset, image_dataset
from repro.fl.cnn import cnn_loss, init_cnn_params
from repro.fl.gossip import GossipConfig, GossipTrainer


def run(quick: bool = True) -> dict:
    """The §4.2 experiment as the registered ``fig6`` scenario preset.

    The preset's ``FLWorkload(paper_setting=True)`` delegates instance
    generation to ``run_fl`` (the legacy code path), so losses and
    bottlenecks are bit-identical to the pre-engine benchmark; full mode
    re-sizes the workload to paper settings and adds cifar10.
    """
    import dataclasses

    from repro.scenarios import get_scenario, run_scenario

    base = get_scenario("fig6")
    out = {}
    datasets = ("mnist",) if quick else ("mnist", "cifar10")
    with Timer() as t:
        for ds in datasets:
            fl = dataclasses.replace(
                base.fl, dataset=ds,
                rounds=3 if quick else 10,
                num_samples=1024 if quick else 4096,
                local_steps=2 if quick else 4,
            )
            sc = dataclasses.replace(base, name=f"fig6_{ds}", fl=fl)
            out[ds] = run_scenario(sc, quick=quick)
    ds0 = datasets[0]
    fl0 = out[ds0]["fl"]
    b = fl0["bottleneck_per_round"]
    emit(
        "fig6_gossip_fl",
        t.seconds * 1e6 / len(datasets),
        f"dataset={ds0};backend={fl0['backend']};"
        f"bottleneck_sdp={b['sdp']:.3f};heft={b['heft']:.3f};"
        f"acc_final={fl0['accuracy_user0'][-1]:.2f}",
    )
    return out


# ---------------------------------------------------------------------------
# Engine throughput: stacked vs reference backend
# ---------------------------------------------------------------------------
#
# The sweep's primary model is a small MLP: the gossip engine's win is
# eliminating per-user/per-edge Python dispatch, which shows in the paper's
# many-users / modest-local-work regime.  The §4.2 CNN is compute-bound on
# this 2-core CPU container (and XLA CPU runs vmapped per-user-weight convs
# as grouped convolutions at a ~1.5x penalty), so it is recorded as an
# auxiliary series — on accelerators the stacked path wins there as well.


def _mlp_init(key, d: int = 784, hidden: int = 64, classes: int = 10) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, hidden)) * np.sqrt(2.0 / d),
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, classes)) * np.sqrt(2.0 / hidden),
        "b2": jnp.zeros(classes),
    }


def _mlp_loss(params: dict, batch: dict) -> jnp.ndarray:
    x = batch["x"].reshape(batch["x"].shape[0], -1)
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# One source of truth for the sweep's engine settings: _bench_trainer
# consumes it and sweep() persists it into BENCH_gossip_fl.json.
BENCH_CONFIG = {"local_steps": 4, "batch_size": 4, "samples_per_user": 32}


def _bench_trainer(
    n_users: int, backend: str, *, model: str = "mlp", seed: int = 0,
    local_steps: int = BENCH_CONFIG["local_steps"],
    batch_size: int = BENCH_CONFIG["batch_size"],
    samples_per_user: int = BENCH_CONFIG["samples_per_user"],
) -> GossipTrainer:
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n_users, degree_low=6, degree_high=7)
    train, _ = image_dataset("mnist", samples_per_user * n_users, seed=seed)
    shards = train.split(n_users, rng)
    cfg = GossipConfig(
        local_steps=local_steps, batch_size=batch_size, backend=backend
    )
    if model == "cnn":
        init = lambda k: init_cnn_params(k, (28, 28, 1), 10)
        loss = cnn_loss
    else:
        init, loss = _mlp_init, _mlp_loss
    return GossipTrainer(tg, init, loss, shards, cfg, seed=seed)


def _sweep_point(n: int, rounds: int, model: str) -> dict:
    row: dict = {"n_users": n, "model": model}
    for backend in ("reference", "stacked"):
        tr = _bench_trainer(n, backend, model=model)
        tr.step_round()                       # warmup: compile + caches
        t0 = time.perf_counter()
        for _ in range(rounds):
            tr.step_round()
        dt = (time.perf_counter() - t0) / rounds
        row[backend] = {
            "round_seconds": dt,
            "rounds_per_sec": 1.0 / dt,
            "dispatches_per_round": tr.last_round_dispatches,
        }
        del tr
    row["speedup"] = (
        row["reference"]["round_seconds"] / row["stacked"]["round_seconds"]
    )
    emit(
        f"gossip_fl_engine_{model}_nt{n}",
        row["stacked"]["round_seconds"] * 1e6,
        f"ref_us={row['reference']['round_seconds'] * 1e6:.0f};"
        f"speedup={row['speedup']:.1f}x;"
        f"dispatch_ref={row['reference']['dispatches_per_round']};"
        f"dispatch_stacked={row['stacked']['dispatches_per_round']}",
    )
    return row


def sweep(
    sizes: tuple[int, ...] = (10, 32, 64, 128),
    rounds: int = 3,
    out_path: str = "BENCH_gossip_fl.json",
    cnn_sizes: tuple[int, ...] = (10, 32),
) -> dict:
    """Rounds/sec of both gossip backends across user counts."""
    points = [_sweep_point(n, rounds, "mlp") for n in sizes]
    points += [_sweep_point(n, rounds, "cnn") for n in cnn_sizes]
    result = {
        "bench": "gossip_fl_engine",
        "device": jax.default_backend(),
        "rounds_timed": rounds,
        "config": BENCH_CONFIG,
        "points": points,
    }
    # Read-modify-write: async_fl_bench records into the same file under
    # its own key; re-running this sweep must not clobber that section.
    path = pathlib.Path(out_path)
    data = json.loads(path.read_text()) if path.exists() else {}
    data.update(result)
    path.write_text(json.dumps(data, indent=2) + "\n")
    return result


# ---------------------------------------------------------------------------
# Population scale: the mesh-sharded engine (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The sharded sweep runs the SAME round math partitioned over a 1-D user
# mesh (fake host devices stand in on CPU — launch with
# XLA_FLAGS=--xla_force_host_platform_device_count=8, the `make
# bench-gossip SHARDED=1` / `make smoke` path).  The workload is a
# hierarchical cluster topology (sparse head ring between dense-ish
# clusters) on a tiny MLP, so the halo exchange — the boundary rows the
# engine actually gathers — stays a small fraction of the dense all-pairs
# alternative and N_T = 10k fits a CPU container.

SHARDED_BENCH_CONFIG = {
    "local_steps": 4, "batch_size": 4, "samples_per_user": 16,
    "image_side": 8, "hidden": 16, "inner_degree": 3,
    "users_per_cluster": 64,
}


def _sharded_instance(n_users: int, seed: int = 0):
    """Cluster task graph + tiny synthetic shards for one sweep point.

    Clusters are contiguous by construction, so the engine's contiguous
    shard blocks already respect them (``cluster_shard_permutation`` is
    the identity here) and only head-ring links cross shards.
    """
    c = SHARDED_BENCH_CONFIG
    rng = np.random.default_rng(seed)
    clusters = max(2, n_users // c["users_per_cluster"])
    tg = cluster_task_graph(
        rng, n_users, clusters=clusters, inner_topology="gossip",
        inner_degree=c["inner_degree"], head_topology="ring",
    )
    side = c["image_side"]
    n = n_users * c["samples_per_user"]
    data = ImageDataset(
        x=rng.normal(size=(n, side, side, 1)).astype(np.float32),
        y=rng.integers(0, 10, size=n).astype(np.int64),
        num_classes=10,
    )
    return tg, data.split(n_users, rng)


def _sharded_trainer(
    n_users: int, backend: str, *, num_shards: int | None = None,
    seed: int = 0,
) -> GossipTrainer:
    c = SHARDED_BENCH_CONFIG
    tg, shards = _sharded_instance(n_users, seed)
    cfg = GossipConfig(
        local_steps=c["local_steps"], batch_size=c["batch_size"],
        backend=backend, num_shards=num_shards,
    )
    d = c["image_side"] ** 2
    init = lambda k: _mlp_init(k, d=d, hidden=c["hidden"])
    return GossipTrainer(tg, init, _mlp_loss, shards, cfg, seed=seed)


def sharded_sweep(
    sizes: tuple[int, ...] = (128, 1000, 10000),
    rounds: int = 2,
    mesh_sizes: tuple[int, ...] = (1, 2, 8),
    stacked_anchor_max: int = 1000,
    out_path: str = "BENCH_gossip_fl.json",
) -> dict:
    """Population-scale sweep of the mesh-sharded engine.

    Per size: rounds/sec at every available mesh size, per-round losses,
    the max loss spread ACROSS mesh sizes (shard-count invariance), the
    max deviation vs the single-device stacked backend on overlapping
    sizes (fp32 equivalence), and the measured halo-exchange volume vs
    the dense all-pairs alternative.  Records under the ``sharded`` key
    of ``BENCH_gossip_fl.json``.
    """
    avail = len(jax.devices())
    meshes = tuple(s for s in mesh_sizes if s <= avail)
    skipped = tuple(s for s in mesh_sizes if s > avail)
    if skipped:
        print(
            f"# sharded_sweep: skipping mesh sizes {skipped} — only {avail} "
            f"device(s); set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(mesh_sizes)}"
        )
    points = []
    for n in sizes:
        row: dict = {"n_users": n, "meshes": {}}
        losses_by_mesh: dict[int, list[float]] = {}
        for s in meshes:
            tr = _sharded_trainer(n, "sharded", num_shards=s)
            losses = [tr.step_round()["mean_loss"]]     # warmup: compile
            t0 = time.perf_counter()
            for _ in range(rounds):
                losses.append(tr.step_round()["mean_loss"])
            dt = (time.perf_counter() - t0) / rounds
            assert tr.last_round_dispatches == 1, tr.last_round_dispatches
            losses_by_mesh[s] = losses
            hs = tr.halo_stats
            row["meshes"][str(s)] = {
                "round_seconds": dt,
                "rounds_per_sec": 1.0 / dt,
                "dispatches_per_round": tr.last_round_dispatches,
                "halo_stats": hs,
                # fraction of the dense all-pairs gather each shard receives
                "halo_fraction": (
                    hs["halo_rows_per_shard"] / hs["dense_rows_per_shard"]
                ),
            }
            del tr
        spreads = [
            max(abs(a - b) for a, b in zip(losses_by_mesh[x], losses_by_mesh[y]))
            for x in meshes for y in meshes if x < y
        ]
        row["losses"] = {str(s): losses_by_mesh[s] for s in meshes}
        row["mesh_loss_max_spread"] = max(spreads) if spreads else 0.0
        if n <= stacked_anchor_max:
            tr = _sharded_trainer(n, "stacked")
            ref = [tr.step_round()["mean_loss"] for _ in range(rounds + 1)]
            del tr
            row["stacked_losses"] = ref
            row["stacked_loss_max_diff"] = max(
                max(abs(a - b) for a, b in zip(ref, losses_by_mesh[s]))
                for s in meshes
            )
        hs = row["meshes"][str(meshes[-1])]
        emit(
            f"gossip_fl_sharded_nt{n}",
            hs["round_seconds"] * 1e6,
            f"mesh={meshes[-1]};halo_frac={hs['halo_fraction']:.3f};"
            f"mesh_spread={row['mesh_loss_max_spread']:.2e};"
            + (
                f"vs_stacked={row['stacked_loss_max_diff']:.2e}"
                if "stacked_loss_max_diff" in row else "vs_stacked=n/a"
            ),
        )
        points.append(row)
    result = {
        "device": jax.default_backend(),
        "num_devices": avail,
        "mesh_sizes": list(meshes),
        "rounds_timed": rounds,
        "config": SHARDED_BENCH_CONFIG,
        "points": points,
    }
    # Read-modify-write: this file carries several benches' sections.
    path = pathlib.Path(out_path)
    data = json.loads(path.read_text()) if path.exists() else {}
    data["sharded"] = result
    path.write_text(json.dumps(data, indent=2) + "\n")
    return result


def sharded_smoke() -> None:
    """CI smoke (``shard_fl_smoke``): mesh=2 sharded == stacked to fp32.

    Needs >= 2 devices (fake host devices in CI:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=2``).  Asserts the
    sharded engine reproduces the stacked per-round losses on a cluster
    topology, issues exactly ONE jitted dispatch per round, and never
    retraces.
    """
    avail = len(jax.devices())
    assert avail >= 2, (
        f"shard_fl_smoke needs >= 2 devices (got {avail}); set "
        f"XLA_FLAGS=--xla_force_host_platform_device_count=2"
    )
    n = 24
    a = _sharded_trainer(n, "stacked")
    b = _sharded_trainer(n, "sharded", num_shards=2)
    diffs = []
    for _ in range(3):
        ia, ib = a.step_round(), b.step_round()
        diffs.append(abs(ia["mean_loss"] - ib["mean_loss"]))
        assert b.last_round_dispatches == 1, b.last_round_dispatches
    assert max(diffs) < 2e-5, diffs
    if hasattr(b._round_jit, "_cache_size"):
        assert b._round_jit._cache_size() == 1, b._round_jit._cache_size()
    hs = b.halo_stats
    emit(
        "smoke_shard_fl", 0.0,
        f"mesh=2;rounds=3;max_loss_diff={max(diffs):.2e};"
        f"halo_rows={hs['halo_rows_per_shard']};"
        f"dense_rows={hs['dense_rows_per_shard']}",
    )


def stacked_smoke() -> None:
    """CI smoke: a 2-round stacked MNIST gossip run on the single-jit path.

    Asserts the stacked backend resolved, each round issued exactly ONE
    jitted dispatch (no per-user / per-edge Python dispatch), and the
    round function never retraced.
    """
    tr = _bench_trainer(8, "auto", model="cnn")
    assert tr.backend == "stacked", tr.backend
    losses = [tr.step_round()["mean_loss"] for _ in range(2)]
    assert tr.last_round_dispatches == 1, tr.last_round_dispatches
    if hasattr(tr._round_jit, "_cache_size"):
        assert tr._round_jit._cache_size() == 1, tr._round_jit._cache_size()
    assert all(np.isfinite(losses)), losses
    emit("smoke_gossip_stacked", 0.0,
         f"rounds=2;dispatches_per_round=1;loss_final={losses[-1]:.3f}")


def main(quick: bool = True):
    out = run(quick)
    for ds, res in out.items():
        print(f"# {ds}: bottleneck/round " + ", ".join(
            f"{m}={v:.3f}" for m, v in res["fl"]["bottleneck_per_round"].items()
        ))
        accs = res["fl"]["accuracy_user0"]
        print(f"# {ds}: accuracy " + ", ".join(f"{a:.2f}" for a in accs))
    return out


if __name__ == "__main__":
    import sys

    if "--sharded" in sys.argv:
        # Needs the fake-device count forced before jax's first init:
        # XLA_FLAGS=--xla_force_host_platform_device_count=8
        sharded_sweep()
    else:
        main(quick=False)
        sweep()
