"""Fig. 6 reproduction: gossip-based FL bottleneck time (MNIST / CIFAR-10).

Paper §4.2 setting: N_T = 10 users (degree ~ Unif{6,7}), N_K = 4
homogeneous machines, C ~ Unif(0, 1); CNN = 2 conv + 3 fc.  We report the
per-round bottleneck of HEFT / TP-HEFT / SDP-naive / SDP-randomized plus
the learning curve (accuracy rises while SDP executes rounds fastest).

The FL engine itself runs on the stacked device-resident backend
(DESIGN.md §8); ``sweep()`` records rounds/sec of the stacked engine vs
the per-user reference loop at N_T ∈ {10, 32, 64, 128} into
``BENCH_gossip_fl.json``, and ``stacked_smoke()`` is the CI check that the
single-jit round path took effect.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core.graphs import gossip_task_graph
from repro.data.synthetic import image_dataset
from repro.fl.cnn import cnn_loss, init_cnn_params
from repro.fl.gossip import GossipConfig, GossipTrainer


def run(quick: bool = True) -> dict:
    """The §4.2 experiment as the registered ``fig6`` scenario preset.

    The preset's ``FLWorkload(paper_setting=True)`` delegates instance
    generation to ``run_fl`` (the legacy code path), so losses and
    bottlenecks are bit-identical to the pre-engine benchmark; full mode
    re-sizes the workload to paper settings and adds cifar10.
    """
    import dataclasses

    from repro.scenarios import get_scenario, run_scenario

    base = get_scenario("fig6")
    out = {}
    datasets = ("mnist",) if quick else ("mnist", "cifar10")
    with Timer() as t:
        for ds in datasets:
            fl = dataclasses.replace(
                base.fl, dataset=ds,
                rounds=3 if quick else 10,
                num_samples=1024 if quick else 4096,
                local_steps=2 if quick else 4,
            )
            sc = dataclasses.replace(base, name=f"fig6_{ds}", fl=fl)
            out[ds] = run_scenario(sc, quick=quick)
    ds0 = datasets[0]
    fl0 = out[ds0]["fl"]
    b = fl0["bottleneck_per_round"]
    emit(
        "fig6_gossip_fl",
        t.seconds * 1e6 / len(datasets),
        f"dataset={ds0};backend={fl0['backend']};"
        f"bottleneck_sdp={b['sdp']:.3f};heft={b['heft']:.3f};"
        f"acc_final={fl0['accuracy_user0'][-1]:.2f}",
    )
    return out


# ---------------------------------------------------------------------------
# Engine throughput: stacked vs reference backend
# ---------------------------------------------------------------------------
#
# The sweep's primary model is a small MLP: the gossip engine's win is
# eliminating per-user/per-edge Python dispatch, which shows in the paper's
# many-users / modest-local-work regime.  The §4.2 CNN is compute-bound on
# this 2-core CPU container (and XLA CPU runs vmapped per-user-weight convs
# as grouped convolutions at a ~1.5x penalty), so it is recorded as an
# auxiliary series — on accelerators the stacked path wins there as well.


def _mlp_init(key, d: int = 784, hidden: int = 64, classes: int = 10) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, hidden)) * np.sqrt(2.0 / d),
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, classes)) * np.sqrt(2.0 / hidden),
        "b2": jnp.zeros(classes),
    }


def _mlp_loss(params: dict, batch: dict) -> jnp.ndarray:
    x = batch["x"].reshape(batch["x"].shape[0], -1)
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# One source of truth for the sweep's engine settings: _bench_trainer
# consumes it and sweep() persists it into BENCH_gossip_fl.json.
BENCH_CONFIG = {"local_steps": 4, "batch_size": 4, "samples_per_user": 32}


def _bench_trainer(
    n_users: int, backend: str, *, model: str = "mlp", seed: int = 0,
    local_steps: int = BENCH_CONFIG["local_steps"],
    batch_size: int = BENCH_CONFIG["batch_size"],
    samples_per_user: int = BENCH_CONFIG["samples_per_user"],
) -> GossipTrainer:
    rng = np.random.default_rng(seed)
    tg = gossip_task_graph(rng, n_users, degree_low=6, degree_high=7)
    train, _ = image_dataset("mnist", samples_per_user * n_users, seed=seed)
    shards = train.split(n_users, rng)
    cfg = GossipConfig(
        local_steps=local_steps, batch_size=batch_size, backend=backend
    )
    if model == "cnn":
        init = lambda k: init_cnn_params(k, (28, 28, 1), 10)
        loss = cnn_loss
    else:
        init, loss = _mlp_init, _mlp_loss
    return GossipTrainer(tg, init, loss, shards, cfg, seed=seed)


def _sweep_point(n: int, rounds: int, model: str) -> dict:
    row: dict = {"n_users": n, "model": model}
    for backend in ("reference", "stacked"):
        tr = _bench_trainer(n, backend, model=model)
        tr.step_round()                       # warmup: compile + caches
        t0 = time.perf_counter()
        for _ in range(rounds):
            tr.step_round()
        dt = (time.perf_counter() - t0) / rounds
        row[backend] = {
            "round_seconds": dt,
            "rounds_per_sec": 1.0 / dt,
            "dispatches_per_round": tr.last_round_dispatches,
        }
        del tr
    row["speedup"] = (
        row["reference"]["round_seconds"] / row["stacked"]["round_seconds"]
    )
    emit(
        f"gossip_fl_engine_{model}_nt{n}",
        row["stacked"]["round_seconds"] * 1e6,
        f"ref_us={row['reference']['round_seconds'] * 1e6:.0f};"
        f"speedup={row['speedup']:.1f}x;"
        f"dispatch_ref={row['reference']['dispatches_per_round']};"
        f"dispatch_stacked={row['stacked']['dispatches_per_round']}",
    )
    return row


def sweep(
    sizes: tuple[int, ...] = (10, 32, 64, 128),
    rounds: int = 3,
    out_path: str = "BENCH_gossip_fl.json",
    cnn_sizes: tuple[int, ...] = (10, 32),
) -> dict:
    """Rounds/sec of both gossip backends across user counts."""
    points = [_sweep_point(n, rounds, "mlp") for n in sizes]
    points += [_sweep_point(n, rounds, "cnn") for n in cnn_sizes]
    result = {
        "bench": "gossip_fl_engine",
        "device": jax.default_backend(),
        "rounds_timed": rounds,
        "config": BENCH_CONFIG,
        "points": points,
    }
    # Read-modify-write: async_fl_bench records into the same file under
    # its own key; re-running this sweep must not clobber that section.
    path = pathlib.Path(out_path)
    data = json.loads(path.read_text()) if path.exists() else {}
    data.update(result)
    path.write_text(json.dumps(data, indent=2) + "\n")
    return result


def stacked_smoke() -> None:
    """CI smoke: a 2-round stacked MNIST gossip run on the single-jit path.

    Asserts the stacked backend resolved, each round issued exactly ONE
    jitted dispatch (no per-user / per-edge Python dispatch), and the
    round function never retraced.
    """
    tr = _bench_trainer(8, "auto", model="cnn")
    assert tr.backend == "stacked", tr.backend
    losses = [tr.step_round()["mean_loss"] for _ in range(2)]
    assert tr.last_round_dispatches == 1, tr.last_round_dispatches
    if hasattr(tr._round_jit, "_cache_size"):
        assert tr._round_jit._cache_size() == 1, tr._round_jit._cache_size()
    assert all(np.isfinite(losses)), losses
    emit("smoke_gossip_stacked", 0.0,
         f"rounds=2;dispatches_per_round=1;loss_final={losses[-1]:.3f}")


def main(quick: bool = True):
    out = run(quick)
    for ds, res in out.items():
        print(f"# {ds}: bottleneck/round " + ", ".join(
            f"{m}={v:.3f}" for m, v in res["fl"]["bottleneck_per_round"].items()
        ))
        accs = res["fl"]["accuracy_user0"]
        print(f"# {ds}: accuracy " + ", ".join(f"{a:.2f}" for a in accs))
    return out


if __name__ == "__main__":
    main(quick=False)
    sweep()
