"""Fig. 6 reproduction: gossip-based FL bottleneck time (MNIST / CIFAR-10).

Paper §4.2 setting: N_T = 10 users (degree ~ Unif{6,7}), N_K = 4
homogeneous machines, C ~ Unif(0, 1); CNN = 2 conv + 3 fc.  We report the
per-round bottleneck of HEFT / TP-HEFT / SDP-naive / SDP-randomized plus
the learning curve (accuracy rises while SDP executes rounds fastest).
"""

from __future__ import annotations

from benchmarks.common import Timer, emit
from repro.fl.gossip import GossipConfig
from repro.fl.runner import FLExperiment, run_fl


def run(quick: bool = True) -> dict:
    out = {}
    datasets = ("mnist",) if quick else ("mnist", "cifar10")
    with Timer() as t:
        for ds in datasets:
            exp = FLExperiment(
                dataset=ds,
                num_users=10,
                num_machines=4,
                degree_low=6,
                degree_high=7,
                rounds=3 if quick else 10,
                num_samples=1024 if quick else 4096,
                gossip=GossipConfig(local_steps=2 if quick else 4, batch_size=32),
            )
            out[ds] = run_fl(
                exp, methods=("heft", "tp_heft", "sdp_naive", "sdp")
            )
    ds0 = datasets[0]
    b = out[ds0]["bottleneck_per_round"]
    emit(
        "fig6_gossip_fl",
        t.seconds * 1e6 / len(datasets),
        f"dataset={ds0};bottleneck_sdp={b['sdp']:.3f};heft={b['heft']:.3f};"
        f"acc_final={out[ds0]['history'][-1]['accuracy_user0']:.2f}",
    )
    return out


def main(quick: bool = True):
    out = run(quick)
    for ds, res in out.items():
        print(f"# {ds}: bottleneck/round " + ", ".join(
            f"{m}={v:.3f}" for m, v in res["bottleneck_per_round"].items()
        ))
        accs = [h["accuracy_user0"] for h in res["history"]]
        print(f"# {ds}: accuracy " + ", ".join(f"{a:.2f}" for a in accs))
    return out


if __name__ == "__main__":
    main(quick=False)
