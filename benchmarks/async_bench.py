"""Sync-vs-async benchmark: event-engine semantics across the preset grid.

Runs every scenario in ``repro.scenarios.presets.ASYNC_COMBINATIONS``
(``async`` and ``overlap`` execution over the sdp/heft/tp_heft family)
through ``run_sweep`` into ``BENCH_scenarios.json``.  Each record carries
the synchronous ``predicted_bottleneck`` (Eq. 2) next to the event
engine's steady-state ``period`` / ``throughput`` and — for async — the
staleness metrics, so one record answers the production question the
barrier model cannot: what does dropping the round barrier buy, and what
does it cost in staleness.

Resume semantics are ``benchmarks.common.sweep_suite``'s (shared with
``scenarios_bench``): completed ``(scenario, seed, quick)`` records are
kept and labeled ``cached=yes``; ``resume=False`` re-measures this
suite's own grid points while leaving records other sweeps wrote intact.

``sync_equivalence_smoke`` is the CI guard (``make smoke``): one small
preset asserting the event engine's sync semantics still equals Eq. 2
to 1e-9, so the engine cannot silently drift from the paper's model.
"""

from __future__ import annotations

from benchmarks.common import Timer, emit, sweep_suite


def sync_equivalence_smoke() -> None:
    """Assert event-engine ``sync`` == Eq. 2 ``round_time`` on a preset."""
    import numpy as np

    from repro.core.scheduler import schedule
    from repro.fl.simulator import round_time
    from repro.scenarios import get_scenario
    from repro.scenarios.engine import build_compute_graph, build_task_graph
    from repro.sim import simulate

    sc = get_scenario("ring_uniform")
    rng = np.random.default_rng(sc.seed)
    tg = build_task_graph(sc, rng)
    cg, _ = build_compute_graph(sc, rng)
    a = schedule(tg, cg, "heft").assignment
    with Timer() as t:
        res = simulate(tg, cg, a, 4)
    err = float(np.max(np.abs(res.round_times - round_time(tg, cg, a))))
    if err > 1e-9:
        raise AssertionError(
            f"event-engine sync drifted from Eq. 2: max round-time err {err:.3e}"
        )
    emit(
        "sim_sync_equivalence",
        t.seconds * 1e6,
        f"preset={sc.name};max_err={err:.1e};events={res.events_processed}",
    )


def main(
    quick: bool = True, out_path: str = "BENCH_scenarios.json",
    resume: bool = True,
) -> dict:
    from repro.scenarios.presets import ASYNC_COMBINATIONS

    def emit_row(rec, cached):
        for m, entry in rec["methods"].items():
            period = entry.get("period", float("nan"))
            sync_t = entry["predicted_bottleneck"]
            emit(
                f"async_{rec['scenario']}_{m}",
                rec["elapsed_seconds"] * 1e6,
                f"exec={entry.get('execution')};sync_bottleneck={sync_t:.3f};"
                f"period={period:.3f};speedup={sync_t / period:.2f};"
                f"staleness={entry.get('staleness_mean', 0.0):.2f};"
                f"cached={'yes' if cached else 'no'}",
            )

    return sweep_suite(
        ASYNC_COMBINATIONS, emit_row, "async_sweep_total",
        quick=quick, out_path=out_path, resume=resume,
    )


if __name__ == "__main__":
    main(quick=False)
