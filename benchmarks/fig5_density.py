"""Fig. 5 reproduction: bottleneck time vs task-graph density (N_T = 21).

The paper varies vertex degree ranges (d_L, d_H); denser graphs favor the
SDP scheme (59-90% vs HEFT, 25-82% vs TP-HEFT) because HEFT only sees
average link quality.

Each degree range is the registered ``fig5_deg{L}_{H}`` scenario preset
run across seeds (quick mode shrinks the instances via ``num_tasks``
override, matching the historical CI sizing).
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import Timer, emit, scenario_rows
from repro.scenarios import get_scenario


def run(quick: bool = True) -> dict:
    degree_ranges = ((2, 4), (6, 8)) if quick else ((2, 4), (4, 6), (6, 8), (8, 10))
    seeds = 2 if quick else 5
    num_samples = 1500 if quick else 4000
    sdp_iters = 2500 if quick else 6000

    rows = {}
    with Timer() as t:
        for (dl, dh) in degree_ranges:
            sc = get_scenario(f"fig5_deg{dl}_{dh}")
            if quick:
                # CI sizing: same degrees on a 12-task instance (an
                # unregistered variant — the paper preset stays intact).
                sc = dataclasses.replace(sc, num_tasks=12)
            rows[f"{dl}-{dh}"] = scenario_rows(
                sc, seeds, num_samples=num_samples, sdp_iters=sdp_iters
            )

    keys = list(rows)
    red_dense = 1 - rows[keys[-1]]["sdp"] / rows[keys[-1]]["heft"]
    red_sparse = 1 - rows[keys[0]]["sdp"] / rows[keys[0]]["heft"]
    emit(
        "fig5_bottleneck_vs_density",
        t.seconds * 1e6 / max(len(degree_ranges) * seeds, 1),
        f"reduction_vs_heft_sparse={red_sparse:.0%};dense={red_dense:.0%}",
    )
    return rows


def main(quick: bool = True):
    rows = run(quick)
    print("# degrees, " + ", ".join(rows[next(iter(rows))].keys()))
    for dr, r in rows.items():
        print(f"# {dr}, " + ", ".join(f"{v:.3f}" for v in r.values()))
    return rows


if __name__ == "__main__":
    main(quick=False)
