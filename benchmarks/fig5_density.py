"""Fig. 5 reproduction: bottleneck time vs task-graph density (N_T = 21).

The paper varies vertex degree ranges (d_L, d_H); denser graphs favor the
SDP scheme (59-90% vs HEFT, 25-82% vs TP-HEFT) because HEFT only sees
average link quality.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, paper_instance, run_methods


def run(quick: bool = True) -> dict:
    degree_ranges = ((2, 4), (6, 8)) if quick else ((2, 4), (4, 6), (6, 8), (8, 10))
    seeds = range(2) if quick else range(5)
    n_tasks = 12 if quick else 21
    num_samples = 1500 if quick else 4000
    sdp_iters = 2500 if quick else 6000

    rows = {}
    with Timer() as t:
        for (dl, dh) in degree_ranges:
            acc: dict[str, list] = {}
            for seed in seeds:
                tg, cg = paper_instance(
                    seed, n_tasks, degree_low=dl, degree_high=dh
                )
                res = run_methods(
                    tg, cg, num_samples=num_samples, sdp_iters=sdp_iters,
                    seed=seed,
                )
                for k, v in res.items():
                    acc.setdefault(k, []).append(v)
            rows[f"{dl}-{dh}"] = {k: float(np.mean(v)) for k, v in acc.items()}

    keys = list(rows)
    red_dense = 1 - rows[keys[-1]]["sdp"] / rows[keys[-1]]["heft"]
    red_sparse = 1 - rows[keys[0]]["sdp"] / rows[keys[0]]["heft"]
    emit(
        "fig5_bottleneck_vs_density",
        t.seconds * 1e6 / max(len(degree_ranges) * len(list(seeds)), 1),
        f"reduction_vs_heft_sparse={red_sparse:.0%};dense={red_dense:.0%}",
    )
    return rows


def main(quick: bool = True):
    rows = run(quick)
    print("# degrees, " + ", ".join(rows[next(iter(rows))].keys()))
    for dr, r in rows.items():
        print(f"# {dr}, " + ", ".join(f"{v:.3f}" for v in r.values()))
    return rows


if __name__ == "__main__":
    main(quick=False)
