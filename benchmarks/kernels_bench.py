"""Kernel micro-benchmarks.

On this CPU-only container wall-clock of interpret-mode Pallas is
meaningless, so per kernel we measure the jnp reference path (CPU µs) and
DERIVE the projected v5e time from the roofline model (bytes / 819 GB/s vs
flops / 197 TFLOP/s) — the same constants as §Roofline.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(quick: bool = True, record_json: bool = False):
    from repro.kernels import ref as kref

    rng = np.random.default_rng(0)
    t = lambda s, d=jnp.bfloat16: jnp.asarray(rng.standard_normal(s), d)

    # flash attention: B=1, S=2048, H=16, D=128 (scaled-down train block)
    b, s, h, hkv, d = 1, 2048 if not quick else 1024, 16, 8, 128
    q, k, v = t((b, h, s, d)), t((b, hkv, s, d)), t((b, hkv, s, d))
    us = _time(jax.jit(lambda q, k, v: kref.flash_attention_ref(q, k, v)), q, k, v)
    flops = 2 * 2 * b * h * s * s * d / 2
    emit("kernel_flash_attention_ref", us,
         f"S={s};proj_v5e_us={flops / PEAK_FLOPS * 1e6:.1f}")

    # decode attention: B=8, S=32768 cache
    s_c = 32768 if not quick else 8192
    q1, kc, vc = t((8, h, d)), t((8, s_c, hkv, d)), t((8, s_c, hkv, d))
    vl = jnp.full((8,), s_c, jnp.int32)
    us = _time(jax.jit(kref.decode_attention_ref), q1, kc, vc, vl)
    bytes_ = 2 * 8 * s_c * hkv * d * 2
    emit("kernel_decode_attention_ref", us,
         f"S={s_c};proj_v5e_us={bytes_ / HBM_BW * 1e6:.1f} (memory-bound)")

    # rmsnorm
    x, w = t((8192, 4096)), t((4096,), jnp.float32)
    us = _time(jax.jit(kref.rmsnorm_ref), x, w)
    bytes_ = 2 * x.size * 2
    emit("kernel_rmsnorm_ref", us,
         f"rows=8192;proj_v5e_us={bytes_ / HBM_BW * 1e6:.1f}")

    # gossip mix: 9 neighbors x 16M params
    n, l = 9, (1 << 24) if not quick else (1 << 21)
    st_, ww = t((n, l), jnp.float32), jnp.ones((n,), jnp.float32) / n
    us = _time(jax.jit(kref.gossip_mix_ref), st_, ww)
    bytes_ = (n + 1) * l * 4
    naive_bytes = 2 * (n - 1) * l * 4 + 2 * l * 4
    emit(
        "kernel_gossip_mix_ref", us,
        f"N={n};proj_v5e_us={bytes_ / HBM_BW * 1e6:.1f};"
        f"naive_axpy_us={naive_bytes / HBM_BW * 1e6:.1f}",
    )

    # all-receivers batched mix (the stacked FL exchange, DESIGN.md §8):
    # N_T users, out-degree-6 random scatter W (sender-normalized 1/deg
    # entries; receiver row sums vary with in-degree — same sparsity and
    # cost shape as the production mixing matrix, not its normalization),
    # vs the (|E|, L) gather + segment_sum reference.  On CPU the Pallas
    # kernel runs in interpret mode (wall-clock meaningless), so it is
    # verified on a small slab and the perf record is the jnp reference
    # timing + the roofline projection.
    from repro.kernels.gossip_mix import gossip_mix_all_fwd

    nt, l2, deg = 64, (1 << 21) if not quick else (1 << 18), 6
    src = jnp.asarray(np.repeat(np.arange(nt), deg), jnp.int32)
    dst = jnp.asarray(rng.integers(0, nt, size=nt * deg), jnp.int32)
    w_e = jnp.full((nt * deg,), 1.0 / deg, jnp.float32)
    W = jnp.zeros((nt, nt), jnp.float32).at[dst, src].add(w_e)
    x_all = t((nt, l2), jnp.float32)

    us_seg = _time(
        jax.jit(lambda s: kref.gossip_mix_segment_ref(s, src, dst, w_e, nt)), x_all
    )
    us_dense = _time(jax.jit(kref.gossip_mix_all_ref), x_all, W)

    on_cpu = jax.default_backend() == "cpu"
    small = x_all[:, : (1 << 16)]
    got = gossip_mix_all_fwd(small, W, block_len=1 << 14, interpret=on_cpu)
    np.testing.assert_allclose(
        got, kref.gossip_mix_all_ref(small, W), atol=2e-4
    )

    kern_bytes = 2 * nt * l2 * 4                    # stream slab once, write once
    seg_bytes = (2 * nt * deg + nt) * l2 * 4        # gather + scatter + write
    emit(
        "kernel_gossip_mix_all", us_dense,
        f"NT={nt};deg={deg};segment_sum_us={us_seg:.1f};"
        f"proj_v5e_us={kern_bytes / HBM_BW * 1e6:.1f};"
        f"segment_proj_v5e_us={seg_bytes / HBM_BW * 1e6:.1f};"
        f"pallas={'interpret_ok' if on_cpu else 'compiled_ok'}",
    )

    # ------------------------------------------------------------------
    # Fused scheduler/FL kernels (DESIGN.md §12).  Same measurement
    # discipline as above: time the jnp reference composition on this
    # host, VERIFY the Pallas kernel in interpret mode on a small slab,
    # and project v5e before/after from the traffic model.  Interpret-mode
    # wall-clock is never reported as a speedup.
    # ------------------------------------------------------------------
    from repro.kernels.bottleneck import bottleneck_eval_fwd
    from repro.kernels.compress import int8_roundtrip_fwd, topk_mask_fwd
    from repro.kernels.sdp_proj import sdp_subspace_fwd

    verified = "interpret_ok" if on_cpu else "compiled_ok"
    rows: dict[str, dict] = {}

    # (a) SDP fused subspace projection: one stream of Y yields the
    # matvec + Rayleigh-Ritz Gram + shift norm (jnp: matvec stream + norm
    # stream; the Gram rides on the small YV).
    n1, kk = (1025, 16) if not quick else (513, 16)
    Ys = t((n1, n1), jnp.float32)
    Ys = Ys + Ys.T
    Vs = t((n1, kk), jnp.float32)
    us_ref = _time(jax.jit(kref.sdp_subspace_ref), Ys, Vs)
    sm = 97                                          # ragged vs block 64
    got = sdp_subspace_fwd(Ys[:sm, :sm], Vs[:sm], block_rows=64,
                           interpret=on_cpu)
    want = kref.sdp_subspace_ref(Ys[:sm, :sm], Vs[:sm])
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-3)
    bytes_before = 2 * n1 * n1 * 4                  # matvec + norm streams
    bytes_after = n1 * n1 * 4                       # one fused stream
    rows["sdp_subspace"] = {
        "n": n1, "k": kk, "cpu_ref_us": us_ref,
        "proj_v5e_us_before": bytes_before / HBM_BW * 1e6,
        "proj_v5e_us_after": bytes_after / HBM_BW * 1e6,
        "traffic_ratio": bytes_before / bytes_after,
        "pallas": verified,
    }
    emit("kernel_sdp_subspace_ref", us_ref,
         f"n={n1};k={kk};"
         f"proj_v5e_us={bytes_before / HBM_BW * 1e6:.1f};"
         f"fused_proj_v5e_us={bytes_after / HBM_BW * 1e6:.1f};"
         f"pallas={verified}")

    # (b) fused delta compression with error feedback: jnp roundtrip +
    # subtract moves ~5 (N, L) slabs (read/write msgs, re-read delta and
    # msgs, write residual); the fused kernel reads once, writes both.
    nc, lc = 64, (1 << 21) if not quick else (1 << 18)
    delta = t((nc, lc), jnp.float32)
    vals, _ = jax.lax.top_k(jnp.abs(delta), max(1, lc // 100))
    thr = vals[:, -1]
    scale = jnp.maximum(jnp.max(jnp.abs(delta), axis=1), 1e-12) / 127.0
    us_topk = _time(jax.jit(kref.topk_mask_ref), delta, thr)
    us_int8 = _time(jax.jit(kref.int8_roundtrip_ref), delta, scale)
    sm_d = delta[:, : (1 << 14)]
    got = topk_mask_fwd(sm_d, thr, block_len=1 << 12, interpret=on_cpu)
    want = kref.topk_mask_ref(sm_d, thr)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    got = int8_roundtrip_fwd(sm_d, scale, block_len=1 << 12,
                             interpret=on_cpu)
    want = kref.int8_roundtrip_ref(sm_d, scale)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    cb_before = 5 * nc * lc * 4
    cb_after = 3 * nc * lc * 4
    rows["compress"] = {
        "n_users": nc, "params": lc,
        "cpu_topk_ref_us": us_topk, "cpu_int8_ref_us": us_int8,
        "proj_v5e_us_before": cb_before / HBM_BW * 1e6,
        "proj_v5e_us_after": cb_after / HBM_BW * 1e6,
        "traffic_ratio": cb_before / cb_after,
        "pallas": verified,
    }
    emit("kernel_compress_ref", us_topk,
         f"N={nc};L={lc};int8_us={us_int8:.1f};"
         f"proj_v5e_us={cb_before / HBM_BW * 1e6:.1f};"
         f"fused_proj_v5e_us={cb_after / HBM_BW * 1e6:.1f};"
         f"pallas={verified}")

    # (c) batched bottleneck evaluation (Eq. 2) over rounding samples:
    # the kernel keeps each (bs, T, K) assignment slab on-chip for all
    # four reductions, so the projection is compute-dominated; the jnp
    # reference re-reads the slab per einsum (4 passes).
    ss_, tt, kk2 = (512, 128, 8) if not quick else (256, 64, 4)
    ne = 3 * tt
    oh = jax.nn.one_hot(
        jnp.asarray(rng.integers(0, kk2, size=(ss_, tt))), kk2,
        dtype=jnp.float32,
    )
    pp = jnp.abs(t((tt,), jnp.float32))
    ee = jnp.abs(t((kk2,), jnp.float32)) + 0.1
    cc = jnp.abs(t((kk2, kk2), jnp.float32))
    s_oh = jax.nn.one_hot(
        jnp.asarray(rng.integers(0, tt, size=ne)), tt, dtype=jnp.float32
    )
    d_oh = jax.nn.one_hot(
        jnp.asarray(rng.integers(0, tt, size=ne)), tt, dtype=jnp.float32
    )
    us_bot = _time(jax.jit(kref.bottleneck_eval_ref), oh, pp, ee, cc,
                   s_oh, d_oh)
    got = bottleneck_eval_fwd(oh[:16], pp, ee, cc, s_oh, d_oh,
                              block_samples=5, interpret=on_cpu)
    want = kref.bottleneck_eval_ref(oh[:16], pp, ee, cc, s_oh, d_oh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    flops = ss_ * (4 * tt * kk2 + 4 * ne * tt * kk2 + 2 * ne * kk2 * kk2)
    slab = ss_ * tt * kk2 * 4
    rows["bottleneck_eval"] = {
        "samples": ss_, "tasks": tt, "machines": kk2, "edges": ne,
        "cpu_ref_us": us_bot,
        "proj_v5e_us_before": 4 * slab / HBM_BW * 1e6,
        "proj_v5e_us_after": max(slab / HBM_BW, flops / PEAK_FLOPS) * 1e6,
        "traffic_ratio": 4.0,
        "pallas": verified,
    }
    emit("kernel_bottleneck_eval_ref", us_bot,
         f"S={ss_};T={tt};K={kk2};"
         f"proj_v5e_us={4 * slab / HBM_BW * 1e6:.1f};"
         f"fused_proj_v5e_us="
         f"{max(slab / HBM_BW, flops / PEAK_FLOPS) * 1e6:.1f};"
         f"pallas={verified}")

    if record_json:
        import json
        import pathlib
        import time as _t

        path = pathlib.Path(__file__).resolve().parent.parent / (
            "BENCH_scheduler_scaling.json"
        )
        # read-modify-write: other suites own the other keys
        record = json.loads(path.read_text()) if path.exists() else {}
        record["kernels"] = rows
        record["kernels_generated_unix"] = _t.time()
        path.write_text(json.dumps(record, indent=2) + "\n")
    return rows


def kernel_diff_smoke():
    """CI gate: every fused scheduler/FL kernel matches its jnp oracle.

    Interpret-mode differential check on block-ragged small slabs (the
    full sweep lives in ``tests/test_kernel_diff.py``), plus one tiny
    seeded ``solve_sdp`` with the fused projection on vs off asserting
    the iteration trajectory is identical — the property that lets
    ``kernel_backend="auto"`` switch per host without changing results.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import (
        SDPOptions,
        build_factored_bqp,
        random_compute_graph,
        random_task_graph,
        solve_sdp,
    )
    from repro.kernels import ref as kref
    from repro.kernels.bottleneck import bottleneck_eval_fwd
    from repro.kernels.compress import int8_roundtrip_fwd, topk_mask_fwd
    from repro.kernels.sdp_proj import rank_k_update_fwd, sdp_subspace_fwd

    t0 = time.perf_counter()
    rng = np.random.default_rng(0)
    interp = jax.default_backend() != "tpu"

    # (a) fused subspace projection + rank-k clip, ragged blocking
    n, k = 33, 4
    Y = rng.standard_normal((n, n)).astype(np.float32)
    Y = jnp.asarray(Y + Y.T)
    V = jnp.asarray(np.linalg.qr(rng.standard_normal((n, k)))[0],
                    jnp.float32)
    got = sdp_subspace_fwd(Y, V, block_rows=8, interpret=interp)
    want = kref.sdp_subspace_ref(Y, V)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(rank_k_update_fwd(Y, V, V, block_rows=8,
                                     interpret=interp)),
        np.asarray(kref.rank_k_update_ref(Y, V, V)),
        rtol=1e-5, atol=1e-5,
    )

    # (b) fused compression with error feedback, ragged tail
    X = jnp.asarray(rng.standard_normal((8, 100)), jnp.float32)
    vals, _ = jax.lax.top_k(jnp.abs(X), 10)
    m, r = topk_mask_fwd(X, vals[:, -1], block_len=64, interpret=interp)
    rm, rr = kref.topk_mask_ref(X, vals[:, -1])
    np.testing.assert_array_equal(np.asarray(m), np.asarray(rm))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))
    scale = jnp.maximum(jnp.max(jnp.abs(X), axis=1), 1e-12) / 127.0
    m, r = int8_roundtrip_fwd(X, scale, block_len=64, interpret=interp)
    rm, rr = kref.int8_roundtrip_ref(X, scale)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(rm))
    np.testing.assert_allclose(np.asarray(r), np.asarray(rr), atol=2e-7)

    # (c) one-hot bottleneck evaluation, ragged sample padding + E=0
    for n_e in (14, 0):
        a = rng.integers(0, 4, size=(8, 7))
        oh = jax.nn.one_hot(jnp.asarray(a), 4, dtype=jnp.float32)
        pp = jnp.asarray(rng.uniform(0.1, 5.0, 7), jnp.float32)
        ee = jnp.asarray(rng.uniform(0.5, 4.0, 4), jnp.float32)
        cc = jnp.asarray(rng.uniform(0.0, 3.0, (4, 4)), jnp.float32)
        s_oh = jax.nn.one_hot(jnp.asarray(rng.integers(0, 7, n_e)), 7,
                              dtype=jnp.float32)
        d_oh = jax.nn.one_hot(jnp.asarray(rng.integers(0, 7, n_e)), 7,
                              dtype=jnp.float32)
        args = (oh, pp, ee, cc, s_oh, d_oh)
        np.testing.assert_allclose(
            np.asarray(bottleneck_eval_fwd(*args, block_samples=3,
                                           interpret=interp)),
            np.asarray(kref.bottleneck_eval_ref(*args)),
            rtol=1e-5, atol=1e-6,
        )

    # (d) tiny seeded e2e: fused projection on == off
    r5 = np.random.default_rng(5)
    tg = random_task_graph(r5, 6, degree_low=1, degree_high=3)
    cg = random_compute_graph(r5, 3)
    bqp = build_factored_bqp(tg, cg)
    sols = {
        kb: solve_sdp(bqp, SDPOptions(max_iters=2000, check_every=50,
                                      tol=1e-4, backend="jax",
                                      kernel_backend=kb))
        for kb in ("jnp", "pallas")
    }
    assert sols["jnp"].iterations == sols["pallas"].iterations
    assert (sols["jnp"].stats["eig_partial"]
            == sols["pallas"].stats["eig_partial"])
    np.testing.assert_allclose(sols["pallas"].Y, sols["jnp"].Y, atol=1e-3)

    emit(
        "kernel_diff_smoke",
        (time.perf_counter() - t0) * 1e6,
        f"kernels=5;e2e_iters={sols['pallas'].iterations};"
        f"mode={'interpret' if interp else 'compiled'};ok=1",
    )


if __name__ == "__main__":
    main(quick=False, record_json=True)
