"""Kernel micro-benchmarks.

On this CPU-only container wall-clock of interpret-mode Pallas is
meaningless, so per kernel we measure the jnp reference path (CPU µs) and
DERIVE the projected v5e time from the roofline model (bytes / 819 GB/s vs
flops / 197 TFLOP/s) — the same constants as §Roofline.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(quick: bool = True):
    from repro.kernels import ref as kref

    rng = np.random.default_rng(0)
    t = lambda s, d=jnp.bfloat16: jnp.asarray(rng.standard_normal(s), d)

    # flash attention: B=1, S=2048, H=16, D=128 (scaled-down train block)
    b, s, h, hkv, d = 1, 2048 if not quick else 1024, 16, 8, 128
    q, k, v = t((b, h, s, d)), t((b, hkv, s, d)), t((b, hkv, s, d))
    us = _time(jax.jit(lambda q, k, v: kref.flash_attention_ref(q, k, v)), q, k, v)
    flops = 2 * 2 * b * h * s * s * d / 2
    emit("kernel_flash_attention_ref", us,
         f"S={s};proj_v5e_us={flops / PEAK_FLOPS * 1e6:.1f}")

    # decode attention: B=8, S=32768 cache
    s_c = 32768 if not quick else 8192
    q1, kc, vc = t((8, h, d)), t((8, s_c, hkv, d)), t((8, s_c, hkv, d))
    vl = jnp.full((8,), s_c, jnp.int32)
    us = _time(jax.jit(kref.decode_attention_ref), q1, kc, vc, vl)
    bytes_ = 2 * 8 * s_c * hkv * d * 2
    emit("kernel_decode_attention_ref", us,
         f"S={s_c};proj_v5e_us={bytes_ / HBM_BW * 1e6:.1f} (memory-bound)")

    # rmsnorm
    x, w = t((8192, 4096)), t((4096,), jnp.float32)
    us = _time(jax.jit(kref.rmsnorm_ref), x, w)
    bytes_ = 2 * x.size * 2
    emit("kernel_rmsnorm_ref", us,
         f"rows=8192;proj_v5e_us={bytes_ / HBM_BW * 1e6:.1f}")

    # gossip mix: 9 neighbors x 16M params
    n, l = 9, (1 << 24) if not quick else (1 << 21)
    st_, ww = t((n, l), jnp.float32), jnp.ones((n,), jnp.float32) / n
    us = _time(jax.jit(kref.gossip_mix_ref), st_, ww)
    bytes_ = (n + 1) * l * 4
    naive_bytes = 2 * (n - 1) * l * 4 + 2 * l * 4
    emit(
        "kernel_gossip_mix_ref", us,
        f"N={n};proj_v5e_us={bytes_ / HBM_BW * 1e6:.1f};"
        f"naive_axpy_us={naive_bytes / HBM_BW * 1e6:.1f}",
    )

    # all-receivers batched mix (the stacked FL exchange, DESIGN.md §8):
    # N_T users, out-degree-6 random scatter W (sender-normalized 1/deg
    # entries; receiver row sums vary with in-degree — same sparsity and
    # cost shape as the production mixing matrix, not its normalization),
    # vs the (|E|, L) gather + segment_sum reference.  On CPU the Pallas
    # kernel runs in interpret mode (wall-clock meaningless), so it is
    # verified on a small slab and the perf record is the jnp reference
    # timing + the roofline projection.
    from repro.kernels.gossip_mix import gossip_mix_all_fwd

    nt, l2, deg = 64, (1 << 21) if not quick else (1 << 18), 6
    src = jnp.asarray(np.repeat(np.arange(nt), deg), jnp.int32)
    dst = jnp.asarray(rng.integers(0, nt, size=nt * deg), jnp.int32)
    w_e = jnp.full((nt * deg,), 1.0 / deg, jnp.float32)
    W = jnp.zeros((nt, nt), jnp.float32).at[dst, src].add(w_e)
    x_all = t((nt, l2), jnp.float32)

    us_seg = _time(
        jax.jit(lambda s: kref.gossip_mix_segment_ref(s, src, dst, w_e, nt)), x_all
    )
    us_dense = _time(jax.jit(kref.gossip_mix_all_ref), x_all, W)

    on_cpu = jax.default_backend() == "cpu"
    small = x_all[:, : (1 << 16)]
    got = gossip_mix_all_fwd(small, W, block_len=1 << 14, interpret=on_cpu)
    np.testing.assert_allclose(
        got, kref.gossip_mix_all_ref(small, W), atol=2e-4
    )

    kern_bytes = 2 * nt * l2 * 4                    # stream slab once, write once
    seg_bytes = (2 * nt * deg + nt) * l2 * 4        # gather + scatter + write
    emit(
        "kernel_gossip_mix_all", us_dense,
        f"NT={nt};deg={deg};segment_sum_us={us_seg:.1f};"
        f"proj_v5e_us={kern_bytes / HBM_BW * 1e6:.1f};"
        f"segment_proj_v5e_us={seg_bytes / HBM_BW * 1e6:.1f};"
        f"pallas={'interpret_ok' if on_cpu else 'compiled_ok'}",
    )


if __name__ == "__main__":
    main(quick=False)
