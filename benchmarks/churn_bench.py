"""Churn benchmark suite: trace-driven fleet dynamics, regret vs oracle.

Runs every scenario in ``repro.scenarios.presets.CHURN_COMBINATIONS``
(seeded churn trace → fail/join/recover/link events → one simulated run
per churn policy) and records the sweep into ``BENCH_scenarios.json``
(schema: ``docs/benchmarks.md``, churn records).  Each record carries an
``oracle_total_time`` — a per-event COLD full SDP re-solve, always
adopted — and each policy's ``regret_vs_oracle`` against it; the
``er_churn_degraded`` preset injects a zero solve budget so the elastic
policy's heft fallback is exercised on the record itself.

Resume semantics are ``benchmarks.common.sweep_suite``'s (shared with
``scenarios_bench`` / ``async_bench``): existing records are kept and
labeled ``cached=yes``; ``resume=False`` (``make bench-churn``)
re-measures this suite's grid points only.

``churn_smoke()`` (``make churn_smoke``) is the CI guard: a short
injected-timeout trace asserting that arrivals re-solve, the fallback
activates, and regret stays finite.
"""

from __future__ import annotations

import math

from benchmarks.common import emit, sweep_suite


def main(
    quick: bool = True, out_path: str = "BENCH_scenarios.json",
    resume: bool = True,
) -> dict:
    from repro.scenarios.presets import CHURN_COMBINATIONS

    def emit_row(rec, cached):
        methods = rec["methods"]
        churn = rec.get("churn", {})
        regrets = ";".join(
            f"{pol}={methods[pol]['regret_vs_oracle']:.4f}"
            for pol in sorted(methods)
        )
        elastic = methods.get("sdp_elastic", {})
        emit(
            f"churn_{rec['scenario']}",
            rec["elapsed_seconds"] * 1e6,
            f"model={churn.get('model')};events={churn.get('num_events', 0)};"
            f"{regrets};fallbacks={elastic.get('fallback_count', 0)};"
            f"cached={'yes' if cached else 'no'}",
        )

    return sweep_suite(
        CHURN_COMBINATIONS, emit_row, "churn_sweep_total",
        quick=quick, out_path=out_path, resume=resume,
    )


def churn_smoke() -> dict:
    """CI smoke: a short churn trace under an injected zero solve budget.

    Asserts the three properties the churn subsystem exists for: fleet
    arrivals trigger elastic re-solves, a stalled SDP degrades to the
    heft fallback instead of wedging the trace, and every policy's regret
    against the oracle is finite.  Returns the scenario record.
    """
    from repro.scenarios import Scenario, run_scenario
    from repro.scenarios.engine import _churn_trace_for

    sc = Scenario(
        name="churn_smoke",
        topology="small_world",
        num_tasks=8,
        num_machines=4,
        machine_profile="lognormal",
        delay_model="uniform",
        schedulers=("sdp",),
        rounds=12,
        topology_params={"k": 4, "rewire_prob": 0.2},
        churn="markov",
        churn_params={
            "p_fail": 0.15, "p_recover": 0.5,
            "start_down_fraction": 0.25, "min_up": 2,
            "link_outages": 1, "outage_len": 3, "outage_factor": 3.0,
            "solve_timeout": 0.0,
        },
    )
    trace = _churn_trace_for(sc)
    counts = trace.counts
    assert counts["join"] + counts["recover"] >= 1, counts
    assert counts["fail"] >= 2, counts

    rec = run_scenario(sc, quick=True)
    elastic = rec["methods"]["sdp_elastic"]
    assert elastic["num_elastic_resolves"] >= 1, (
        "no arrival/failure re-solve reached the ElasticScheduler"
    )
    assert elastic["fallback_count"] >= 1, (
        "the injected zero solve budget never activated the fallback"
    )
    for pol, entry in rec["methods"].items():
        assert math.isfinite(entry["regret_vs_oracle"]), (
            f"{pol}: non-finite regret {entry['regret_vs_oracle']}"
        )
        assert math.isfinite(entry["total_time"]), pol
    emit(
        "churn_smoke",
        rec["elapsed_seconds"] * 1e6,
        f"events={rec['churn']['num_events']};"
        f"fallbacks={elastic['fallback_count']};"
        f"elastic_regret={elastic['regret_vs_oracle']:.4f}",
    )
    return rec


if __name__ == "__main__":
    main(quick=False)
