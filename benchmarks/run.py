"""Benchmark entrypoint: one function per paper table/figure.

``python -m benchmarks.run``          — quick mode (CI-sized)
``python -m benchmarks.run --full``   — paper-sized settings

Prints ``name,us_per_call,derived`` CSV lines (plus commented detail rows).
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig4,fig5,fig6,roofline,"
                         "kernels,scheduler,scenarios,async,churn,async_fl")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (
        async_bench,
        async_fl_bench,
        churn_bench,
        fig4_tasks,
        fig5_density,
        fig6_gossip_fl,
        kernels_bench,
        roofline,
        scenarios_bench,
        scheduler_bench,
    )

    suites = {
        "fig4": fig4_tasks.main,
        "fig5": fig5_density.main,
        "fig6": fig6_gossip_fl.main,
        "roofline": roofline.main,
        "kernels": kernels_bench.main,
        "scheduler": scheduler_bench.main,
        "scenarios": scenarios_bench.main,
        "async": async_bench.main,
        "churn": churn_bench.main,
        "async_fl": async_fl_bench.main,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            fn(quick=quick)
        except Exception as e:  # noqa: BLE001 — report and continue
            failed.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
