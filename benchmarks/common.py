"""Shared benchmark helpers: instance generation per paper settings, CSV."""

from __future__ import annotations

import time

import numpy as np

from repro.core import random_compute_graph, random_task_graph


def paper_instance(seed: int, num_tasks: int, num_machines: int = 4,
                   degree_low: int = 2, degree_high: int = 4):
    """§4.1.2: C ~ |N(0,√10)|, e ~ |N(0,√15)|, p ~ |N(0,1)| (folded)."""
    rng = np.random.default_rng(seed)
    tg = random_task_graph(
        rng, num_tasks, degree_low=degree_low, degree_high=degree_high
    )
    cg = random_compute_graph(rng, num_machines)
    return tg, cg


def scenario_rows(preset, seeds: int, *, num_samples=3000, sdp_iters=4000):
    """Seed-averaged method bottlenecks of a scenario preset.

    The fig4/fig5 adapter onto the scenario engine: runs ``preset`` (a
    registered name or a ``Scenario`` object) under seeds 0..seeds-1 with
    paper-sized budgets and returns a ``{method: mean bottleneck,
    upper_bound, sdp_seconds}`` row.
    """
    import dataclasses

    from repro.scenarios import Scenario, get_scenario, run_scenario

    sc = preset if isinstance(preset, Scenario) else get_scenario(preset)
    base = dataclasses.replace(
        sc,
        schedule_params={"num_samples": num_samples, "max_iters": sdp_iters},
    )
    acc: dict[str, list] = {}
    for seed in range(seeds):
        rec = run_scenario(base.with_seed(seed))
        for m, entry in rec["methods"].items():
            acc.setdefault(m, []).append(entry["predicted_bottleneck"])
        sdp = rec["methods"]["sdp"]
        acc.setdefault("upper_bound", []).append(sdp["upper_bound"])
        acc.setdefault("sdp_seconds", []).append(sdp["sdp_seconds"])
    return {k: float(np.mean(v)) for k, v in acc.items()}


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def sweep_suite(combinations, emit_row, total_label: str, *,
                quick: bool, out_path: str, resume: bool) -> dict:
    """Shared scaffolding of the scenario-sweep benchmark suites.

    Runs ``combinations`` through ``repro.scenarios.run_sweep`` into
    ``out_path`` with the suites' common resume contract: records that
    already exist are kept (and reported ``cached=True`` to
    ``emit_row(record, cached)``); ``resume=False`` re-measures THIS
    suite's own grid points while leaving records other sweeps wrote
    intact.  Both ``scenarios_bench`` and ``async_bench`` are thin
    emit-row wrappers over this, so the resume semantics cannot diverge.
    """
    import json
    import pathlib

    from repro.scenarios import run_sweep
    from repro.scenarios.engine import _write_atomic, record_key, scenario_key

    mine = {scenario_key(sc, quick) for sc in combinations}
    pre: set = set()
    path = pathlib.Path(out_path)
    if path.exists():
        existing = json.loads(path.read_text()).get("records", [])
        if resume:
            pre = {record_key(r) for r in existing}
        else:
            keep = [r for r in existing if record_key(r) not in mine]
            _write_atomic(path, {"bench": "scenario_sweep", "records": keep})
    with Timer() as t:
        payload = run_sweep(
            combinations, out_path=out_path, quick=quick, resume=True
        )
    records = [r for r in payload["records"] if record_key(r) in mine]
    fresh = 0
    for rec in records:
        cached = record_key(rec) in pre
        fresh += not cached
        emit_row(rec, cached)
    emit(
        total_label,
        t.seconds * 1e6 / max(fresh, 1),
        f"scenarios={len(records)};fresh={fresh};out={out_path}",
    )
    return payload


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
